"""Roofline reporting pipeline against the committed dry-run artifacts."""
import os

import pytest

from repro.launch.roofline import (
    CHIPS, PEAK_FLOPS, bottleneck_hint, fmt, load_rows, render_comparison,
    render_markdown,
)

DRY = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

pytestmark = pytest.mark.skipif(
    not os.path.isdir(DRY), reason="dry-run artifacts not generated yet")


def test_loads_all_cells():
    rows = load_rows(DRY)
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    assert len(ok) == 32 and len(skipped) == 8  # 40 single-pod cells


def test_terms_positive_and_dominant_consistent():
    for r in load_rows(DRY):
        if r["status"] != "ok":
            continue
        terms = {"compute": r["t_compute"], "memory": r["t_memory"],
                 "collective": r["t_collective"]}
        assert all(v >= 0 for v in terms.values()), r
        assert max(terms, key=terms.get) == r["dominant"], r
        assert 0 <= r["roofline_fraction"] <= 1.0, r
        assert bottleneck_hint(r)  # every cell gets a recommendation


def test_model_flops_sane():
    """MODEL_FLOPS for train cells ~ 6·N_active·D within useful range."""
    for r in load_rows(DRY):
        if r["status"] != "ok" or r["kind"] != "train":
            continue
        # useful-compute ratio in (0, 1.3] (whisper analytic slightly over)
        assert 0.01 < r["useful_ratio"] <= 1.3, (r["arch"], r["useful_ratio"])


def test_comparison_no_unexplained_regression():
    """Optimized profile must not regress any cell's max term beyond noise —
    except cells whose baseline didn't fit HBM (temp > 16 GB/device), where
    microbatching trades ≤10% term time for fitting at all."""
    base = {(r["arch"], r["shape"]): r for r in load_rows(DRY)
            if r["status"] == "ok"}
    opt = {(r["arch"], r["shape"]): r
           for r in load_rows(DRY, profile="optimized")
           if r["status"] == "ok"}
    for key, b in base.items():
        o = opt.get(key)
        if o is None:
            continue
        mt_b = max(b["t_compute"], b["t_memory"], b["t_collective"])
        mt_o = max(o["t_compute"], o["t_memory"], o["t_collective"])
        budget = 1.10 if b["mem_gb"] > 16.0 else 1.05
        assert mt_o <= mt_b * budget, (key, mt_b, mt_o, b["mem_gb"])


def test_markdown_renders():
    rows = load_rows(DRY)
    md = render_markdown(rows)
    assert md.count("\n") > 30 and "skipped" in md
    cmp_md = render_comparison(rows, load_rows(DRY, profile="optimized"))
    assert "→" in cmp_md


def test_fmt():
    assert fmt(0) == "0"
    assert fmt(5e-5) == "50µs"
    assert fmt(0.02) == "20.0ms"
    assert fmt(3.0) == "3.00s"
