"""MappingService: concurrency-safe artifact serving — request coalescing,
cross-process file locking, stale-lock recovery, cache-off degradation, and
streamed grid sweeps (the 'many clients share one artifact store' scenario)."""
import json
import os
import threading
import time

import pytest

from repro.core.artifact import ArtifactCache, FileLock
from repro.core.backends import MockLLMBackend
from repro.core.pipeline import derive_mapping
from repro.serving import MappingService

MODEL = "OSS:120b"


class CountingBackend:
    """Thread-safe MockLLMBackend wrapper counting `generate` calls, with a
    small sleep so concurrent requests genuinely overlap."""

    def __init__(self, model: str, delay: float = 0.05):
        self._inner = MockLLMBackend(model)
        self.name = self._inner.name
        self.calls = 0
        self.delay = delay
        self._mu = threading.Lock()

    @property
    def cache_fingerprint(self):
        return self._inner.cache_fingerprint

    def generate(self, prompt, *, meta):
        with self._mu:
            self.calls += 1
        time.sleep(self.delay)
        return self._inner.generate(prompt, meta=meta)


def shared_factory():
    """One backend per model, shared across every service built from this
    factory — lets a test count derivations across 'processes'."""
    bank: dict[str, CountingBackend] = {}
    mu = threading.Lock()

    def factory(model: str) -> CountingBackend:
        with mu:
            if model not in bank:
                bank[model] = CountingBackend(model)
            return bank[model]

    factory.bank = bank
    return factory


def service(tmp_path, factory, **kw) -> MappingService:
    kw.setdefault("n_validate", 2000)
    kw.setdefault("sample_every", 1)
    return MappingService(cache=ArtifactCache(tmp_path), backend_factory=factory,
                          **kw)


# ---------------------------------------------------------------------------
# In-process coalescing (threads on one service)
# ---------------------------------------------------------------------------


def test_concurrent_threads_one_derivation(tmp_path):
    """N threads asking for the same cell: one generate call, one cached
    record, and every caller receives an identical DerivationResult."""
    factory = shared_factory()
    svc = service(tmp_path, factory)
    results = []
    mu = threading.Lock()

    def client():
        r = svc.derive("tri2d", MODEL, 20)
        with mu:
            results.append(r)

    threads = [threading.Thread(target=client) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert factory.bank[MODEL].calls == 1
    assert svc.stats.derivations == 1
    assert svc.stats.coalesced == 7
    assert len(results) == 8
    first = results[0]
    for r in results:
        assert r.cache_key == first.cache_key
        assert r.report == first.report
        assert r.complexity_class == first.complexity_class
    # exactly one well-formed record on disk
    records = list(tmp_path.glob("*.json"))
    assert len(records) == 1
    rec = json.loads(records[0].read_text())
    assert rec["domain"] == "tri2d" and rec["compiled"]
    # no leftover lock or temp files
    assert not list(tmp_path.glob("*.lock")) and not list(tmp_path.glob("*.tmp"))


def test_concurrent_distinct_cells_all_derive(tmp_path):
    factory = shared_factory()
    svc = service(tmp_path, factory)
    cells = [("tri2d", 20), ("tri2d", 50), ("gasket2d", 50)]
    threads = [threading.Thread(target=svc.derive, args=(d, MODEL, s))
               for d, s in cells]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert factory.bank[MODEL].calls == 3
    assert len(list(tmp_path.glob("*.json"))) == 3


# ---------------------------------------------------------------------------
# Cross-process safety (two services sharing one store, file-locked)
# ---------------------------------------------------------------------------


def test_two_services_share_one_derivation(tmp_path):
    """Two service instances (distinct in-flight tables — the two-process
    scenario) racing on one cell: the file lock serializes them, the loser
    is served from the store, and both results are identical."""
    factory = shared_factory()
    s1 = service(tmp_path, factory)
    s2 = service(tmp_path, factory)
    out = {}

    def client(tag, svc):
        out[tag] = svc.derive("carpet2d", MODEL, 100)

    t1 = threading.Thread(target=client, args=("a", s1))
    t2 = threading.Thread(target=client, args=("b", s2))
    t1.start()
    t2.start()
    t1.join()
    t2.join()
    assert factory.bank[MODEL].calls == 1
    assert s1.stats.derivations + s2.stats.derivations == 1
    assert out["a"].cache_key == out["b"].cache_key
    assert out["a"].report == out["b"].report
    assert out["a"].source == out["b"].source
    assert len(list(tmp_path.glob("*.json"))) == 1


def test_hammered_store_never_corrupts(tmp_path):
    """Threaded writers + readers on one key: the atomic-rename publish means
    a reader only ever sees a complete record or a miss — never a torn one."""
    cache = ArtifactCache(tmp_path)
    record = {"domain": "tri2d", "payload": "x" * 4096}
    stop = threading.Event()
    seen_bad = []

    def writer():
        while not stop.is_set():
            cache.store("k", record)

    def reader():
        while not stop.is_set():
            rec = cache.load("k")
            if rec is not None and rec.get("payload") != record["payload"]:
                seen_bad.append(rec)

    threads = [threading.Thread(target=writer) for _ in range(3)] + \
              [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    time.sleep(0.5)
    stop.set()
    for t in threads:
        t.join()
    assert not seen_bad
    assert json.loads(cache.path("k").read_text())["payload"] == record["payload"]


def test_clear_skips_live_locks_and_inflight_tmp_files(tmp_path):
    """clear() drops published records only: a live writer's .lock sentinel
    and an in-flight .tmp file must survive untouched (deleting the sentinel
    would let a second writer race the holder)."""
    cache = ArtifactCache(tmp_path)
    cache.store("k1", {"domain": "tri2d", "payload": "a"})
    cache.store("k2", {"domain": "gasket2d", "payload": "b"})
    lock = cache.lock("k1").acquire()
    inflight = tmp_path / "inflight01234.tmp"
    inflight.write_text('{"partial":')  # a writer mid-publish
    try:
        assert cache.clear() == 2
        assert not list(tmp_path.glob("*.json"))
        assert lock.path.exists()
        assert lock.path.read_text() == lock.token  # still the holder's
        assert inflight.exists()
    finally:
        lock.release()
    assert not lock.path.exists()


# ---------------------------------------------------------------------------
# Lock lifecycle
# ---------------------------------------------------------------------------


def test_stale_lock_is_broken(tmp_path):
    """A lock left by a crashed holder must not wedge the service."""
    factory = shared_factory()
    svc = service(tmp_path, factory, stale_lock_seconds=5.0)
    req = svc.request("gasket2d", MODEL, 20)
    lock_path = tmp_path / f"{req.key}.lock"
    lock_path.write_text("424242 0.0\n")
    old = time.time() - 600
    os.utime(lock_path, (old, old))
    res = svc.derive("gasket2d", MODEL, 20)
    assert res.compiled
    assert svc.stats.stale_locks_broken == 1
    assert not lock_path.exists()


def test_fresh_lock_makes_waiter_use_published_record(tmp_path):
    """A *live* lock blocks the second writer until the leader publishes;
    the waiter then reads the record instead of re-deriving."""
    factory = shared_factory()
    svc = service(tmp_path, factory, lock_timeout=10.0)
    req = svc.request("tri2d", MODEL, 50)
    with svc.cache.lock(req.key):
        t = threading.Thread(target=svc.derive, args=("tri2d", MODEL, 50))
        t.start()
        time.sleep(0.15)  # waiter is now polling the held lock
        assert factory.bank[MODEL].calls == 0
        # the "other process" publishes while still holding the lock
        derive_mapping(req.domain, factory(MODEL), 50, n_validate=2000,
                       cache=svc.cache)
        calls_after_publish = factory.bank[MODEL].calls
    # lock released: the waiter acquires it, re-checks the store, and hits
    t.join(timeout=10.0)
    assert not t.is_alive()
    assert factory.bank[MODEL].calls == calls_after_publish  # no re-derivation
    assert svc.stats.cache_hits == 1


def test_lock_timeout_raises(tmp_path):
    lock = FileLock(tmp_path / "k.lock", timeout=0.2, stale_seconds=60.0)
    (tmp_path / "k.lock").write_text("1 0\n")
    with pytest.raises(TimeoutError):
        lock.acquire()


def test_heartbeat_keeps_long_held_lock_alive(tmp_path):
    """A live holder running past stale_seconds must not be broken — the
    heartbeat refreshes the sentinel's mtime while held."""
    holder = FileLock(tmp_path / "k.lock", stale_seconds=0.3)
    holder.acquire()
    try:
        time.sleep(0.8)  # well past stale_seconds without a heartbeat
        contender = FileLock(tmp_path / "k.lock", timeout=0.2,
                             stale_seconds=0.3)
        with pytest.raises(TimeoutError):
            contender.acquire()
        assert not contender.broke_stale
        assert (tmp_path / "k.lock").read_text() == holder.token
    finally:
        holder.release()
    assert not (tmp_path / "k.lock").exists()


def test_release_never_deletes_foreign_lock(tmp_path):
    """A holder whose lock was broken (stale) must not delete the sentinel
    of whoever holds the lock now — release verifies the ownership token."""
    a = FileLock(tmp_path / "k.lock", stale_seconds=60.0)
    a.acquire()
    # simulate: a was deemed stale, broken, and b acquired
    (tmp_path / "k.lock").write_text("somebody-else")
    a.release()
    assert (tmp_path / "k.lock").read_text() == "somebody-else"


# ---------------------------------------------------------------------------
# Cache-off degradation + streamed sweeps
# ---------------------------------------------------------------------------


def test_cache_off_env_serves_without_store(monkeypatch, tmp_path):
    """REPRO_ARTIFACT_CACHE=off: the service degrades to coalescing-only —
    concurrent same-cell requests still trigger one derivation, but nothing
    is persisted and a second service re-derives."""
    monkeypatch.setenv("REPRO_ARTIFACT_CACHE", "off")
    factory = shared_factory()
    svc = MappingService(backend_factory=factory, n_validate=2000,
                         sample_every=1)
    assert svc.cache is None
    threads = [threading.Thread(target=svc.derive, args=("tri2d", MODEL, 20))
               for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert factory.bank[MODEL].calls == 1
    assert svc.stats.coalesced == 3
    assert not list(tmp_path.glob("*.json"))
    svc2 = MappingService(backend_factory=factory, n_validate=2000,
                          sample_every=1)
    svc2.derive("tri2d", MODEL, 20)
    assert factory.bank[MODEL].calls == 2  # nothing was shared


def test_run_grid_streams_and_reuses_cache(tmp_path):
    factory = shared_factory()
    svc = service(tmp_path, factory)
    seen = []
    for res in svc.run_grid(domains=["tri2d", "msimplex3"], models=[MODEL],
                            stages=(20, 50)):
        seen.append((res.domain, res.stage, res.cache_hit))
    assert len(seen) == 4
    assert factory.bank[MODEL].calls == 4
    assert not any(hit for _, _, hit in seen)
    # a second client over the same store: streamed entirely from cache
    svc2 = service(tmp_path, factory)
    grid = svc2.grid(domains=["tri2d", "msimplex3"], models=[MODEL],
                     stages=(20, 50))
    assert len(grid) == 4
    assert all(r.cache_hit for r in grid.values())
    assert factory.bank[MODEL].calls == 4
    assert svc2.stats.derivations == 0


def test_service_artifact_roundtrip(tmp_path):
    factory = shared_factory()
    svc = service(tmp_path, factory)
    art = svc.artifact("msimplex4", MODEL, 20)
    assert art is not None and art.deployable
    assert art.domain == "msimplex4"
    # the derived scalar agrees with the registry's ground truth
    from repro.core.registry import REGISTRY
    gt = REGISTRY.tier("msimplex4", None, "scalar")
    for lam in (0, 9, 1234, 10**6):
        assert tuple(art.scalar_fn()(lam)) == tuple(gt(lam))
