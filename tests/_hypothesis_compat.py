"""Deterministic fallback for the `hypothesis` property-testing API.

The property tests prefer real hypothesis (declared in pyproject's test
extra).  When it isn't installed, this shim keeps them running instead of
skipping: ``st.integers`` strategies yield a fixed, deterministic sample set
(boundaries + geometric spread + seeded randoms) and ``@given`` iterates the
test body over them.  Only the tiny API surface the test-suite uses is
implemented.
"""
from __future__ import annotations

import functools
import inspect
import random


class _Integers:
    def __init__(self, min_value: int, max_value: int):
        self.lo, self.hi = int(min_value), int(max_value)

    def examples(self, n: int = 25) -> list[int]:
        lo, hi = self.lo, self.hi
        if hi - lo + 1 <= n:            # small range: exhaustive
            return list(range(lo, hi + 1))
        vals = {lo + 1, hi - 1, (lo + hi) // 2}
        v = max(lo, 1)
        while v < hi:           # geometric spread across magnitudes
            vals.add(v)
            v *= 7
        rng = random.Random(0xC0FFEE ^ lo ^ hi)
        while len(vals) < n - 2:
            vals.add(rng.randint(lo, hi))
        # boundaries survive truncation unconditionally
        interior = sorted(vals - {lo, hi})[: n - 2]
        return sorted({lo, hi, *interior})


class strategies:
    @staticmethod
    def integers(min_value: int = 0, max_value: int = 2**63 - 1) -> _Integers:
        return _Integers(min_value, max_value)


st = strategies


def settings(**_kw):
    """max_examples/deadline are hypothesis tuning knobs — no-op here."""
    def decorate(fn):
        return fn
    return decorate


def given(*arg_strategies, **kw_strategies):
    """Run the test once per deterministic example of each strategy.

    Positional strategies bind to the test's trailing parameters (matching
    hypothesis); remaining parameters stay visible to pytest (fixtures /
    parametrize)."""
    def decorate(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        bound = dict(zip(names[len(names) - len(arg_strategies):],
                         arg_strategies))
        bound.update(kw_strategies)
        remaining = [n for n in names if n not in bound]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            base = dict(zip(remaining, args))
            base.update(kwargs)
            samples = {k: s.examples() for k, s in bound.items()}
            rounds = max(len(v) for v in samples.values())
            for i in range(rounds):
                call = dict(base)
                for k, vals in samples.items():
                    call[k] = vals[i % len(vals)]
                fn(**call)

        wrapper.__signature__ = sig.replace(
            parameters=[sig.parameters[n] for n in remaining])
        return wrapper

    return decorate
