"""serving/engine generation paths: eos early-stop, fixed-seed sampling
determinism, cache-size guard — plus the EngineBackend that drives the
engine as a real (non-mock) LLMBackend behind the mapping service."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.backends import EngineBackend, MockLLMBackend, canonical_code
from repro.models import transformer as T
from repro.serving.engine import generate

PROMPT_LEN = 8
MAX_NEW = 6


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("yi-6b").replace(max_seq=PROMPT_LEN + MAX_NEW)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (1, PROMPT_LEN), 0, cfg.vocab_size, jnp.int32)
    return params, cfg, prompts


def test_eos_early_stop(engine_setup):
    """eos_id matching the first generated token stops decode after step 1
    instead of running out max_new_tokens."""
    params, cfg, prompts = engine_setup
    full = generate(params, cfg, prompts, MAX_NEW)
    assert full.steps == MAX_NEW
    first_tok = int(full.tokens[0, PROMPT_LEN])
    stopped = generate(params, cfg, prompts, MAX_NEW, eos_id=first_tok)
    assert stopped.steps == 1
    assert stopped.tokens.shape == (1, PROMPT_LEN + 1)
    assert int(stopped.tokens[0, PROMPT_LEN]) == first_tok


def test_eos_waits_for_whole_batch(engine_setup):
    """With batch > 1, decode only stops once *every* row has emitted eos —
    a row finishing early must not truncate its neighbours."""
    params, cfg, _ = engine_setup
    prompts = jax.random.randint(
        jax.random.PRNGKey(2), (3, PROMPT_LEN), 0, cfg.vocab_size, jnp.int32)
    full = generate(params, cfg, prompts, MAX_NEW)
    # pick the first token of row 0 only; other rows almost surely differ
    eos = int(full.tokens[0, PROMPT_LEN])
    res = generate(params, cfg, prompts, MAX_NEW, eos_id=eos)
    others = np.asarray(full.tokens[1:, PROMPT_LEN])
    if not (others == eos).any():
        assert res.steps > 1


def test_temperature_sampling_deterministic_under_fixed_seed(engine_setup):
    """temperature > 0 draws through jax.random with an explicit seed: the
    same seed must reproduce the exact token sequence; greedy must be
    unaffected by the seed entirely."""
    params, cfg, prompts = engine_setup
    a = generate(params, cfg, prompts, MAX_NEW, temperature=0.9, seed=42)
    b = generate(params, cfg, prompts, MAX_NEW, temperature=0.9, seed=42)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    g1 = generate(params, cfg, prompts, MAX_NEW, temperature=0.0, seed=1)
    g2 = generate(params, cfg, prompts, MAX_NEW, temperature=0.0, seed=2)
    np.testing.assert_array_equal(np.asarray(g1.tokens), np.asarray(g2.tokens))


def test_cache_too_small_asserts(engine_setup):
    """prompt + max_new beyond cfg.max_seq must fail loudly, not overflow
    the KV cache."""
    params, cfg, prompts = engine_setup
    with pytest.raises(AssertionError, match="cache too small"):
        generate(params, cfg, prompts, MAX_NEW + 1)


# ---------------------------------------------------------------------------
# EngineBackend: the engine as a real LLMBackend
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_backend():
    return EngineBackend("OSS:120b", max_new_tokens=4)


def test_engine_backend_fallback_is_canonical(engine_backend):
    """The untrained smoke model's sampled text fails synthesis, so the
    backend must emit the canonical derivation for the requested domain."""
    from repro.core.backends import build_prompt
    from repro.core.domains import DOMAINS

    prompt = build_prompt(DOMAINS["tri2d"], 20)
    resp = engine_backend.generate(prompt, meta={"domain": "tri2d", "stage": 20})
    assert canonical_code("tri2d") in resp.text
    assert resp.tokens_out == 4       # real decode steps, not replayed priors
    assert resp.seconds > 0 and resp.joules > 0


def test_engine_backend_batch_matches_single(engine_backend):
    """generate_batch (one padded prefill) and generate (singleton batch)
    must emit identical text for the same cell — batching is a throughput
    knob, never a behaviour change."""
    metas = [{"domain": "tri2d", "stage": 20},
             {"domain": "msimplex3", "stage": 20}]
    prompts = ["0 -> (0, 0)\n1 -> (1, 0)", "0 -> (0, 0, 0)\n1 -> (1, 0, 0)"]
    batch = engine_backend.generate_batch(prompts, metas)
    singles = [engine_backend.generate(p, meta=m)
               for p, m in zip(prompts, metas)]
    assert [r.text for r in batch] == [r.text for r in singles]
    assert batch[0].text != batch[1].text  # per-domain fallback, not shared


def test_engine_backend_cache_identity_distinct_from_mock(engine_backend):
    """Engine cells must occupy different content addresses than mock cells
    (and than an engine with different decode knobs)."""
    mock = MockLLMBackend("OSS:120b")
    other = EngineBackend("OSS:120b", max_new_tokens=8)
    fps = {engine_backend.cache_fingerprint, mock.cache_fingerprint,
           other.cache_fingerprint}
    assert len(fps) == 3
    # stable across instances with the same knobs
    twin = EngineBackend("OSS:120b", max_new_tokens=4)
    assert twin.cache_fingerprint == engine_backend.cache_fingerprint
