"""Load-aware request routing: RequestQueue admission/TTL/retry-lane
semantics, ReplicaSelector EWMA + queue-depth ranking and epsilon-greedy
exploration, RequestRouter dispatch, and the end-to-end traffic shift —
a fleet with one artificially slowed replica routes around it."""
import threading
import time

import pytest

from repro.core.backends import MockLLMBackend
from repro.core.store import build_store
from repro.serving import (
    ClusterMembership, MappingHTTPServer, MappingService,
    RemoteMappingService, ReplicaSelector, RequestQueue, RequestRouter,
)

MODEL = "OSS:120b"


# ---------------------------------------------------------------------------
# RequestQueue
# ---------------------------------------------------------------------------


def test_queue_fifo_and_retry_lane_priority():
    q = RequestQueue(capacity=8, ttl=10.0)
    for item in ("a", "b", "c"):
        assert q.offer(item)
    assert q.depth() == 3
    assert q.requeue("c")                 # failed once: jumps the line
    assert q.take() == "c"
    assert q.take() == "a" and q.take() == "b"
    assert q.take() is None
    assert q.stats.enqueued == 3
    assert q.stats.dequeued == 3
    assert q.stats.retried == 1


def test_queue_capacity_covers_both_lanes_and_sheds():
    q = RequestQueue(capacity=2, ttl=10.0)
    assert q.offer("a") and q.offer("b")
    assert not q.offer("c")               # full: shed
    assert q.stats.shed == 1
    assert q.requeue("a")                 # already queued: lane move, free
    assert not q.requeue("x")             # unknown + full: shed
    assert q.stats.shed == 2
    assert q.depth() == 2


def test_queue_ttl_expiry_is_lazy_and_counted():
    q = RequestQueue(capacity=8, ttl=0.05)
    q.offer("stale")
    q.offer("fresh", ttl=10.0)            # per-item override
    time.sleep(0.08)
    assert q.take() == "fresh"            # stale was dropped, not served
    assert q.stats.expired == 1
    assert q.take() is None


def test_queue_requeue_keeps_original_deadline():
    """A retry must not extend the request's TTL budget."""
    q = RequestQueue(capacity=8, ttl=10.0)
    q.offer("a", ttl=0.05)
    q.requeue("a")                        # lane move, same deadline
    time.sleep(0.08)
    assert q.take() is None
    assert q.stats.expired == 1


def test_queue_remove_withdraws_admission():
    q = RequestQueue(capacity=2, ttl=10.0)
    token = object()
    q.offer(token)
    assert q.remove(token)
    assert not q.remove(token)
    assert q.depth() == 0 and q.offer("next")


# ---------------------------------------------------------------------------
# ReplicaSelector
# ---------------------------------------------------------------------------


def test_selector_ewma_and_failure_penalty():
    sel = ReplicaSelector(alpha=0.3, epsilon=0.0, failure_penalty_ms=250.0)
    sel.observe("u", 0.010)
    assert sel.cost("u") == pytest.approx(10.0)
    sel.observe("u", 0.020)               # 10 + 0.3 * (20 - 10)
    assert sel.cost("u") == pytest.approx(13.0)
    sel.observe("u", 0.001, ok=False)     # books >= failure_penalty_ms
    assert sel.cost("u") > 70.0
    snap = sel.snapshot()["u"]
    assert snap["samples"] == 3 and snap["failures"] == 1
    assert snap["last_ms"] == 250.0


def test_selector_rank_blends_latency_and_advertised_depth():
    sel = ReplicaSelector(epsilon=0.0, depth_penalty_ms=5.0)
    sel.observe("fast", 0.010)
    sel.observe("slow", 0.030)
    assert sel.rank(["slow", "fast"]) == ["fast", "slow"]
    # 20ms of advertised queue depth flips the 20ms latency edge
    sel.advertise("fast", {"queue_depth": 5})
    assert sel.cost("fast") == pytest.approx(35.0)
    assert sel.rank(["slow", "fast"]) == ["slow", "fast"]
    sel.advertise("fast", {"queue_depth": 0})
    assert sel.rank(["slow", "fast"]) == ["fast", "slow"]
    # malformed advertisements are ignored, never raise
    sel.advertise("fast", None)
    sel.advertise("fast", {"queue_depth": "soup"})
    assert sel.cost("fast") == pytest.approx(10.0)


def test_selector_unknown_replicas_are_optimistic():
    """A fresh joiner (no samples) outranks every measured replica, and
    forget() resets a replica back to optimism."""
    sel = ReplicaSelector(epsilon=0.0)
    sel.observe("old", 0.005)
    assert sel.rank(["old", "new"]) == ["new", "old"]
    sel.observe("new", 0.050)
    assert sel.rank(["old", "new"]) == ["old", "new"]
    sel.forget("new")
    assert sel.rank(["old", "new"]) == ["new", "old"]


def test_selector_epsilon_exploration_is_seeded():
    sel = ReplicaSelector(epsilon=1.0, seed=7)
    sel.observe("a", 0.001)
    sel.observe("b", 0.100)
    ranks = [sel.rank(["a", "b"]) for _ in range(8)]
    assert all(r[0] == "b" for r in ranks)   # epsilon=1: always explore
    assert sel.explorations == 8
    twin = ReplicaSelector(epsilon=1.0, seed=7)
    twin.observe("a", 0.001)
    twin.observe("b", 0.100)
    assert [twin.rank(["a", "b"]) for _ in range(8)] == ranks
    greedy = ReplicaSelector(epsilon=0.0, seed=7)
    greedy.observe("a", 0.001)
    greedy.observe("b", 0.100)
    assert greedy.rank(["a", "b"]) == ["a", "b"]
    assert greedy.explorations == 0


# ---------------------------------------------------------------------------
# RequestRouter dispatch
# ---------------------------------------------------------------------------


def test_dispatch_prefers_measured_fast_replica():
    router = RequestRouter(policy="loaded", epsilon=0.0, seed=0)
    router.observe("slow", 0.200)
    router.observe("fast", 0.002)
    hops = []

    def attempt(url):
        hops.append(url)
        return f"ok:{url}"

    out = router.dispatch("k", ["slow", "fast"], attempt)
    assert out == "ok:fast" and hops == ["fast"]
    assert router.selector.snapshot()["fast"]["selections"] == 1
    assert router.queue.depth() == 0      # admission released


def test_dispatch_walks_candidates_on_failure():
    router = RequestRouter(policy="loaded", epsilon=0.0, seed=0)
    errors = []

    def attempt(url):
        if url == "dead":
            raise OSError("refused")
        return url

    router.observe("dead", 0.001)         # looks best until it fails
    router.observe("alive", 0.050)
    out = router.dispatch("k", ["dead", "alive"], attempt,
                          on_error=lambda u, e: errors.append((u, str(e))))
    assert out == "alive"
    assert errors == [("dead", "refused")]
    assert router.queue.stats.retried == 1
    assert router.selector.snapshot()["dead"]["failures"] == 1
    # the failure penalty reorders the next dispatch
    assert router.rank_owners(["dead", "alive"]) == ["alive", "dead"]


def test_dispatch_sheds_when_queue_full_and_expires_on_ttl():
    full = RequestRouter(policy="loaded", max_pending=1)
    full.queue.offer("occupant")
    assert full.dispatch("k", ["u"], lambda u: "x") is None
    assert full.queue.stats.shed == 1

    expired = RequestRouter(policy="loaded", ttl=0.0)
    assert expired.dispatch("k", ["u"], lambda u: "x") is None
    assert expired.queue.stats.expired >= 1

    assert full.dispatch("nope", [], lambda u: "x") is None  # no candidates


def test_static_policy_keeps_ring_order_but_still_measures():
    router = RequestRouter(policy="static")
    router.observe("b", 0.001)            # would win under "loaded"
    router.observe("a", 0.500)
    assert router.rank_owners(["a", "b"]) == ["a", "b"]
    out = router.dispatch("k", ["a", "b"], lambda u: u)
    assert out == "a"
    assert router.selector.snapshot()["a"]["samples"] == 2
    with pytest.raises(ValueError):
        RequestRouter(policy="mystery")


def test_track_and_load_advertisement():
    router = RequestRouter()
    assert router.load() == {"queue_depth": 0, "inflight": 0}
    with router.track():
        with router.track():
            assert router.load()["inflight"] == 2
        assert router.inflight() == 1
    assert router.inflight() == 0
    stats = router.stats_dict()
    assert stats["policy"] == "loaded"
    assert stats["queue"]["capacity"] == 256
    assert "replicas" in stats


# ---------------------------------------------------------------------------
# End to end: the fleet routes around a slowed replica
# ---------------------------------------------------------------------------


class CountingBackend:
    calls = 0
    _mu = threading.Lock()

    def __init__(self, model: str):
        self._inner = MockLLMBackend(model)
        self.name = self._inner.name

    @property
    def cache_fingerprint(self):
        return self._inner.cache_fingerprint

    def generate(self, prompt, *, meta):
        with CountingBackend._mu:
            CountingBackend.calls += 1
        return self._inner.generate(prompt, meta=meta)


def _boot(tmp_path, name, seeds, port=0, serve_delay=0.0, router=None):
    svc = MappingService(store=build_store(root=tmp_path / name),
                         backend_factory=CountingBackend,
                         n_validate=2000, sample_every=1)
    server = MappingHTTPServer(svc, port=port, router=router,
                               serve_delay=serve_delay).start()
    server.attach_cluster(ClusterMembership(
        server.url, seeds=seeds, replicas=2, vnodes=64,
        heartbeat_interval=0.15, down_after=1.0, sync_interval=0.3,
        probe_timeout=1.0))
    return server


def _await(predicate, timeout=20.0, what="condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(0.05)
    raise AssertionError(f"timed out waiting for {what}")


def test_traffic_shifts_away_from_slow_replica(tmp_path):
    """3-node fleet, one cell owned by two replicas, one of them slowed by
    the chaos knob: the non-owner's router must concentrate forwards on
    the fast owner (selection counters prove it), the slow owner's latency
    is learned from real observations, and the whole run costs exactly one
    inference.  The healthz/heartbeat load piggyback is live too."""
    import json
    import urllib.request

    CountingBackend.calls = 0
    # boot the seed first, pick the slow node after placement is known
    seed = _boot(tmp_path, "n0", [])
    b = _boot(tmp_path, "n1", [seed.url])
    c = _boot(tmp_path, "n2", [seed.url])
    servers = [seed, b, c]
    try:
        _await(lambda: all(len(s.cluster.ring.nodes) == 3 for s in servers),
               what="3-node convergence")
        key = seed.service.request_key("tri2d", MODEL, 20)
        owners = seed.cluster.owners(key)
        non_owner = next(s for s in servers if s.url not in owners)
        slow = next(s for s in servers if s.url == owners[0])
        fast = next(s for s in servers if s.url == owners[1])
        slow.serve_delay = 0.25           # the chaos knob, applied live
        # deterministic selection on the forwarding node: no exploration
        non_owner.router.selector.epsilon = 0.0

        body = json.dumps({"domain": "tri2d", "model": MODEL,
                           "stage": 20}).encode()
        for _ in range(6):
            req = urllib.request.Request(
                f"{non_owner.url}/v1/derive", data=body,
                headers={"Content-Type": "application/json"})
            with urllib.request.urlopen(req, timeout=30.0) as resp:
                assert json.loads(resp.read())["key"] == key
        assert CountingBackend.calls == 1

        snap = non_owner.router.selector.snapshot()
        sel_fast = snap[fast.url]["selections"]
        sel_slow = snap.get(slow.url, {}).get("selections", 0)
        # first hop probes optimistically; every later hop goes fast
        assert sel_fast >= 4, snap
        assert sel_slow <= 2, snap
        assert snap[fast.url]["ewma_ms"] < 250.0
        if slow.url in snap and snap[slow.url]["samples"]:
            assert snap[slow.url]["ewma_ms"] >= 200.0

        # healthz piggybacks the advertised load
        with urllib.request.urlopen(f"{fast.url}/healthz",
                                    timeout=5.0) as resp:
            health = json.loads(resp.read())
        assert "load" in health and "queue_depth" in health["load"]

        # /metrics exposes the router block both frontends share
        with urllib.request.urlopen(f"{non_owner.url}/metrics",
                                    timeout=5.0) as resp:
            metrics = json.loads(resp.read())
        assert metrics["router"]["policy"] == "loaded"
        assert fast.url in metrics["router"]["replicas"]
    finally:
        for s in servers:
            s.close()


def test_heartbeat_piggybacks_load_between_peers(tmp_path):
    """The cluster view carries each node's advertised queue depth, and a
    peer's selector learns it without any request traffic."""
    seed = _boot(tmp_path, "h0", [])
    other = _boot(tmp_path, "h1", [seed.url])
    try:
        _await(lambda: len(seed.cluster.ring.nodes) == 2
               and len(other.cluster.ring.nodes) == 2,
               what="2-node convergence")
        with other.router.track():        # fake one in-flight derive
            _await(lambda: seed.router.selector.snapshot()
                   .get(other.url, {}).get("queue_depth") == 1,
                   timeout=5.0,
                   what="load piggyback via heartbeat")
        _await(lambda: seed.router.selector.snapshot()
               .get(other.url, {}).get("queue_depth") == 0,
               timeout=5.0, what="load decay after the work drains")
        loads = seed.cluster.node_loads()
        assert other.url in loads and "queue_depth" in loads[other.url]
    finally:
        seed.close()
        other.close()
