"""Continuous batching: the step-interleaved cohort scheduler.

The headline property under test — a derive admitted while a decode batch is
in flight joins at the *next step boundary* instead of waiting for the batch
to drain — plus its admission-control contract (LLMBusyError on a full
queue, LLMTimeoutError past the admission deadline) and the real-engine
integration (responses indistinguishable from the drained-batch path)."""
import concurrent.futures
import threading
import time

import pytest

from repro.core.backends import (
    EngineBackend, LLMBusyError, LLMResponse, LLMTimeoutError,
)
from repro.serving.async_engine import (
    ContinuousBatcher, ContinuousBatchingBackend,
)

MODEL = "OSS:120b"


class FakeState:
    def __init__(self, prompts):
        self.prompts = tuple(prompts)
        self.steps_done = 0


class FakeStepper:
    """Scriptable CohortStepper: fixed step count, configurable per-step
    sleep, and an event log ordered exactly as the scheduler acted."""

    def __init__(self, steps: int = 4, step_sleep: float = 0.02):
        self.steps = steps
        self.step_sleep = step_sleep
        self.events: list[tuple] = []
        self._mu = threading.Lock()

    def prefill(self, prompts):
        with self._mu:
            self.events.append(("prefill", tuple(prompts)))
        return FakeState(prompts)

    def step(self, state):
        time.sleep(self.step_sleep)
        state.steps_done += 1
        with self._mu:
            self.events.append(("step", state.prompts, state.steps_done))
        return state.steps_done >= self.steps

    def finalize(self, state, metas):
        return [LLMResponse(text=f"gen:{p}", model="fake", tokens_in=1,
                            tokens_out=state.steps_done, seconds=0.0,
                            joules=0.0)
                for p in state.prompts]


def test_join_at_next_step_boundary():
    """A request arriving while cohort A decodes is prefilled as cohort B
    *between* A's steps — before A drains — and decode-slot occupancy
    exceeds the drained-batch baseline of one batch at a time."""
    stepper = FakeStepper(steps=6, step_sleep=0.03)
    batcher = ContinuousBatcher(stepper, decode_slots=4)
    try:
        fut_a = batcher.submit("A", {})
        # wait until A's cohort has visibly started decoding
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            with stepper._mu:
                if any(e[0] == "step" and e[1] == ("A",)
                       for e in stepper.events):
                    break
            time.sleep(0.005)
        else:
            pytest.fail("cohort A never started decoding")
        fut_b = batcher.submit("B", {})
        assert fut_a.result(timeout=10.0).text == "gen:A"
        assert fut_b.result(timeout=10.0).text == "gen:B"
    finally:
        batcher.close()

    events = stepper.events
    b_prefill = events.index(("prefill", ("B",)))
    a_steps_before = [i for i, e in enumerate(events)
                      if e[0] == "step" and e[1] == ("A",) and i < b_prefill]
    a_steps_after = [i for i, e in enumerate(events)
                     if e[0] == "step" and e[1] == ("A",) and i > b_prefill]
    # B was admitted mid-flight: after >=1 of A's steps, before A finished
    assert a_steps_before, "B's prefill should come after A started decoding"
    assert a_steps_after, "B's prefill must land before A's batch drained"
    assert batcher.stats.joined_inflight >= 1
    # occupancy high-water: two requests decoding at once beats the
    # gather-then-drain baseline (one batch, occupancy 1, at a time)
    assert batcher.stats.max_occupancy >= 2
    assert batcher.stats.cohorts == 2
    assert batcher.stats.prefills == 2


def test_cohorts_interleave_stepwise():
    """With two cohorts in flight the scheduler alternates their steps
    (A B A B ...) rather than draining one before touching the other."""
    stepper = FakeStepper(steps=8, step_sleep=0.02)
    batcher = ContinuousBatcher(stepper, decode_slots=4)
    try:
        fut_a = batcher.submit("A", {})
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            with stepper._mu:
                if any(e[0] == "step" for e in stepper.events):
                    break
            time.sleep(0.005)
        fut_b = batcher.submit("B", {})
        fut_a.result(timeout=10.0)
        fut_b.result(timeout=10.0)
    finally:
        batcher.close()
    # within the overlap window, consecutive steps alternate cohorts
    overlap = [e[1] for e in stepper.events if e[0] == "step"]
    first_b = overlap.index(("B",))
    last_a = len(overlap) - 1 - overlap[::-1].index(("A",))
    window = overlap[first_b:last_a + 1]
    assert window, "cohorts never overlapped"
    # strict alternation while both are live
    for prev, cur in zip(window, window[1:]):
        assert prev != cur, f"scheduler ran {prev} twice in a row mid-overlap"


def test_same_boundary_arrivals_share_one_cohort():
    """Requests already queued at a step boundary form ONE cohort (one
    batched prefill), not one cohort each."""
    stepper = FakeStepper(steps=30, step_sleep=0.03)
    batcher = ContinuousBatcher(stepper, decode_slots=8)
    try:
        # park a long-running cohort so the worker is provably mid-decode
        hog = batcher.submit("hog", {})
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline and not stepper.events:
            time.sleep(0.005)
        # all three queue before the next boundary (steps take 30ms)
        futs = [batcher.submit(p, {}) for p in ("A", "B", "C")]
        for f in futs:
            f.result(timeout=10.0)
        hog.result(timeout=10.0)
    finally:
        batcher.close()
    joint = [e for e in stepper.events
             if e[0] == "prefill" and len(e[1]) > 1]
    assert len(joint) == 1
    assert set(joint[0][1]) == {"A", "B", "C"}
    assert batcher.stats.cohorts == 2
    assert batcher.stats.max_occupancy == 4


def test_busy_shed_on_full_queue():
    stepper = FakeStepper(steps=50, step_sleep=0.05)
    batcher = ContinuousBatcher(stepper, decode_slots=1, max_pending=2)
    try:
        occupant = batcher.submit("hog", {})
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline and not stepper.events:
            time.sleep(0.005)
        batcher.submit("q1", {})
        batcher.submit("q2", {})
        with pytest.raises(LLMBusyError):
            batcher.submit("overflow", {})
        assert batcher.stats.rejected == 1
        assert not occupant.done()
    finally:
        batcher.close()


def test_admission_timeout_is_typed():
    """A request that cannot reach a decode slot before admission_timeout
    fails with LLMTimeoutError (the 504 of the wire layer), while the
    occupant keeps decoding unharmed."""
    stepper = FakeStepper(steps=40, step_sleep=0.05)
    batcher = ContinuousBatcher(stepper, decode_slots=1,
                                admission_timeout=0.2)
    try:
        occupant = batcher.submit("hog", {})
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline and not stepper.events:
            time.sleep(0.005)
        starved = batcher.submit("starved", {})
        with pytest.raises(LLMTimeoutError):
            starved.result(timeout=5.0)
        assert batcher.stats.timeouts == 1
        assert occupant.result(timeout=10.0).text == "gen:hog"
    finally:
        batcher.close()


def test_step_error_fans_out_to_cohort():
    class Exploding(FakeStepper):
        def step(self, state):
            raise RuntimeError("device fell over")

    batcher = ContinuousBatcher(Exploding(), decode_slots=4)
    futs = [batcher.submit(p, {}) for p in ("A", "B")]
    try:
        for fut in futs:
            with pytest.raises(RuntimeError, match="device fell over"):
                fut.result(timeout=5.0)
    finally:
        batcher.close()


def test_close_fails_pending_requests():
    stepper = FakeStepper(steps=100, step_sleep=0.05)
    batcher = ContinuousBatcher(stepper, decode_slots=1)
    inflight = batcher.submit("hog", {})
    queued = batcher.submit("queued", {})
    time.sleep(0.1)
    batcher.close()
    for fut in (inflight, queued):
        with pytest.raises(LLMBusyError):
            fut.result(timeout=1.0)


def test_engine_continuous_matches_drained_semantics():
    """The real engine through the continuous scheduler: concurrent
    generates complete, the smoke model's canonical-fallback responses are
    identical to the drained-batch path's, and occupancy shows true
    mid-flight joining."""
    inner = EngineBackend(MODEL, max_new_tokens=4)
    cb = ContinuousBatchingBackend(inner, decode_slots=4)
    try:
        meta = {"domain": "tri2d"}
        warm = cb.generate("warm", meta=meta)  # jit prefill+step once
        assert warm.tokens_out == 4

        results = {}
        mu = threading.Lock()
        gate = threading.Barrier(4)

        def go(i):
            gate.wait()  # submit all four within the same step window
            r = cb.generate(f"prompt {i}", meta=meta)
            with mu:
                results[i] = r

        threads = [threading.Thread(target=go, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        assert sorted(results) == [0, 1, 2, 3]
        # the untrained smoke model never synthesizes: every response is the
        # canonical fallback, exactly as EngineBackend.generate_batch yields
        baseline = inner.generate("prompt 0", meta=meta)
        for r in results.values():
            assert r.text == baseline.text
            assert r.tokens_out == baseline.tokens_out
            assert r.model == MODEL
        stats = cb.stats
        assert stats.completed >= 5
        assert stats.max_occupancy > 1, \
            "continuous path never held >1 request in decode slots"
    finally:
        cb.close()


def test_sync_facade_raises_after_close():
    batcher = ContinuousBatchingBackend(
        EngineBackend(MODEL, max_new_tokens=2))
    batcher.close()
    with pytest.raises(LLMBusyError):
        batcher.generate("p", meta={"domain": "tri2d"})


def test_submit_returns_concurrent_future():
    stepper = FakeStepper(steps=2, step_sleep=0.0)
    batcher = ContinuousBatcher(stepper, decode_slots=2)
    try:
        fut = batcher.submit("A", {})
        assert isinstance(fut, concurrent.futures.Future)
        assert fut.result(timeout=5.0).tokens_out == 2
    finally:
        batcher.close()
