"""Evaluation plane end-to-end: batched EvaluationService grouping +
correctness against the direct kernel path, artifact-key resolution through
the store, the POST /v1/evaluate wire surface (single / batch / NDJSON
sweep / error codes), client retry-and-fallback parity with derive, and the
multi-device sharded sweep (subprocess, fake devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import compile_cache as cc
from repro.core.artifact import ArtifactCache
from repro.core.backends import MockLLMBackend
from repro.core.maps import np_map
from repro.kernels.domain_map import ops
from repro.serving import (
    MappingHTTPServer, MappingService, RemoteMappingService,
    RemoteServiceError,
)
from repro.serving.evaluate import (
    EvaluationService, hydrate_result, wire_result,
)

MODEL = "OSS:120b"
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def fresh_evaluator(**kw) -> EvaluationService:
    kw.setdefault("compile_cache", cc.CompileCache(max_entries=32))
    return EvaluationService(**kw)


def local_service(tmp_path) -> MappingService:
    return MappingService(cache=ArtifactCache(tmp_path),
                          backend_factory=MockLLMBackend,
                          n_validate=2000, sample_every=1)


# ---------------------------------------------------------------------------
# batching semantics + correctness
# ---------------------------------------------------------------------------


def test_batch_groups_share_executables_and_match_direct_kernels():
    """Same-family map queries merge into one padded launch; every member's
    slice is byte-equal to the uncached direct kernel call."""
    ev = fresh_evaluator()
    queries = [
        {"domain": "tri2d", "n_points": 100, "block_n": 128},
        {"domain": "tri2d", "n_points": 200, "block_n": 128},
        {"domain": "tri2d", "n_points": 300, "block_n": 128},
        {"domain": "gasket2d", "n_points": 128, "block_n": 128},
        {"domain": "tri2d", "tier": "membership", "extent": [16, 16],
         "block_n": 128},
        {"domain": "tri2d", "tier": "membership", "extent": [16, 16],
         "block_n": 128},
    ]
    results, meta = ev.evaluate_batch(queries)
    assert meta["queries"] == 6
    # tri2d maps merge, gasket2d is its own group, the twin membership
    # boxes share one launch: 3 groups, 3 dispatches
    assert meta["groups"] == meta["dispatches"] == 3
    tri_groups = {r["group"] for r in results[:3]}
    assert len(tri_groups) == 1
    assert results[0]["group_size"] == 3
    assert results[4]["group"] == results[5]["group"]
    assert ev.stats.shared == 3
    assert ev.cache.stats.misses == 3  # one compile per group

    for r, q in zip(results[:4], queries[:4]):
        direct = ops.map_coordinates(
            q["domain"], q["n_points"], block_n=q["block_n"],
            interpret=True, compile_cache=None)
        np.testing.assert_array_equal(r["coords"], direct)
        assert r["coords"].shape == (q["n_points"],
                                     2 if q["domain"] != "msimplex3" else 3)
        ref = np_map(q["domain"], np.arange(q["n_points"], dtype=np.int64))
        np.testing.assert_array_equal(r["coords"].astype(np.int64), ref)
    direct_mask = ops.bb_membership("tri2d", (16, 16), block_n=128,
                                    interpret=True, compile_cache=None)
    np.testing.assert_array_equal(results[4]["mask"], direct_mask)
    np.testing.assert_array_equal(results[5]["mask"], direct_mask)


def test_padding_overhead_stays_nonnegative_when_groups_merge():
    """Merged groups account the padded launch once PER member query.
    Regression: a 3-way merge of 100/200/300-point queries used to count
    the 384-point launch once against 600 requested points, reporting a
    negative padding_overhead."""
    ev = fresh_evaluator()
    ev.evaluate_batch([
        {"domain": "tri2d", "n_points": 100, "block_n": 128},
        {"domain": "tri2d", "n_points": 200, "block_n": 128},
        {"domain": "tri2d", "n_points": 300, "block_n": 128},
    ])
    stats = ev.stats.as_dict()
    assert stats["points"] == 600
    assert stats["padded_points"] >= stats["points"]
    assert 0 <= stats["padding_overhead"] < 1

    # and it stays a weighted average, not a reset, across batches
    ev.evaluate({"domain": "gasket2d", "n_points": 256, "block_n": 128})
    stats = ev.stats.as_dict()
    assert stats["padded_points"] >= stats["points"]
    assert 0 <= stats["padding_overhead"] < 1


def test_repeat_batch_is_all_hits_and_lambda_range_equals_slice():
    ev = fresh_evaluator()
    first = ev.evaluate({"domain": "gasket2d", "n_points": 256,
                         "block_n": 128})
    assert first["executable"] == "miss"
    again = ev.evaluate({"domain": "gasket2d", "n_points": 256,
                         "block_n": 128})
    assert again["executable"] == "hit"
    assert ev.cache.stats.hits == 1
    np.testing.assert_array_equal(first["coords"], again["coords"])

    # a λ-range query [start, start+n) equals the slice of a from-zero run
    tail = ev.evaluate({"domain": "gasket2d", "n_points": 128, "start": 128,
                        "block_n": 128})
    full = ops.map_coordinates("gasket2d", 256, block_n=128, interpret=True,
                               compile_cache=None)
    np.testing.assert_array_equal(tail["coords"], full[128:256])
    assert tail["start"] == 128


def test_query_validation_and_error_accounting():
    ev = fresh_evaluator()
    bad = [
        ({"domain": "tri2d"}, "n_points"),
        ({"domain": "tri2d", "n_points": 0}, "n_points"),
        ({"domain": "tri2d", "n_points": -5}, "n_points"),
        ({"domain": "tri2d", "n_points": True}, "n_points"),
        ({"domain": "tri2d", "n_points": 1 << 22}, "max"),
        ({"domain": "tri2d", "n_points": 10, "start": -1}, "start"),
        ({"domain": "tri2d", "n_points": 10, "tier": "nope"}, "tier"),
        ({"domain": "tri2d", "n_points": 10, "block_n": 0}, "block_n"),
        ({"domain": "tri2d", "n_points": 10, "interpret": "yes"},
         "interpret"),
        ({"domain": "tri2d", "tier": "membership"}, "extent"),
        ({"domain": "tri2d", "tier": "membership", "extent": []}, "extent"),
        ({"domain": "tri2d", "tier": "membership", "extent": [4, 4, 4]},
         "axes"),
        ({"domain": "msimplex3", "tier": "membership",
          "extent": [1 << 8, 1 << 8, 1 << 8]}, "max"),
        ({"key": "not-hex"}, "key"),
        ({}, "domain"),
        ("not a dict", "object"),
    ]
    for query, needle in bad:
        with pytest.raises(ValueError, match=needle):
            ev.evaluate(query)  # type: ignore[arg-type]
    with pytest.raises(KeyError):
        ev.evaluate({"domain": "atlantis", "n_points": 10})
    with pytest.raises(ValueError, match="empty"):
        ev.evaluate_batch([])  # rejected pre-admission, not an eval error
    assert ev.stats.errors == len(bad) + 1
    assert ev.stats.queries == 0          # nothing was ever dispatched
    assert ev.cache.stats.misses == 0


def test_artifact_key_queries_resolve_through_the_store(tmp_path):
    """A derived artifact's content address drives the mapped kernel — the
    paper's Phase-4 gate — and produces ground-truth coordinates."""
    svc = local_service(tmp_path)
    res = svc.derive("tri2d", MODEL, 20)
    ev = fresh_evaluator(artifact_resolver=svc.artifact_for_key)

    got = ev.evaluate({"key": res.cache_key, "n_points": 150,
                       "block_n": 128})
    assert got["domain"] == "tri2d"
    ref = np_map("tri2d", np.arange(150, dtype=np.int64))
    np.testing.assert_array_equal(got["coords"].astype(np.int64), ref)
    # the artifact owns its executable identity (content-addressed), so a
    # same-shape domain query compiles separately
    dom = ev.evaluate({"domain": "tri2d", "n_points": 150, "block_n": 128})
    np.testing.assert_array_equal(dom["coords"], got["coords"])
    assert ev.cache.stats.misses == 2
    fps = {k.fingerprint for k in ev.cache.keys()}
    assert f"artifact:{res.cache_key}" in fps and "domain:tri2d" in fps

    with pytest.raises(KeyError):
        ev.evaluate({"key": "ab" * 32, "n_points": 10})  # never stored
    with pytest.raises(ValueError, match="64-hex"):
        ev.evaluate({"key": "xyz", "n_points": 10})
    bare = fresh_evaluator()  # no resolver attached
    with pytest.raises(ValueError, match="resolve artifact keys"):
        bare.evaluate({"key": res.cache_key, "n_points": 10})


def test_sweep_streams_every_cell_and_wire_roundtrip():
    ev = fresh_evaluator()
    cells = list(ev.sweep(["tri2d", "gasket2d"], [64, 128], block_n=64))
    assert len(cells) == 4
    assert ev.stats.sweep_cells == 4
    assert [(c["domain"], c["n_points"]) for c in cells] == [
        ("tri2d", 64), ("tri2d", 128), ("gasket2d", 64), ("gasket2d", 128)]
    # wire_result/hydrate_result round-trip through JSON byte-identically
    for c in cells:
        back = hydrate_result(json.loads(json.dumps(wire_result(c))))
        np.testing.assert_array_equal(back["coords"], c["coords"])
        assert back["coords"].dtype == np.int32
    stats = ev.stats_dict()
    assert stats["queries"] == 4
    assert stats["compile_cache"]["misses"] == ev.cache.stats.misses
    assert 0 <= stats["padding_overhead"] < 1


# ---------------------------------------------------------------------------
# wire surface: POST /v1/evaluate
# ---------------------------------------------------------------------------


def test_http_evaluate_single_batch_and_sweep(tmp_path):
    svc = local_service(tmp_path)
    with MappingHTTPServer(svc) as server:
        client = RemoteMappingService(server.url)

        single = client.evaluate("tri2d", n_points=200, block_n=128)
        local = ops.map_coordinates("tri2d", 200, block_n=128,
                                    interpret=True, compile_cache=None)
        np.testing.assert_array_equal(single["coords"], local)

        # derived artifact, evaluated by content address over the wire
        res = client.derive("tri2d", MODEL, 20)
        by_key = client.evaluate(key=res.cache_key, n_points=128,
                                 block_n=128)
        np.testing.assert_array_equal(
            by_key["coords"].astype(np.int64),
            np_map("tri2d", np.arange(128, dtype=np.int64)))

        batch = client.evaluate_batch([
            {"domain": "tri2d", "n_points": 100, "block_n": 128},
            {"domain": "tri2d", "n_points": 200, "block_n": 128},
            {"domain": "tri2d", "tier": "membership", "extent": [12, 12]},
        ])
        assert len(batch) == 3
        assert batch[0]["group"] == batch[1]["group"]
        np.testing.assert_array_equal(batch[1]["coords"], local)
        np.testing.assert_array_equal(
            batch[2]["mask"],
            ops.bb_membership("tri2d", (12, 12), interpret=True,
                              compile_cache=None))

        swept = list(client.evaluate_sweep(["tri2d", "gasket2d"], [64, 128],
                                           block_n=64))
        assert len(swept) == 4
        assert all(isinstance(c["coords"], np.ndarray) for c in swept)

        metrics = client.metrics()
        assert metrics["evaluate"]["queries"] >= 8
        assert metrics["evaluate"]["batches"] >= 3
        assert metrics["evaluate"]["sweep_cells"] == 4
        assert metrics["compile_cache"]["misses"] >= 1
        assert metrics["http"]["evaluate"]["requests"] >= 4
        assert metrics["http"]["evaluate"]["p95_ms"] > 0
        assert client.store_stats()["compile_cache"]["entries"] >= 1


def test_http_evaluate_error_codes(tmp_path):
    svc = local_service(tmp_path)
    with MappingHTTPServer(svc) as server:
        client = RemoteMappingService(server.url)
        with pytest.raises(RemoteServiceError) as e404:
            client.evaluate("atlantis", n_points=10)
        assert e404.value.status == 404
        with pytest.raises(RemoteServiceError) as k404:
            client.evaluate(key="ab" * 32, n_points=10)  # never stored
        assert k404.value.status == 404
        with pytest.raises(RemoteServiceError) as e400:
            client.evaluate("tri2d")  # no n_points
        assert e400.value.status == 400
        with pytest.raises(RemoteServiceError) as b400:
            client._call_json("/v1/evaluate", {"queries": "nope"})
        assert b400.value.status == 400
        with pytest.raises(RemoteServiceError) as s400:
            client._call_json("/v1/evaluate", {"sweep": {"domains": []}})
        assert s400.value.status == 400
        # a batch with one bad member fails atomically — nothing dispatched
        before = client.metrics()["evaluate"]["queries"]
        with pytest.raises(RemoteServiceError) as mix:
            client.evaluate_batch([
                {"domain": "tri2d", "n_points": 10},
                {"domain": "tri2d", "n_points": -1},
            ])
        assert mix.value.status == 400
        assert client.metrics()["evaluate"]["queries"] == before
        # malformed requests must not poison the endpoint
        assert client.evaluate("tri2d", n_points=16)["n_points"] == 16


def test_client_evaluate_falls_back_like_derive(tmp_path):
    """Dead server + configured fallback: evaluation degrades to the local
    kernels (same bytes); without a fallback the transport error surfaces."""
    local = local_service(tmp_path)
    art = local.derive("tri2d", MODEL, 20)
    client = RemoteMappingService("http://127.0.0.1:9", retries=1,
                                  backoff=0.01, fallback=local)
    got = client.evaluate("tri2d", n_points=150, block_n=128)
    assert client.stats.fallbacks == 1
    np.testing.assert_array_equal(
        got["coords"],
        ops.map_coordinates("tri2d", 150, block_n=128, interpret=True,
                            compile_cache=None))
    # artifact keys resolve against the fallback service's store
    by_key = client.evaluate(key=art.cache_key, n_points=64, block_n=64)
    np.testing.assert_array_equal(
        by_key["coords"].astype(np.int64),
        np_map("tri2d", np.arange(64, dtype=np.int64)))
    swept = list(client.evaluate_sweep(["tri2d"], [64], block_n=64))
    assert len(swept) == 1 and client.stats.fallbacks == 3
    assert client.stats.retries >= 3

    bare = RemoteMappingService("http://127.0.0.1:9", retries=0,
                                backoff=0.01)
    with pytest.raises(RemoteServiceError):
        bare.evaluate("tri2d", n_points=10)
    with pytest.raises(RemoteServiceError):
        list(bare.evaluate_sweep(["tri2d"], [16]))
    with pytest.raises(ValueError, match="'domain' or 'key'"):
        bare.evaluate()
    with pytest.raises(RemoteServiceError) as badkey:
        bare.evaluate(key="nope")  # rejected before any round-trip
    assert badkey.value.status == 400


# ---------------------------------------------------------------------------
# multi-device sharded sweep (subprocess: 4 fake host devices)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import numpy as np
    from repro.core.maps import np_map
    from repro.serving.evaluate import EvaluationService

    assert jax.device_count() == 4
    ev = EvaluationService()
    cells = list(ev.sweep(["tri2d", "gasket2d"], [100, 256]))
    assert len(cells) == 4
    for c in cells:
        assert c["executable"] == "sharded" and c["devices"] == 4
        ref = np_map(c["domain"], np.arange(c["n_points"], dtype=np.int64))
        np.testing.assert_array_equal(
            np.asarray(c["coords"], dtype=np.int64), ref)
    assert ev.stats.sharded_dispatches == 4
    hits = ev.cache.stats.hits
    list(ev.sweep(["tri2d"], [100]))        # repeat: compiled-cache hit
    assert ev.cache.stats.hits == hits + 1
    print("OK sharded-sweep")
""")


@pytest.mark.slow
def test_sharded_sweep_matches_ground_truth_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "OK sharded-sweep" in res.stdout, res.stdout
