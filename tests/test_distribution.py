"""Sharding rules (unit) + multi-device semantics (subprocess, 8 fake devs)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distribution import sharding as shd
from repro.distribution.compression import (
    _dequantize, _quantize, quantization_error_bound,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_resolve_spec_basic():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = shd.resolve_spec(("embed", "ffn"), shd.PARAM_RULES, mesh,
                            (64, 128))
    assert spec == P("data", "model")


def test_resolve_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # simulate a 16-wide axis via rules on a fake mesh: use shape-aware check
    mesh16 = jax.make_mesh((1,), ("model",))
    # 24 heads % 1 == 0 -> sharded; emulate non-divisible with explicit size
    spec = shd.resolve_spec(("heads",), {"heads": "model"}, mesh16, (24,))
    assert spec == P("model")
    # axis absent from mesh -> dropped
    spec = shd.resolve_spec(("heads",), {"heads": "tensor"}, mesh16, (24,))
    assert spec == P()


def test_resolve_spec_duplicate_axis_dropped():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = shd.resolve_spec(("ffn", "ffn"), shd.PARAM_RULES, mesh, (8, 8))
    assert spec == P("model")  # second use of the mesh axis is dropped


def test_logical_constraint_identity_outside_mesh():
    x = jnp.ones((4, 4))
    assert shd.logical_constraint(x, "batch", None) is x


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1000,)) * 3.0)
    q, s = _quantize(x)
    back = _dequantize(q, s, x.shape, x.dtype)
    bound = quantization_error_bound(x) + 1e-6
    assert float(jnp.max(jnp.abs(back - x))) <= bound


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from functools import partial
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.distribution import sharding as shd
    from repro.distribution.compression import compressed_psum_mean
    from repro.models import transformer as T
    from repro.train import optimizer as opt, checkpoint as ckpt
    from repro.train.train_step import TrainConfig, make_train_step
    from repro.train.data import SyntheticLM, DataConfig

    assert jax.device_count() == 8

    cfg = get_smoke_config("yi-6b")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8))
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))
    tcfg = TrainConfig()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init_state(params)

    # ---- 1. sharded step == single-device step -------------------------
    single_p, single_s, single_m = jax.jit(make_train_step(cfg, tcfg))(
        params, opt_state, batch)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with shd.use_sharding(mesh):
        p_sh = shd.param_sharding(T.param_specs(cfg), params, mesh)
        params_d = jax.device_put(params, p_sh)
        o_sh = shd.param_sharding(opt.state_specs(T.param_specs(cfg)),
                                  opt_state, mesh)
        opt_d = jax.device_put(opt_state, o_sh)
        bsh = NamedSharding(mesh, P(("data",), None))
        batch_d = jax.tree.map(lambda t: jax.device_put(t, bsh), batch)
        sp, ss, sm = jax.jit(make_train_step(cfg, tcfg))(
            params_d, opt_d, batch_d)
    assert abs(float(sm["loss"]) - float(single_m["loss"])) < 2e-4, (
        float(sm["loss"]), float(single_m["loss"]))
    err = max(float(jnp.max(jnp.abs(np.asarray(a, dtype=np.float32)
                                    - np.asarray(b, dtype=np.float32))))
              for a, b in zip(jax.tree.leaves(sp), jax.tree.leaves(single_p)))
    assert err < 2e-3, err
    print("OK sharded-step-numerics", float(sm["loss"]), err)

    # ---- 2. elastic checkpoint reshard: (4,2) -> (2,4) ------------------
    d = "/tmp/elastic_ck"
    ckpt.save(d, 3, sp, ss)
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    with shd.use_sharding(mesh2):
        p_sh2 = shd.param_sharding(T.param_specs(cfg), params, mesh2)
        o_sh2 = shd.param_sharding(opt.state_specs(T.param_specs(cfg)),
                                   opt_state, mesh2)
        restored, man = ckpt.restore(
            d, 3, {"params": params, "opt_state": opt_state},
            shardings={"params": p_sh2, "opt_state": o_sh2})
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(sp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("OK elastic-reshard")

    # ---- 3. compressed gradient all-reduce ------------------------------
    mesh1d = jax.make_mesh((8,), ("pod",))
    rng = np.random.default_rng(1)
    local = jnp.asarray(rng.standard_normal((8, 512)), jnp.float32)

    try:
        from jax import shard_map            # jax >= 0.5
    except ImportError:
        from jax.experimental.shard_map import shard_map
    @partial(shard_map, mesh=mesh1d, in_specs=P("pod"),
             out_specs=P("pod"))
    def reduce_fn(x):
        return compressed_psum_mean(x, "pod", 8)

    out = reduce_fn(local)
    expect = jnp.broadcast_to(local.mean(axis=0, keepdims=True), local.shape)
    err = float(jnp.max(jnp.abs(out - expect)))
    assert err < 0.05, err            # int8 quantization error bound
    assert err > 0.0                  # it IS lossy (sanity that it ran)
    print("OK compressed-psum", err)
""")


@pytest.mark.slow
def test_multidevice_semantics_subprocess():
    """8 fake devices: sharded numerics, elastic reshard, compression."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT], env=env,
        capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for marker in ("OK sharded-step-numerics", "OK elastic-reshard",
                   "OK compressed-psum"):
        assert marker in res.stdout, res.stdout
