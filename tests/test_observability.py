"""Observability plane: metrics registry, Prometheus exposition, request
tracing across frontends/threads/nodes, and the loadgen/SLO harness."""
from __future__ import annotations

import dataclasses
import json
import time
import urllib.error
import urllib.request

import pytest

from repro.core.backends import MockLLMBackend
from repro.core.store import build_store
from repro.obs import Observability
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    EndpointStats,
    Gauge,
    Histogram,
    MetricsRegistry,
    flatten_payload,
    parse_prometheus,
)
from repro.obs.trace import (
    TRACE_HEADER,
    TraceBuffer,
    activate,
    deactivate,
    new_trace_id,
    record_for_meta,
    span,
    valid_trace_id,
)
from repro.serving import (
    AsyncMappingHTTPServer,
    MappingHTTPServer,
    MappingService,
    RemoteMappingService,
)
from repro.serving.cluster import ClusterMembership

MODEL = "OSS:120b"


def make_service(tmp_path, name="svc"):
    return MappingService(store=build_store(root=tmp_path / name),
                          backend_factory=MockLLMBackend,
                          n_validate=2000, sample_every=1)


def post_json(url: str, body: dict, headers: dict | None = None):
    req = urllib.request.Request(
        url, data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json", **(headers or {})})
    with urllib.request.urlopen(req, timeout=30) as resp:
        return resp.status, dict(resp.headers), json.loads(resp.read())


def get_json(url: str, headers: dict | None = None):
    req = urllib.request.Request(url, headers=headers or {})
    with urllib.request.urlopen(req, timeout=30) as resp:
        return resp.status, dict(resp.headers), resp.read()


def wait_for_span(url: str, trace_id: str, name: str,
                  timeout: float = 5.0) -> dict:
    """Poll one node for a span — the ingress span lands an instant after
    the response bytes, so reads must tolerate that gap."""
    deadline = time.monotonic() + timeout
    last = None
    while time.monotonic() < deadline:
        try:
            _, _, raw = get_json(f"{url}/v1/trace/{trace_id}")
            last = json.loads(raw)
            for sp in last["spans"]:
                if sp["name"] == name:
                    return last
        except urllib.error.HTTPError:
            pass
        time.sleep(0.02)
    raise AssertionError(
        f"span {name!r} never appeared in trace {trace_id} on {url}: {last}")


# ---------------------------------------------------------------------------
# Registry units
# ---------------------------------------------------------------------------


def test_counter_gauge_basics():
    c = Counter("repro_things_total", "things")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = Gauge("repro_level", "level", labels={"tier": "memory"})
    g.set(7)
    g.inc()
    g.dec(3)
    assert g.value == 5
    assert list(g.samples()) == [("repro_level", {"tier": "memory"}, 5)]


def test_histogram_fixed_buckets_and_quantiles():
    h = Histogram("repro_lat_seconds", buckets=(0.001, 0.01, 0.1))
    for _ in range(100):
        h.observe(0.0004)
    for _ in range(10_000):
        h.observe(0.05)
    h.observe(5.0)  # overflow bucket
    # storage is bounded by construction: one int per bucket + overflow
    assert len(h._counts) == 4
    assert h.count == 10_101
    assert h.quantile(0.5) > 0.0, "quantiles must be nonzero with samples"
    assert 0.01 <= h.quantile(0.5) <= 0.1
    # the open-ended bucket is capped at the observed max
    assert h.quantile(0.9999) <= 5.0


def test_histogram_first_bucket_quantile_nonzero():
    h = Histogram("repro_fast_seconds")
    for _ in range(8):
        h.observe(1e-5)  # far below the first bucket bound
    assert h.quantile(0.5) > 0.0
    assert h.quantile(0.95) > 0.0


def test_endpoint_stats_dict_shape():
    stats = EndpointStats(Histogram("repro_http_request_seconds"))
    stats.record(0.002, ok=True)
    stats.record(0.004, ok=False)
    d = stats.as_dict()
    assert d["requests"] == 2
    assert d["errors"] == 1
    for k in ("p50_ms", "p95_ms", "p99_ms"):
        assert d[k] > 0.0


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c1 = reg.counter("repro_hits", "hits", tier="memory")
    c2 = reg.counter("repro_hits", tier="memory")
    assert c1 is c2
    # same name, different labels = a distinct series
    assert reg.counter("repro_hits", tier="disk") is not c1
    with pytest.raises(ValueError):
        reg.gauge("repro_hits", tier="memory")
    with pytest.raises(ValueError):
        reg.counter("bad name with spaces")


def test_prometheus_exposition_round_trips():
    reg = MetricsRegistry()
    reg.counter("repro_derivations_total", "count").inc(3)
    reg.histogram("repro_lat_seconds", "latency",
                  endpoint="derive").observe(0.002)
    text = reg.prometheus({"store": {"hits": 5, "nested": {"rate": 0.5}},
                           "name": "skipped-string"})
    series = parse_prometheus(text)
    assert series["repro_derivations_total"] == 3
    assert series["repro_lat_seconds_count{endpoint=\"derive\"}"] == 1
    assert series["repro_store_hits"] == 5
    assert series["repro_store_nested_rate"] == 0.5
    assert not any("skipped-string" in k for k in series)
    assert "# TYPE repro_lat_seconds histogram" in text


def test_prometheus_label_escaping():
    reg = MetricsRegistry()
    reg.counter("repro_c", "c", node='we"ird\nvalue\\x').inc()
    text = reg.prometheus()
    assert '\\"' in text and "\\n" in text and "\\\\" in text
    parse_prometheus(text)  # must still parse


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError):
        parse_prometheus("repro_ok 1\njustonetoken")
    with pytest.raises(ValueError):
        parse_prometheus("repro_bad notanumber")


def test_flatten_payload_numeric_leaves_only():
    flat = dict(flatten_payload({
        "a": 1, "b": {"c": 2.5, "d": "str", "e": None, "f": [1, 2]},
        "ok": True}, "x"))
    assert flat == {"x_a": 1.0, "x_b_c": 2.5, "x_ok": 1.0}


# ---------------------------------------------------------------------------
# Trace primitives
# ---------------------------------------------------------------------------


def test_valid_trace_id():
    assert valid_trace_id(new_trace_id())
    assert valid_trace_id("ab" * 4)
    assert not valid_trace_id("AB" * 16)      # uppercase
    assert not valid_trace_id("xyz")          # short + non-hex
    assert not valid_trace_id("ab" * 40)      # too long
    assert not valid_trace_id(None)
    assert not valid_trace_id(123)


def test_trace_buffer_ring_bounds():
    buf = TraceBuffer(max_traces=2, max_spans=2)
    for i in range(4):
        buf.record(f"{i:032x}", {"name": f"s{i}"})
    assert len(buf.ids()) == 2
    assert buf.dropped_traces == 2
    tid = buf.ids()[-1]
    buf.record(tid, {"name": "extra1"})
    buf.record(tid, {"name": "extra2"})  # over max_spans
    assert buf.get(tid)["span_count"] == 2
    assert buf.dropped_spans == 1
    stats = buf.stats()
    assert stats["traces"] == 2 and stats["dropped_spans"] == 1


def test_span_noop_without_active_trace():
    with span("orphan", attr=1) as s:
        s["later"] = 2  # writable, but recorded nowhere
    # record_for_meta without a snapshot is also a no-op
    record_for_meta({}, "orphan", 0.1)


def test_span_records_into_active_trace_with_error():
    buf = TraceBuffer()
    token = activate(buf, "ab" * 16)
    try:
        with span("work", tier="disk") as s:
            s["hit"] = True
        with pytest.raises(RuntimeError):
            with span("boom"):
                raise RuntimeError("x")
    finally:
        deactivate(token)
    spans = buf.get("ab" * 16)["spans"]
    assert spans[0]["name"] == "work"
    assert spans[0]["tier"] == "disk" and spans[0]["hit"] is True
    assert spans[0]["duration_ms"] >= 0.0
    assert spans[1]["error"] == "RuntimeError"
    # deactivated: spans no longer record
    with span("after"):
        pass
    assert buf.get("ab" * 16)["span_count"] == 2


def test_observability_disabled_skips_tracing_not_metrics():
    obs = Observability(mode="x", enabled=False)
    assert obs.begin_request("ab" * 16) is None
    obs.end_request(None, "derive", 0.01, True)
    assert obs.traces.ids() == []
    obs.observe("derive", 0.01, True)  # metrics still flow
    assert obs.http_dict()["derive"]["requests"] == 1


# ---------------------------------------------------------------------------
# Frontend surface: parity, healthz, Prometheus, single-node traces
# ---------------------------------------------------------------------------


def _exercise(url: str):
    post_json(f"{url}/v1/derive",
              {"domain": "tri2d", "model": MODEL, "stage": 100})
    get_json(f"{url}/healthz")
    get_json(f"{url}/metrics")


def test_metrics_parity_between_frontends(tmp_path):
    with MappingHTTPServer(make_service(tmp_path, "t")) as threaded, \
            AsyncMappingHTTPServer(make_service(tmp_path, "a")) as aio:
        for server in (threaded, aio):
            _exercise(server.url)
        # endpoint stats land in a finally after response bytes: poll until
        # both frontends have recorded all three exercised endpoints
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            mt = threaded.metrics()
            ma = aio.metrics()
            if set(ma["http"]) == set(mt["http"]) == {
                    "derive", "healthz", "metrics"}:
                break
            time.sleep(0.02)
    # identical top-level key set, modulo the async-only "aio" alias
    assert set(ma) - {"aio"} == set(mt)
    # the shared frontend section carries the same keys too
    assert set(ma["frontend"]) - {"aio"} == set(mt["frontend"])
    assert mt["frontend"]["mode"] == "threaded"
    assert ma["frontend"]["mode"] == "async"
    # and the http sections saw the same endpoints with the same shape
    assert set(ma["http"]) == set(mt["http"])
    for section in (mt, ma):
        d = section["http"]["derive"]
        assert d["requests"] >= 1 and d["p50_ms"] > 0.0


@pytest.mark.parametrize("cls", [MappingHTTPServer, AsyncMappingHTTPServer])
def test_healthz_reports_uptime_and_mode(tmp_path, cls):
    with cls(make_service(tmp_path)) as server:
        _, _, raw = get_json(f"{server.url}/healthz")
        hz = json.loads(raw)
    assert hz["status"] == "ok"
    assert hz["mode"] in ("threaded", "async")
    assert hz["uptime_seconds"] > 0.0
    assert hz["started_unix"] <= time.time()
    assert hz["backend_names"] == []


@pytest.mark.parametrize("cls", [MappingHTTPServer, AsyncMappingHTTPServer])
def test_prometheus_endpoint_is_valid_exposition(tmp_path, cls):
    with cls(make_service(tmp_path)) as server:
        _exercise(server.url)
        _, headers, raw = get_json(
            f"{server.url}/metrics?format=prometheus")
    assert headers["Content-Type"].startswith("text/plain")
    series = parse_prometheus(raw.decode())
    assert any(k.startswith('repro_http_request_seconds_bucket{')
               for k in series)
    assert any(k.startswith("repro_service_") for k in series)
    # JSON /metrics numeric leaves are all scrapeable
    assert "repro_store_hits" in series


@pytest.mark.parametrize("cls", [MappingHTTPServer, AsyncMappingHTTPServer])
def test_trace_roundtrip_single_node(tmp_path, cls):
    tid = "cd" * 16
    with cls(make_service(tmp_path)) as server:
        status, headers, _ = post_json(
            f"{server.url}/v1/derive",
            {"domain": "tri2d", "model": MODEL, "stage": 100},
            headers={TRACE_HEADER: tid})
        assert status == 200
        # the trace ID echoes on the response
        assert headers[TRACE_HEADER] == tid
        trace = wait_for_span(server.url, tid, "derive")
        names = [sp["name"] for sp in trace["spans"]]
        # cold derive: local tier probes + inference + validation happened
        # under this request's trace
        assert "store_memory" in names
        assert "inference" in names
        assert "validation" in names
        assert trace["trace_id"] == tid
        assert trace["node"] == server.url
        # a hot repeat records a fresh (server-minted) trace too
        status, headers, _ = post_json(
            f"{server.url}/v1/derive",
            {"domain": "tri2d", "model": MODEL, "stage": 100})
        minted = headers[TRACE_HEADER]
        assert valid_trace_id(minted) and minted != tid
        _, _, raw = get_json(f"{server.url}/v1/traces")
        listing = json.loads(raw)
        assert tid in listing["traces"]
        assert listing["stats"]["max_traces"] > 0


def test_malformed_trace_header_gets_fresh_id(tmp_path):
    with MappingHTTPServer(make_service(tmp_path)) as server:
        _, headers, _ = post_json(
            f"{server.url}/v1/derive",
            {"domain": "tri2d", "model": MODEL, "stage": 100},
            headers={TRACE_HEADER: "NOT-HEX-AT-ALL!"})
        echoed = headers[TRACE_HEADER]
        assert valid_trace_id(echoed)
        assert echoed != "NOT-HEX-AT-ALL!"


@pytest.mark.parametrize("cls", [MappingHTTPServer, AsyncMappingHTTPServer])
def test_tracing_disabled_serves_without_traces(tmp_path, cls):
    tid = "ef" * 16
    with cls(make_service(tmp_path), observability=False) as server:
        status, headers, _ = post_json(
            f"{server.url}/v1/derive",
            {"domain": "tri2d", "model": MODEL, "stage": 100},
            headers={TRACE_HEADER: tid})
        assert status == 200
        assert TRACE_HEADER not in headers
        with pytest.raises(urllib.error.HTTPError):
            get_json(f"{server.url}/v1/trace/{tid}")
        # metrics keep flowing
        m = server.metrics()
        assert m["http"]["derive"]["requests"] == 1
        assert m["frontend"]["observability"] is False


def test_trace_unknown_id_is_404(tmp_path):
    with MappingHTTPServer(make_service(tmp_path)) as server:
        with pytest.raises(urllib.error.HTTPError) as exc:
            get_json(f"{server.url}/v1/trace/{'aa' * 16}")
        assert exc.value.code == 404


# ---------------------------------------------------------------------------
# Acceptance: one trace ID across a 3-node ring (forward hop + peer pull)
# ---------------------------------------------------------------------------


def boot_node(tmp_path, name: str, seeds, async_frontend: bool = False):
    svc = make_service(tmp_path, name)
    server = (AsyncMappingHTTPServer(svc).start() if async_frontend
              else MappingHTTPServer(svc).start())
    cluster = ClusterMembership(
        server.url, seeds=seeds or (), replicas=2, vnodes=64,
        heartbeat_interval=0.15, down_after=1.0, sync_interval=0.3,
        probe_timeout=1.0)
    server.attach_cluster(cluster)
    return server


def wait_fleet(servers, timeout=10.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if all(len(s.cluster.live_peers()) == len(servers) - 1
               for s in servers):
            return
        time.sleep(0.05)
    raise AssertionError("fleet never converged")


def test_one_trace_spans_forward_and_peer_pull(tmp_path):
    """The PR's acceptance scenario: a single client-injected trace ID
    covers the forwarded derive AND the peer pull it triggers —
    ingress node records the forward hop, the owner records admission +
    store probes + store_peer, and the pulled-from sibling records its
    replicate_pull, all retrievable per node from GET /v1/trace/<id>."""
    servers = []
    try:
        n0 = boot_node(tmp_path, "n0", seeds=None)
        servers.append(n0)
        for name in ("n1", "n2"):
            servers.append(boot_node(tmp_path, name, seeds=[n0.url]))
        wait_fleet(servers)

        # derive once so the cell exists on its 2 owners; learn the key
        res = RemoteMappingService(servers[0].url).derive(
            "gasket2d", MODEL, 100)
        key = res.cache_key
        deadline = time.monotonic() + 5.0
        owners = []
        while time.monotonic() < deadline:
            owners = [s for s in servers if key in s.service.store]
            if len(owners) == 2:
                break
            time.sleep(0.05)
        assert len(owners) == 2, f"expected 2 replicas, got {len(owners)}"
        non_owner = next(s for s in servers if s not in owners)
        # the forwarder hops to its first replica peer — evict exactly that
        # node's copy so the forwarded derive must peer-pull
        by_url = {s.url: s for s in servers}
        primary = by_url[non_owner.cluster.replica_peers(key)[0]]
        assert primary in owners
        sibling = next(s for s in owners if s is not primary)

        req = urllib.request.Request(
            f"{primary.url}/v1/artifact/{key}", method="DELETE")
        with urllib.request.urlopen(req, timeout=10):
            pass
        assert key not in primary.service.store

        # ONE trace ID through the whole story: non-owner forwards to the
        # primary owner, which misses locally and pulls from its sibling
        tid = new_trace_id()
        status, headers, payload = post_json(
            f"{non_owner.url}/v1/derive",
            {"domain": "gasket2d", "model": MODEL, "stage": 100},
            headers={TRACE_HEADER: tid})
        assert status == 200
        assert headers[TRACE_HEADER] == tid
        assert payload["key"] == key

        # ingress node: request-level span + the forward hop it took
        ingress = wait_for_span(non_owner.url, tid, "derive")
        fwd = next(sp for sp in ingress["spans"] if sp["name"] == "forward")
        assert fwd["owner"] == primary.url

        # owner: admission (derive), local tier probes, then the peer pull
        owner_trace = wait_for_span(primary.url, tid, "derive")
        names = [sp["name"] for sp in owner_trace["spans"]]
        assert "store_memory" in names and "store_disk" in names
        pull = next(sp for sp in owner_trace["spans"]
                    if sp["name"] == "store_peer")
        assert pull["hit"] is True
        assert pull["peer"] == sibling.url

        # pulled-from sibling: its replicate_pull ran under the same ID
        sib = wait_for_span(sibling.url, tid, "replicate_pull")
        assert sib["trace_id"] == tid

        # and the client-side fetchers see the same shards
        client = RemoteMappingService(non_owner.url)
        assert client.trace(tid)["trace_id"] == tid
        assert tid in client.traces(base=primary.url)["traces"]
    finally:
        for s in servers:
            s.close()


# ---------------------------------------------------------------------------
# Loadgen / SLO harness
# ---------------------------------------------------------------------------


def _loadgen():
    import importlib
    import pathlib
    import sys

    root = pathlib.Path(__file__).resolve().parent.parent
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    return importlib.import_module("benchmarks.loadgen")


def test_synth_trace_zipf_and_determinism():
    lg = _loadgen()
    spec = lg.LoadSpec(requests=400, cells=8, zipf_s=1.3, seed=7,
                       trace_sample=0.25)
    t1, t2 = lg.synth_trace(spec), lg.synth_trace(spec)
    assert t1 == t2, "same seed must give the same trace"
    assert len(t1) == 400
    cells = lg.synth_cells(spec)
    counts = {c: 0 for c in cells}
    for op in t1:
        counts[op["cell"]] += 1
    # zipf skew: the hottest cell dominates the coldest
    assert counts[cells[0]] > counts[cells[-1]] * 2
    traced = [op for op in t1 if "trace_id" in op]
    assert traced and all(valid_trace_id(op["trace_id"]) for op in traced)
    assert lg.synth_trace(lg.LoadSpec(requests=400, seed=8)) != t1


def test_zipf_weights_normalized_and_skewed():
    lg = _loadgen()
    w = lg.zipf_weights(10, 1.1)
    assert abs(sum(w) - 1.0) < 1e-9
    assert w[0] > w[-1]
    assert w == sorted(w, reverse=True)


def test_arrival_offsets_pacing_and_bursts():
    lg = _loadgen()
    assert lg.arrival_offsets(lg.LoadSpec(rate=None)) is None
    spec = lg.LoadSpec(requests=20, rate=100.0, burst_every=0.05,
                       burst_size=4)
    offsets = lg.arrival_offsets(spec)
    assert len(offsets) == 20
    assert offsets == sorted(offsets)
    # bursts: some consecutive arrivals share an offset
    gaps = [b - a for a, b in zip(offsets, offsets[1:])]
    assert any(g == 0.0 for g in gaps)


def test_slo_report_and_check():
    lg = _loadgen()
    records = [
        {"op": "derive", "ok": True, "shed": False, "seconds": 0.010,
         "wall_seconds": 1.0},
        {"op": "derive", "ok": True, "shed": False, "seconds": 0.020,
         "wall_seconds": 1.0},
        {"op": "derive", "ok": False, "shed": True, "seconds": 0.001,
         "wall_seconds": 1.0},
        {"op": "evaluate", "ok": False, "shed": False, "seconds": 0.500,
         "error": "X", "wall_seconds": 1.0},
    ]
    report = lg.slo_report(records, lg.LoadSpec(requests=4))
    assert report["requests"] == 4
    assert report["sheds"] == 1 and report["errors"] == 1
    assert report["shed_rate"] == 0.25 and report["error_rate"] == 0.25
    assert report["p99_ms"] == pytest.approx(500.0)
    assert report["per_op"]["derive"]["requests"] == 3
    assert report["per_op"]["derive"]["sheds"] == 1
    assert lg.check_slo(report, None, None, None) == []
    violations = lg.check_slo(report, slo_p99_ms=100.0, max_shed_rate=0.0,
                              max_error_rate=0.1)
    assert len(violations) == 3
    assert lg.check_slo(report, 1000.0, 0.5, 0.5) == []


def test_ramp_finds_knee_and_bounds_accepted_p99():
    """The ramp steps the offered rate geometrically, stops at the first
    shedding step, calls the last shed-free step the knee, and reports the
    p99 of accepted requests only — sheds must not pollute the latency
    bound they exist to protect."""
    lg = _loadgen()

    def fake_runner(urls, spec):
        capacity = 100.0  # the fleet "sheds" past this offered rate
        n = spec.requests
        sheds = int(n * 0.3) if spec.rate > capacity else 0
        records = []
        for i in range(n):
            shed = i < sheds
            records.append({"op": "derive", "ok": not shed, "shed": shed,
                            "seconds": 0.001 if shed else 0.020,
                            "wall_seconds": 1.0})
        return records, lg.slo_report(records, spec)

    report = lg.ramp(["http://x"], lg.LoadSpec(requests=50),
                     start_rate=25.0, step_factor=2.0, max_steps=8,
                     runner=fake_runner)
    assert [s["offered_rps"] for s in report["steps"]] \
        == [25.0, 50.0, 100.0, 200.0]
    assert report["saturated"]
    assert report["steps"][-1]["sheds"] > 0
    assert report["knee_offered_rps"] == 100.0
    assert report["knee_goodput_rps"] == pytest.approx(50.0)
    # accepted p99 excludes the 1ms sheds on the saturated step
    assert report["accepted_p99_ms"] == pytest.approx(20.0)

    # a fleet that never sheds reports an unsaturated ramp, knee at the top
    calm = lg.ramp(["http://x"], lg.LoadSpec(requests=20),
                   start_rate=10.0, step_factor=2.0, max_steps=3,
                   runner=lambda u, s: fake_runner(
                       u, dataclasses.replace(s, rate=1.0)))
    assert not calm["saturated"]
    assert len(calm["steps"]) == 3
    assert calm["knee_offered_rps"] == 40.0


def test_ramp_live_smoke(tmp_path):
    """End-to-end ramp against one live node: well-formed steps whether or
    not the node saturates at these tiny rates."""
    lg = _loadgen()
    spec = lg.LoadSpec(requests=20, concurrency=4, cells=4,
                       mix={"derive": 1.0})
    with AsyncMappingHTTPServer(make_service(tmp_path)) as server:
        report = lg.ramp([server.url], spec, start_rate=200.0, max_steps=2)
    assert 1 <= len(report["steps"]) <= 2
    assert report["accepted_p99_ms"] > 0.0
    for step in report["steps"]:
        assert step["accepted"] + step["sheds"] + step["errors"] \
            >= spec.requests - step["errors"]
        assert step["goodput_rps"] <= step["achieved_rps"] + 1e-9


def test_loadgen_replay_against_live_node(tmp_path):
    lg = _loadgen()
    spec = lg.LoadSpec(requests=30, concurrency=4, cells=4,
                       trace_sample=0.5,
                       mix={"derive": 0.8, "artifact": 0.2})
    with AsyncMappingHTTPServer(make_service(tmp_path)) as server:
        records, report = lg.run([server.url], spec)
        # traced derives are retrievable from the node they hit
        traced = [r for r in records if r.get("trace_id")]
        assert traced
        wait_for_span(server.url, traced[0]["trace_id"], "derive")
    assert report["requests"] == 30
    assert report["errors"] == 0 and report["sheds"] == 0
    assert report["p99_ms"] >= report["p50_ms"] > 0.0
    assert report["throughput_rps"] > 0.0
    ops = {r["op"] for r in records}
    assert "derive" in ops
