"""Pipeline: prompt building, synthesis rules, mock backend replay, energy."""
import pytest

from repro.core import paper_tables as pt
from repro.core import synthesis
from repro.core.backends import (
    CODE_TEMPLATES, MockLLMBackend, build_prompt, mock_behavior,
)
from repro.core.complexity import classify
from repro.core.domains import DOMAINS
from repro.core.energy import (
    amortization, estimate_bounding_box, estimate_mapped, points_per_joule,
)
from repro.core.maps import VARIANT_MAPS
from repro.core.pipeline import derive_mapping


def test_prompt_contains_rules_and_points():
    p = build_prompt(DOMAINS["tri2d"], 20)
    assert "map_to_coordinates" in p
    assert "0 -> (0, 0)" in p and "2 -> (1, 1)" in p
    assert p.count("->") >= 20


def test_synthesis_rejects_syntax_error():
    with pytest.raises(synthesis.SynthesisError):
        synthesis.synthesize("def map_to_coordinates(n:\n  return")


def test_synthesis_rejects_wrong_name():
    with pytest.raises(synthesis.SynthesisError):
        synthesis.synthesize("def f(n):\n    return (n, n)\n")


def test_synthesis_rejects_forbidden_import():
    code = "import os\ndef map_to_coordinates(n):\n    return (n, n)\n"
    with pytest.raises(synthesis.SynthesisError):
        synthesis.synthesize(code)


def test_synthesis_flags_hardcoded_chains():
    code = ("def map_to_coordinates(n):\n"
            + "".join(f"    if n == {i}:\n        return ({i}, 0)\n"
                      for i in range(8))
            + "    return (n, 0)\n")
    s = synthesis.synthesize(code)
    assert any("hardcoded" in v for v in s.rule_violations)


def test_synthesis_sandbox_blocks_os_access():
    code = ("def map_to_coordinates(n):\n"
            "    __import__('os').system('true')\n"
            "    return (n, n)\n")
    with pytest.raises(synthesis.SynthesisError):
        synthesis.synthesize(code)


@pytest.mark.parametrize("dom_logic", sorted(CODE_TEMPLATES))
def test_all_code_templates_are_perfect(dom_logic):
    dom, logic = dom_logic
    s = synthesis.synthesize(CODE_TEMPLATES[dom_logic])
    gt = DOMAINS[dom].enumerate_points(3000)
    for lam in (0, 1, 2, 17, 999, 2999):
        assert tuple(s.fn(lam)) == tuple(gt[lam]), (dom, logic, lam)
    with pytest.raises(ValueError):
        s.fn(-1)


def test_mock_backend_replays_nc_cells():
    behavior, code = mock_behavior("gasket2d", "Qw3:235b", 20)
    assert behavior == "noncompiling"
    with pytest.raises(synthesis.SynthesisError):
        synthesis.synthesize(code)


def test_mock_backend_energy_scales_with_cot():
    r1 = MockLLMBackend("R1:70b").generate(
        "x" * 400, meta={"domain": "tri2d", "stage": 20})
    lla = MockLLMBackend("Lla3.3:70b").generate(
        "x" * 400, meta={"domain": "tri2d", "stage": 20})
    assert r1.joules > lla.joules  # CoT reasoning penalty (Sec. V.B)


def test_derive_mapping_perfect_cell():
    res = derive_mapping(DOMAINS["tri2d"], MockLLMBackend("OSS:120b"), 20,
                         n_validate=5000)
    assert res.perfect and res.complexity_class == "O(1)"
    am = res.amortization()
    assert am.runs_to_break_even < 100


@pytest.mark.parametrize("dom,logic,expected", [
    ("tri2d", "analytical", "O(1)"),
    ("tri2d", "binsearch", "O(log N)"),
    ("pyramid3d", "linear", "O(N^1/3)"),
    ("gasket2d", "bitwise", "O(log N)"),
    ("menger3d", "bitwise", "O(log N)"),
])
def test_complexity_classification(dom, logic, expected):
    assert classify(VARIANT_MAPS[(dom, logic)])["class"] == expected


def test_paper_accuracy_tables_complete():
    for dom, table in pt.ACCURACY.items():
        assert set(table) == set(pt.MODELS), dom
        for rows in table.values():
            assert len(rows) == 3


def test_energy_model_matches_paper_rows():
    """Calibrated model must reproduce Table VIII/IX anchor times exactly."""
    tri = DOMAINS["tri2d"]
    est = estimate_mapped(tri, "analytical", 500_000_000)
    assert est.time_ms == pytest.approx(1.46, rel=0.01)
    est_bin = estimate_mapped(tri, "binsearch", 500_000_000)
    assert est_bin.time_ms == pytest.approx(14.86, rel=0.01)
    pyr = DOMAINS["pyramid3d"]
    assert estimate_mapped(pyr, "linear", 500_000_000).time_ms == \
        pytest.approx(117.03, rel=0.01)
    bb = estimate_bounding_box(tri, 500_000_000)
    assert bb.wasted_blocks > 0 and bb.time_ms > 100


def test_amortization_fractal_first_run():
    """Paper: fractal-domain savings amortize the inference instantly."""
    am = amortization(DOMAINS["sierpinski3d"], "bitwise",
                      inference_j=5000.0)
    assert am.runs_to_break_even < 1.0
    assert am.speedup > 1000 and am.energy_reduction > 1000


def test_points_per_joule():
    assert points_per_joule(1_000_000, 100.0) == 10_000.0
    assert points_per_joule(1, 0.0) == 0.0
