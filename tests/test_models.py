"""Model-layer tests: per-arch smoke, equivalences, MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from types import SimpleNamespace

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv
from repro.models import transformer as T
from repro.models.common import count_params


def _extra_for(cfg, b, key):
    if cfg.family == "vlm":
        return jax.random.normal(key, (b, cfg.vision_seq, cfg.d_model),
                                 jnp.float32)
    if cfg.family == "audio":
        return jax.random.normal(key, (b, cfg.encoder_seq, cfg.d_model),
                                 jnp.float32)
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one train step, shapes + finiteness."""
    from repro.train import optimizer as opt
    from repro.train.train_step import TrainConfig, make_train_step

    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    assert count_params(params) > 10_000
    b, s = 2, 64
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    extra = _extra_for(cfg, b, jax.random.PRNGKey(2))
    logits = T.forward(params, cfg, tokens, extra)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())

    batch = {"tokens": tokens, "labels": tokens}
    if extra is not None:
        batch["extra"] = extra
    step = make_train_step(cfg, TrainConfig())
    opt_state = opt.init_state(params)
    new_params, new_state, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["step"]) == 1
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b_.astype(jnp.float32))))
                for a, b_ in zip(jax.tree.leaves(new_params),
                                 jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=16.0)  # no-drop => exact match
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    extra = _extra_for(cfg, b, jax.random.PRNGKey(2))
    ref = T.forward(params, cfg, tokens, extra)
    pre, cache = T.prefill(params, cfg, tokens, extra)
    assert float(jnp.max(jnp.abs(pre - ref))) < 5e-4
    nt = jnp.argmax(pre[:, -1:], axis=-1).astype(jnp.int32)
    dec, _ = T.decode_step(params, cfg, nt, cache, extra)
    full = T.forward(params, cfg, jnp.concatenate([tokens, nt], 1), extra)
    assert float(jnp.max(jnp.abs(dec[:, 0] - full[:, -1]))) < 5e-3


def test_param_spec_trees_mirror_params():
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        shapes = jax.eval_shape(lambda k: T.init_params(k, cfg),
                                jax.random.PRNGKey(0))
        specs = T.param_specs(cfg)
        assert (jax.tree.structure(shapes)
                == jax.tree.structure(
                    specs, is_leaf=lambda s: isinstance(s, tuple)))
        jax.tree.map(lambda s, p: None if len(s) == p.ndim
                     else pytest.fail(f"{arch}: {s} vs {p.shape}"),
                     specs, shapes, is_leaf=lambda s: isinstance(s, tuple))


# --- attention ---------------------------------------------------------------


def test_sdpa_chunked_equals_unchunked():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 512, 4, 32))
    k = jax.random.normal(ks[1], (2, 512, 2, 32))
    v = jax.random.normal(ks[2], (2, 512, 2, 32))
    pos = jnp.broadcast_to(jnp.arange(512)[None], (2, 512))
    full = attn._sdpa(q, k, v, 2, pos, chunk=1024)   # single shot
    chunked = attn._sdpa(q, k, v, 2, pos, chunk=128)
    assert float(jnp.max(jnp.abs(full - chunked))) < 1e-5


def test_sdpa_cross_no_mask():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 8, 2, 16))
    k = jax.random.normal(ks[1], (1, 24, 2, 16))
    v = jax.random.normal(ks[2], (1, 24, 2, 16))
    out = attn._sdpa(q, k, v, 2, None)
    assert out.shape == (1, 8, 2, 16)
    assert bool(jnp.isfinite(out).all())


def test_gqa_pallas_paths_match_xla():
    cfg = get_smoke_config("yi-6b").replace(
        attn_impl="pallas_mapped", attn_block=16, pallas_interpret=True,
        rope_theta=10000.0)
    p = attn.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.3
    out_k, _ = attn.gqa_apply(p, cfg, x)
    out_x, _ = attn.gqa_apply(p, cfg.replace(attn_impl="xla"), x)
    assert float(jnp.max(jnp.abs(out_k - out_x))) < 1e-4
    out_bb, _ = attn.gqa_apply(p, cfg.replace(attn_impl="pallas_bb"), x)
    assert float(jnp.max(jnp.abs(out_bb - out_x))) < 1e-4


def test_mla_cache_decode_matches_full():
    cfg = get_smoke_config("deepseek-v2-236b")
    p = attn.mla_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    full, _ = attn.mla_apply(p, cfg, x)
    cache = attn.mla_cache_init(cfg, 2, 32, jnp.float32)
    pre, cache = attn.mla_apply(p, cfg, x[:, :15],
                                positions=jnp.arange(15)[None], cache=cache)
    last, _ = attn.mla_apply(p, cfg, x[:, 15:16],
                             positions=jnp.full((2, 1), 15), cache=cache)
    assert float(jnp.max(jnp.abs(last[:, 0] - full[:, 15]))) < 1e-4


# --- MoE ---------------------------------------------------------------------


def _moe_cfg(**kw):
    base = dict(d_model=32, n_experts=8, moe_top_k=2, expert_d_ff=64,
                n_shared_experts=0, capacity_factor=1.25,
                moe_renormalize=True)
    base.update(kw)
    return SimpleNamespace(**base)


def test_moe_no_drop_matches_dense_computation():
    """With huge capacity, MoE == explicit per-token expert mixture."""
    cfg = _moe_cfg(capacity_factor=100.0)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5
    out = moe_mod.moe_apply(p, cfg, x)

    toks = x.reshape(-1, 32)
    logits = toks @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, e = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    expected = jnp.zeros_like(toks)
    for t in range(toks.shape[0]):
        acc = jnp.zeros((32,))
        for j in range(2):
            ei = int(e[t, j])
            g = jax.nn.silu(toks[t] @ p["gate"][:, ei, :])
            u = toks[t] @ p["up"][:, ei, :]
            acc += w[t, j] * ((g * u) @ p["down"][:, ei, :])
        expected = expected.at[t].set(acc)
    assert float(jnp.max(jnp.abs(out.reshape(-1, 32) - expected))) < 1e-4


def test_moe_capacity_drops_tokens():
    """Tiny capacity must drop assignments (outputs partially zeroed)."""
    cfg = _moe_cfg(capacity_factor=0.2)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32))
    out_small = moe_mod.moe_apply(p, cfg, x)
    out_big = moe_mod.moe_apply(p, _moe_cfg(capacity_factor=100.0), x)
    assert float(jnp.max(jnp.abs(out_small - out_big))) > 1e-4


def test_moe_aux_loss_positive_and_balanced_lower():
    cfg = _moe_cfg()
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    _, aux = moe_mod.moe_apply(p, cfg, x, with_aux=True)
    assert float(aux) >= 1.0  # e * sum(f*P) >= 1 by Cauchy-Schwarz


# --- SSM equivalences --------------------------------------------------------


def test_rwkv_chunked_equals_scan():
    cfg = SimpleNamespace(d_model=64, rwkv_heads=4, rwkv_decay_lora=16,
                          d_ff=128)
    p = rwkv.rwkv_block_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 64)) * 0.5
    xp = jnp.zeros((2, 64))
    st = jnp.zeros((2, 4, 16, 16))
    o1, x1, s1 = rwkv.rwkv_mix_scan(p, cfg, x, xp, st)
    o2, x2, s2 = rwkv.rwkv_mix_chunked(p, cfg, x, xp, st, chunk=32)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5
    assert float(jnp.max(jnp.abs(s1 - s2))) < 1e-4


def test_mamba2_chunked_equals_scan_and_decode():
    cfg = SimpleNamespace(d_model=64, mamba_d_inner=128, ssm_state=16,
                          mamba_heads=4, mamba_conv_width=4)
    p = m2.mamba2_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64)) * 0.5
    o1, s1, _ = m2.mamba2_apply(p, cfg, x, use_scan=True)
    o2, s2, _ = m2.mamba2_apply(p, cfg, x, use_scan=False, chunk=32)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5
    # token-by-token decode matches the parallel form
    st, tail, outs = None, None, []
    for t in range(8):
        ot, st, tail = m2.mamba2_apply(p, cfg, x[:, t:t + 1], state=st,
                                       conv_tail=tail)
        outs.append(ot)
    dec = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - o1[:, :8]))) < 1e-5


def test_full_configs_have_published_dims():
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_experts, c.kv_lora_rank) == \
        (60, 5120, 160, 512)
    c = get_config("qwen3-32b")
    assert c.qk_norm and c.n_heads == 64 and c.d_ff == 25600
    c = get_config("rwkv6-3b")
    assert c.d_model == 2560 and c.attention_type == "none"
    c = get_config("whisper-medium")
    assert c.encoder_layers == 24 and c.decoder_layers == 24
    c = get_config("zamba2-1.2b")
    assert c.ssm_state == 64 and c.hybrid_attn_every == 6
