"""int8 KV-cache quantization (GQA): accuracy bound + size + consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import attention as attn
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ["yi-6b", "qwen3-32b", "granite-8b"])
def test_int8_kv_decode_accuracy(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    ref = T.forward(params, cfg, toks)
    cfgq = cfg.replace(kv_cache_quant=True)
    _, cache = T.prefill(params, cfgq, toks[:, :15])
    assert cache["layers"]["k"].dtype == jnp.int8
    dec, _ = T.decode_step(params, cfgq, toks[:, 15:16], cache)
    rel = float(jnp.max(jnp.abs(dec[:, 0] - ref[:, 15]))) \
        / float(jnp.max(jnp.abs(ref[:, 15])))
    assert rel < 0.03, rel  # int8 noise bound on logits


def test_int8_cache_halves_bytes():
    cfg = get_smoke_config("yi-6b")
    full = T.init_cache(cfg, 2, 128)
    quant = T.init_cache(cfg.replace(kv_cache_quant=True), 2, 128)

    def nbytes(tree):
        return sum(t.size * t.dtype.itemsize for t in jax.tree.leaves(tree))

    # int8 values + fp32 scales: ~(1 + 4/head_dim)/2 of the bf16... the smoke
    # config is fp32, so full cache is 4B/elem vs 1B + scales.
    assert nbytes(quant) < 0.5 * nbytes(full)


def test_quantize_rows_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 2, 16)) * 3.0
    q, s = attn._quantize_rows(x)
    back = q.astype(jnp.float32) * s
    # absmax rounding error <= scale/2 per element
    assert float(jnp.max(jnp.abs(back - x))) <= float(jnp.max(s)) * 0.5 + 1e-6


def test_mla_cache_never_quantizes():
    cfg = get_smoke_config("deepseek-v2-236b").replace(kv_cache_quant=True)
    cache = T.init_cache(cfg, 2, 64)
    assert cache["layers"]["ckv"].dtype != jnp.int8
