"""Networked mapping service end-to-end: HTTP frontend + remote client +
batching/admission — concurrent remote clients share one server-side
derivation and one store, the wire schema round-trips byte-identically, the
EngineBackend serves real prefill/decode inference through POST /v1/derive,
and two servers with disjoint local stores replicate derivations through
the peer tier (one backend inference for the whole fleet)."""
import json
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro.core import pipeline, synthesis
from repro.core.artifact import ArtifactCache
from repro.core.backends import EngineBackend, LLMResponse, MockLLMBackend
from repro.core.domains import DOMAINS
from repro.core.store import PeerStore, build_store
from repro.serving import (
    AdmissionError, BatchingBackend, MappingHTTPServer, MappingService,
    RemoteMappingService, RemoteServiceError, batching_factory,
)

MODEL = "OSS:120b"


class CountingBackend:
    """Thread-safe MockLLMBackend wrapper counting `generate` calls, with a
    small sleep so concurrent requests genuinely overlap."""

    def __init__(self, model: str, delay: float = 0.05):
        self._inner = MockLLMBackend(model)
        self.name = self._inner.name
        self.calls = 0
        self.delay = delay
        self._mu = threading.Lock()

    @property
    def cache_fingerprint(self):
        return self._inner.cache_fingerprint

    def generate(self, prompt, *, meta):
        with self._mu:
            self.calls += 1
        time.sleep(self.delay)
        return self._inner.generate(prompt, meta=meta)


def shared_factory():
    bank: dict[str, CountingBackend] = {}
    mu = threading.Lock()

    def factory(model: str) -> CountingBackend:
        with mu:
            if model not in bank:
                bank[model] = CountingBackend(model)
            return bank[model]

    factory.bank = bank
    return factory


def make_server(tmp_path, factory, **kw):
    kw.setdefault("n_validate", 2000)
    kw.setdefault("sample_every", 1)
    svc = MappingService(cache=ArtifactCache(tmp_path),
                         backend_factory=factory, **kw)
    return MappingHTTPServer(svc)


# ---------------------------------------------------------------------------
# Acceptance: two concurrent remote clients, one backend inference
# ---------------------------------------------------------------------------


def test_two_concurrent_clients_one_inference(tmp_path):
    """Two RemoteMappingService clients racing on one (domain, model, stage):
    exactly one backend inference, byte-identical artifact records for both,
    and /metrics reports the coalesced/cached resolution."""
    factory = shared_factory()
    with make_server(tmp_path, factory) as server:
        out = {}
        mu = threading.Lock()

        def client(tag):
            c = RemoteMappingService(server.url)
            res = c.derive("tri2d", MODEL, 20)
            with mu:
                out[tag] = res

        threads = [threading.Thread(target=client, args=(t,)) for t in "ab"]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        assert factory.bank[MODEL].calls == 1  # exactly one inference
        a, b = out["a"], out["b"]
        assert a.cache_key == b.cache_key
        assert a.artifact is not None and b.artifact is not None
        assert (json.dumps(a.artifact.to_record(), sort_keys=True) ==
                json.dumps(b.artifact.to_record(), sort_keys=True))

        metrics = RemoteMappingService(server.url).metrics()
        svc = metrics["service"]
        assert svc["requests"] == 2
        assert svc["derivations"] == 1
        assert svc["coalesced"] + svc["cache_hits"] == 1  # the reported hit
        assert svc["cache_hit_ratio"] == pytest.approx(0.5)
        assert metrics["http"]["derive"]["requests"] == 2
        assert metrics["http"]["derive"]["p95_ms"] > 0


def test_engine_backend_served_map_validates(tmp_path):
    """EngineBackend through POST /v1/derive on a smoke config: real
    prefill/decode runs server-side, and the returned map passes
    stage_validation."""
    def factory(model):
        return EngineBackend(model, max_new_tokens=4)

    with make_server(tmp_path, factory) as server:
        client = RemoteMappingService(server.url)
        res = client.derive("tri2d", MODEL, 20)
    assert res.compiled and res.source is not None
    assert res.response.tokens_out == 4  # genuine decode steps
    # re-validate the served source through the pipeline's own stage
    req = pipeline.prepare_request(
        DOMAINS["tri2d"], EngineBackend(MODEL, max_new_tokens=4), 20,
        n_validate=2000, sample_every=1)
    assert req.key == res.cache_key  # same content address client-side
    rep, cls = pipeline.stage_validation(
        req, synthesis.synthesize(res.source))
    assert rep.ordered == 1.0
    assert cls is not None


# ---------------------------------------------------------------------------
# Batching / admission
# ---------------------------------------------------------------------------


class BatchRecorder:
    """Mock backend exposing generate_batch, recording group sizes."""

    def __init__(self, model: str, delay: float = 0.05):
        self._inner = MockLLMBackend(model)
        self.name = model
        self.batch_sizes = []
        self.delay = delay
        self._mu = threading.Lock()

    @property
    def cache_fingerprint(self):
        return self._inner.cache_fingerprint

    def generate(self, prompt, *, meta):
        return self.generate_batch([prompt], [meta])[0]

    def generate_batch(self, prompts, metas):
        with self._mu:
            self.batch_sizes.append(len(prompts))
        time.sleep(self.delay)
        return [self._inner.generate(p, meta=m)
                for p, m in zip(prompts, metas)]


def test_batching_groups_concurrent_same_model_derives(tmp_path):
    """Concurrent derive requests for *different* cells on one model are
    admitted as one batched backend call (coalescing handles same-cell)."""
    inner = {}

    def base_factory(model):
        return inner.setdefault(model, BatchRecorder(model))

    factory = batching_factory(base_factory, max_batch=8, max_wait=0.25)
    svc = MappingService(cache=ArtifactCache(tmp_path),
                         backend_factory=factory, n_validate=2000,
                         sample_every=1)
    with MappingHTTPServer(svc) as server:
        cells = [("tri2d", 20), ("tri2d", 50), ("gasket2d", 20),
                 ("gasket2d", 50), ("carpet2d", 20), ("msimplex3", 20)]
        results = {}
        mu = threading.Lock()

        def one(domain, stage):
            res = RemoteMappingService(server.url).derive(domain, MODEL, stage)
            with mu:
                results[(domain, stage)] = res

        threads = [threading.Thread(target=one, args=c) for c in cells]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    rec = inner[MODEL]
    assert sum(rec.batch_sizes) == len(cells)      # every request served
    assert len(rec.batch_sizes) < len(cells)       # ...in fewer backend calls
    assert max(rec.batch_sizes) > 1
    stats = factory.batchers[MODEL].stats
    assert stats.requests == len(cells)
    assert stats.max_batch_seen == max(rec.batch_sizes)
    assert all(r.compiled for r in results.values())


def test_admission_queue_sheds_load():
    """A full admission queue rejects instead of queueing unboundedly."""
    class Slow:
        name = MODEL

        def generate(self, prompt, *, meta):
            time.sleep(0.5)
            return LLMResponse("x", MODEL, 1, 1, 0.0, 0.0)

    backend = BatchingBackend(Slow(), max_batch=1, max_wait=0.0,
                              max_pending=1)
    errors, oks = [], []
    mu = threading.Lock()

    def caller():
        try:
            backend.generate("p", meta={})
            with mu:
                oks.append(1)
        except AdmissionError:
            with mu:
                errors.append(1)

    first = threading.Thread(target=caller)
    first.start()
    time.sleep(0.15)  # worker is now busy with the first request
    rest = [threading.Thread(target=caller) for _ in range(4)]
    for t in rest:
        t.start()
    for t in [first, *rest]:
        t.join()
    assert errors, "queue never shed load"
    assert oks, "admitted requests must still complete"
    assert backend.stats.rejected == len(errors)
    backend.close()


# ---------------------------------------------------------------------------
# Wire schema + endpoints
# ---------------------------------------------------------------------------


def test_wire_roundtrip_preserves_result(tmp_path):
    svc = MappingService(cache=ArtifactCache(tmp_path),
                         backend_factory=MockLLMBackend,
                         n_validate=2000, sample_every=1)
    res = svc.derive("msimplex3", MODEL, 20)
    payload = json.loads(json.dumps(pipeline.wire_from_result(res)))
    back = pipeline.result_from_wire(payload)
    assert back.cache_key == res.cache_key
    assert back.source == res.source
    assert back.report == res.report
    assert back.domainobj.name == "msimplex3"
    assert back.artifact.to_record() == res.artifact.to_record()
    with pytest.raises(ValueError, match="wire version"):
        pipeline.result_from_wire({**payload, "wire": 999})


def test_grid_streams_and_second_client_hits_server_cache(tmp_path):
    factory = shared_factory()
    with make_server(tmp_path, factory) as server:
        c1 = RemoteMappingService(server.url)
        first = [(r.domain, r.stage, r.cache_hit)
                 for r in c1.run_grid(domains=["tri2d", "gasket2d"],
                                      models=[MODEL], stages=[20, 50])]
        assert len(first) == 4 and not any(hit for _, _, hit in first)
        c2 = RemoteMappingService(server.url)
        grid = c2.grid(domains=["tri2d", "gasket2d"], models=[MODEL],
                       stages=[20, 50])
        assert len(grid) == 4
        assert all(r.cache_hit for r in grid.values())
        assert c2.stats.server_cache_hits == 4
        assert factory.bank[MODEL].calls == 4  # nothing re-derived


def test_artifact_miss_is_structured_json(tmp_path):
    """GET /v1/artifact/<key> misses answer with a JSON error body carrying
    the key, under the JSON content type — same envelope as every other
    endpoint, so clients never special-case the miss path."""
    missing = "deadbeef" * 8  # well-formed content address, never stored
    factory = shared_factory()
    with make_server(tmp_path, factory) as server:
        with pytest.raises(urllib.error.HTTPError) as err:
            urllib.request.urlopen(f"{server.url}/v1/artifact/{missing}")
        e = err.value
        assert e.code == 404
        assert e.headers.get("Content-Type") == "application/json"
        body = json.loads(e.read())
        assert body["key"] == missing
        assert missing in body["error"]


def test_store_stats_and_delete_endpoints(tmp_path):
    factory = shared_factory()
    with make_server(tmp_path, factory) as server:
        client = RemoteMappingService(server.url)
        res = client.derive("tri2d", MODEL, 20)

        stats = client.store_stats()
        assert stats["store"]["memory"]["entries"] == 1
        assert stats["usage"]["records"] == 1 and stats["usage"]["bytes"] > 0

        deleted = client.delete_artifact(res.cache_key)
        assert deleted == {"key": res.cache_key, "deleted": True}
        assert client.store_stats()["usage"]["records"] == 0
        with pytest.raises(RemoteServiceError) as gone:
            client.delete_artifact(res.cache_key)  # idempotent via 404
        assert gone.value.status == 404
        with pytest.raises(RemoteServiceError) as miss:
            client.fetch_artifact(res.cache_key)
        assert miss.value.status == 404
        # the cell re-derives rather than serving the deleted record
        again = client.derive("tri2d", MODEL, 20)
        assert not again.cache_hit
        assert factory.bank[MODEL].calls == 2


# ---------------------------------------------------------------------------
# Peer replication: two servers, disjoint local stores, one inference
# ---------------------------------------------------------------------------


def two_servers(tmp_path, factory):
    """A <-> B with disjoint local stores and mutual peer wiring.  B's peer
    tier is wired at construction; A's is attached after B boots (ports are
    ephemeral, so somebody has to go second)."""
    store_a = build_store(root=tmp_path / "a")
    svc_a = MappingService(store=store_a, backend_factory=factory,
                           n_validate=2000, sample_every=1)
    srv_a = MappingHTTPServer(svc_a).start()
    store_b = build_store(root=tmp_path / "b", peers=[srv_a.url])
    svc_b = MappingService(store=store_b, backend_factory=factory,
                           n_validate=2000, sample_every=1)
    srv_b = MappingHTTPServer(svc_b).start()
    store_a.peer = PeerStore([srv_b.url])
    return srv_a, srv_b


def test_two_servers_one_inference_acceptance(tmp_path):
    """The acceptance scenario: derive on A, hit from B — one backend
    inference total across the fleet, verified by both servers' stats; and
    B's repeat is a memory-tier hit with zero disk reads."""
    factory = shared_factory()
    srv_a, srv_b = two_servers(tmp_path, factory)
    try:
        res_a = RemoteMappingService(srv_a.url).derive("carpet2d", MODEL, 100)
        assert not res_a.cache_hit

        # write-back: A pushed its publish to B's local tiers already
        store_b = srv_b.service.store
        assert store_b.load_local(res_a.cache_key) is not None
        assert store_b.disk.path(res_a.cache_key).exists()

        client_b = RemoteMappingService(srv_b.url)
        res_b = client_b.derive("carpet2d", MODEL, 100)
        assert res_b.cache_hit
        assert res_b.source == res_a.source
        assert factory.bank[MODEL].calls == 1          # ONE inference total
        assert srv_b.service.stats.derivations == 0    # B never ran a pipeline

        # hot repeat on B: memory tier, no disk read
        reads = store_b.disk.reads
        assert client_b.derive("carpet2d", MODEL, 100).cache_hit
        assert store_b.disk.reads == reads

        metrics = client_b.metrics()
        assert metrics["service"]["derivations"] == 0
        assert metrics["service"]["cache_hits"] >= 2
        assert metrics["store"]["tiers"]["memory"]["hits"] >= 1
    finally:
        srv_a.close()
        srv_b.close()


def test_peer_read_through_after_local_delete(tmp_path):
    """Delete on B, re-request on B: the record comes back through the peer
    tier (read-through from A) and replicates onto B — still zero extra
    inferences."""
    factory = shared_factory()
    srv_a, srv_b = two_servers(tmp_path, factory)
    try:
        client_b = RemoteMappingService(srv_b.url)
        res = RemoteMappingService(srv_a.url).derive("tri2d", MODEL, 50)
        client_b.delete_artifact(res.cache_key)       # drop B's local copy
        store_b = srv_b.service.store
        assert store_b.load_local(res.cache_key) is None

        res_b = client_b.derive("tri2d", MODEL, 50)
        assert res_b.cache_hit
        assert factory.bank[MODEL].calls == 1
        assert store_b.peer.hits == 1                 # served via peer pull
        assert store_b.load_local(res.cache_key) is not None  # replicated
        # the replication pull endpoint serves the raw record
        rec = client_b.pull_record(res.cache_key)
        assert rec["domain"] == "tri2d" and rec["key"] == res.cache_key
    finally:
        srv_a.close()
        srv_b.close()


def test_replicate_push_rejects_bad_checksum(tmp_path):
    """The push endpoint verifies the record envelope before storing —
    corruption (or a forged record) must not enter through the wire when
    the disk tier would quarantine the same bytes on read."""
    from repro.core.store import finalize_record

    k1, k2, k3, k4 = ("a1" * 32, "b2" * 32, "c3" * 32, "d4" * 32)
    factory = shared_factory()
    with make_server(tmp_path, factory) as server:
        client = RemoteMappingService(server.url)
        good = finalize_record(k1, {"domain": "tri2d", "pad": "x"})
        assert client._call_json(f"/v1/replicate/{k1}", good) == {
            "key": k1, "stored": True}
        assert client.pull_record(k1)["pad"] == "x"

        tampered = {**good, "pad": "y"}  # payload changed, checksum stale
        with pytest.raises(RemoteServiceError) as bad:
            client._call_json(f"/v1/replicate/{k2}", tampered)
        assert bad.value.status == 400
        naked = {"domain": "tri2d", "pad": "z"}  # no envelope at all
        with pytest.raises(RemoteServiceError) as no_env:
            client._call_json(f"/v1/replicate/{k3}", naked)
        assert no_env.value.status == 400
        mismatched_key = finalize_record("e5" * 32, {"domain": "tri2d"})
        with pytest.raises(RemoteServiceError) as wrong_key:
            client._call_json(f"/v1/replicate/{k4}", mismatched_key)
        assert wrong_key.value.status == 400
        for key in (k2, k3, k4):
            with pytest.raises(RemoteServiceError):
                client.pull_record(key)  # nothing landed


def test_wire_keys_cannot_escape_the_store_root(tmp_path):
    """A wire-supplied key becomes a filesystem path component inside the
    store, so anything that is not a sha256 content address is rejected
    with 400 before it touches the store — ``../`` can neither read,
    delete, nor write outside the store root."""
    import http.client

    from repro.core.store import finalize_record

    secret = tmp_path / "secret.json"
    secret.write_text(json.dumps({"outside": "the store"}))
    store = build_store(root=tmp_path / "store")
    svc = MappingService(store=store, backend_factory=shared_factory(),
                         n_validate=2000, sample_every=1)
    with MappingHTTPServer(svc) as server:
        def raw(method, path, body=None):
            # http.client sends the path verbatim (urllib would not let a
            # "../" segment through unmangled)
            conn = http.client.HTTPConnection(server.host, server.port)
            try:
                headers = {"Content-Type": "application/json"} if body else {}
                conn.request(method, path, body=body, headers=headers)
                resp = conn.getresponse()
                return resp.status, json.loads(resp.read())
            finally:
                conn.close()

        evil = "../secret"
        for method, path in (("GET", f"/v1/artifact/{evil}"),
                             ("DELETE", f"/v1/artifact/{evil}"),
                             ("GET", f"/v1/replicate/{evil}")):
            status, body = raw(method, path)
            assert status == 400, (method, path)
            assert "invalid key" in body["error"]
        assert secret.exists()  # nothing deleted it
        assert json.loads(secret.read_text()) == {"outside": "the store"}

        planted = finalize_record("../planted", {"domain": "tri2d"})
        status, body = raw("POST", "/v1/replicate/../planted",
                           json.dumps(planted))
        assert status == 400
        assert not (tmp_path / "planted.json").exists()  # nothing landed


def test_peer_absence_degrades_to_local_derivation(tmp_path):
    """A dead peer is a miss, not an error: the service derives locally and
    the peer tier just counts the failure."""
    factory = shared_factory()
    store = build_store(root=tmp_path, peers=["http://127.0.0.1:9"])
    store.peer.timeout = 0.2
    svc = MappingService(store=store, backend_factory=factory,
                         n_validate=2000, sample_every=1)
    res = svc.derive("gasket2d", MODEL, 20)
    assert res.compiled and svc.stats.derivations == 1
    assert store.peer.errors >= 1
    assert store.peer.push_errors >= 1  # write-back also failed quietly


def test_artifact_endpoint_and_error_codes(tmp_path):
    factory = shared_factory()
    with make_server(tmp_path, factory) as server:
        client = RemoteMappingService(server.url)
        res = client.derive("tri2d", MODEL, 100)
        fetched = client.fetch_artifact(res.cache_key)
        assert fetched["record"]["domain"] == "tri2d"
        assert fetched["artifact"]["source"] == res.source
        with pytest.raises(RemoteServiceError) as e404:
            client.fetch_artifact("f0" * 32)  # well-formed, never stored
        assert e404.value.status == 404
        with pytest.raises(RemoteServiceError) as ekey:
            client.fetch_artifact("no-such-key")  # malformed address
        assert ekey.value.status == 400
        with pytest.raises(RemoteServiceError) as edom:
            client.derive("not-a-domain", MODEL, 20)
        assert edom.value.status == 404
        with pytest.raises(RemoteServiceError) as ebad:
            client._call_json("/v1/derive", {"domain": "tri2d"})  # no model
        assert ebad.value.status == 400
        assert client.healthy()


def test_client_falls_back_to_local_service(tmp_path):
    """Unreachable server + configured fallback: the request is served
    locally instead of failing."""
    local = MappingService(cache=ArtifactCache(tmp_path),
                           backend_factory=MockLLMBackend,
                           n_validate=2000, sample_every=1)
    client = RemoteMappingService("http://127.0.0.1:9", retries=1,
                                  backoff=0.01, fallback=local)
    res = client.derive("gasket2d", MODEL, 20)
    assert res.compiled
    assert client.stats.fallbacks == 1
    assert client.stats.retries == 1
    assert not client.healthy()
    # grid falls back too, and without a fallback the error surfaces
    assert len(list(client.run_grid(domains=["gasket2d"], models=[MODEL],
                                    stages=[20]))) == 1
    bare = RemoteMappingService("http://127.0.0.1:9", retries=0, backoff=0.01)
    with pytest.raises(RemoteServiceError):
        bare.derive("gasket2d", MODEL, 20)


def test_service_stats_in_process_path(tmp_path):
    """The promoted ServiceStats counters on the plain in-process service:
    requests/errors/cache_hit_ratio move without any HTTP involved."""
    svc = MappingService(cache=ArtifactCache(tmp_path),
                         backend_factory=MockLLMBackend,
                         n_validate=2000, sample_every=1)
    svc.derive("tri2d", MODEL, 20)
    svc.derive("tri2d", MODEL, 20)
    snap = svc.stats_snapshot()
    assert snap.requests == 2
    assert snap.derivations == 1 and snap.cache_hits == 1
    assert snap.cache_hit_ratio == pytest.approx(0.5)
    assert snap.errors == 0
    with pytest.raises(ValueError):
        svc.derive("tri2d", "no-such-model", 20)
    assert svc.stats.errors == 1 and svc.stats.requests == 3
    assert svc.inflight_count() == 0
    d = snap.as_dict()
    assert set(d) >= {"requests", "derivations", "cache_hits", "coalesced",
                      "errors", "cache_hit_ratio"}
