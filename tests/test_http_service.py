"""Networked mapping service end-to-end: HTTP frontend + remote client +
batching/admission — concurrent remote clients share one server-side
derivation and one store, the wire schema round-trips byte-identically, and
the EngineBackend serves real prefill/decode inference through POST
/v1/derive."""
import json
import threading
import time

import pytest

from repro.core import pipeline, synthesis
from repro.core.artifact import ArtifactCache
from repro.core.backends import EngineBackend, LLMResponse, MockLLMBackend
from repro.core.domains import DOMAINS
from repro.serving import (
    AdmissionError, BatchingBackend, MappingHTTPServer, MappingService,
    RemoteMappingService, RemoteServiceError, batching_factory,
)

MODEL = "OSS:120b"


class CountingBackend:
    """Thread-safe MockLLMBackend wrapper counting `generate` calls, with a
    small sleep so concurrent requests genuinely overlap."""

    def __init__(self, model: str, delay: float = 0.05):
        self._inner = MockLLMBackend(model)
        self.name = self._inner.name
        self.calls = 0
        self.delay = delay
        self._mu = threading.Lock()

    @property
    def cache_fingerprint(self):
        return self._inner.cache_fingerprint

    def generate(self, prompt, *, meta):
        with self._mu:
            self.calls += 1
        time.sleep(self.delay)
        return self._inner.generate(prompt, meta=meta)


def shared_factory():
    bank: dict[str, CountingBackend] = {}
    mu = threading.Lock()

    def factory(model: str) -> CountingBackend:
        with mu:
            if model not in bank:
                bank[model] = CountingBackend(model)
            return bank[model]

    factory.bank = bank
    return factory


def make_server(tmp_path, factory, **kw):
    kw.setdefault("n_validate", 2000)
    kw.setdefault("sample_every", 1)
    svc = MappingService(cache=ArtifactCache(tmp_path),
                         backend_factory=factory, **kw)
    return MappingHTTPServer(svc)


# ---------------------------------------------------------------------------
# Acceptance: two concurrent remote clients, one backend inference
# ---------------------------------------------------------------------------


def test_two_concurrent_clients_one_inference(tmp_path):
    """Two RemoteMappingService clients racing on one (domain, model, stage):
    exactly one backend inference, byte-identical artifact records for both,
    and /metrics reports the coalesced/cached resolution."""
    factory = shared_factory()
    with make_server(tmp_path, factory) as server:
        out = {}
        mu = threading.Lock()

        def client(tag):
            c = RemoteMappingService(server.url)
            res = c.derive("tri2d", MODEL, 20)
            with mu:
                out[tag] = res

        threads = [threading.Thread(target=client, args=(t,)) for t in "ab"]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        assert factory.bank[MODEL].calls == 1  # exactly one inference
        a, b = out["a"], out["b"]
        assert a.cache_key == b.cache_key
        assert a.artifact is not None and b.artifact is not None
        assert (json.dumps(a.artifact.to_record(), sort_keys=True) ==
                json.dumps(b.artifact.to_record(), sort_keys=True))

        metrics = RemoteMappingService(server.url).metrics()
        svc = metrics["service"]
        assert svc["requests"] == 2
        assert svc["derivations"] == 1
        assert svc["coalesced"] + svc["cache_hits"] == 1  # the reported hit
        assert svc["cache_hit_ratio"] == pytest.approx(0.5)
        assert metrics["http"]["derive"]["requests"] == 2
        assert metrics["http"]["derive"]["p95_ms"] > 0


def test_engine_backend_served_map_validates(tmp_path):
    """EngineBackend through POST /v1/derive on a smoke config: real
    prefill/decode runs server-side, and the returned map passes
    stage_validation."""
    def factory(model):
        return EngineBackend(model, max_new_tokens=4)

    with make_server(tmp_path, factory) as server:
        client = RemoteMappingService(server.url)
        res = client.derive("tri2d", MODEL, 20)
    assert res.compiled and res.source is not None
    assert res.response.tokens_out == 4  # genuine decode steps
    # re-validate the served source through the pipeline's own stage
    req = pipeline.prepare_request(
        DOMAINS["tri2d"], EngineBackend(MODEL, max_new_tokens=4), 20,
        n_validate=2000, sample_every=1)
    assert req.key == res.cache_key  # same content address client-side
    rep, cls = pipeline.stage_validation(
        req, synthesis.synthesize(res.source))
    assert rep.ordered == 1.0
    assert cls is not None


# ---------------------------------------------------------------------------
# Batching / admission
# ---------------------------------------------------------------------------


class BatchRecorder:
    """Mock backend exposing generate_batch, recording group sizes."""

    def __init__(self, model: str, delay: float = 0.05):
        self._inner = MockLLMBackend(model)
        self.name = model
        self.batch_sizes = []
        self.delay = delay
        self._mu = threading.Lock()

    @property
    def cache_fingerprint(self):
        return self._inner.cache_fingerprint

    def generate(self, prompt, *, meta):
        return self.generate_batch([prompt], [meta])[0]

    def generate_batch(self, prompts, metas):
        with self._mu:
            self.batch_sizes.append(len(prompts))
        time.sleep(self.delay)
        return [self._inner.generate(p, meta=m)
                for p, m in zip(prompts, metas)]


def test_batching_groups_concurrent_same_model_derives(tmp_path):
    """Concurrent derive requests for *different* cells on one model are
    admitted as one batched backend call (coalescing handles same-cell)."""
    inner = {}

    def base_factory(model):
        return inner.setdefault(model, BatchRecorder(model))

    factory = batching_factory(base_factory, max_batch=8, max_wait=0.25)
    svc = MappingService(cache=ArtifactCache(tmp_path),
                         backend_factory=factory, n_validate=2000,
                         sample_every=1)
    with MappingHTTPServer(svc) as server:
        cells = [("tri2d", 20), ("tri2d", 50), ("gasket2d", 20),
                 ("gasket2d", 50), ("carpet2d", 20), ("msimplex3", 20)]
        results = {}
        mu = threading.Lock()

        def one(domain, stage):
            res = RemoteMappingService(server.url).derive(domain, MODEL, stage)
            with mu:
                results[(domain, stage)] = res

        threads = [threading.Thread(target=one, args=c) for c in cells]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    rec = inner[MODEL]
    assert sum(rec.batch_sizes) == len(cells)      # every request served
    assert len(rec.batch_sizes) < len(cells)       # ...in fewer backend calls
    assert max(rec.batch_sizes) > 1
    stats = factory.batchers[MODEL].stats
    assert stats.requests == len(cells)
    assert stats.max_batch_seen == max(rec.batch_sizes)
    assert all(r.compiled for r in results.values())


def test_admission_queue_sheds_load():
    """A full admission queue rejects instead of queueing unboundedly."""
    class Slow:
        name = MODEL

        def generate(self, prompt, *, meta):
            time.sleep(0.5)
            return LLMResponse("x", MODEL, 1, 1, 0.0, 0.0)

    backend = BatchingBackend(Slow(), max_batch=1, max_wait=0.0,
                              max_pending=1)
    errors, oks = [], []
    mu = threading.Lock()

    def caller():
        try:
            backend.generate("p", meta={})
            with mu:
                oks.append(1)
        except AdmissionError:
            with mu:
                errors.append(1)

    first = threading.Thread(target=caller)
    first.start()
    time.sleep(0.15)  # worker is now busy with the first request
    rest = [threading.Thread(target=caller) for _ in range(4)]
    for t in rest:
        t.start()
    for t in [first, *rest]:
        t.join()
    assert errors, "queue never shed load"
    assert oks, "admitted requests must still complete"
    assert backend.stats.rejected == len(errors)
    backend.close()


# ---------------------------------------------------------------------------
# Wire schema + endpoints
# ---------------------------------------------------------------------------


def test_wire_roundtrip_preserves_result(tmp_path):
    svc = MappingService(cache=ArtifactCache(tmp_path),
                         backend_factory=MockLLMBackend,
                         n_validate=2000, sample_every=1)
    res = svc.derive("msimplex3", MODEL, 20)
    payload = json.loads(json.dumps(pipeline.wire_from_result(res)))
    back = pipeline.result_from_wire(payload)
    assert back.cache_key == res.cache_key
    assert back.source == res.source
    assert back.report == res.report
    assert back.domainobj.name == "msimplex3"
    assert back.artifact.to_record() == res.artifact.to_record()
    with pytest.raises(ValueError, match="wire version"):
        pipeline.result_from_wire({**payload, "wire": 999})


def test_grid_streams_and_second_client_hits_server_cache(tmp_path):
    factory = shared_factory()
    with make_server(tmp_path, factory) as server:
        c1 = RemoteMappingService(server.url)
        first = [(r.domain, r.stage, r.cache_hit)
                 for r in c1.run_grid(domains=["tri2d", "gasket2d"],
                                      models=[MODEL], stages=[20, 50])]
        assert len(first) == 4 and not any(hit for _, _, hit in first)
        c2 = RemoteMappingService(server.url)
        grid = c2.grid(domains=["tri2d", "gasket2d"], models=[MODEL],
                       stages=[20, 50])
        assert len(grid) == 4
        assert all(r.cache_hit for r in grid.values())
        assert c2.stats.server_cache_hits == 4
        assert factory.bank[MODEL].calls == 4  # nothing re-derived


def test_artifact_endpoint_and_error_codes(tmp_path):
    factory = shared_factory()
    with make_server(tmp_path, factory) as server:
        client = RemoteMappingService(server.url)
        res = client.derive("tri2d", MODEL, 100)
        fetched = client.fetch_artifact(res.cache_key)
        assert fetched["record"]["domain"] == "tri2d"
        assert fetched["artifact"]["source"] == res.source
        with pytest.raises(RemoteServiceError) as e404:
            client.fetch_artifact("no-such-key")
        assert e404.value.status == 404
        with pytest.raises(RemoteServiceError) as edom:
            client.derive("not-a-domain", MODEL, 20)
        assert edom.value.status == 404
        with pytest.raises(RemoteServiceError) as ebad:
            client._call_json("/v1/derive", {"domain": "tri2d"})  # no model
        assert ebad.value.status == 400
        assert client.healthy()


def test_client_falls_back_to_local_service(tmp_path):
    """Unreachable server + configured fallback: the request is served
    locally instead of failing."""
    local = MappingService(cache=ArtifactCache(tmp_path),
                           backend_factory=MockLLMBackend,
                           n_validate=2000, sample_every=1)
    client = RemoteMappingService("http://127.0.0.1:9", retries=1,
                                  backoff=0.01, fallback=local)
    res = client.derive("gasket2d", MODEL, 20)
    assert res.compiled
    assert client.stats.fallbacks == 1
    assert client.stats.retries == 1
    assert not client.healthy()
    # grid falls back too, and without a fallback the error surfaces
    assert len(list(client.run_grid(domains=["gasket2d"], models=[MODEL],
                                    stages=[20]))) == 1
    bare = RemoteMappingService("http://127.0.0.1:9", retries=0, backoff=0.01)
    with pytest.raises(RemoteServiceError):
        bare.derive("gasket2d", MODEL, 20)


def test_service_stats_in_process_path(tmp_path):
    """The promoted ServiceStats counters on the plain in-process service:
    requests/errors/cache_hit_ratio move without any HTTP involved."""
    svc = MappingService(cache=ArtifactCache(tmp_path),
                         backend_factory=MockLLMBackend,
                         n_validate=2000, sample_every=1)
    svc.derive("tri2d", MODEL, 20)
    svc.derive("tri2d", MODEL, 20)
    snap = svc.stats_snapshot()
    assert snap.requests == 2
    assert snap.derivations == 1 and snap.cache_hits == 1
    assert snap.cache_hit_ratio == pytest.approx(0.5)
    assert snap.errors == 0
    with pytest.raises(ValueError):
        svc.derive("tri2d", "no-such-model", 20)
    assert svc.stats.errors == 1 and svc.stats.requests == 3
    assert svc.inflight_count() == 0
    d = snap.as_dict()
    assert set(d) >= {"requests", "derivations", "cache_hits", "coalesced",
                      "errors", "cache_hit_ratio"}
