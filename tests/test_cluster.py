"""Consistent-hash sharded fleet: ring placement properties, membership
heartbeats + anti-entropy repair, owner forwarding, ring-aware client
routing, and the keep-alive transport's failure behavior.

The acceptance e2e boots a real 3-node ring from one seed and walks the
whole lifecycle: N derives of one cell through different nodes -> exactly
one inference fleet-wide with the record on exactly ``replicas`` nodes;
owner death -> the surviving replica serves and anti-entropy restores the
replication factor; rejoin with a wiped store -> repair refills it — all
with zero additional inferences."""
import hashlib
import threading
import time

import pytest

try:  # prefer real hypothesis; fall back to the deterministic shim
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.core.backends import MockLLMBackend
from repro.core.store import PeerStore, build_store
from repro.serving import (
    ClusterMembership, HashRing, MappingHTTPServer, MappingService,
    RemoteMappingService, RemoteServiceError, RendezvousHash, make_placement,
)

MODEL = "OSS:120b"
N_KEYS = 256


def _keys() -> list[str]:
    return [hashlib.sha256(f"cell-{i}".encode()).hexdigest()
            for i in range(N_KEYS)]


def _await(predicate, timeout: float = 20.0, what: str = "condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(0.05)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# HashRing placement properties
# ---------------------------------------------------------------------------


@settings(max_examples=14, deadline=None)
@given(st.integers(min_value=2, max_value=8))
def test_ring_assignment_deterministic_and_balanced(n_nodes):
    """Key->owner assignment is a pure function of the node set (insertion
    order irrelevant), always yields `replicas` distinct owners, and primary
    ownership stays within 2x of the ideal share across 100+ keys."""
    import random

    nodes = [f"http://node-{j}:80" for j in range(n_nodes)]
    shuffled = list(nodes)
    random.Random(n_nodes).shuffle(shuffled)
    ring = HashRing(nodes, vnodes=128, replicas=2)
    reordered = HashRing(shuffled, vnodes=128, replicas=2)
    counts: dict[str, int] = {u: 0 for u in nodes}
    for key in _keys():
        owners = ring.owners(key)
        assert owners == reordered.owners(key)  # deterministic placement
        assert len(owners) == 2 and len(set(owners)) == 2
        counts[owners[0]] += 1
    ideal = N_KEYS / n_nodes
    assert max(counts.values()) <= 2 * ideal, counts
    assert min(counts.values()) >= ideal / 2, counts


@settings(max_examples=14, deadline=None)
@given(st.integers(min_value=2, max_value=8))
def test_ring_join_leave_remaps_only_adjacent_keys(n_nodes):
    """A join moves ~1/(n+1) of the keys, every moved primary moves *to*
    the new node, and no key acquires a different pre-existing owner — the
    no-full-reshuffle property that makes scaling the fleet cheap.  A
    leave is the exact inverse."""
    nodes = [f"http://node-{j}:80" for j in range(n_nodes)]
    newcomer = f"http://node-{n_nodes}:80"
    before = HashRing(nodes, vnodes=128, replicas=2)
    after = HashRing([*nodes, newcomer], vnodes=128, replicas=2)
    moved = 0
    for key in _keys():
        owners_a, owners_b = before.owners(key), after.owners(key)
        assert set(owners_b) <= set(owners_a) | {newcomer}
        if owners_a[0] != owners_b[0]:
            assert owners_b[0] == newcomer  # primaries only move to the join
            moved += 1
    assert 0 < moved <= 2 * N_KEYS / (n_nodes + 1), moved
    shrunk = HashRing([*nodes, newcomer], vnodes=128, replicas=2)
    shrunk.remove(newcomer)
    assert all(shrunk.owners(k) == before.owners(k) for k in _keys())


def test_ring_edge_shapes():
    ring = HashRing(replicas=3)
    assert ring.owners("ab" * 32) == [] and ring.primary("ab" * 32) is None
    ring.add("http://only:1")
    assert ring.owners("ab" * 32) == ["http://only:1"]  # K > nodes: all of them
    ring.add("http://only:1")  # re-add is a no-op, not duplicate vnodes
    assert len(ring) == 1
    ring.remove("http://only:1")
    assert len(ring) == 0 and "http://only:1" not in ring


def test_weighted_ring_scales_keyspace_share():
    """A node's weight scales its vnode count, so a weight-3 node owns
    roughly 3x the primaries of a weight-1 sibling."""
    nodes = [("http://small:1", 1.0), ("http://big:1", 3.0),
             ("http://small2:1", 1.0)]
    ring = HashRing(nodes, vnodes=128, replicas=2)
    assert ring.weight("http://big:1") == 3.0
    counts = {u: 0 for u, _ in nodes}
    for key in _keys():
        counts[ring.owners(key)[0]] += 1
    # big's ideal share is 3/5 of the keyspace; smalls get 1/5 each
    assert counts["http://big:1"] > 1.8 * counts["http://small:1"]
    assert counts["http://big:1"] > 1.8 * counts["http://small2:1"]
    # malformed weights clamp to 1.0 instead of corrupting the ring
    clamped = HashRing([("http://a:1", -2.0), ("http://b:1", float("nan"))],
                       vnodes=64, replicas=2)
    assert clamped.weight("http://a:1") == 1.0
    assert clamped.weight("http://b:1") == 1.0


def test_rendezvous_placement_properties():
    """Rendezvous hashing behind the same Placement interface: same
    determinism/balance/minimal-disruption contract as the ring, plus the
    weighted share."""
    nodes = [f"http://node-{j}:80" for j in range(5)]
    p1 = RendezvousHash(nodes, replicas=2)
    p2 = RendezvousHash(list(reversed(nodes)), replicas=2)
    counts = {u: 0 for u in nodes}
    for key in _keys():
        owners = p1.owners(key)
        assert owners == p2.owners(key)
        assert len(owners) == 2 and len(set(owners)) == 2
        counts[owners[0]] += 1
    ideal = N_KEYS / len(nodes)
    assert max(counts.values()) <= 2 * ideal, counts
    assert min(counts.values()) >= ideal / 2, counts

    # minimal disruption: a leave only reassigns the leaver's keys
    before = {k: p1.owners(k) for k in _keys()}
    p1.remove(nodes[2])
    for key, owners_a in before.items():
        owners_b = p1.owners(key)
        if nodes[2] not in owners_a:
            assert owners_b == owners_a
        else:
            assert set(owners_b) >= set(owners_a) - {nodes[2]}
    # weighted share
    heavy = RendezvousHash([("http://small:1", 1.0), ("http://big:1", 3.0)],
                           replicas=1)
    primaries = sum(1 for k in _keys()
                    if heavy.owners(k)[0] == "http://big:1")
    assert primaries > N_KEYS * 0.6


def test_make_placement_factory():
    ring = make_placement("ring", ["http://a:1"], vnodes=8, replicas=2)
    rdv = make_placement("rendezvous", ["http://a:1"], replicas=2)
    assert isinstance(ring, HashRing) and isinstance(rdv, RendezvousHash)
    assert ring.kind == "ring" and rdv.kind == "rendezvous"
    with pytest.raises(ValueError):
        make_placement("mulberry", ["http://a:1"])


def test_peer_store_router_scopes_targets():
    """With a router attached, pulls/pushes address the key's owners — not
    the static broadcast list; an empty owner list means nobody, not
    everybody."""
    p = PeerStore(["http://static:1"], timeout=0.2,
                  router=lambda key: ["http://a:1/", "http://b:2"])
    assert p.targets("k") == ["http://a:1", "http://b:2"]
    p.router = lambda key: []
    assert p.targets("k") == []
    p.store("k", {"domain": "tri2d"})     # no targets: push is a no-op
    assert p.pushes == 0 and p.push_errors == 0
    p.router = None
    assert p.targets("k") == ["http://static:1"]  # static mesh fallback


# ---------------------------------------------------------------------------
# Client-side key validation (fail fast, no round-trip)
# ---------------------------------------------------------------------------


def test_client_rejects_malformed_keys_locally():
    """A malformed content address raises status=400 *locally* — the URL
    here is unreachable, so any round-trip attempt would surface as a
    transport error (status=None) instead."""
    client = RemoteMappingService("http://127.0.0.1:9", retries=0,
                                  backoff=0.01)
    for method in (client.fetch_artifact, client.delete_artifact,
                   client.pull_record):
        with pytest.raises(RemoteServiceError) as err:
            method("../../etc/passwd")
        assert err.value.status == 400
        assert "invalid key" in str(err.value)
    assert client.stats.remote_requests == 0
    assert client.stats.retries == 0


# ---------------------------------------------------------------------------
# Fleet harness
# ---------------------------------------------------------------------------


class CountingBackend:
    """Thread-safe mock backend counting fleet-wide `generate` calls."""

    calls = 0
    _mu = threading.Lock()

    def __init__(self, model: str):
        self._inner = MockLLMBackend(model)
        self.name = self._inner.name

    @property
    def cache_fingerprint(self):
        return self._inner.cache_fingerprint

    def generate(self, prompt, *, meta):
        with CountingBackend._mu:
            CountingBackend.calls += 1
        return self._inner.generate(prompt, meta=meta)


@pytest.fixture()
def counting_backend():
    CountingBackend.calls = 0
    return CountingBackend


def boot_node(tmp_path, name: str, seeds, backend_factory, port: int = 0,
              weight: float = 1.0, gossip_fanout: int = 0,
              placement: str = "ring", serve_delay: float = 0.0,
              router=None):
    """One fleet node: service + HTTP frontend + membership (fast timers)."""
    svc = MappingService(store=build_store(root=tmp_path / name),
                         backend_factory=backend_factory,
                         n_validate=2000, sample_every=1)
    server = MappingHTTPServer(svc, port=port, router=router,
                               serve_delay=serve_delay).start()
    cluster = ClusterMembership(
        server.url, seeds=seeds, replicas=2, vnodes=64,
        heartbeat_interval=0.15, down_after=1.0, sync_interval=0.3,
        probe_timeout=1.0, weight=weight, gossip_fanout=gossip_fanout,
        placement=placement)
    server.attach_cluster(cluster)
    return server


def holders(servers, key: str) -> list[str]:
    """Which nodes list `key` in their replication manifest."""
    out = []
    for s in servers:
        if key in RemoteMappingService(s.url).manifest()["keys"]:
            out.append(s.url)
    return out


# ---------------------------------------------------------------------------
# Acceptance: 3-node ring end to end
# ---------------------------------------------------------------------------


def test_three_node_ring_lifecycle_acceptance(tmp_path, counting_backend):
    """The PR's acceptance scenario, one inference for the whole story:
    seed bootstrap -> sharded placement -> owner death -> repair ->
    rejoin."""
    seed = boot_node(tmp_path, "n0", [], counting_backend)
    servers = [seed,
               boot_node(tmp_path, "n1", [seed.url], counting_backend),
               boot_node(tmp_path, "n2", [seed.url], counting_backend)]
    try:
        _await(lambda: all(len(s.cluster.ring.nodes) == 3 for s in servers),
               what="3-node membership convergence")
        views = [s.cluster.ring.nodes for s in servers]
        assert views[0] == views[1] == views[2]  # one consistent ring

        # -- derive the same cell through every node: ONE inference --------
        key = servers[0].service.request_key("tri2d", MODEL, 20)
        owners = servers[0].cluster.owners(key)
        assert len(owners) == 2
        non_owner = next(s for s in servers if s.url not in owners)
        ordered = [non_owner] + [s for s in servers if s is not non_owner]
        results = [RemoteMappingService(s.url).derive("tri2d", MODEL, 20)
                   for s in ordered]
        assert counting_backend.calls == 1      # fleet-wide single inference
        assert results[0].cache_key == key
        assert all(r.source == results[0].source for r in results)
        assert non_owner.forwarded >= 1         # first hop went to the owner
        fleet_derivations = sum(s.service.stats.derivations for s in servers)
        assert fleet_derivations == 1

        # -- placement: the record lives on exactly `replicas` nodes -------
        _await(lambda: sorted(holders(servers, key)) == sorted(owners),
               what="record on exactly the K owners")
        stats = RemoteMappingService(ordered[1].url).store_stats()
        assert stats["cluster"]["nodes_up"] == 3
        assert stats["cluster"]["replicas"] == 2

        # -- ring-aware client: repeats hash locally, hit the owner --------
        client = RemoteMappingService(non_owner.url)
        client.derive("tri2d", MODEL, 20)       # learns the cell's key
        before = non_owner.forwarded
        repeat = client.derive("tri2d", MODEL, 20)
        assert repeat.cache_hit
        assert client.stats.routed == 1         # went straight to the owner
        assert non_owner.forwarded == before    # no server-side hop needed

        # -- kill the primary owner ----------------------------------------
        dead = next(s for s in servers if s.url == owners[0])
        dead_port = dead.port
        dead.close()
        alive = [s for s in servers if s is not dead]
        _await(lambda: all(len(s.cluster.ring.nodes) == 2 for s in alive),
               what="death detection")

        # anti-entropy restores the replication factor on the smaller ring
        # — before any request touches it, so this is the repair loop, not
        # the derive path's read-through
        _await(lambda: len(holders(alive, key)) == 2,
               what="replication factor restored after owner death")
        assert sum(s.cluster.repairs for s in alive) >= 1
        assert counting_backend.calls == 1

        # the surviving replica set serves the record, zero new inferences
        for s in alive:
            assert RemoteMappingService(s.url).derive(
                "tri2d", MODEL, 20).cache_hit
        assert counting_backend.calls == 1

        # -- rejoin at the same URL with a wiped store ---------------------
        rejoined = boot_node(tmp_path, "n0-rejoined", [alive[0].url],
                             counting_backend, port=dead_port)
        servers = [*alive, rejoined]
        _await(lambda: all(len(s.cluster.ring.nodes) == 3 for s in servers),
               what="rejoin convergence")
        # the rejoined node owns the key again and repairs itself from the
        # surviving replica — without a single new inference
        assert rejoined.url in rejoined.cluster.owners(key)
        _await(lambda: key in rejoined.service.store,
               what="anti-entropy repair onto the rejoined node")
        assert rejoined.cluster.repairs >= 1
        assert counting_backend.calls == 1
        assert rejoined.service.stats.derivations == 0
        # ...and the interim replica (now a non-owner again) hands off: the
        # fleet self-heals back to exactly-K placement on the old owner set
        _await(lambda: sorted(holders(servers, key)) == sorted(owners),
               what="exactly-K placement restored after rejoin")
    finally:
        for s in servers:
            s.close()


def test_sharded_placement_spreads_cells(tmp_path, counting_backend):
    """Different cells land on different owner sets: the fleet holds ~K/N
    of the store per node instead of N full copies (the PR 4 broadcast
    behavior this refactor replaces)."""
    seed = boot_node(tmp_path, "s0", [], counting_backend)
    servers = [seed,
               boot_node(tmp_path, "s1", [seed.url], counting_backend),
               boot_node(tmp_path, "s2", [seed.url], counting_backend)]
    try:
        _await(lambda: all(len(s.cluster.ring.nodes) == 3 for s in servers),
               what="membership convergence")
        cells = [("tri2d", 20), ("tri2d", 50), ("gasket2d", 20),
                 ("gasket2d", 50), ("carpet2d", 20), ("msimplex3", 20)]
        client = RemoteMappingService(servers[0].url)
        keys = [client.derive(d, MODEL, s).cache_key for d, s in cells]
        for key in keys:
            _await(lambda k=key: sorted(holders(servers, k)) ==
                   sorted(servers[0].cluster.owners(k)),
                   what="per-cell placement on exactly the K owners")
        # every node's manifest holds exactly its ring-predicted shard —
        # K copies per cell fleet-wide, not the N-copy broadcast of PR 4
        # (balance across many keys is the hypothesis property test above)
        expected: dict[str, int] = {s.url: 0 for s in servers}
        for key in keys:
            for owner in servers[0].cluster.owners(key):
                expected[owner] += 1
        per_node = {s.url: len(RemoteMappingService(s.url).manifest()["keys"])
                    for s in servers}
        assert per_node == expected
        assert sum(per_node.values()) == 2 * len(cells)
    finally:
        for s in servers:
            s.close()


def test_forwarded_requests_serve_where_they_land(tmp_path, counting_backend):
    """A request carrying the forwarded marker is always served locally —
    two nodes with disagreeing views can never bounce a derive forever."""
    import json
    import urllib.request

    from repro.serving.http import FORWARDED_HEADER

    seed = boot_node(tmp_path, "f0", [], counting_backend)
    other = boot_node(tmp_path, "f1", [seed.url], counting_backend)
    try:
        _await(lambda: all(len(s.cluster.ring.nodes) == 2
                           for s in (seed, other)),
               what="membership convergence")
        key = seed.service.request_key("tri2d", MODEL, 20)
        # address the request at a node and mark it forwarded: it must not
        # hop again even if the ring disagrees with the landing spot
        target = next(s for s in (seed, other)
                      if s.cluster.owners(key)[0] != s.url)
        req = urllib.request.Request(
            f"{target.url}/v1/derive",
            data=json.dumps({"domain": "tri2d", "model": MODEL,
                             "stage": 20}).encode(),
            headers={"Content-Type": "application/json",
                     FORWARDED_HEADER: "1"})
        with urllib.request.urlopen(req, timeout=60) as resp:
            payload = json.loads(resp.read())
        assert payload["key"] == key
        assert target.forwarded == 0            # served where it landed
        assert target.service.stats.derivations == 1
    finally:
        seed.close()
        other.close()


# ---------------------------------------------------------------------------
# Gossip fanout cap: big-fleet membership stays O(N log N)
# ---------------------------------------------------------------------------


def test_fanout_cap_and_probe_cycle_units():
    """effective_fanout tiers (explicit / auto-log / uncapped) and the
    shuffled probe cycle's coverage guarantee: every known node is visited
    within ceil(N/fanout) rounds, never more than fanout per round."""
    auto = ClusterMembership("http://self:1", seeds=[])
    assert auto.effective_fanout(2) == 3    # ceil(log2 2) + 2
    assert auto.effective_fanout(16) == 6
    assert auto.effective_fanout(100) == 9
    uncapped = ClusterMembership("http://self:1", seeds=[],
                                 gossip_fanout=-1)
    assert uncapped.effective_fanout(50) == 50
    capped = ClusterMembership("http://self:1", seeds=[], gossip_fanout=3)
    assert capped.effective_fanout(50) == 3

    peers = {f"http://peer-{i}:1" for i in range(10)}
    for url in sorted(peers):
        capped.observe(url)
    rounds = [capped._next_probe_targets() for _ in range(8)]
    assert all(len(r) <= 3 for r in rounds)
    # one full cycle = ceil(10/3) = 4 rounds and it covers everyone
    assert set().union(*rounds[:4]) == peers
    # deterministic under the node's own seed: a replay walks the same cycle
    replay = ClusterMembership("http://self:1", seeds=[], gossip_fanout=3)
    for url in sorted(peers):
        replay.observe(url)
    assert [replay._next_probe_targets() for _ in range(8)] == rounds


def test_seven_node_fleet_capped_gossip_lifecycle(tmp_path,
                                                 counting_backend):
    """Satellite acceptance on a 7-node fleet with gossip_fanout=2: the
    fleet converges through capped probe subsets; a killed node is marked
    down fleet-wide within ``down_after`` + O(cycle) heartbeat rounds; a
    partitioned node rejoins and the whole story costs ONE inference —
    the rejoin must not re-derive."""
    n = 7
    seed = boot_node(tmp_path, "g0", [], counting_backend, gossip_fanout=2)
    servers = [seed] + [
        boot_node(tmp_path, f"g{i}", [seed.url], counting_backend,
                  gossip_fanout=2)
        for i in range(1, n)]
    try:
        _await(lambda: all(len(s.cluster.ring.nodes) == n for s in servers),
               what="7-node convergence under capped gossip")
        # steady state: every round respects the cap (bootstrap exempt)
        time.sleep(0.5)
        samples = []
        for _ in range(6):
            time.sleep(0.16)
            samples += [s.cluster.stats()["probes_last_round"]
                        for s in servers]
        assert max(samples) <= 2, samples
        assert all(s.cluster.stats()["gossip_fanout"] == 2 for s in servers)

        # one derive through a non-owner: exactly one inference fleet-wide
        key = servers[0].service.request_key("tri2d", MODEL, 20)
        owners = servers[0].cluster.owners(key)
        non_owner = next(s for s in servers if s.url not in owners)
        RemoteMappingService(non_owner.url).derive("tri2d", MODEL, 20)
        assert counting_backend.calls == 1
        _await(lambda: sorted(holders(servers, key)) == sorted(owners),
               what="record on exactly the K owners")

        # -- kill a non-owner: down fleet-wide within down_after + O(cycle)
        victim = next(s for s in servers
                      if s.url not in owners and s is not non_owner)
        victim_port, victim_name = victim.port, None
        for i in range(n):
            if servers[i] is victim:
                victim_name = f"g{i}" if i else "g0"
        victim.close()
        alive = [s for s in servers if s is not victim]
        t0 = time.monotonic()
        _await(lambda: all(len(s.cluster.ring.nodes) == n - 1
                           for s in alive),
               what="capped-gossip death detection")
        elapsed = time.monotonic() - t0
        # down_after=1.0 + one probe cycle (ceil(6/2)=3 rounds @0.15s) +
        # generous scheduling slack — the point: capping the fanout must
        # not push detection toward the uncapped-timeout regime
        assert elapsed < 1.0 + 3 * 0.15 + 3.0, elapsed

        # -- the partitioned node rejoins on its old port + store ----------
        rejoined = boot_node(tmp_path, victim_name, [seed.url],
                             counting_backend, port=victim_port,
                             gossip_fanout=2)
        servers = alive + [rejoined]
        _await(lambda: all(len(s.cluster.ring.nodes) == n for s in servers),
               what="rejoin convergence")
        # re-derive the same cell through several nodes: still ONE
        # inference total — a rejoin must never duplicate work
        for s in (rejoined, non_owner, servers[0]):
            res = RemoteMappingService(s.url).derive("tri2d", MODEL, 20)
            assert res.cache_key == key
        assert counting_backend.calls == 1
    finally:
        for s in servers:
            try:
                s.close()
            except Exception:
                pass


# ---------------------------------------------------------------------------
# Keep-alive transport failure behavior
# ---------------------------------------------------------------------------


def test_pooled_socket_death_reconnects_then_retries(tmp_path):
    """When a pooled keep-alive socket dies (server restart), the client
    reconnects silently; when the server is really gone, the existing
    retry/backoff surfaces the documented error."""
    store_root = tmp_path / "store"
    svc = MappingService(store=build_store(root=store_root),
                         n_validate=2000, sample_every=1)
    server = MappingHTTPServer(svc).start()
    port = server.port
    client = RemoteMappingService(server.url, retries=1, backoff=0.01)
    first = client.derive("tri2d", MODEL, 20)
    assert client.stats.reconnects == 0
    server.close()  # severs the pooled connection

    svc2 = MappingService(store=build_store(root=store_root),
                          n_validate=2000, sample_every=1)
    with MappingHTTPServer(svc2, port=port) as server2:
        res = client.derive("tri2d", MODEL, 20)
        assert res.cache_hit and res.cache_key == first.cache_key
        assert client.stats.reconnects >= 1     # silent reconnect, no retry
        assert client.stats.retries == 0
    with pytest.raises(RemoteServiceError) as err:
        client.derive("tri2d", MODEL, 20)       # nobody listening anymore
    assert err.value.status is None
    assert client.stats.retries >= 1            # backoff machinery engaged


def test_nested_call_during_grid_stream_gets_own_connection(tmp_path):
    """A call issued while a grid stream is suspended must not steal (and
    kill) the connection carrying the stream — checked-out connections are
    owned by exactly one in-flight response."""
    svc = MappingService(store=build_store(root=tmp_path),
                         n_validate=2000, sample_every=1)
    with MappingHTTPServer(svc) as server:
        client = RemoteMappingService(server.url)
        seen = []
        for res in client.run_grid(domains=["tri2d", "gasket2d"],
                                   models=[MODEL], stages=[20, 50]):
            seen.append(res.cache_key)
            fetched = client.fetch_artifact(res.cache_key)  # mid-stream call
            assert fetched["record"]["key"] == res.cache_key
        assert len(seen) == 4 and len(set(seen)) == 4


def test_error_response_does_not_desync_keepalive(tmp_path):
    """An error answered before the request body was read (e.g. a POST to
    an unknown route) must not leave the body bytes in the socket to be
    parsed as the next request on the kept-alive connection."""
    svc = MappingService(store=build_store(root=tmp_path),
                         n_validate=2000, sample_every=1)
    with MappingHTTPServer(svc) as server:
        client = RemoteMappingService(server.url)
        client.derive("tri2d", MODEL, 20)
        with pytest.raises(RemoteServiceError) as err:
            client._call_json("/v1/no-such-route", {"pad": "x" * 4096})
        assert err.value.status == 404
        again = client.derive("tri2d", MODEL, 20)  # same client, clean conn
        assert again.cache_hit
        assert client.stats.retries == 0


def test_observe_is_candidate_only_until_probed():
    """A ``?from=`` announcement nominates a node but never places it in
    the ring — only this node's own successful probe does (an
    unauthenticated announce must not poison routing)."""
    cluster = ClusterMembership("http://127.0.0.1:1", heartbeat_interval=9e9)
    cluster.observe("http://127.0.0.1:2/")
    assert cluster.ring.nodes == ["http://127.0.0.1:1"]  # not in the ring
    view_urls = [n["url"] for n in cluster.view()["nodes"]]
    assert "http://127.0.0.1:2" in view_urls             # but known/probed
    # a few failed probes forget a never-seen non-seed candidate entirely
    for _ in range(3):
        cluster.heartbeat_now()
    assert "http://127.0.0.1:2" not in [
        n["url"] for n in cluster.view()["nodes"]]
    assert cluster.forgotten == 1


def test_self_seed_under_an_alias_does_not_double_join(tmp_path):
    """The documented bootstrap seeds the first node from its own URL; if
    the operator spells it differently (localhost vs 127.0.0.1) the alias
    must be detected and excluded — a node ringed under two names would
    silently collapse the replication factor onto one machine."""
    svc = MappingService(store=build_store(root=tmp_path),
                         n_validate=2000, sample_every=1)
    server = MappingHTTPServer(svc).start()  # binds 127.0.0.1
    try:
        cluster = ClusterMembership(
            server.url, seeds=[f"http://localhost:{server.port}"],
            heartbeat_interval=9e9, probe_timeout=2.0)
        server.attach_cluster(cluster)  # start() runs one heartbeat round
        assert cluster.ring.nodes == [server.url]  # one node, one name
        assert f"http://localhost:{server.port}" in cluster._aliases
        cluster.heartbeat_now()  # the alias stays excluded on later rounds
        assert cluster.ring.nodes == [server.url]
    finally:
        server.close()


def test_standalone_server_keeps_pr4_wire_behavior(tmp_path):
    """No seeds -> no cluster: /v1/cluster answers 404, the ring-aware
    client degrades to plain single-host routing, and the manifest endpoint
    still serves (it is part of the replication surface, not membership)."""
    svc = MappingService(store=build_store(root=tmp_path),
                         n_validate=2000, sample_every=1)
    with MappingHTTPServer(svc) as server:
        client = RemoteMappingService(server.url)
        with pytest.raises(RemoteServiceError) as err:
            client.cluster_view()
        assert err.value.status == 404
        res = client.derive("tri2d", MODEL, 20)
        repeat = client.derive("tri2d", MODEL, 20)  # triggers the ring probe
        assert repeat.cache_hit and client.stats.routed == 0
        assert client.manifest()["keys"] == [res.cache_key]
        assert "cluster" not in client.metrics()
