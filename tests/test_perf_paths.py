"""Optimization-path equivalences (§Perf): every optimized variant must be
exact vs its naive counterpart before its measurements count."""
import jax
import jax.numpy as jnp
import pytest
from types import SimpleNamespace

from repro.configs import get_smoke_config
from repro.models import attention as attn
from repro.models import moe as M
from repro.models import transformer as T


def test_xla_mapped_attention_matches_xla():
    cfg = get_smoke_config("yi-6b").replace(d_model=64)
    p = attn.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    for s in (512, 768, 1024):
        x = jax.random.normal(jax.random.PRNGKey(1), (2, s, 64)) * 0.3
        o_x, _ = attn.gqa_apply(p, cfg.replace(attn_impl="xla"), x)
        o_m, _ = attn.gqa_apply(p, cfg.replace(attn_impl="xla_mapped"), x)
        assert float(jnp.max(jnp.abs(o_x - o_m))) < 2e-5, s


def test_xla_mapped_gradients_match():
    cfg = get_smoke_config("yi-6b").replace(d_model=64)
    p = attn.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 512, 64)) * 0.3
    g1 = jax.grad(lambda xx: attn.gqa_apply(
        p, cfg.replace(attn_impl="xla_mapped"), xx)[0].sum())(x)
    g2 = jax.grad(lambda xx: attn.gqa_apply(
        p, cfg.replace(attn_impl="xla"), xx)[0].sum())(x)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-4


def test_xla_mapped_pair_count_is_triangular():
    """The static λ→(i,j) tables enumerate exactly the triangular pairs."""
    import numpy as np

    from repro.core.maps import np_map_tri2d

    for nb in (4, 7, 16, 31):
        lam = np.arange(nb * (nb + 1) // 2)
        ij = np_map_tri2d(lam)
        i_np = ((np.sqrt(8 * lam + 1).astype(np.int64) - 1) // 2)
        i_np += ((i_np + 2) * (i_np + 1) // 2 <= lam)
        j_np = lam - i_np * (i_np + 1) // 2
        np.testing.assert_array_equal(np.stack([i_np, j_np], -1), ij)


def _moe_cfg(**kw):
    base = dict(d_model=32, n_experts=8, moe_top_k=2, expert_d_ff=64,
                n_shared_experts=1, capacity_factor=16.0,
                moe_renormalize=True, moe_groups=1, moe_impl="global")
    base.update(kw)
    return SimpleNamespace(**base)


def test_grouped_moe_matches_global():
    cfg = _moe_cfg()
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32)) * 0.5
    ref = M.moe_apply(p, cfg, x)
    for g in (2, 4, 8):
        out = M.moe_apply(p, _moe_cfg(moe_groups=g), x)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-5, g


def test_grouped_moe_gradients_match():
    cfg = _moe_cfg()
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    g1 = jax.grad(lambda xx: M.moe_apply(p, cfg, xx).sum())(x)
    g2 = jax.grad(lambda xx: M.moe_apply(
        p, _moe_cfg(moe_groups=4), xx).sum())(x)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-4


def test_local_sort_dispatch_invariants():
    ids = jnp.asarray([3, 0, 3, 1, 3, 0, 2, 3])
    slot, keep = M._local_sort_dispatch(ids, n_buckets=4, cap=2)
    # at most `cap` kept per bucket; slots unique among kept
    kept_slots = [int(s) for s, k in zip(slot, keep) if bool(k)]
    assert len(set(kept_slots)) == len(kept_slots)
    for bucket in range(4):
        in_bucket = [s for s in kept_slots if bucket * 2 <= s < bucket * 2 + 2]
        assert len(in_bucket) <= 2
    # bucket 3 has 4 entries, cap 2 -> exactly 2 dropped
    assert int(keep.sum()) == 2 + 2 + 1 + 1


def test_mla_absorption_exact():
    cfg = get_smoke_config("deepseek-v2-236b").replace(capacity_factor=16.0)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    _, cache = T.prefill(params, cfg, toks[:, :15])
    nt = toks[:, 15:16]
    dec_abs, _ = T.decode_step(params, cfg, nt, cache)
    dec_no, _ = T.decode_step(params, cfg.replace(mla_absorb="never"), nt,
                              cache)
    assert float(jnp.max(jnp.abs(dec_abs - dec_no))) < 2e-4


def test_moe_a2a_falls_back_without_mesh():
    """a2a config outside a mesh context must use the global path."""
    cfg = _moe_cfg(moe_impl="a2a")
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32)) * 0.5
    ref = M.moe_apply(p, _moe_cfg(), x)
    out = M.moe_apply(p, cfg, x)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-6


@pytest.mark.slow
def test_moe_a2a_subprocess():
    """a2a EP vs global MoE on 8 fake devices (fwd + grad)."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from types import SimpleNamespace
        from repro.models import moe as M
        from repro.distribution import sharding as shd

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = SimpleNamespace(d_model=32, n_experts=8, moe_top_k=2,
                              expert_d_ff=64, n_shared_experts=1,
                              capacity_factor=8.0, moe_renormalize=True,
                              moe_groups=1, moe_impl="global")
        p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32)) * 0.5
        ref = M.moe_apply(p, cfg, x)
        g0 = jax.grad(lambda x_: M.moe_apply(p, cfg, x_).sum())(x)
        cfg2 = SimpleNamespace(**{**vars(cfg), "moe_impl": "a2a"})
        with shd.use_sharding(mesh):
            out = jax.jit(lambda p_, x_: M.moe_apply(p_, cfg2, x_))(p, x)
            g = jax.jit(jax.grad(lambda x_: M.moe_apply(p, cfg2, x_).sum()))(x)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
        assert float(jnp.max(jnp.abs(g - g0))) < 1e-3
        print("OK a2a")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK a2a" in res.stdout


def test_serving_engine_greedy():
    from repro.serving.engine import generate

    cfg = get_smoke_config("granite-8b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    res = generate(params, cfg, prompts, max_new_tokens=8)
    assert res.tokens.shape == (2, 16)
    # greedy generation must match teacher-forced argmax step by step
    logits = T.forward(params, cfg, res.tokens[:, :-1])
    preds = jnp.argmax(logits[:, :, : cfg.vocab_size], axis=-1)
    assert bool((preds[:, 7:] == res.tokens[:, 8:]).all())
