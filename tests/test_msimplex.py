"""Generalized m-simplex maps (paper's future-work direction)."""
import numpy as np
import pytest

try:  # prefer real hypothesis; fall back to the deterministic shim
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.core.maps import map_pyramid3d, map_tri2d
from repro.core.msimplex import (
    block_accounting_msimplex, enumerate_msimplex, map_msimplex,
    simplex_layer, simplex_size, unmap_msimplex,
)


@pytest.mark.parametrize("m", [1, 2, 3, 4, 5])
def test_map_matches_enumeration(m):
    n = 2000
    gt = enumerate_msimplex(n, m)
    got = np.array([map_msimplex(i, m) for i in range(n)])
    np.testing.assert_array_equal(got, gt)


def test_specializes_to_table_I():
    """m=2 and m=3 must reproduce the paper's triangular/tetrahedral maps."""
    for lam in (0, 1, 7, 100, 5000, 99999):
        x2, y2 = map_tri2d(lam)
        assert map_msimplex(lam, 2) == (y2, x2)   # sorted-ascending convention
        x, y, z = map_pyramid3d(lam)
        assert map_msimplex(lam, 3) == (y, x, z)


@given(st.integers(0, 10**8), st.integers(1, 6))
@settings(max_examples=150, deadline=None)
def test_layer_inverse(lam, m):
    x = simplex_layer(lam, m)
    assert simplex_size(x, m) <= lam < simplex_size(x + 1, m)


@given(st.integers(0, 10**7), st.integers(1, 5))
@settings(max_examples=100, deadline=None)
def test_roundtrip(lam, m):
    c = map_msimplex(lam, m)
    assert all(c[i] <= c[i + 1] for i in range(m - 1))  # sorted invariant
    assert unmap_msimplex(c) == lam


def test_waste_grows_with_dimension():
    """The paper's 2D ~50% / 3D ~83% BB waste generalizes: 1 - 1/m!."""
    prev = 0.0
    for m in (2, 3, 4, 5):
        acc = block_accounting_msimplex(10**6, m)
        assert acc["waste_fraction"] > prev
        assert acc["waste_fraction"] == pytest.approx(
            acc["asymptotic_waste"], abs=0.08)
        prev = acc["waste_fraction"]
    assert block_accounting_msimplex(10**6, 5)["asymptotic_waste"] > 0.99