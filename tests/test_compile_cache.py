"""CompileCache unit + regression coverage: LRU/stat semantics, key
sensitivity, in-flight coalescing, on-disk executable persistence, env
knobs — and the PR-6 regression that made the cache necessary: a repeat
``map_coordinates``/``bb_membership`` call must never re-trace."""
import threading

import numpy as np
import pytest

from repro.core import compile_cache as cc
from repro.core.domains import DOMAINS
from repro.kernels.domain_map import ops


def _key(tag: str, **kw) -> cc.ExecKey:
    base = dict(fingerprint=f"domain:{tag}", tier="map", shape=(0, 256),
                block_n=128, ndigits=13, interpret=True)
    base.update(kw)
    return cc.ExecKey(**base)


def _cheap_build(value: float):
    """A zero-arg jittable thunk that compiles in milliseconds."""
    import jax.numpy as jnp

    def build():
        return lambda: jnp.full((4,), value)

    return build


# ---------------------------------------------------------------------------
# LRU + stats semantics
# ---------------------------------------------------------------------------


def test_hit_miss_and_lru_eviction_order():
    cache = cc.CompileCache(max_entries=2)
    a, b, c = _key("a"), _key("b"), _key("c")
    fa = cache.get(a, _cheap_build(1.0))
    assert cache.stats.misses == 1 and cache.stats.hits == 0
    assert cache.get(a, _cheap_build(1.0)) is fa  # identical executable back
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    assert cache.stats.trace_seconds > 0

    cache.get(b, _cheap_build(2.0))
    cache.get(a, _cheap_build(1.0))       # touch a: b is now the LRU entry
    cache.get(c, _cheap_build(3.0))       # capacity 2: evicts b, keeps a
    assert cache.stats.evictions == 1
    assert a in cache and c in cache and b not in cache
    cache.get(b, _cheap_build(2.0))       # re-compiling b is a fresh miss
    assert cache.stats.misses == 4
    d = cache.stats_dict()
    assert d["entries"] == 2 and d["max_entries"] == 2
    assert d["hit_ratio"] == pytest.approx(2 / 6)
    assert cache.clear() == 2 and len(cache) == 0


def test_key_fields_are_all_significant():
    """Any field that changes the lowering must change the key."""
    base = _key("x")
    variants = [
        _key("y"),
        _key("x", tier="membership"),
        _key("x", shape=(0, 512)),
        _key("x", block_n=256),
        _key("x", ndigits=9),
        _key("x", dtype="int64"),
        _key("x", interpret=False),
        _key("x", device="tpu:v5e"),
    ]
    assert len({base, *variants}) == len(variants) + 1
    assert len({k.digest() for k in (base, *variants)}) == len(variants) + 1
    cache = cc.CompileCache(max_entries=32)
    for i, k in enumerate((base, *variants)):
        cache.get(k, _cheap_build(float(i)))
    assert cache.stats.misses == len(variants) + 1  # no accidental sharing


def test_concurrent_cold_callers_coalesce_to_one_compile():
    cache = cc.CompileCache(max_entries=8)
    key = _key("shared")
    builds = []
    gate = threading.Event()

    def build():
        import jax.numpy as jnp

        builds.append(1)
        gate.wait(5)  # hold the leader so followers genuinely queue
        return lambda: jnp.zeros((2,))

    fns = []
    mu = threading.Lock()

    def caller():
        fn = cache.get(key, build)
        with mu:
            fns.append(fn)

    threads = [threading.Thread(target=caller) for _ in range(6)]
    for t in threads:
        t.start()
    while not builds:  # leader is inside build()
        pass
    gate.set()
    for t in threads:
        t.join()
    assert sum(builds) == 1                       # exactly one trace
    assert len({id(f) for f in fns}) == 1         # everyone shares it
    assert cache.stats.misses == 1
    assert cache.stats.coalesced == 5


def test_failed_build_propagates_and_is_not_cached():
    cache = cc.CompileCache(max_entries=8)
    key = _key("boom")

    def bad_build():
        raise RuntimeError("synthetic build failure")

    with pytest.raises(RuntimeError, match="synthetic"):
        cache.get(key, bad_build)
    assert key not in cache
    fn = cache.get(key, _cheap_build(7.0))  # key is retryable afterwards
    assert float(np.asarray(fn())[0]) == 7.0


# ---------------------------------------------------------------------------
# the PR-6 regression: repeat kernel calls are trace-free
# ---------------------------------------------------------------------------


def test_second_identical_map_call_performs_zero_traces(monkeypatch):
    """The per-call re-trace this PR removes: with a warm cache, a repeat
    ``map_coordinates`` (and ``bb_membership``) performs zero new builds —
    it is a cache hit plus a dispatch, byte-equal to the uncached path."""
    calls = {"map": 0, "bb": 0}
    real_map, real_bb = ops.build_map_call, ops.build_membership_call

    def counting_map(*a, **kw):
        calls["map"] += 1
        return real_map(*a, **kw)

    def counting_bb(*a, **kw):
        calls["bb"] += 1
        return real_bb(*a, **kw)

    monkeypatch.setattr(ops, "build_map_call", counting_map)
    monkeypatch.setattr(ops, "build_membership_call", counting_bb)
    cache = cc.CompileCache(max_entries=16)

    first = ops.map_coordinates("tri2d", 200, block_n=128, interpret=True,
                                compile_cache=cache)
    assert calls["map"] == 1
    second = ops.map_coordinates("tri2d", 200, block_n=128, interpret=True,
                                 compile_cache=cache)
    assert calls["map"] == 1              # ZERO new traces on the repeat
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    np.testing.assert_array_equal(first, second)
    uncached = ops.map_coordinates("tri2d", 200, block_n=128, interpret=True,
                                   compile_cache=None)
    np.testing.assert_array_equal(first, uncached)
    assert calls["map"] == 2              # the bypass path does re-trace

    mask1 = ops.bb_membership("tri2d", (16, 16), block_n=128, interpret=True,
                              compile_cache=cache)
    mask2 = ops.bb_membership("tri2d", (16, 16), block_n=128, interpret=True,
                              compile_cache=cache)
    assert calls["bb"] == 1
    np.testing.assert_array_equal(mask1, mask2)


def test_distinct_launch_parameters_get_distinct_executables():
    cache = cc.CompileCache(max_entries=32)
    kw = dict(interpret=True, compile_cache=cache)
    ops.map_coordinates("tri2d", 200, block_n=128, **kw)
    ops.map_coordinates("tri2d", 300, block_n=128, **kw)   # pads 256 vs 384
    ops.map_coordinates("tri2d", 200, block_n=64, **kw)
    ops.map_coordinates("tri2d", 200, block_n=128, start=128, **kw)
    ops.map_coordinates("gasket2d", 200, block_n=128, **kw)
    assert cache.stats.misses == 5 and cache.stats.hits == 0


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------


def test_persisted_executable_survives_a_cold_cache(tmp_path):
    """Second cache over the same persist dir rehydrates without tracing —
    and produces identical bytes."""
    warm = cc.CompileCache(max_entries=8, persist_dir=tmp_path)
    out1 = ops.map_coordinates("tri2d", 200, block_n=128, interpret=True,
                               compile_cache=warm)
    if warm.stats.disk_errors:  # jaxlib that can't export pallas: degrade
        assert warm.stats.disk_stores == 0
        pytest.skip("jax.export cannot round-trip this lowering here")
    assert warm.stats.disk_stores == 1
    assert len(list(tmp_path.glob("*.jaxexec"))) == 1

    cold = cc.CompileCache(max_entries=8, persist_dir=tmp_path)
    out2 = ops.map_coordinates("tri2d", 200, block_n=128, interpret=True,
                               compile_cache=cold)
    assert cold.stats.disk_hits == 1 and cold.stats.misses == 0
    np.testing.assert_array_equal(out1, out2)
    # the rehydrated entry now lives in memory: repeats are plain hits
    ops.map_coordinates("tri2d", 200, block_n=128, interpret=True,
                        compile_cache=cold)
    assert cold.stats.hits == 1


def test_corrupt_persisted_file_recompiles_and_heals(tmp_path):
    warm = cc.CompileCache(max_entries=8, persist_dir=tmp_path)
    key = _key("p")
    warm.get(key, _cheap_build(5.0))
    files = list(tmp_path.glob("*.jaxexec"))
    if not files:
        pytest.skip("jax.export unavailable for persistence here")
    files[0].write_bytes(b"not an executable")

    cold = cc.CompileCache(max_entries=8, persist_dir=tmp_path)
    fn = cold.get(key, _cheap_build(5.0))
    assert float(np.asarray(fn())[0]) == 5.0
    assert cold.stats.disk_errors == 1         # corrupt file detected...
    assert cold.stats.misses == 1              # ...recompiled...
    assert not files[0].exists() or \
        files[0].read_bytes() != b"not an executable"  # ...and not trusted


# ---------------------------------------------------------------------------
# process default + env knobs
# ---------------------------------------------------------------------------


@pytest.fixture
def _fresh_default(monkeypatch):
    monkeypatch.setattr(cc, "_default", None)
    monkeypatch.setattr(cc, "_default_off", False)
    yield
    cc._default = None
    cc._default_off = False


def test_env_knobs_shape_the_default_cache(monkeypatch, tmp_path,
                                           _fresh_default):
    monkeypatch.setenv("REPRO_COMPILE_CACHE_ENTRIES", "7")
    monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", str(tmp_path))
    cache = cc.default_compile_cache()
    assert cache is not None and cache.max_entries == 7
    assert cache.persist_dir == tmp_path
    assert cc.default_compile_cache() is cache  # stable singleton
    assert cc.resolve(cc.USE_DEFAULT) is cache
    assert cc.resolve(None) is None
    mine = cc.CompileCache(max_entries=1)
    assert cc.resolve(mine) is mine


def test_env_zero_and_configure_zero_disable_caching(monkeypatch,
                                                     _fresh_default):
    monkeypatch.setenv("REPRO_COMPILE_CACHE_ENTRIES", "0")
    assert cc.default_compile_cache() is None
    monkeypatch.delenv("REPRO_COMPILE_CACHE_ENTRIES")
    assert cc.configure_default(max_entries=4).max_entries == 4
    assert cc.configure_default(max_entries=0) is None
    assert cc.default_compile_cache() is None  # stays off until reconfigured
    assert cc.configure_default(max_entries=2).max_entries == 2


def test_malformed_env_value_warns_and_falls_back(monkeypatch,
                                                  _fresh_default):
    monkeypatch.setenv("REPRO_COMPILE_CACHE_ENTRIES", "lots")
    with pytest.warns(UserWarning, match="REPRO_COMPILE_CACHE_ENTRIES"):
        cache = cc.default_compile_cache()
    assert cache is not None
    assert cache.max_entries == cc.DEFAULT_MAX_ENTRIES


def test_spec_fingerprint_identities():
    assert cc.spec_fingerprint("tri2d") == "domain:tri2d"
    assert cc.spec_fingerprint(DOMAINS["gasket2d"]) == "domain:gasket2d"
