"""WKV chunked Pallas kernel vs the recurrence oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.wkv.ops import wkv_chunked
from repro.kernels.wkv.ref import wkv_ref

CASES = [
    # (batch*heads, seq, head_dim, chunk)
    (2, 128, 16, 32),
    (1, 256, 32, 64),
    (4, 64, 64, 16),
]


@pytest.mark.parametrize("bh,s,d,chunk", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv_kernel_matches_recurrence(bh, s, d, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = (jax.random.normal(ks[0], (bh, s, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (bh, s, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (bh, s, d)) * 0.5).astype(dtype)
    # decays near 1 (the rwkv regime: w = exp(-exp(decay)), decay ~ -6)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (bh, s, d)) * 0.3 - 5.0))
    u = jax.random.normal(ks[4], (bh, d)) * 0.5
    s0 = jnp.zeros((bh, d, d), jnp.float32)

    # decays stay fp32 in production (models/rwkv6.py); only r/k/v narrow
    o_k, s_k = wkv_chunked(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    o_r, s_r = wkv_ref(*(t.astype(jnp.float32) for t in (r, k, v, w)),
                       u.astype(jnp.float32), s0)
    # bf16 bound = output rounding quantum at |o|~8 (state stays fp32)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
    assert float(jnp.max(jnp.abs(o_k.astype(jnp.float32) - o_r))) < tol
    assert float(jnp.max(jnp.abs(s_k - s_r))) < 1e-4


def test_wkv_state_carries_across_calls():
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    bh, s, d = 2, 128, 16
    r, k, v = (jax.random.normal(kk, (bh, s, d)) * 0.5 for kk in ks[:3])
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (bh, s, d)) * 0.3 - 5.0))
    u = jax.random.normal(ks[4], (bh, d)) * 0.5
    s0 = jnp.zeros((bh, d, d), jnp.float32)
    o_full, s_full = wkv_chunked(r, k, v, w, u, s0, chunk=32, interpret=True)
    oa, sa = wkv_chunked(r[:, :64], k[:, :64], v[:, :64], w[:, :64], u, s0,
                         chunk=32, interpret=True)
    ob, sb = wkv_chunked(r[:, 64:], k[:, 64:], v[:, 64:], w[:, 64:], u, sa,
                         chunk=32, interpret=True)
    assert float(jnp.max(jnp.abs(jnp.concatenate([oa, ob], 1) - o_full))) < 1e-4
    assert float(jnp.max(jnp.abs(sb - s_full))) < 1e-4
