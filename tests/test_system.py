"""End-to-end behaviour of the paper's system: derive -> validate ->
integrate -> deploy, with the published claims as assertions."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paper_tables as pt
from repro.core.backends import MockLLMBackend
from repro.core.domains import DOMAINS
from repro.core.energy import amortization
from repro.core.pipeline import derive_mapping
from repro.kernels.domain_map.ops import map_coordinates
from repro.kernels.tri_attn.ops import causal_attention, grid_steps
from repro.kernels.tri_attn.ref import causal_attention_ref


def test_full_pipeline_tri2d_to_kernel():
    """Fig. 3 end-to-end: sample -> infer -> synthesize -> validate ->
    deploy the derived logic as the Pallas grid map."""
    dom = DOMAINS["tri2d"]
    res = derive_mapping(dom, MockLLMBackend("OSS:120b"), stage=20,
                         n_validate=10_000)
    assert res.perfect and res.complexity_class == "O(1)"

    # the derived λ->(i,j) logic is exactly the kernel's index_map — deploy:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (1, 2, 128, 32)) for kk in ks)
    out = causal_attention(q, k, v, 32, 32, "mapped", True)
    ref = causal_attention_ref(q, k, v)
    assert float(jnp.max(jnp.abs(out - ref))) < 3e-5

    # zero wasted grid steps vs the BB baseline (paper Fig. 1)
    assert grid_steps(128, 32, "mapped") == 10
    assert grid_steps(128, 32, "bounding_box") == 16


def test_pipeline_respects_published_stratification():
    """Cells the paper scored 100% ordered must live-validate at 100%;
    (NC) cells must fail synthesis; sub-1%-any Menger cells must not pass."""
    n_perfect = 0
    for dom_name, table in pt.ACCURACY.items():
        dom = DOMAINS[dom_name]
        gt = dom.enumerate_points(5000)
        for model, rows in table.items():
            for stage, (o, a, ok) in zip(pt.STAGES, rows):
                if o >= 100 and ok:
                    res = derive_mapping(dom, MockLLMBackend(model), stage,
                                         n_validate=5000, gt=gt)
                    assert res.perfect, (dom_name, model, stage)
                    n_perfect += 1
    assert n_perfect == 34  # number of 100%-ordered cells in Tables II-VII


def test_menger_limit():
    """No model reaches a perfect Menger mapping (the 'Menger Limit')."""
    dom = DOMAINS["menger3d"]
    gt = dom.enumerate_points(4000)
    for model in pt.MODELS:
        res = derive_mapping(dom, MockLLMBackend(model), 100,
                             n_validate=4000, gt=gt)
        assert not res.perfect, model


def test_headline_claims_magnitude():
    """Abstract: up to ~4833x speedup / ~2890x energy reduction for the 3D
    fractal. Our exact block accounting is *more* favorable than the paper's
    (their BB count was projected from a smaller run), so assert >=."""
    am = amortization(DOMAINS["sierpinski3d"], "bitwise", inference_j=5000.0)
    assert am.speedup >= pt.CLAIM_SPEEDUP
    assert am.energy_reduction >= pt.CLAIM_ENERGY_REDUCTION
    assert am.runs_to_break_even < 1.0  # amortized on the first run


def test_mapped_kernel_coords_feed_real_work():
    """Deployment: mapped coords drive a scatter workload (oracle check)."""
    n = 2048
    ext = DOMAINS["gasket2d"].bounding_box_extent(n)
    coords = map_coordinates("gasket2d", n, interpret=True)
    grid = np.zeros(ext, np.int32)
    np.add.at(grid, (coords[:, 0], coords[:, 1]), 1)
    # bijective: every touched cell exactly once, count == N
    assert grid.max() == 1 and grid.sum() == n
    inside = DOMAINS["gasket2d"].contains(np.argwhere(grid == 1))
    assert inside.all()
