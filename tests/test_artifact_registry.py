"""MappingArtifact layer: registry resolution, variant logic coverage at
large lambda, content-addressed derivation cache, and artifact-driven
kernel deployment."""
import numpy as np
import pytest

from repro.core import maps, validate
from repro.core.artifact import ArtifactCache, MappingArtifact, cache_key
from repro.core.backends import MockLLMBackend, build_prompt
from repro.core.domains import DOMAINS
from repro.core.pipeline import derive_mapping, run_grid
from repro.core.registry import REGISTRY, MapRegistry, register_map

ALL_DOMAINS = sorted(DOMAINS)
LARGE_LAMBDAS = (10**6, 10**6 + 7, 10**7 + 13, 123_456_789, 10**9 + 1)


class CountingBackend:
    """MockLLMBackend wrapper that counts `generate` calls."""

    def __init__(self, model: str):
        self._inner = MockLLMBackend(model)
        self.name = self._inner.name
        self.calls = 0

    def generate(self, prompt, *, meta):
        self.calls += 1
        return self._inner.generate(prompt, meta=meta)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_DOMAINS)
def test_registry_ground_truth_has_all_tiers(name):
    entry = REGISTRY.ground_truth(name)
    assert entry.ground_truth
    for tier in ("scalar", "unmap", "numpy", "jnp", "pallas", "membership"):
        assert callable(REGISTRY.tier(name, None, tier)), (name, tier)


def test_registry_unknown_domain_and_logic_raise():
    with pytest.raises(KeyError):
        REGISTRY.resolve("moebius9d")
    with pytest.raises(KeyError):
        REGISTRY.resolve("tri2d", "quantum_annealing")
    with pytest.raises(KeyError):
        REGISTRY.ground_truth("tri2d").tier("nope")


def test_registry_duplicate_tier_rejected_without_overwrite():
    reg = MapRegistry()
    reg.register("toy", "analytical", tiers={"scalar": lambda n: (n,)})
    with pytest.raises(ValueError):
        reg.register("toy", "analytical", tiers={"scalar": lambda n: (n,)})
    reg.register("toy", "analytical", tiers={"scalar": lambda n: (n, 0)},
                 overwrite=True)
    assert reg.resolve("toy", "analytical").scalar(3) == (3, 0)


def test_one_file_plugin_registration():
    """A new geometry is one register_map call on a fresh registry."""
    reg = MapRegistry()

    @register_map("diag1d", "analytical", tier="scalar",
                  complexity_class="O(1)", ground_truth=True, registry=reg)
    def map_diag(lam):
        return (lam, lam)

    entry = reg.ground_truth("diag1d")
    assert entry.scalar(7) == (7, 7)
    assert entry.complexity_class == "O(1)"
    assert reg.logics("diag1d") == ["analytical"]
    assert ("diag1d", "analytical") in reg


def test_variant_maps_view_matches_registry():
    """The compatibility dicts are views of the registry, not a fork."""
    for (dom, logic), fn in maps.VARIANT_MAPS.items():
        assert REGISTRY.resolve(dom, logic).scalar is fn
    for dom, fn in maps.SCALAR_MAPS.items():
        assert REGISTRY.ground_truth(dom).scalar is fn


# ---------------------------------------------------------------------------
# Variant logic classes at large lambda (>= 10^6)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lam", LARGE_LAMBDAS)
@pytest.mark.parametrize("dom_logic", sorted(maps.VARIANT_MAPS))
def test_variant_agrees_with_analytical_large_lambda(dom_logic, lam):
    dom, logic = dom_logic
    variant = maps.VARIANT_MAPS[dom_logic]
    assert tuple(variant(lam)) == tuple(maps.SCALAR_MAPS[dom](lam)), dom_logic


@pytest.mark.parametrize("lam", LARGE_LAMBDAS)
@pytest.mark.parametrize("dom_logic", sorted(maps.VARIANT_MAPS))
def test_variant_roundtrips_through_unmap_large_lambda(dom_logic, lam):
    dom, logic = dom_logic
    coords = maps.VARIANT_MAPS[dom_logic](lam)
    assert maps.unmap(dom)(*coords) == lam, dom_logic


# ---------------------------------------------------------------------------
# Content-addressed artifact cache
# ---------------------------------------------------------------------------


def test_cache_hit_skips_inference_and_validation(tmp_path, monkeypatch):
    """Second derivation of the same cell: zero generate calls, zero
    re-validation — the amortization claim, literally."""
    cache = ArtifactCache(tmp_path)
    dom = DOMAINS["tri2d"]
    b1 = CountingBackend("OSS:120b")
    r1 = derive_mapping(dom, b1, 20, n_validate=3000, cache=cache)
    assert b1.calls == 1 and r1.perfect and not r1.cache_hit

    def _boom(*a, **kw):  # any re-validation on the hit path is a bug
        raise AssertionError("validation must not run on a cache hit")

    monkeypatch.setattr(validate, "validate_scalar_fn", _boom)
    b2 = CountingBackend("OSS:120b")
    r2 = derive_mapping(dom, b2, 20, n_validate=3000, cache=cache)
    assert b2.calls == 0
    assert r2.cache_hit and r2.cache_key == r1.cache_key
    assert r2.report == r1.report
    assert r2.complexity_class == r1.complexity_class
    assert r2.inference_joules == r1.inference_joules
    assert cache.hits == 1


def test_cache_key_separates_cells(tmp_path):
    prompt = build_prompt(DOMAINS["tri2d"], 20)
    base = cache_key("tri2d", "OSS:120b", 20, prompt, n_validate=1000)
    assert cache_key("tri2d", "R1:70b", 20, prompt, n_validate=1000) != base
    assert cache_key("tri2d", "OSS:120b", 50, prompt, n_validate=1000) != base
    assert cache_key("tri2d", "OSS:120b", 20, prompt + "x",
                     n_validate=1000) != base
    assert cache_key("tri2d", "OSS:120b", 20, prompt, n_validate=2000) != base
    assert cache_key("tri2d", "OSS:120b", 20, prompt, n_validate=1000) == base


def test_cache_caches_noncompiling_cells_too(tmp_path):
    """NC cells cost inference joules as well — they amortize identically."""
    cache = ArtifactCache(tmp_path)
    dom = DOMAINS["gasket2d"]
    b1 = CountingBackend("Qw3:235b")
    r1 = derive_mapping(dom, b1, 20, n_validate=2000, cache=cache)
    assert not r1.compiled and r1.error
    b2 = CountingBackend("Qw3:235b")
    r2 = derive_mapping(dom, b2, 20, n_validate=2000, cache=cache)
    assert b2.calls == 0 and r2.cache_hit
    assert not r2.compiled and r2.error == r1.error
    assert r2.artifact is None


def test_cache_corrupt_record_is_a_miss(tmp_path):
    cache = ArtifactCache(tmp_path)
    dom = DOMAINS["tri2d"]
    r1 = derive_mapping(dom, CountingBackend("OSS:120b"), 20,
                        n_validate=2000, cache=cache)
    cache.path(r1.cache_key).write_text("{not json")
    b = CountingBackend("OSS:120b")
    r2 = derive_mapping(dom, b, 20, n_validate=2000, cache=cache)
    assert b.calls == 1 and not r2.cache_hit and r2.perfect


def test_run_grid_reuses_cache(tmp_path):
    cache = ArtifactCache(tmp_path)
    backends = []

    def factory(model):
        b = CountingBackend(model)
        backends.append(b)
        return b

    kw = dict(domains=["tri2d"], models=["OSS:120b", "R1:70b"],
              stages=(20, 50), n_validate=2000, sample_every=1,
              backend_factory=factory, cache=cache)
    g1 = run_grid(**kw)
    assert len(g1) == 4 and sum(b.calls for b in backends) == 4
    backends.clear()
    g2 = run_grid(**kw)
    assert all(r.cache_hit for r in g2.values())
    assert sum(b.calls for b in backends) == 0
    for key in g1:
        assert g2[key].report == g1[key].report


# ---------------------------------------------------------------------------
# Artifact-driven deployment
# ---------------------------------------------------------------------------


def test_artifact_roundtrip_rebuilds_scalar(tmp_path):
    cache = ArtifactCache(tmp_path)
    dom = DOMAINS["pyramid3d"]
    derive_mapping(dom, CountingBackend("Qw3:32b"), 100,
                   n_validate=3000, cache=cache)
    r2 = derive_mapping(dom, CountingBackend("Qw3:32b"), 100,
                        n_validate=3000, cache=cache)
    art = r2.artifact
    assert r2.cache_hit and art is not None
    for lam in (0, 5, 1000, 10**6):
        assert tuple(art.scalar_fn()(lam)) == tuple(maps.map_pyramid3d(lam))
    rec = art.to_record()
    art2 = MappingArtifact.from_record(rec)
    assert art2.report == art.report and art2.report_digest == art.report_digest


def test_artifact_drives_pallas_kernel(tmp_path):
    from repro.kernels.domain_map.ops import map_coordinates
    from repro.kernels.domain_map.ref import map_coordinates_ref

    res = derive_mapping(DOMAINS["tri2d"], CountingBackend("OSS:120b"), 20,
                         n_validate=3000, cache=ArtifactCache(tmp_path))
    art = res.artifact
    assert art.deployable
    got = map_coordinates(art, 2048, interpret=True)
    np.testing.assert_array_equal(got, map_coordinates_ref("tri2d", 2048))


def test_non_deployable_artifact_rejected(tmp_path):
    from repro.kernels.domain_map.ops import map_coordinates

    # the 'Menger limit': no model derives a perfect menger3d map
    res = derive_mapping(DOMAINS["menger3d"], CountingBackend("R1:70b"), 100,
                         n_validate=2000, cache=ArtifactCache(tmp_path))
    art = res.artifact
    assert art is not None and not art.deployable
    with pytest.raises(ValueError):
        map_coordinates(art, 1024, interpret=True)


def test_artifact_registers_into_registry(tmp_path):
    res = derive_mapping(DOMAINS["tri2d"], CountingBackend("OSS:120b"), 50,
                         n_validate=2000, cache=ArtifactCache(tmp_path))
    reg = MapRegistry()
    entry = res.artifact.register(reg)
    assert entry.logic == "derived:OSS:120b:s50"
    assert reg.resolve("tri2d", entry.logic).scalar(10) == maps.map_tri2d(10)


def test_artifact_deployment_analytics(tmp_path):
    from repro.launch.analytic import artifact_deployment_analytics

    res = derive_mapping(DOMAINS["sierpinski3d"], CountingBackend("OSS:120b"),
                         100, n_validate=2000, cache=ArtifactCache(tmp_path))
    art = res.artifact
    assert art.deployable
    dep = artifact_deployment_analytics(art)
    assert dep["logic"] == "bitwise"
    assert dep["speedup"] > 1000 and dep["energy_reduction"] > 1000
    assert dep["runs_to_break_even"] < 1.0  # amortized on the first run
