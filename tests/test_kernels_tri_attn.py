"""tri_attn Pallas kernel: shape/dtype sweep vs the jnp oracle (interpret)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.tri_attn.kernel import lam_to_ij, tri_grid_size
from repro.kernels.tri_attn.ops import causal_attention, grid_steps
from repro.kernels.tri_attn.ref import causal_attention_ref

CASES = [
    # (batch, heads, seq, head_dim, block)
    (1, 1, 128, 64, 32),
    (1, 2, 256, 64, 64),
    (2, 1, 128, 128, 32),
    (1, 1, 256, 32, 128),
    (2, 2, 64, 16, 16),
]


@pytest.mark.parametrize("b,h,s,d,blk", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["mapped", "bounding_box"])
def test_kernel_matches_oracle(b, h, s, d, blk, dtype, mode):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d), dtype) for kk in ks)
    out = causal_attention(q, k, v, blk, blk, mode, True)
    ref = causal_attention_ref(q, k, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    assert float(jnp.max(jnp.abs(
        out.astype(jnp.float32) - ref.astype(jnp.float32)))) < tol


def test_gqa_repeat_path():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = causal_attention(q, k, v, 32, 32, "mapped", True)
    kr = jnp.repeat(k, 2, axis=1)
    vr = jnp.repeat(v, 2, axis=1)
    ref = causal_attention_ref(q, kr, vr)
    assert float(jnp.max(jnp.abs(out - ref))) < 3e-5


def test_gradients_flow_through_kernel():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (1, 1, 64, 32)) for kk in ks)

    def loss_kernel(q):
        return causal_attention(q, k, v, 32, 32, "mapped", True).sum()

    def loss_ref(q):
        return causal_attention_ref(q, k, v).sum()

    g1 = jax.grad(loss_kernel)(q)
    g2 = jax.grad(loss_ref)(q)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-5


def test_mapped_grid_is_exact_triangular():
    """λ-grid enumerates exactly the lower-triangular block pairs in order."""
    nb = 7
    lams = jnp.arange(tri_grid_size(nb))
    i, j = jax.vmap(lam_to_ij)(lams)
    seen = list(zip(i.tolist(), j.tolist()))
    expect = [(a, b) for a in range(nb) for b in range(a + 1)]
    assert seen == expect


def test_waste_accounting():
    """BB grid wastes nb(nb-1)/2 steps; mapped wastes none (paper Fig. 1)."""
    s, blk = 4096, 128
    nb = s // blk
    assert grid_steps(s, blk, "bounding_box") == nb * nb
    assert grid_steps(s, blk, "mapped") == nb * (nb + 1) // 2
    waste = 1 - grid_steps(s, blk, "mapped") / grid_steps(s, blk, "bounding_box")
    assert waste == pytest.approx(0.5 - 0.5 / nb)
