"""Tiered ArtifactStore lifecycle: memory-LRU eviction order, disk TTL +
max-bytes eviction over the access-time index, schema-version migration of
legacy records, checksum-mismatch quarantine, read-through promotion, and
the env/CLI construction surface."""
import json
import os
import threading
import time
from pathlib import Path

import pytest

from repro.core.store import (
    SCHEMA_VERSION, DiskStore, MemoryStore, NullLock, PeerStore, TieredStore,
    build_store, default_store, finalize_record, record_checksum, valid_key,
)

REC = {"domain": "tri2d", "model": "OSS:120b", "stage": 20, "compiled": True}


def padded(n_bytes: int = 1024, **over) -> dict:
    return {**REC, "pad": "x" * n_bytes, **over}


# ---------------------------------------------------------------------------
# MemoryStore — bounded LRU
# ---------------------------------------------------------------------------


def test_memory_lru_evicts_least_recently_used():
    m = MemoryStore(max_entries=2)
    m.store("a", padded(8))
    m.store("b", padded(8))
    m.load("a")            # refresh a: b is now the LRU entry
    m.store("c", padded(8))
    assert "a" in m and "c" in m and "b" not in m
    assert m.evictions == 1
    assert m.keys() == ["a", "c"]  # least-recent first
    # store-refresh moves an existing key to MRU as well
    m.store("a", padded(8))
    m.store("d", padded(8))
    assert m.keys() == ["a", "d"] and "c" not in m


def test_memory_store_remembers_rehydrated_results():
    m = MemoryStore(max_entries=4)
    m.store("k", padded(8))
    assert m.load_result("k") is None
    token = object()
    m.remember_result("k", token)
    assert m.load_result("k") is token
    assert m.result_hits == 1
    m.delete("k")
    assert m.load_result("k") is None
    # remembering against an evicted/absent key is a silent no-op
    m.remember_result("gone", token)
    assert m.load_result("gone") is None


def test_memory_zero_entries_disables_tier():
    m = MemoryStore(max_entries=0)
    m.store("k", padded(8))
    assert m.load("k") is None and len(m) == 0


# ---------------------------------------------------------------------------
# DiskStore — versioned records, TTL/size eviction, migration, quarantine
# ---------------------------------------------------------------------------


def test_disk_roundtrip_stamps_versioned_checksummed_envelope(tmp_path):
    d = DiskStore(tmp_path)
    d.store("k", padded(16))
    on_disk = json.loads(d.path("k").read_text())
    assert on_disk["schema"] == SCHEMA_VERSION
    assert on_disk["key"] == "k"
    assert on_disk["checksum"] == record_checksum(on_disk)
    rec = d.load("k")
    assert rec["domain"] == "tri2d" and d.hits == 1


def test_disk_ttl_evicts_idle_records(tmp_path):
    d = DiskStore(tmp_path, ttl_seconds=0.2)
    d.store("old", padded(16))
    time.sleep(0.3)
    d.store("fresh", padded(16))  # publish triggers opportunistic eviction
    assert "old" not in d and "fresh" in d
    assert d.evictions_ttl == 1
    # a loaded (touched) record is not idle: access refreshes the clock
    time.sleep(0.15)
    assert d.load("fresh") is not None
    time.sleep(0.15)
    assert d.evict()["ttl"] == 0  # accessed 0.15s ago < 0.2s ttl
    assert "fresh" in d


def test_disk_max_bytes_evicts_least_recently_accessed(tmp_path):
    probe = DiskStore(tmp_path / "probe")
    probe.store("k", padded(1024))
    size = probe.path("k").stat().st_size

    d = DiskStore(tmp_path / "store", max_bytes=int(size * 2.5))
    d.store("a", padded(1024))
    time.sleep(0.05)
    d.store("b", padded(1024))
    time.sleep(0.05)
    assert d.load("a") is not None  # refresh a: b becomes the LRA record
    time.sleep(0.05)
    d.store("c", padded(1024))      # 3 records > budget for 2.5
    assert "a" in d and "c" in d and "b" not in d
    assert d.evictions_bytes == 1


def test_disk_migrates_schema1_record_in_place(tmp_path):
    d = DiskStore(tmp_path)
    legacy = {"schema": 1, "key": "k", **padded(16)}
    d.path("k").write_text(json.dumps(legacy))
    rec = d.load("k")
    assert rec["schema"] == SCHEMA_VERSION and rec["domain"] == "tri2d"
    assert d.migrated == 1 and d.hits == 1
    on_disk = json.loads(d.path("k").read_text())
    assert on_disk["schema"] == SCHEMA_VERSION
    assert on_disk["checksum"] == record_checksum(on_disk)
    # the migrated record now round-trips through the normal verified path
    d2 = DiskStore(tmp_path)
    assert d2.load("k")["domain"] == "tri2d"
    assert d2.migrated == 0


def test_disk_quarantines_checksum_mismatch(tmp_path):
    d = DiskStore(tmp_path)
    d.store("k", padded(16))
    on_disk = json.loads(d.path("k").read_text())
    on_disk["pad"] = "tampered"  # payload changed, checksum stale
    d.path("k").write_text(json.dumps(on_disk))
    assert d.load("k") is None
    assert d.quarantined == 1
    assert not d.path("k").exists()
    quarantined = tmp_path / "k.quarantined"
    assert quarantined.exists()
    assert json.loads(quarantined.read_text())["pad"] == "tampered"
    # quarantined bytes are set aside, not destroyed: clear() and an
    # unbounded store's evict() leave them for inspection
    d.store("other", padded(16))
    d.evict()
    d.clear()
    assert quarantined.exists()
    usage = d.usage()
    assert usage["quarantined_records"] == 1
    assert usage["total_bytes"] == usage["quarantined_bytes"] > 0


def test_quarantined_bytes_count_against_disk_budget(tmp_path):
    """Under byte pressure quarantined files are reclaimed *first* — a
    corrupting disk must not let set-aside bytes exceed the budget while
    live records get evicted around them."""
    probe = DiskStore(tmp_path / "probe")
    probe.store("k", padded(1024))
    size = probe.path("k").stat().st_size

    d = DiskStore(tmp_path / "store", max_bytes=int(size * 2.5))
    d.store("bad", padded(1024))
    on_disk = json.loads(d.path("bad").read_text())
    on_disk["pad"] = "y" * 1024  # same size, stale checksum
    d.path("bad").write_text(json.dumps(on_disk))
    assert d.load("bad") is None  # quarantined, still on disk
    time.sleep(0.05)
    d.store("a", padded(1024))
    time.sleep(0.05)
    d.store("b", padded(1024))   # 2 records + 1 quarantine > 2.5x budget
    assert not (tmp_path / "store" / "bad.quarantined").exists()
    assert "a" in d and "b" in d  # live records survived
    assert d.evictions_bytes == 1
    assert d.usage()["total_bytes"] <= int(size * 2.5)


def test_disk_unknown_future_schema_is_a_miss(tmp_path):
    d = DiskStore(tmp_path)
    d.path("k").write_text(json.dumps({"schema": 99, **padded(8)}))
    assert d.load("k") is None and d.misses == 1
    assert d.path("k").exists()  # not quarantined — just not ours to parse


def test_evict_reclaims_abandoned_tmp_files(tmp_path):
    """A crashed writer's orphaned ``.tmp`` (hours old) is reclaimed by
    evict(); an in-flight one (fresh mtime) is never touched."""
    d = DiskStore(tmp_path, ttl_seconds=3600.0)
    d.store("aa" * 32, padded(8))
    old = tmp_path / "orphan123.tmp"
    old.write_text("{")
    os.utime(old, (time.time() - 7200, time.time() - 7200))
    fresh = tmp_path / "inflight456.tmp"
    fresh.write_text("{")
    assert d.evict()["tmp"] == 1
    assert not old.exists() and fresh.exists()
    assert "aa" * 32 in d  # records untouched by the tmp sweep


def test_disk_delete(tmp_path):
    d = DiskStore(tmp_path)
    d.store("k", padded(8))
    assert d.delete("k") and not d.delete("k")
    assert d.deletes == 1 and "k" not in d


# ---------------------------------------------------------------------------
# TieredStore — promotion, fast paths, per-tier stats
# ---------------------------------------------------------------------------


def test_memory_tier_hit_performs_no_disk_read(tmp_path):
    t = TieredStore(memory=MemoryStore(8), disk=DiskStore(tmp_path))
    t.store("k", padded(16))
    reads_before = t.disk.reads
    for _ in range(5):
        assert t.load("k") is not None
    assert t.disk.reads == reads_before  # hot hits never touch disk
    assert t.memory.hits == 5
    assert t.stats()["memory"]["hits"] == 5


def test_memory_hits_keep_disk_recency_fresh(tmp_path):
    """Memory-shielded hits must still count as access recency for the
    disk tier's eviction index — otherwise the hottest records look
    coldest to TTL/max-bytes eviction and get evicted from disk first."""
    key = "ab" * 32
    t = TieredStore(memory=MemoryStore(8),
                    disk=DiskStore(tmp_path, ttl_seconds=0.3))
    t.store(key, padded(16))
    reads = t.disk.reads
    for _ in range(3):
        time.sleep(0.2)
        assert t.load(key) is not None   # memory hits the whole time
    assert t.disk.reads == reads         # ...with zero disk I/O
    # 0.6s wall > ttl, but the hits kept the access index fresh
    assert t.disk.evict()["ttl"] == 0
    assert key in t.disk


def test_tiered_local_only_load_skips_peer_probe():
    """The serving fast path (pre-coalescing) reads local tiers only — a
    cold cell must not cost every concurrent thread a peer probe."""
    p = PeerStore(["http://127.0.0.1:9"], timeout=0.2)
    t = TieredStore(memory=MemoryStore(2), peers=p)
    assert t.load("ab" * 32, local_only=True) is None
    assert p.errors == 0 and p.misses == 0   # peer never probed
    assert t.load("ab" * 32) is None
    assert p.errors == 1                     # full read-through does probe


def test_disk_hit_promotes_into_memory(tmp_path):
    disk = DiskStore(tmp_path)
    disk.store("k", padded(16))
    t = TieredStore(memory=MemoryStore(8), disk=disk)  # memory starts cold
    assert t.load("k") is not None   # disk hit, promoted
    reads = disk.reads
    assert t.load("k") is not None   # now a memory hit
    assert disk.reads == reads
    assert t.hits == 2 and t.misses == 0


def test_tiered_delete_and_clear_cover_all_local_tiers(tmp_path):
    t = TieredStore(memory=MemoryStore(8), disk=DiskStore(tmp_path))
    t.store("k", padded(8))
    assert t.delete("k")
    assert t.load("k") is None and t.misses == 1
    t.store("k2", padded(8))
    assert t.clear() == 1
    assert len(t) == 0 and "k2" not in t


def test_tiered_without_disk_uses_null_lock():
    t = TieredStore(memory=MemoryStore(8))
    with t.lock("k") as lock:
        assert isinstance(lock, NullLock) and not lock.broke_stale
    t.store("k", padded(8))
    assert t.load("k") is not None and t.root is None


def test_disk_publish_matches_checksum_serialization(tmp_path):
    """Any value record_checksum can serialize (default=str — e.g. a Path)
    must also publish, and a record that can't serialize at all degrades to
    None — never an exception on the serving path."""
    d = DiskStore(tmp_path)
    key = "aa" * 32
    assert d.store(key, padded(8, source_path=Path("/tmp/somewhere"))) \
        is not None
    back = d.load(key)
    assert back["source_path"] == "/tmp/somewhere"
    assert d.hits == 1 and d.quarantined == 0  # checksum verified on read
    circular: dict = {}
    circular["self"] = circular
    assert d._publish("bb" * 32, circular) is None  # swallowed, not raised


def test_valid_key_accepts_only_content_addresses():
    assert valid_key("ab" * 32)
    for bad in ("", "ab" * 31, "AB" * 32, "../secret", "ab" * 32 + "\n",
                "g" * 64, None):
        assert not valid_key(bad)


def test_peer_load_rejects_record_for_a_different_key():
    """A mis-routed peer response (valid envelope, wrong cell) must not
    verify: the checksum covers only the payload, so without the key check
    it would pass and be re-stamped under the requested key downstream —
    permanently caching the wrong mapping."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    served = finalize_record("ee" * 32, dict(REC))

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *args):
            pass

        def do_GET(self):  # noqa: N802 (http.server API)
            body = json.dumps(served).encode()
            self.send_response(200)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

    httpd = ThreadingHTTPServer(("127.0.0.1", 0), Handler)
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    try:
        p = PeerStore([f"http://127.0.0.1:{httpd.server_address[1]}"])
        assert p.load("ff" * 32) is None   # asked for ff…, served ee…
        assert p.errors == 1 and p.misses == 1
        assert p.load("ee" * 32) == served  # the matching key still verifies
        assert p.hits == 1
    finally:
        httpd.shutdown()
        httpd.server_close()
        thread.join(timeout=2.0)


def test_peer_store_degrades_cleanly_when_unreachable():
    p = PeerStore(["http://127.0.0.1:9"], timeout=0.2)
    assert p.load("k") is None
    assert p.errors == 1 and p.misses == 1
    p.store("k", padded(8))  # push failure is counted, never raised
    assert p.push_errors == 1 and p.pushes == 0
    circular: dict = {}
    circular["self"] = circular
    p.store("k2", circular)  # unserializable: also counted, never raised
    assert p.push_errors == 2 and p.pushes == 0
    t = TieredStore(memory=MemoryStore(2), peers=p)
    assert t.load("nope") is None and t.misses == 1


# ---------------------------------------------------------------------------
# Construction surface (env + knobs)
# ---------------------------------------------------------------------------


def test_build_store_assembles_requested_tiers(tmp_path):
    t = build_store(root=tmp_path, ttl_seconds=5.0, max_bytes=1 << 20,
                    memory_entries=7, peers=["http://a:1", "http://b:2/"])
    assert t.memory.max_entries == 7
    assert t.disk.ttl_seconds == 5.0 and t.disk.max_bytes == 1 << 20
    assert t.peer.peers == ["http://a:1", "http://b:2"]
    no_mem = build_store(root=tmp_path, memory_entries=0)
    assert no_mem.memory is None and no_mem.peer is None


def test_build_store_diskless_peer_node(monkeypatch):
    """Cache opt-out + peers = a diskless memory+peer node (read-through
    replication without local persistence) — opt-out with no peers stays
    store-less."""
    monkeypatch.setenv("REPRO_ARTIFACT_CACHE", "off")
    t = build_store(peers=["http://a:1"])
    assert t is not None and t.disk is None
    assert t.memory is not None and t.peer.peers == ["http://a:1"]
    assert build_store() is None


def test_default_store_honors_env_knobs(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_ARTIFACT_CACHE", str(tmp_path))
    monkeypatch.setenv("REPRO_STORE_TTL", "9.5")
    monkeypatch.setenv("REPRO_STORE_MAX_BYTES", "4096")
    monkeypatch.setenv("REPRO_MEMORY_ENTRIES", "3")
    monkeypatch.setenv("REPRO_PEERS", "http://a:1, http://b:2")
    t = default_store()
    assert t.disk.ttl_seconds == 9.5 and t.disk.max_bytes == 4096
    assert t.memory.max_entries == 3
    assert t.peer.peers == ["http://a:1", "http://b:2"]
    assert default_store() is t  # memoized: counters accumulate
    monkeypatch.setenv("REPRO_ARTIFACT_CACHE", "off")
    diskless = default_store()  # peers still configured: diskless node
    assert diskless is not None and diskless.disk is None
    assert diskless.peer.peers == ["http://a:1", "http://b:2"]
    monkeypatch.delenv("REPRO_PEERS")
    assert default_store() is None  # full opt-out


def test_finalize_record_is_idempotent():
    rec = finalize_record("k", dict(REC))
    assert finalize_record("k", rec) is rec
    rekeyed = finalize_record("k2", rec)
    assert rekeyed["key"] == "k2"
    assert rekeyed["checksum"] == rec["checksum"]  # payload unchanged


# ---------------------------------------------------------------------------
# Served lifecycle: the service on a tiered store
# ---------------------------------------------------------------------------


def test_service_hot_path_skips_disk_and_rehydration(tmp_path):
    from repro.serving import MappingService

    svc = MappingService(store=build_store(root=tmp_path),
                         n_validate=2000, sample_every=1)
    first = svc.derive("tri2d", "OSS:120b", 20)
    warm = svc.derive("tri2d", "OSS:120b", 20)     # memory record + rehydrate
    reads = svc.store.disk.reads
    hot = svc.derive("tri2d", "OSS:120b", 20)      # remembered result
    assert svc.store.disk.reads == reads           # no disk read
    assert hot is warm                             # no reconstruction either
    assert not first.cache_hit and warm.cache_hit and hot.cache_hit
    stats = svc.store_stats()
    assert stats["memory"]["result_hits"] == 1
    assert svc.stats.cache_hits == 2


def test_service_survives_memory_eviction_via_disk(tmp_path):
    from repro.serving import MappingService

    svc = MappingService(store=build_store(root=tmp_path, memory_entries=1),
                         n_validate=2000, sample_every=1)
    svc.derive("tri2d", "OSS:120b", 20)
    svc.derive("gasket2d", "OSS:120b", 20)   # evicts tri2d from memory
    res = svc.derive("tri2d", "OSS:120b", 20)
    assert res.cache_hit                     # disk tier caught it
    assert svc.stats.derivations == 2
    assert svc.store.memory.evictions >= 1
    assert svc.store.disk.hits >= 1


@pytest.mark.skipif(os.name != "posix", reason="posix path semantics")
def test_env_int_float_parsers_reject_gracefully(monkeypatch, tmp_path):
    """Empty knob strings mean 'unset', not zero — and a malformed value
    degrades to unset with a warning instead of crashing every store
    construction in the process."""
    monkeypatch.setenv("REPRO_ARTIFACT_CACHE", str(tmp_path))
    monkeypatch.setenv("REPRO_STORE_TTL", "")
    monkeypatch.setenv("REPRO_STORE_MAX_BYTES", " ")
    monkeypatch.delenv("REPRO_MEMORY_ENTRIES", raising=False)
    monkeypatch.delenv("REPRO_PEERS", raising=False)
    t = default_store()
    assert t.disk.ttl_seconds is None and t.disk.max_bytes is None
    assert t.memory.max_entries == 256 and t.peer is None

    monkeypatch.setenv("REPRO_STORE_TTL", "7d")       # not a number
    monkeypatch.setenv("REPRO_STORE_MAX_BYTES", "1G")  # not an integer
    monkeypatch.setenv("REPRO_MEMORY_ENTRIES", "many")
    with pytest.warns(UserWarning, match="malformed"):
        t = default_store()
    assert t.disk.ttl_seconds is None and t.disk.max_bytes is None
    assert t.memory.max_entries == 256
