"""Property tests for the six domains and their ground-truth maps."""
import numpy as np
import pytest

try:  # prefer real hypothesis; fall back to the deterministic shim
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.core import maps
from repro.core.domains import DOMAINS
from repro.core.inverse import isqrt, np_isqrt, np_tet_layer, tet, tri, tri_row

ALL_DOMAINS = sorted(DOMAINS)


@pytest.mark.parametrize("name", ALL_DOMAINS)
def test_map_matches_enumeration(name):
    d = DOMAINS[name]
    n = 20_000
    gt = d.enumerate_points(n)
    got = maps.np_map(name, np.arange(n))
    np.testing.assert_array_equal(got, gt)


@pytest.mark.parametrize("name", ALL_DOMAINS)
def test_membership_of_enumerated_points(name):
    d = DOMAINS[name]
    pts = d.enumerate_points(5_000)
    assert d.contains(pts).all()


@pytest.mark.parametrize("name", ALL_DOMAINS)
def test_bijectivity_no_duplicates(name):
    """The map over [0, N) must produce N distinct coordinates."""
    from repro.core.validate import encode_coords

    got = maps.np_map(name, np.arange(50_000))
    assert len(np.unique(encode_coords(got))) == 50_000


@given(st.integers(0, 10**12))
@settings(max_examples=200, deadline=None)
def test_isqrt_exact(v):
    r = isqrt(v)
    assert r * r <= v < (r + 1) * (r + 1)


@given(st.integers(0, 10**9))
@settings(max_examples=200, deadline=None)
def test_np_isqrt_matches_scalar(v):
    assert int(np_isqrt(np.array([v]))[0]) == isqrt(v)


@given(st.integers(0, 10**9))
@settings(max_examples=200, deadline=None)
def test_tri_row_inverse(lam):
    x = tri_row(lam)
    assert tri(x) <= lam < tri(x + 1)


@given(st.integers(0, 10**9))
@settings(max_examples=200, deadline=None)
def test_tet_layer_inverse(lam):
    z = int(np_tet_layer(np.array([lam]))[0])
    assert tet(z) <= lam < tet(z + 1)


@given(st.integers(0, 10**6))
@settings(max_examples=100, deadline=None)
def test_tri2d_scalar_roundtrip(lam):
    x, y = maps.map_tri2d(lam)
    assert 0 <= y <= x
    assert maps.unmap_tri2d(x, y) == lam


@given(st.integers(0, 10**6))
@settings(max_examples=100, deadline=None)
def test_pyramid3d_scalar_roundtrip(lam):
    x, y, z = maps.map_pyramid3d(lam)
    assert 0 <= y <= x <= z
    assert maps.unmap_pyramid3d(x, y, z) == lam


@pytest.mark.parametrize("name", ["gasket2d", "carpet2d", "sierpinski3d",
                                  "menger3d"])
@given(lam=st.integers(0, 10**6))
@settings(max_examples=60, deadline=None)
def test_fractal_roundtrip(name, lam):
    d = DOMAINS[name]
    c = maps.map_fractal(d, lam)
    assert maps.unmap_fractal(d, c) == lam


@given(st.integers(2, 10**4))
@settings(max_examples=50, deadline=None)
def test_variant_maps_agree_with_ground_truth(lam):
    for (dom, logic), fn in maps.VARIANT_MAPS.items():
        assert tuple(fn(lam)) == tuple(maps.SCALAR_MAPS[dom](lam)), (dom, logic)


@pytest.mark.parametrize("name", ALL_DOMAINS)
def test_jnp_map_matches_numpy(name):
    lams = np.arange(4096)
    got = np.asarray(maps.jnp_map(name, lams, ndigits=13))
    np.testing.assert_array_equal(got, maps.np_map(name, lams))


def test_paper_block_accounting():
    """Valid blocks at N=5e8 must equal the paper's 1,953,125 exactly."""
    for name in ALL_DOMAINS:
        acc = DOMAINS[name].block_accounting(500_000_000)
        assert acc["valid_blocks"] == 1_953_125
    tri = DOMAINS["tri2d"].block_accounting(500_000_000)
    # BB waste for the triangle is ~50% (paper: 1,959,359 / 3,912,484)
    assert 0.49 < tri["waste_fraction"] < 0.51
    s3 = DOMAINS["sierpinski3d"].block_accounting(500_000_000)
    assert s3["waste_fraction"] > 0.999  # fractal sparsity
