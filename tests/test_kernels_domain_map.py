"""domain_map Pallas kernels vs oracles, across all six domains."""
import numpy as np
import pytest

from repro.core.domains import DOMAINS
from repro.kernels.domain_map.ops import bb_membership, block_counts, map_coordinates
from repro.kernels.domain_map.ref import bb_membership_ref, map_coordinates_ref

ALL = sorted(DOMAINS)


@pytest.mark.parametrize("dom", ALL)
@pytest.mark.parametrize("n", [1024, 4096])
def test_map_kernel_matches_ref(dom, n):
    got = map_coordinates(dom, n, block_n=1024, interpret=True)
    np.testing.assert_array_equal(got, map_coordinates_ref(dom, n))


@pytest.mark.parametrize("dom,ext", [
    ("tri2d", (64, 64)),
    ("gasket2d", (64, 64)),
    ("carpet2d", (81, 81)),
    ("pyramid3d", (16, 16, 16)),
    ("sierpinski3d", (16, 16, 16)),
    ("menger3d", (27, 27, 27)),
])
def test_membership_kernel_matches_ref(dom, ext):
    got = bb_membership(dom, ext, block_n=1024, interpret=True)
    np.testing.assert_array_equal(got, bb_membership_ref(dom, ext))


@pytest.mark.parametrize("dom", ALL)
def test_membership_counts_match_domain_size(dom):
    """Valid cells in a full-level bounding box == |domain| at that level."""
    d = DOMAINS[dom]
    if d.kind == "dense":
        pytest.skip("box of a dense domain is not a full level")
    level = 4 if d.base <= 4 else (2 if d.base < 20 else 2)
    ext = (d.scale ** level,) * d.dim
    mask = bb_membership(dom, ext, block_n=1024, interpret=True)
    assert int(mask.sum()) == d.size(level)


def test_block_counts_paper_scale():
    bc = block_counts("tri2d", 500_000_000)
    assert bc["mapped_steps"] == 1_953_125
    assert bc["waste_fraction"] > 0.4
