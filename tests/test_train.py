"""Training substrate: optimizer, microbatching, checkpointing, FT, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train.data import DataConfig, SyntheticLM
from repro.train.fault_tolerance import (
    InjectedFailure, ResilientLoop, StepWatchdog,
)
from repro.train.train_step import TrainConfig, make_train_step


@pytest.fixture(scope="module")
def small_setup():
    cfg = get_smoke_config("yi-6b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=4))
    return cfg, params, data


def test_loss_decreases(small_setup):
    cfg, params, data = small_setup
    tcfg = TrainConfig(optimizer=opt.OptimizerConfig(
        lr=1e-3, warmup_steps=2, total_steps=40))
    step = jax.jit(make_train_step(cfg, tcfg))
    state = opt.init_state(params)
    losses = []
    for i in range(25):
        batch = jax.tree.map(jnp.asarray, data.batch_at(i))
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05


def test_microbatch_grads_equivalent(small_setup):
    """4 microbatches must produce the same update as 1 (linear grads)."""
    cfg, params, data = small_setup
    batch = jax.tree.map(jnp.asarray, data.batch_at(0))
    t1 = TrainConfig(microbatches=1)
    t4 = TrainConfig(microbatches=4)
    p1, _, m1 = jax.jit(make_train_step(cfg, t1))(
        params, opt.init_state(params), batch)
    p4, _, m4 = jax.jit(make_train_step(cfg, t4))(
        params, opt.init_state(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert err < 5e-5


def test_lr_schedule():
    c = opt.OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    assert float(opt.lr_at(c, jnp.asarray(0))) < 2e-4
    assert float(opt.lr_at(c, jnp.asarray(10))) == pytest.approx(1e-3, rel=0.01)
    assert float(opt.lr_at(c, jnp.asarray(100))) == pytest.approx(1e-4, rel=0.01)


def test_grad_clipping():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 100.0)}
    c = opt.OptimizerConfig(clip_norm=1.0, lr=1.0, weight_decay=0.0)
    _, _, m = opt.apply_updates(params, grads, opt.init_state(params), c)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_checkpoint_roundtrip(tmp_path, small_setup):
    cfg, params, _ = small_setup
    state = opt.init_state(params)
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, params, state)
    assert ckpt.latest_step(d) == 7
    restored, manifest = ckpt.restore(
        d, 7, {"params": params, "opt_state": state})
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path, small_setup):
    cfg, params, _ = small_setup
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, params)
    # a stale .tmp dir must never be picked up as a checkpoint
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    assert ckpt.latest_step(d) == 1


def test_checkpoint_gc(tmp_path, small_setup):
    cfg, params, _ = small_setup
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, params)
    ckpt.gc_old(d, keep=2)
    assert ckpt.latest_step(d) == 5
    assert not os.path.exists(os.path.join(d, "step_00000001"))


def test_resilient_loop_recovers(tmp_path, small_setup):
    """Inject a failure mid-run; loop must restore and finish all steps."""
    cfg, params, data = small_setup
    tcfg = TrainConfig()
    step = jax.jit(make_train_step(cfg, tcfg))
    fails = {"armed": True}

    def failure_hook(s):
        if s == 7 and fails["armed"]:
            fails["armed"] = False
            raise InjectedFailure("simulated host loss")

    loop = ResilientLoop(
        step_fn=step,
        batch_fn=lambda s: jax.tree.map(jnp.asarray, data.batch_at(s)),
        ckpt_dir=str(tmp_path / "ft"), ckpt_every=3,
        failure_hook=failure_hook)
    p, s, info = loop.run(params, opt.init_state(params), 0, 12)
    assert info["final_step"] == 12
    assert info["restores"] == 1
    assert int(s["step"]) >= 12  # optimizer stepped through recovery


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=3.0)
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 0.5)       # 5x median => straggler
    assert not wd.observe(11, 0.15)
    assert len(wd.stragglers) == 1


def test_data_determinism_and_host_sharding():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    d = SyntheticLM(cfg)
    b1 = d.batch_at(5)
    b2 = d.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # per-host shards are independent of when they're generated
    h0 = d.batch_at(5, host_index=0, host_count=2)
    assert h0["tokens"].shape[0] == 4
    # labels are tokens shifted by one
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
