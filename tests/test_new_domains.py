"""The m-simplex (m=2..5) and embedded-2D-fractal families as first-class
registry plugins: every tier resolvable, pallas/scalar agreement at >=10^5
points, round-trips, membership, block-waste accounting, and the full
artifact->deployment flow."""
import math

import numpy as np
import pytest

from repro.core.domains import (
    DOMAINS, EMBEDDED_FRACTAL_DOMAINS, MSIMPLEX_MS, bb_block_dims,
)
from repro.core.registry import REGISTRY
from repro.kernels.domain_map.ops import bb_membership, map_coordinates
from repro.launch.analytic import map_deployment_analytics

MSIMPLEX = tuple(f"msimplex{m}" for m in MSIMPLEX_MS)
EMBEDDED = tuple(d.name for d in EMBEDDED_FRACTAL_DOMAINS)
NEW_DOMAINS = MSIMPLEX + EMBEDDED

N_AGREE = 102_400  # >= 10^5 points for the pallas-vs-scalar acceptance check


def test_registry_includes_both_families():
    domains = REGISTRY.domains()
    for name in NEW_DOMAINS:
        assert name in domains, name
        assert name in DOMAINS, name


@pytest.mark.parametrize("name", NEW_DOMAINS)
def test_all_six_tiers_resolvable(name):
    entry = REGISTRY.ground_truth(name)
    assert entry.ground_truth
    for tier in ("scalar", "unmap", "numpy", "jnp", "pallas", "membership"):
        assert callable(REGISTRY.tier(name, None, tier)), (name, tier)


@pytest.mark.parametrize("name", NEW_DOMAINS)
def test_pallas_tier_agrees_with_scalar_tier_1e5(name):
    """The acceptance gate: in-kernel coordinates == exact scalar map over
    >= 10^5 points."""
    scalar = REGISTRY.tier(name, None, "scalar")
    dim = DOMAINS[name].dim
    want = np.array([scalar(i) for i in range(N_AGREE)], dtype=np.int64)
    assert want.shape == (N_AGREE, dim)
    got = map_coordinates(name, N_AGREE, block_n=12_800, interpret=True)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("name", NEW_DOMAINS)
def test_scalar_unmap_roundtrip_large_lambda(name):
    scalar = REGISTRY.tier(name, None, "scalar")
    unmap = REGISTRY.tier(name, None, "unmap")
    for lam in (0, 1, 17, 4096, 10**6 + 7, 10**9 + 1):
        coords = scalar(lam)
        assert all(int(c) >= 0 for c in coords), (name, lam)
        assert unmap(*coords) == lam, (name, lam)


@pytest.mark.parametrize("name", NEW_DOMAINS)
def test_numpy_tier_matches_enumeration(name):
    d = DOMAINS[name]
    n = 20_000
    got = REGISTRY.tier(name, None, "numpy")(np.arange(n, dtype=np.int64))
    np.testing.assert_array_equal(got, d.enumerate_points(n))


@pytest.mark.parametrize("m", MSIMPLEX_MS)
def test_msimplex_jnp_tier_exact_at_large_lambda(m):
    """The int32 kernel tier must agree with the exact int64 map up to
    ~2^31/m — the stepwise-division binomial keeps intermediates in range
    (a naive product overflows m=5 beyond ~1.8e7)."""
    import jax.numpy as jnp

    from repro.core import msimplex as ms

    lams = np.array([0, 1, 18_400_000, 10**8, (2**31 - 8) // m // 2],
                    dtype=np.int64)
    want = ms.np_map_msimplex(lams, m)
    got = np.asarray(ms.vec_map_msimplex(jnp, jnp.asarray(lams, jnp.int32), m))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("m", MSIMPLEX_MS)
def test_msimplex_domain_wraps_core_math(m):
    """The Domain plugin must expose exactly core/msimplex.py's geometry."""
    from repro.core import msimplex as ms

    d = DOMAINS[f"msimplex{m}"]
    assert d.dim == m and d.kind == "dense"
    assert d.size(7) == ms.simplex_size(7, m)
    scalar = REGISTRY.tier(d.name, None, "scalar")
    for lam in (0, 5, 999, 123_456):
        assert tuple(scalar(lam)) == ms.map_msimplex(lam, m)


@pytest.mark.parametrize("name", EMBEDDED)
def test_embedded_membership_counts_full_level(name):
    """Valid cells in a full-level bounding box == |domain| at that level."""
    d = DOMAINS[name]
    level = 3
    ext = (d.scale ** level,) * d.dim
    mask = bb_membership(name, ext, block_n=1024, interpret=True)
    assert int(mask.sum()) == d.size(level)


@pytest.mark.parametrize("name", MSIMPLEX[2:])  # the dim>3 members
def test_high_dim_membership_kernel(name):
    d = DOMAINS[name]
    side = 6
    ext = (side,) * d.dim
    mask = bb_membership(name, ext, block_n=1024, interpret=True)
    # sorted tuples from side values: C(side+m-1, m)
    assert int(mask.sum()) == math.comb(side + d.dim - 1, d.dim)


def test_waste_grows_with_dimension_through_domains():
    """1 - 1/m! through the Domain accounting (not core/msimplex directly)."""
    prev = 0.0
    for m in MSIMPLEX_MS:
        acc = DOMAINS[f"msimplex{m}"].block_accounting(10**6)
        assert acc["valid_blocks"] == -(-10**6 // 256)
        assert acc["waste_fraction"] > prev
        assert acc["waste_fraction"] == pytest.approx(
            1.0 - 1.0 / math.factorial(m), abs=0.08)
        prev = acc["waste_fraction"]


def test_bb_block_dims_factorization():
    assert bb_block_dims(2) == (16, 16)
    assert bb_block_dims(3) == (8, 8, 4)
    assert bb_block_dims(4) == (4, 4, 4, 4)
    assert bb_block_dims(5) == (4, 4, 4, 2, 2)
    for dim in (2, 3, 4, 5):
        assert int(np.prod(bb_block_dims(dim))) == 256


@pytest.mark.parametrize("name", NEW_DOMAINS)
def test_deployment_analytics_registry_driven(name):
    dep = map_deployment_analytics(name, n_points=10**6)
    assert dep["domain"] == name
    assert dep["mapped_blocks"] == -(-10**6 // 256)
    assert dep["bb_blocks"] > dep["mapped_blocks"]
    assert dep["speedup"] > 1.0 and dep["energy_reduction"] > 1.0


def test_unservable_extension_domain_does_not_break_replay_bank():
    """Registering a domain the mock bank cannot serve must not poison the
    fingerprint sweep (and with it every derivation's cache key)."""
    from repro.core.backends import MockLLMBackend
    from repro.core.domains import DenseTriangularDomain, register_domain

    base_fp = MockLLMBackend("OSS:120b").cache_fingerprint
    name = "toytri_ext"
    register_domain(DenseTriangularDomain(name, "Toy Tri", 2, "dense", "O(1)"))
    try:
        fp = MockLLMBackend("OSS:120b").cache_fingerprint
        assert fp == base_fp  # unservable domain contributes no bank content
    finally:
        DOMAINS.pop(name, None)
    assert MockLLMBackend("OSS:120b").cache_fingerprint == base_fp


def test_new_domains_flow_through_artifacts(tmp_path):
    """Derive -> artifact -> kernel deployment for one member per family."""
    from repro.core.artifact import ArtifactCache
    from repro.core.backends import MockLLMBackend
    from repro.core.pipeline import derive_mapping
    from repro.launch.analytic import artifact_deployment_analytics

    cache = ArtifactCache(tmp_path)
    for name in ("msimplex5", "vicsek2d"):
        res = derive_mapping(DOMAINS[name], MockLLMBackend("OSS:120b"), 20,
                             n_validate=3000, cache=cache)
        art = res.artifact
        assert res.perfect and art is not None and art.deployable, name
        got = map_coordinates(art, 2048, interpret=True)
        want = REGISTRY.tier(name, None, "numpy")(
            np.arange(2048, dtype=np.int64))
        np.testing.assert_array_equal(got, want)
        dep = artifact_deployment_analytics(art, n_points=10**6)
        assert dep["runs_to_break_even"] >= 0.0
        # repeat derivation is a pure cache hit
        res2 = derive_mapping(DOMAINS[name], MockLLMBackend("OSS:120b"), 20,
                              n_validate=3000, cache=cache)
        assert res2.cache_hit and res2.report == res.report
