"""launch/specs structural guarantees: every arch x shape must produce
abstract inputs whose spec trees mirror the real runtime structures."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config, get_smoke_config
from repro.launch.specs import cache_specs
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_specs_mirror_init_cache(arch):
    cfg = get_smoke_config(arch)
    extra_len = cfg.encoder_seq if cfg.family == "audio" else 0
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 2, 64, extra_len))
    specs = cache_specs(cfg)
    assert (jax.tree.structure(cache)
            == jax.tree.structure(specs,
                                  is_leaf=lambda s: isinstance(s, tuple)))
    jax.tree.map(
        lambda s, c: None if len(s) == c.ndim
        else pytest.fail(f"{arch}: spec {s} vs cache shape {c.shape}"),
        specs, cache, is_leaf=lambda s: isinstance(s, tuple))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", sorted(SHAPES))
def test_input_specs_trace_on_tiny_mesh(arch, shape_name):
    """Abstract-eval every (arch, shape) step on a 1x1 mesh — catches
    structural breakage without the 512-device compile."""
    from repro.distribution import sharding as shd
    from repro.launch.specs import input_specs

    shape = SHAPES[shape_name]
    cfg = get_config(arch).replace(max_seq=shape.seq_len)
    ok, _ = applicable(cfg, shape)
    if not ok:
        pytest.skip("documented long_500k skip")
    # full configs are too big to trace quickly here; shrink to smoke dims
    # but keep the family/shape structure (batch/seq from the real shape
    # spec would explode eval_shape memory on CPU — scale them down too).
    smoke = get_smoke_config(arch)
    cfg = smoke.replace(max_seq=256)
    import dataclasses

    shape = dataclasses.replace(shape, seq_len=256, global_batch=4)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.use_sharding(mesh):
        fn, args, donate = input_specs(cfg, shape, mesh)
        out = jax.eval_shape(fn, *args)  # abstract eval only
    assert out is not None
