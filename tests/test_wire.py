"""Binary evaluation wire (PR 10): frame codec round-trips (zero-copy
hydration, dtype fidelity including the bool membership mask), format
negotiation + binary/JSON answer parity on both frontends, malformed-frame
negative paths as structured 400s over raw ``http.client`` (the keep-alive
connection survives), the encoded-response LRU, and binary passthrough
across a one-hop owner forward on a 2-node ring."""
import http.client
import io
import json
import struct
import time

import numpy as np
import pytest

from repro.core import compile_cache as cc
from repro.core.artifact import ArtifactCache
from repro.core.backends import MockLLMBackend
from repro.core.store import build_store
from repro.serving import (
    AsyncMappingHTTPServer, ClusterMembership, MappingHTTPServer,
    MappingService, RemoteMappingService, WireFormatError,
)
from repro.serving import wire
from repro.serving.evaluate import EvaluationService, hydrate_result, \
    wire_result

MODEL = "OSS:120b"
FRONTENDS = [MappingHTTPServer, AsyncMappingHTTPServer]


def local_service(tmp_path) -> MappingService:
    return MappingService(cache=ArtifactCache(tmp_path),
                          backend_factory=MockLLMBackend,
                          n_validate=2000, sample_every=1)


def _await(predicate, timeout: float = 20.0, what: str = "condition"):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return
        time.sleep(0.05)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------


def test_frame_roundtrip_preserves_dtypes_and_structure():
    payload = {
        "coords": np.arange(24, dtype=np.int32).reshape(12, 2),
        "mask": np.array([[True, False], [False, True]]),
        "lam": np.linspace(0.0, 1.0, 7, dtype=np.float64),
        "wide": np.array([1 << 40, -5], dtype=np.int64),
        "f32": np.array([1.5, -2.25], dtype=np.float32),
        "meta": {"domain": "tri2d", "n": 12, "nested": [1, "two", None],
                 "empty": np.array([], dtype=np.int32)},
        "scalar": np.int64(7),
    }
    back = wire.decode_frame(wire.encode_frame(payload))
    for field in ("coords", "mask", "lam", "wide", "f32"):
        np.testing.assert_array_equal(back[field], payload[field])
        assert back[field].dtype == payload[field].dtype
    assert back["meta"]["domain"] == "tri2d"
    assert back["meta"]["nested"] == [1, "two", None]
    assert back["meta"]["empty"].shape == (0,)
    assert back["scalar"] == 7  # numpy scalar rides the JSON header


def test_frame_normalizes_layout_and_endianness():
    """Non-contiguous and big-endian inputs encode to canonical LE bytes
    and decode value-equal."""
    base = np.arange(40, dtype=np.int32).reshape(10, 4)
    strided = base[::2, ::2]
    assert not strided.flags.c_contiguous
    big = base.astype(">i4")
    back = wire.decode_frame(wire.encode_frame(
        {"s": strided, "b": big}))
    np.testing.assert_array_equal(back["s"], strided)
    np.testing.assert_array_equal(back["b"].astype(np.int64),
                                  base.astype(np.int64))
    assert back["b"].dtype.byteorder in ("<", "=")


def test_decoded_arrays_are_zero_copy_views():
    blob = wire.encode_frame({"coords": np.arange(8, dtype=np.int32)})
    back = wire.decode_frame(blob)
    arr = back["coords"]
    assert arr.base is not None          # a view over the frame buffer,
    assert not arr.flags.writeable       # not a copy
    assert not arr.flags.owndata


def _tamper(blob: bytes, what: str) -> bytes:
    if what == "magic":
        return b"XXXX" + blob[4:]
    if what == "version":
        return blob[:4] + struct.pack("<I", 99) + blob[8:]
    if what == "header-json":
        head_len = struct.unpack_from("<I", blob, 8)[0]
        return blob[:12] + b"{" * head_len + blob[12 + head_len:]
    if what == "header-overrun":
        return blob[:8] + struct.pack("<I", (1 << 20) + 1) + blob[12:]
    if what == "truncated-header":
        return blob[:10]
    if what == "truncated-segment":
        return blob[:-3]
    if what == "trailing-garbage":
        return blob + b"\x00\x01"
    raise AssertionError(what)


@pytest.mark.parametrize("what", ["magic", "version", "header-json",
                                  "header-overrun", "truncated-header",
                                  "truncated-segment", "trailing-garbage"])
def test_malformed_frames_raise_wireformaterror(what):
    blob = wire.encode_frame({"coords": np.arange(64, dtype=np.int32)})
    with pytest.raises(WireFormatError):
        wire.decode_frame(_tamper(blob, what))


def test_header_payload_segment_consistency_is_enforced():
    # a segment whose byte count disagrees with its declared dtype x shape
    arr = np.arange(16, dtype=np.int32)
    blob = bytearray(wire.encode_frame({"a": arr}))
    head_len = struct.unpack_from("<I", blob, 8)[0]
    header = json.loads(bytes(blob[12:12 + head_len]))
    header["segments"][0]["shape"] = [15]  # 60 bytes expected, 64 shipped
    new_head = json.dumps(header).encode()
    tampered = (bytes(blob[:8]) + struct.pack("<I", len(new_head))
                + new_head + bytes(blob[12 + head_len:]))
    with pytest.raises(WireFormatError, match="needs"):
        wire.decode_frame(tampered)
    def frame_with_header(header_obj, segment_bytes=b""):
        head = json.dumps(header_obj).encode()
        return (wire.MAGIC + struct.pack("<I", wire.VERSION)
                + struct.pack("<I", len(head)) + head + segment_bytes)

    # a payload referencing a segment that does not exist
    with pytest.raises(WireFormatError, match="references segment"):
        wire.decode_frame(frame_with_header(
            {"payload": {"__nd__": 3}, "segments": []}))
    # segments the payload never references are corruption, not padding
    with pytest.raises(WireFormatError, match="never references"):
        wire.decode_frame(frame_with_header(
            {"payload": None,
             "segments": [{"dtype": "int32", "shape": [2]}]},
            struct.pack("<I", 8) + arr[:2].tobytes()))
    with pytest.raises(WireFormatError, match="JSON object"):
        wire.decode_request(wire.encode_frame([1, 2, 3]))


def test_stream_framing_roundtrip_and_truncation():
    cells = [{"i": i, "coords": np.arange(4 * (i + 1), dtype=np.int32)}
             for i in range(3)]
    stream = b"".join(wire.stream_chunk(wire.encode_frame(c))
                      for c in cells)
    back = list(wire.iter_stream(io.BytesIO(stream).read))
    assert [c["i"] for c in back] == [0, 1, 2]
    np.testing.assert_array_equal(back[2]["coords"], cells[2]["coords"])
    # EOF mid-frame is an error, not a silent stop
    with pytest.raises(WireFormatError, match="truncated"):
        list(wire.iter_stream(io.BytesIO(stream[:-5]).read))
    with pytest.raises(WireFormatError, match="truncated"):
        list(wire.iter_stream(io.BytesIO(stream[:2]).read))


def test_wire_cache_generations_and_artifact_invalidation():
    cache = wire.WireCache(entries=2)
    cell = ("bin", "single", ("k",))
    cache.put(cell, b"blob", generation=0, artifact_keys=("aa" * 32,))
    assert cache.get(cell, 0) == b"blob"
    # compile-cache rotation bumps the generation: stale entry stops serving
    assert cache.get(cell, 1) is None
    assert cache.stats_dict()["entries"] == 0
    cache.put(cell, b"blob", artifact_keys=("aa" * 32,))
    cache.invalidate_artifact("aa" * 32)
    assert cache.get(cell) is None
    # LRU evicts the oldest cell
    cache.put(("a",), b"1")
    cache.put(("b",), b"2")
    cache.put(("c",), b"3")
    assert cache.get(("a",)) is None and cache.get(("c",)) == b"3"
    stats = cache.stats_dict()
    assert stats["capacity"] == 2 and stats["hits"] >= 1


# ---------------------------------------------------------------------------
# negotiation + parity, both frontends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("frontend", FRONTENDS)
def test_negotiation_and_parity_over_raw_http(tmp_path, frontend):
    """Accept header, ?format=binary, and a binary request body each flip
    the response to binary; absent all three the answer stays JSON — and
    both framings carry numerically identical arrays."""
    svc = local_service(tmp_path)
    with frontend(svc) as server:
        conn = http.client.HTTPConnection(server.host, server.port)
        body = json.dumps({"domain": "tri2d", "n_points": 96,
                           "block_n": 128}).encode()

        def post(path, payload, headers):
            conn.request("POST", path, payload,
                         {"Content-Type": "application/json", **headers})
            resp = conn.getresponse()
            return resp.status, resp.getheader("Content-Type"), resp.read()

        st, ctype, raw = post("/v1/evaluate", body,
                              {"Accept": wire.CONTENT_TYPE})
        assert st == 200 and wire.is_binary(ctype)
        via_accept = wire.decode_frame(raw)

        st, ctype, raw = post("/v1/evaluate?format=binary", body, {})
        assert st == 200 and wire.is_binary(ctype)
        via_query = wire.decode_frame(raw)

        conn.request("POST", "/v1/evaluate",
                     wire.encode_frame(json.loads(body)),
                     {"Content-Type": wire.CONTENT_TYPE})
        resp = conn.getresponse()
        assert resp.status == 200
        assert wire.is_binary(resp.getheader("Content-Type"))
        via_body = wire.decode_frame(resp.read())

        st, ctype, raw = post("/v1/evaluate", body, {})
        assert st == 200 and ctype.startswith("application/json")
        via_json = hydrate_result(json.loads(raw))

        for res in (via_query, via_body, via_json):
            np.testing.assert_array_equal(res["coords"],
                                          via_accept["coords"])
            assert res["coords"].dtype == via_accept["coords"].dtype
        conn.close()


@pytest.mark.parametrize("frontend", FRONTENDS)
def test_binary_and_json_clients_agree_end_to_end(tmp_path, frontend):
    """The negotiated client and the JSON fallback client get the same
    dicts back — single, batch (membership mask as real bools), and the
    sweep stream — through either frontend."""
    svc = local_service(tmp_path)
    with frontend(svc) as server:
        cli_b = RemoteMappingService(server.url)
        cli_j = RemoteMappingService(server.url, binary=False)
        queries = [
            {"domain": "tri2d", "n_points": 200, "block_n": 128},
            {"domain": "gasket2d", "n_points": 128, "block_n": 128},
            {"domain": "tri2d", "tier": "membership", "extent": [12, 12]},
        ]
        got_b = cli_b.evaluate_batch(queries)
        got_j = cli_j.evaluate_batch(queries)
        for rb, rj in zip(got_b, got_j):
            assert set(rb) == set(rj)
            for field in ("coords", "mask"):
                if field in rb:
                    np.testing.assert_array_equal(rb[field], rj[field])
                    assert rb[field].dtype == rj[field].dtype
        assert got_b[2]["mask"].dtype == np.bool_  # not int32-coerced
        single_b = cli_b.evaluate("tri2d", n_points=200, block_n=128)
        np.testing.assert_array_equal(single_b["coords"],
                                      got_j[0]["coords"])
        sweep_b = list(cli_b.evaluate_sweep(["tri2d"], [64, 128],
                                            block_n=64))
        sweep_j = list(cli_j.evaluate_sweep(["tri2d"], [64, 128],
                                            block_n=64))
        assert len(sweep_b) == len(sweep_j) == 2
        for cb, cj in zip(sweep_b, sweep_j):
            np.testing.assert_array_equal(cb["coords"], cj["coords"])
        cli_b.close()
        cli_j.close()


@pytest.mark.parametrize("frontend", FRONTENDS)
def test_malformed_binary_bodies_answer_400_and_keep_alive(tmp_path,
                                                           frontend):
    """Wire-supplied garbage is a structured 400 — never a 500, and the
    keep-alive connection stays usable for the next (valid) request."""
    svc = local_service(tmp_path)
    with frontend(svc) as server:
        conn = http.client.HTTPConnection(server.host, server.port)
        good = wire.encode_frame({"domain": "tri2d", "n_points": 64})
        bad_bodies = [
            b"this is not a frame",
            _tamper(good, "version"),
            _tamper(good, "truncated-segment"),
            wire.encode_frame([1, 2]),  # frames fine, not a JSON object
        ]
        for bad in bad_bodies:
            conn.request("POST", "/v1/evaluate", bad,
                         {"Content-Type": wire.CONTENT_TYPE})
            resp = conn.getresponse()
            payload = json.loads(resp.read())
            assert resp.status == 400, payload
            assert "error" in payload
            # same connection, next request: served normally
            conn.request("POST", "/v1/evaluate", good,
                         {"Content-Type": wire.CONTENT_TYPE})
            resp = conn.getresponse()
            assert resp.status == 200
            assert wire.decode_frame(resp.read())["n_points"] == 64
        conn.close()


@pytest.mark.parametrize("frontend", FRONTENDS)
def test_repeat_evaluates_serve_from_the_wire_cache(tmp_path, frontend):
    svc = local_service(tmp_path)
    with frontend(svc) as server:
        cli = RemoteMappingService(server.url)
        first = cli.evaluate("tri2d", n_points=128, block_n=128)
        again = cli.evaluate("tri2d", n_points=128, block_n=128)
        np.testing.assert_array_equal(first["coords"], again["coords"])
        stats = cli.metrics()["evaluate_wire"]
        assert stats["entries"] >= 1
        assert stats["hits"] >= 1
        # only warm (all-executable-hit) responses are cached: the cached
        # blob must say so
        assert again["executable"] == "hit"
        cli.close()


def test_artifact_delete_invalidates_cached_wire_blobs(tmp_path):
    svc = local_service(tmp_path)
    with MappingHTTPServer(svc) as server:
        cli = RemoteMappingService(server.url)
        key = cli.derive("tri2d", MODEL, 20).cache_key
        cli.evaluate(key=key, n_points=96)   # compile (miss, uncached)
        cli.evaluate(key=key, n_points=96)   # warm: lands in the wire LRU
        assert server.eval_wire.stats_dict()["entries"] >= 1
        hits_before = server.eval_wire.stats_dict()["hits"]
        cli.evaluate(key=key, n_points=96)   # served straight off the LRU
        assert server.eval_wire.stats_dict()["hits"] == hits_before + 1
        cli.delete_artifact(key)
        assert server.eval_wire.stats_dict()["entries"] == 0
        cli.close()


# ---------------------------------------------------------------------------
# one-hop owner forward: binary passthrough
# ---------------------------------------------------------------------------


def test_forwarded_evaluate_relays_binary_verbatim(tmp_path):
    """A 2-node ring with replicas=1: the non-owner forwards an
    artifact-key evaluate to the owner and relays the owner's bytes +
    Content-Type untouched — the hop is binary end to end, and the decoded
    answer equals the owner's own."""
    def boot(name, seeds):
        svc = MappingService(store=build_store(root=tmp_path / name),
                             backend_factory=MockLLMBackend,
                             n_validate=2000, sample_every=1)
        server = MappingHTTPServer(svc).start()
        server.attach_cluster(ClusterMembership(
            server.url, seeds=seeds, replicas=1, vnodes=64,
            heartbeat_interval=0.15, down_after=2.0, sync_interval=0.3))
        return server
    a = boot("a", [])
    b = boot("b", [a.url])
    try:
        _await(lambda: all(len(s.cluster.ring.nodes) == 2 for s in (a, b)),
               what="2-node membership convergence")
        owner_cli = RemoteMappingService(a.url)
        key = owner_cli.derive("tri2d", MODEL, 20).cache_key
        owner, other = (a, b) if a.cluster.owns(key) else (b, a)
        _await(lambda: owner.service.store is not None
               and key in owner.service.store,
               what="record resident on its owner")
        assert not other.cluster.owns(key)
        assert key not in other.service.store

        reference = RemoteMappingService(owner.url).evaluate(
            key=key, n_points=96)
        conn = http.client.HTTPConnection(other.host, other.port)
        conn.request("POST", "/v1/evaluate",
                     json.dumps({"key": key, "n_points": 96}).encode(),
                     {"Content-Type": "application/json",
                      "Accept": wire.CONTENT_TYPE})
        resp = conn.getresponse()
        raw = resp.read()
        assert resp.status == 200
        assert wire.is_binary(resp.getheader("Content-Type"))
        hopped = wire.decode_frame(raw)
        np.testing.assert_array_equal(hopped["coords"],
                                      reference["coords"])
        assert hopped["coords"].dtype == reference["coords"].dtype
        assert other.forwarded >= 1      # the hop really happened
        assert other.eval_wire.stats_dict()["entries"] == 0  # relay, no cache
        conn.close()
    finally:
        for s in (a, b):
            s.close()


# ---------------------------------------------------------------------------
# wire_result/hydrate_result dtype fidelity (the JSON path)
# ---------------------------------------------------------------------------


def test_json_wire_dict_round_trips_mask_as_bool():
    ev = EvaluationService(compile_cache=cc.CompileCache(max_entries=8))
    res = ev.evaluate({"domain": "tri2d", "tier": "membership",
                       "extent": [8, 8]})
    assert res["mask"].dtype == np.bool_
    over_json = hydrate_result(json.loads(json.dumps(wire_result(res))))
    np.testing.assert_array_equal(over_json["mask"], res["mask"])
    assert over_json["mask"].dtype == np.bool_
    assert "dtype" not in over_json  # hydration consumes the annotation
    # a pre-PR-10 server's wire dict (no dtype field) still hydrates, on
    # the historical int32 default
    legacy = json.loads(json.dumps(wire_result(res)))
    legacy.pop("dtype")
    assert hydrate_result(legacy)["mask"].dtype == np.int32
