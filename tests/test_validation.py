"""Validator semantics: ordered vs any-order vs bijectivity (Sec. IV)."""
import numpy as np
import pytest

from repro.core import maps
from repro.core.domains import DOMAINS
from repro.core.validate import (
    ValidationReport, encode_coords, evaluate_candidate_array,
    validate_scalar_fn, validate_vectorized,
)


@pytest.fixture(scope="module")
def tri_gt():
    return DOMAINS["tri2d"].enumerate_points(10_000)


def test_perfect_candidate(tri_gt):
    rep = validate_vectorized(maps.np_map_tri2d, DOMAINS["tri2d"], 10_000,
                              gt=tri_gt)
    assert rep.ordered == 1.0 and rep.any_order == 1.0 and rep.bijective


def test_permuted_candidate_is_silver(tri_gt):
    """Row-reversed traversal: any-order 100%, ordered < 100%."""
    def permuted(lams):
        xy = maps.np_map_tri2d(lams)
        return np.stack([xy[:, 0], xy[:, 0] - xy[:, 1]], axis=-1)

    n = DOMAINS["tri2d"].size(140)  # full triangle => permutation is onto
    rep = validate_vectorized(permuted, DOMAINS["tri2d"], n)
    assert rep.any_order == 1.0
    assert rep.ordered < 0.2
    assert rep.bijective  # still a bijection, just reordered


def test_duplicates_detected():
    gt = DOMAINS["tri2d"].enumerate_points(1000)
    pred = gt.copy()
    pred[500:] = pred[:500]
    rep = evaluate_candidate_array(pred, gt, 1000)
    assert rep.duplicates > 0 and not rep.bijective
    assert rep.any_order == 0.5


def test_out_of_domain_detected():
    gt = DOMAINS["tri2d"].enumerate_points(1000)
    pred = gt.copy()
    pred[:, 1] += 10**6  # push everything out of the GT set
    rep = evaluate_candidate_array(pred, gt, 1000)
    assert rep.out_of_domain > 0 and rep.any_order == 0.0


def test_scalar_runtime_error_is_nc():
    rep = validate_scalar_fn(lambda n: 1 // 0, DOMAINS["tri2d"], 100)
    assert not rep.compiled and rep.ordered == 0.0


def test_scalar_wrong_arity_rejected():
    rep = validate_scalar_fn(lambda n: (n, n, n), DOMAINS["tri2d"], 100)
    assert not rep.compiled


def test_negative_coords_rejected():
    gt = DOMAINS["tri2d"].enumerate_points(100)
    pred = gt.copy()
    pred[0, 0] = -1
    rep = evaluate_candidate_array(pred, gt, 100)
    assert not rep.compiled


def test_encode_coords_unique_per_coordinate():
    pts = DOMAINS["menger3d"].enumerate_points(8000)
    keys = encode_coords(pts)
    assert len(np.unique(keys)) == len(pts)


def test_subsampled_validation(tri_gt):
    rep = validate_scalar_fn(maps.map_tri2d, DOMAINS["tri2d"], 10_000,
                             gt=tri_gt, sample_every=7)
    assert rep.ordered == 1.0 and rep.bijective


def test_report_pct_properties():
    rep = ValidationReport(100, 0.5, 0.75, False, 1, 2)
    assert rep.ordered_pct == 50.0 and rep.any_order_pct == 75.0
