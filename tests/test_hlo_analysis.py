"""hlo_analysis: trip-count-aware FLOPs/collective extraction, validated on
a compiled module with hand-computable costs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_computations


@pytest.fixture(scope="module")
def scan_matmul_hlo():
    """scan of 7 (64x64)@(64x64) matmuls -> known 7 * 2*64^3 FLOPs."""
    w = jnp.ones((64, 64), jnp.float32)

    def step(x, _):
        return x @ w, None

    def fn(x):
        out, _ = jax.lax.scan(step, x, None, length=7)
        return out

    compiled = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    return compiled.as_text()


def test_trip_count_multiplication(scan_matmul_hlo):
    res = analyze(scan_matmul_hlo)
    expect = 7 * 2 * 64 ** 3
    assert res["flops"] == pytest.approx(expect, rel=0.01), res["flops"]


def test_entry_detection(scan_matmul_hlo):
    comps, entry = parse_computations(scan_matmul_hlo)
    assert entry is not None and entry in comps


def test_flat_matmul_flops():
    def fn(a, b):
        return a @ b

    sds = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    sds2 = jax.ShapeDtypeStruct((128, 16), jnp.float32)
    txt = jax.jit(fn).lower(sds, sds2).compile().as_text()
    res = analyze(txt)
    assert res["flops"] == pytest.approx(2 * 32 * 128 * 16, rel=0.01)


def test_no_collectives_on_single_device():
    txt = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32)).compile().as_text()
    res = analyze(txt)
    assert res["collectives"]["total_bytes"] == 0
