"""Async serving core end-to-end: the event-loop frontend speaks the same
wire surface as the threaded server (pooled keep-alive clients work against
either unchanged), typed backend errors map to 503/504 and surface as typed
client errors after retry exhaustion, slow readers stall only their own
stream, and the frontend sheds with 503 under admission pressure."""
import json
import socket
import threading
import time
import urllib.request

import pytest

from repro.core.artifact import ArtifactCache
from repro.core.backends import (
    EngineBackend, LLMBusyError, LLMTimeoutError, MockLLMBackend,
)
from repro.serving import (
    AsyncMappingHTTPServer, BatchingBackend, MappingHTTPServer,
    MappingService, RemoteBusyError, RemoteMappingService,
    RemoteTimeoutError,
)
from repro.serving.async_engine import AsyncEngineBackend

MODEL = "OSS:120b"


class CountingBackend:
    """Thread-safe MockLLMBackend wrapper counting `generate` calls, with a
    small sleep so concurrent requests genuinely overlap."""

    def __init__(self, model: str, delay: float = 0.05):
        self._inner = MockLLMBackend(model)
        self.name = self._inner.name
        self.calls = 0
        self.delay = delay
        self._mu = threading.Lock()

    @property
    def cache_fingerprint(self):
        return self._inner.cache_fingerprint

    def generate(self, prompt, *, meta):
        with self._mu:
            self.calls += 1
        time.sleep(self.delay)
        return self._inner.generate(prompt, meta=meta)


class TimeoutBackend:
    """Backend whose every generate blows its deadline — the 504 story."""

    def __init__(self, model: str):
        self._inner = MockLLMBackend(model)
        self.name = self._inner.name
        self.calls = 0
        self._mu = threading.Lock()

    @property
    def cache_fingerprint(self):
        return self._inner.cache_fingerprint

    def generate(self, prompt, *, meta):
        with self._mu:
            self.calls += 1
        raise LLMTimeoutError(f"inference on {self.name!r} timed out")


def shared_factory(cls=CountingBackend, **bkw):
    bank: dict = {}
    mu = threading.Lock()

    def factory(model: str):
        with mu:
            if model not in bank:
                bank[model] = cls(model, **bkw)
            return bank[model]

    factory.bank = bank
    return factory


def make_service(tmp_path, factory, **kw):
    kw.setdefault("n_validate", 2000)
    kw.setdefault("sample_every", 1)
    return MappingService(cache=ArtifactCache(tmp_path),
                         backend_factory=factory, **kw)


def make_async(tmp_path, factory, *, service_kw=None, **kw):
    svc = make_service(tmp_path, factory, **(service_kw or {}))
    return AsyncMappingHTTPServer(svc, **kw)


def post_json(url, path, payload):
    req = urllib.request.Request(
        url + path, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    with urllib.request.urlopen(req, timeout=30) as resp:
        return json.loads(resp.read())


# ---------------------------------------------------------------------------
# Wire parity: the pooled keep-alive client against the event loop
# ---------------------------------------------------------------------------


def test_async_frontend_serves_keepalive_client(tmp_path):
    """RemoteMappingService (pooled keep-alive transport) against the async
    frontend: derives round-trip, cache_hit is truthful (False exactly once),
    and /metrics carries the threaded payload shape plus the aio section."""
    factory = shared_factory()
    with make_async(tmp_path, factory) as server:
        client = RemoteMappingService(server.url)
        r1 = client.derive("tri2d", MODEL, 20)
        r2 = client.derive("tri2d", MODEL, 20)
        assert r1.cache_key == r2.cache_key
        assert factory.bank[MODEL].calls == 1

        # truthful cache_hit on the wire: fresh derivation says False, every
        # repeat (event-loop fast path) says True
        hits = [post_json(server.url, "/v1/derive",
                          {"domain": "cantor2d", "model": MODEL,
                           "stage": 20})["cache_hit"]
                for _ in range(3)]
        assert hits == [False, True, True]

        assert client.healthy()
        metrics = client.metrics()
        assert metrics["service"]["derivations"] == 2
        assert metrics["http"]["derive"]["requests"] == 5
        aio = metrics["aio"]
        assert aio["fast_hits"] >= 3      # r2 + the two repeats
        assert aio["wire_hits"] >= 1      # repeat #2 skipped serialization
        assert aio["offloaded"] == 2      # the two cold derivations
        assert aio["shed"] == 0

        # streamed /v1/grid through the client's NDJSON path
        cells = client.grid(["tri2d"], [MODEL], [20, 50])
        assert len(cells) == 2
        assert factory.bank[MODEL].calls == 3  # only stage 50 was new


def test_concurrent_same_cell_single_inference(tmp_path):
    """16 clients racing on one cell through the async frontend: the
    service's in-flight coalescing still guarantees exactly one backend
    inference, and every client gets the same content address."""
    factory = shared_factory()
    with make_async(tmp_path, factory) as server:
        out = {}
        mu = threading.Lock()
        gate = threading.Barrier(16)

        def client(i):
            c = RemoteMappingService(server.url)
            gate.wait()
            res = c.derive("tri2d", MODEL, 20)
            with mu:
                out[i] = res.cache_key

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        assert factory.bank[MODEL].calls == 1
        assert len(set(out.values())) == 1
        assert len(out) == 16


def test_async_engine_backend_lifecycle(tmp_path):
    """The server drives AsyncLLMBackend lifecycles: health shows up in
    /healthz, and close() tears the batcher down with the loop."""
    inner = EngineBackend(MODEL, max_new_tokens=2)
    backend = AsyncEngineBackend(inner, decode_slots=2)
    factory = shared_factory()
    server = make_async(tmp_path, factory, async_backends=[backend])
    with server:
        with urllib.request.urlopen(server.url + "/healthz",
                                    timeout=10) as resp:
            payload = json.loads(resp.read())
        assert payload["loop"] == "asyncio"
        assert payload["backends"] == {MODEL: True}
    # server close() drove backend.close(): the batcher refuses new work
    with pytest.raises(LLMBusyError):
        backend.batcher.submit("p", {})


# ---------------------------------------------------------------------------
# Typed errors on the wire: 503 shed, 504 timeout, client-side surfacing
# ---------------------------------------------------------------------------


def test_async_frontend_sheds_503_when_saturated(tmp_path):
    """Past max_pending in-flight cold derives the frontend sheds with 503;
    a no-retry client surfaces it as RemoteBusyError — which IS an
    LLMBusyError, so remote saturation reads like local saturation."""
    factory = shared_factory(delay=0.5)
    with make_async(tmp_path, factory, max_pending=1) as server:
        domains = ["tri2d", "cantor2d", "carpet2d", "gasket2d"]
        results, errors = {}, {}
        mu = threading.Lock()
        gate = threading.Barrier(len(domains))

        def client(dom):
            c = RemoteMappingService(server.url, retries=0)
            gate.wait()
            try:
                res = c.derive(dom, MODEL, 20)
                with mu:
                    results[dom] = res
            except RemoteBusyError as e:
                with mu:
                    errors[dom] = e

        threads = [threading.Thread(target=client, args=(d,))
                   for d in domains]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        assert results, "at least one derive should get the slot"
        assert errors, "the rest should be shed with 503"
        assert len(results) + len(errors) == len(domains)
        for err in errors.values():
            assert isinstance(err, LLMBusyError)
            assert err.status == 503
        assert server.shed == len(errors)


@pytest.mark.parametrize("frontend", ["threaded", "async"])
def test_backend_timeout_maps_to_504_and_surfaces_typed(tmp_path, frontend):
    """LLMTimeoutError in the backend → 504 on the wire → client retries
    with backoff → RemoteTimeoutError (an LLMTimeoutError) on exhaustion.
    Identical through either frontend."""
    factory = shared_factory(cls=TimeoutBackend)
    svc = make_service(tmp_path, factory)
    server = MappingHTTPServer(svc) if frontend == "threaded" \
        else AsyncMappingHTTPServer(svc)
    with server:
        client = RemoteMappingService(server.url, retries=2, backoff=0.01)
        with pytest.raises(RemoteTimeoutError) as exc:
            client.derive("tri2d", MODEL, 20)
        assert isinstance(exc.value, LLMTimeoutError)
        assert exc.value.status == 504
        # 504 is retryable: every attempt reached the backend
        assert factory.bank[MODEL].calls == 3


# ---------------------------------------------------------------------------
# Batching satellite: a full batch must not sleep out max_wait
# ---------------------------------------------------------------------------


def test_full_batch_dispatches_without_waiting():
    """The max_batch-th arrival dispatches the batch immediately — a burst
    never sleeps out max_wait (here 5s: failing the old gather loop's
    behavior by an order of magnitude, not a timing jitter)."""
    bb = BatchingBackend(MockLLMBackend(MODEL), max_batch=4, max_wait=5.0)
    meta = {"domain": "tri2d", "stage": 20}
    gate = threading.Barrier(4)
    done = []
    mu = threading.Lock()

    def go(i):
        gate.wait()
        r = bb.generate(f"prompt {i}", meta=meta)
        with mu:
            done.append(r)

    t0 = time.monotonic()
    threads = [threading.Thread(target=go, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    elapsed = time.monotonic() - t0
    bb.close()

    assert len(done) == 4
    assert elapsed < 2.0, (
        f"full batch took {elapsed:.2f}s — it waited out max_wait instead "
        f"of dispatching on the 4th arrival")
    assert bb.stats.batches == 1
    assert bb.stats.max_batch_seen == 4
    assert bb.stats.batched_requests == 4


# ---------------------------------------------------------------------------
# Backpressure: a stalled reader pauses its own stream, nothing else
# ---------------------------------------------------------------------------


def test_slow_grid_reader_stalls_only_its_own_stream(tmp_path):
    """A client that stops reading mid /v1/grid NDJSON pauses *production*
    for that connection (bounded by the write buffer, not the sweep size),
    while other connections keep deriving; when it resumes it gets every
    line, and the server records the stall."""
    factory = shared_factory()
    server = make_async(tmp_path, factory, stream_buffer_bytes=4096,
                        stall_threshold=0.2)
    # shrink the kernel-side send buffer so backpressure reaches the
    # transport quickly (accepted sockets inherit from the listener)
    server._sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 4096)
    with server:
        svc = server.service
        # warm the cell: every grid line below is then a cheap cache hit,
        # so production speed is bounded only by backpressure
        RemoteMappingService(server.url).derive("tri2d", MODEL, 20)

        body = json.dumps({"domains": ["tri2d"] * 200,
                           "models": [MODEL], "stages": [20]}).encode()
        raw = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        raw.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 4096)
        raw.settimeout(30)
        raw.connect((server.host, server.port))
        raw.sendall(b"POST /v1/grid HTTP/1.1\r\nHost: t\r\n"
                    b"Content-Type: application/json\r\n"
                    b"Content-Length: " + str(len(body)).encode() +
                    b"\r\n\r\n" + body)
        buf = raw.recv(2048)  # headers + first lines, then... stall.

        time.sleep(0.6)  # let production hit the high-water mark
        s1 = svc.stats.requests
        time.sleep(0.4)
        s2 = svc.stats.requests
        # production is paused: at most one in-flight cell moved
        assert s2 - s1 <= 1, f"producer kept running while stalled ({s1}->{s2})"
        assert s2 < 150, f"sweep ran {s2} cells ahead of a stalled reader"

        # other connections are not behind this stream: a cold derive on a
        # second connection completes while the grid reader is stalled
        other = RemoteMappingService(server.url).derive("cantor2d", MODEL, 20)
        assert other.cache_key

        # resume: drain the whole stream to EOF (close-delimited)
        while True:
            chunk = raw.recv(65536)
            if not chunk:
                break
            buf += chunk
        raw.close()

        head, _, payload = buf.partition(b"\r\n\r\n")
        assert head.startswith(b"HTTP/1.1 200")
        lines = [ln for ln in payload.split(b"\n") if ln]
        assert len(lines) == 200
        assert all(json.loads(ln)["record"]["domain"] == "tri2d"
                   for ln in lines)
        assert server.stream_stalls >= 1
        # one inference for the whole exercise on this cell
        assert factory.bank[MODEL].calls == 2  # tri2d + cantor2d
