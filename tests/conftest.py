"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
multi-device coverage uses subprocesses (test_distribution.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
