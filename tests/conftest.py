"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
multi-device coverage uses subprocesses (test_distribution.py)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session", autouse=True)
def _isolated_artifact_cache(tmp_path_factory):
    """Keep the artifact cache hermetic: never read/write the user's
    ~/.cache during the test run unless the env var is set explicitly."""
    if "REPRO_ARTIFACT_CACHE" not in os.environ:
        os.environ["REPRO_ARTIFACT_CACHE"] = str(
            tmp_path_factory.mktemp("artifact-cache"))
    yield


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
