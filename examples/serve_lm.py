"""Batched serving demo: prefill + greedy decode with per-family KV caches.

    PYTHONPATH=src python examples/serve_lm.py [arch]   # default yi-6b smoke
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "yi-6b"
    sys.argv = [sys.argv[0], "--arch", arch, "--smoke", "--batch", "4",
                "--prompt-len", "24", "--max-new", "24"]
    main()
