"""Full derivation sweep: every domain x a chosen model, with deployment
accounting — the operational framework of paper Fig. 3 over all six domains,
driven by the artifact layer: each cell is a cached ``MappingArtifact``, so
a second run of this script performs zero LLM calls and zero re-validation.

    PYTHONPATH=src python examples/derive_and_deploy.py [model]
"""
import sys

from repro.core.domains import DOMAINS
from repro.core.pipeline import run_grid
from repro.launch.analytic import artifact_deployment_analytics

model = sys.argv[1] if len(sys.argv) > 1 else "OSS:120b"
N_DEPLOY = 500_000_000

grid = run_grid(domains=sorted(DOMAINS), models=[model], stages=(20, 50, 100),
                n_validate=50_000, sample_every=10)
hits = sum(1 for r in grid.values() if r.cache_hit)

print(f"model = {model}   ({hits}/{len(grid)} cells from artifact cache)\n")
print(f"{'domain':22s}{'stage':>6s}{'ordered':>9s}{'any':>8s}{'class':>10s}"
      f"{'speedup':>9s}{'energy x':>9s}")
for name, dom in sorted(DOMAINS.items()):
    best = None
    for stage in (20, 50, 100):
        res = grid[(name, model, stage)]
        if best is None or res.report.ordered > best[1].report.ordered:
            best = (stage, res)
    stage, res = best
    art = res.artifact
    if art is not None and art.deployable:
        dep = artifact_deployment_analytics(art, N_DEPLOY)
        sp = f"{dep['speedup']:8.0f}x"
        ex = f"{dep['energy_reduction']:8.0f}x"
    else:
        sp = ex = "      --"
    print(f"{dom.paper_name:22s}{stage:>6d}{res.report.ordered_pct:>8.1f}%"
          f"{res.report.any_order_pct:>7.1f}%"
          f"{str(res.complexity_class):>10s}{sp}{ex}")
print("\n'--' rows: the model never derived a perfect map (e.g. the paper's "
      "'Menger limit'); deployment falls back to the bounding-box kernel.")
