"""Full derivation sweep: every domain x a chosen model, with deployment
accounting — the operational framework of paper Fig. 3 over all six domains.

    PYTHONPATH=src python examples/derive_and_deploy.py [model]
"""
import sys

from repro.core.backends import MockLLMBackend
from repro.core.domains import DOMAINS
from repro.core.energy import estimate_bounding_box, estimate_mapped
from repro.core.pipeline import derive_mapping

model = sys.argv[1] if len(sys.argv) > 1 else "OSS:120b"
backend = MockLLMBackend(model)
N_DEPLOY = 500_000_000

print(f"model = {backend.name}\n")
print(f"{'domain':22s}{'stage':>6s}{'ordered':>9s}{'any':>8s}{'class':>10s}"
      f"{'speedup':>9s}{'energy x':>9s}")
for name, dom in sorted(DOMAINS.items()):
    best = None
    for stage in (20, 50, 100):
        res = derive_mapping(dom, backend, stage, n_validate=50_000,
                             sample_every=10)
        if best is None or res.report.ordered > best[1].report.ordered:
            best = (stage, res)
    stage, res = best
    if res.perfect:
        logic = ("analytical" if dom.kind == "dense" else "bitwise")
        bb = estimate_bounding_box(dom, N_DEPLOY)
        mp = estimate_mapped(dom, logic, N_DEPLOY)
        sp = f"{bb.time_ms / mp.time_ms:8.0f}x"
        ex = f"{bb.energy_j / mp.energy_j:8.0f}x"
    else:
        sp = ex = "      --"
    print(f"{dom.paper_name:22s}{stage:>6d}{res.report.ordered_pct:>8.1f}%"
          f"{res.report.any_order_pct:>7.1f}%"
          f"{str(res.complexity_class):>10s}{sp}{ex}")
print("\n'--' rows: the model never derived a perfect map (e.g. the paper's "
      "'Menger limit'); deployment falls back to the bounding-box kernel.")
