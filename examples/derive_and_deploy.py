"""Full derivation sweep served through the MappingService: every paper
domain x a chosen model, with deployment accounting — the operational
framework of paper Fig. 3, as a *served* workload: the service streams
per-cell results, coalesces concurrent requests, and shares one artifact
store across clients, so a second client (or a second run of this script)
performs zero LLM calls and zero re-validation.

    PYTHONPATH=src python examples/derive_and_deploy.py [model]
"""
import sys

from repro.core.domains import DOMAINS, PAPER_DOMAINS
from repro.launch.analytic import artifact_deployment_analytics
from repro.serving import MappingService

model = sys.argv[1] if len(sys.argv) > 1 else "OSS:120b"
N_DEPLOY = 500_000_000
names = sorted(d.name for d in PAPER_DOMAINS)

# client 1: streams the grid (derives on first run, cache-served afterwards)
svc = MappingService(n_validate=50_000, sample_every=10)
grid = {}
for res in svc.run_grid(domains=names, models=[model], stages=(20, 50, 100)):
    grid[(res.domain, res.model, res.stage)] = res

# client 2: a fresh service over the same store — every cell is a hit
client2 = MappingService(n_validate=50_000, sample_every=10)
for res in client2.run_grid(domains=names, models=[model], stages=(20, 50, 100)):
    pass

print(f"model = {model}   (client 1: {svc.stats.derivations} derivations, "
      f"{svc.stats.cache_hits} cache hits; client 2 shared the store: "
      f"{client2.stats.cache_hits} hits, {client2.stats.derivations} "
      f"derivations)\n")
print(f"{'domain':22s}{'stage':>6s}{'ordered':>9s}{'any':>8s}{'class':>10s}"
      f"{'speedup':>9s}{'energy x':>9s}")
for name in names:
    dom = DOMAINS[name]
    best = None
    for stage in (20, 50, 100):
        res = grid[(name, model, stage)]
        if best is None or res.report.ordered > best[1].report.ordered:
            best = (stage, res)
    stage, res = best
    art = res.artifact
    if art is not None and art.deployable:
        dep = artifact_deployment_analytics(art, N_DEPLOY)
        sp = f"{dep['speedup']:8.0f}x"
        ex = f"{dep['energy_reduction']:8.0f}x"
    else:
        sp = ex = "      --"
    print(f"{dom.paper_name:22s}{stage:>6d}{res.report.ordered_pct:>8.1f}%"
          f"{res.report.any_order_pct:>7.1f}%"
          f"{str(res.complexity_class):>10s}{sp}{ex}")
print("\n'--' rows: the model never derived a perfect map (e.g. the paper's "
      "'Menger limit'); deployment falls back to the bounding-box kernel.")
