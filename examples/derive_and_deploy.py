"""Full derivation sweep served through the MappingService: every paper
domain x a chosen model, with deployment accounting — the operational
framework of paper Fig. 3, as a *served* workload: the service streams
per-cell results, coalesces concurrent requests, and shares one artifact
store across clients, so a second client (or a second run of this script)
performs zero LLM calls and zero re-validation.

    PYTHONPATH=src python examples/derive_and_deploy.py [model]

With ``--url`` the same sweep runs against a network server (boot one with
``python -m repro.launch.serve --serve-maps``): two RemoteMappingService
clients share the *server's* store, so client 2's whole grid is served from
the server-side cache — the derivation cost is paid once per fleet, not
once per machine.

    PYTHONPATH=src python examples/derive_and_deploy.py --url http://127.0.0.1:8000
"""
import argparse

from repro.core.domains import DOMAINS, PAPER_DOMAINS
from repro.launch.analytic import artifact_deployment_analytics

N_DEPLOY = 500_000_000
STAGES = (20, 50, 100)


def make_clients(args):
    """Two independent clients over one store: in-process services sharing
    the local cache, or remote clients sharing the server's cache."""
    if args.url:
        from repro.serving import RemoteMappingService

        return (RemoteMappingService(args.url),
                RemoteMappingService(args.url))
    from repro.serving import MappingService

    return (MappingService(n_validate=50_000, sample_every=10),
            MappingService(n_validate=50_000, sample_every=10))


def client_summary(args, c1, c2) -> str:
    if args.url:
        hits = c1.metrics()["service"]["cache_hits"]
        return (f"client 1: {c1.stats.server_cache_hits} server-side hits; "
                f"client 2: {c2.stats.server_cache_hits} server-side hits "
                f"(all {len(STAGES) * len(PAPER_DOMAINS)} cells); server "
                f"store served {hits} hits total")
    return (f"client 1: {c1.stats.derivations} derivations, "
            f"{c1.stats.cache_hits} cache hits; client 2 shared the store: "
            f"{c2.stats.cache_hits} hits, {c2.stats.derivations} derivations")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("model", nargs="?", default="OSS:120b")
    p.add_argument("--url", default=None,
                   help="derivation server URL (e.g. http://127.0.0.1:8000); "
                        "omit for in-process services")
    args = p.parse_args()
    names = sorted(d.name for d in PAPER_DOMAINS)

    client1, client2 = make_clients(args)
    # client 1: streams the grid (derives on first pass, cache-served after)
    grid = {}
    for res in client1.run_grid(domains=names, models=[args.model],
                                stages=STAGES):
        grid[(res.domain, res.model, res.stage)] = res

    # client 2: a fresh client over the same store — every cell is a hit
    second = list(client2.run_grid(domains=names, models=[args.model],
                                   stages=STAGES))
    if args.url:
        assert all(r.cache_hit for r in second), \
            "client 2 must be served entirely from the server-side cache"

    print(f"model = {args.model}   ({client_summary(args, client1, client2)})\n")
    print(f"{'domain':22s}{'stage':>6s}{'ordered':>9s}{'any':>8s}{'class':>10s}"
          f"{'speedup':>9s}{'energy x':>9s}")
    for name in names:
        dom = DOMAINS[name]
        best = None
        for stage in STAGES:
            res = grid[(name, args.model, stage)]
            if best is None or res.report.ordered > best[1].report.ordered:
                best = (stage, res)
        stage, res = best
        art = res.artifact
        if art is not None and art.deployable:
            dep = artifact_deployment_analytics(art, N_DEPLOY)
            sp = f"{dep['speedup']:8.0f}x"
            ex = f"{dep['energy_reduction']:8.0f}x"
        else:
            sp = ex = "      --"
        print(f"{dom.paper_name:22s}{stage:>6d}{res.report.ordered_pct:>8.1f}%"
              f"{res.report.any_order_pct:>7.1f}%"
              f"{str(res.complexity_class):>10s}{sp}{ex}")
    print("\n'--' rows: the model never derived a perfect map (e.g. the "
          "paper's 'Menger limit'); deployment falls back to the "
          "bounding-box kernel.")


if __name__ == "__main__":
    main()
