"""Fractal block-space computing: evaluate the derived maps as Pallas
kernels over all fractal domains and account the bounding-box waste —
paper Table IX at reduced N, live.

    PYTHONPATH=src python examples/fractal_compute.py
"""
import numpy as np

from repro.core.domains import DOMAINS
from repro.kernels.domain_map.ops import bb_membership, block_counts, map_coordinates

N = 16_384
print(f"{'domain':22s}{'valid':>8s}{'bb pts':>12s}{'waste':>8s}  kernel check")
for name in ("gasket2d", "carpet2d", "sierpinski3d", "menger3d"):
    dom = DOMAINS[name]
    coords = map_coordinates(name, N, interpret=True)
    # every mapped point must be inside the domain, no duplicates
    assert dom.contains(coords).all()
    keys = coords @ (np.array([2**21, 1, 0])[: coords.shape[1]] + 0)
    ext = dom.bounding_box_extent(N)
    mask = bb_membership(name, ext, interpret=True)
    bc = block_counts(name, N)
    print(f"{dom.paper_name:22s}{N:>8,}{int(np.prod(ext)):>12,}"
          f"{bc['waste_fraction']:>8.1%}  "
          f"mapped kernel bijective over first {N:,} pts ✓ "
          f"(BB membership kernel finds {int(mask.sum()):,} valid)")
print("\nAt the paper's N=5e8 the 3D Sierpinski BB waste is 99.9986% — "
      "the mapped kernel eliminates it entirely (benchmarks/block_fractal.py).")
