"""Fractal block-space computing, served end-to-end: derive each fractal's
map through the MappingService (two clients sharing one artifact store),
deploy the resulting MappingArtifact as a Pallas kernel, and account the
bounding-box waste — paper Table IX at reduced N, live, now including the
embedded-2D-fractal family (Cantor dust, Vicsek saltire).

    PYTHONPATH=src python examples/fractal_compute.py
"""
import numpy as np

from repro.core.domains import DOMAINS
from repro.kernels.domain_map.ops import bb_membership, block_counts, map_coordinates
from repro.serving import MappingService

N = 16_384
MODEL = "OSS:120b"
FRACTALS = sorted(n for n, d in DOMAINS.items() if d.kind == "fractal")

svc = MappingService(n_validate=20_000, sample_every=10)

print(f"{'domain':22s}{'valid':>8s}{'bb pts':>12s}{'waste':>8s}  kernel check")
for name in FRACTALS:
    dom = DOMAINS[name]
    art = svc.artifact(name, MODEL, 100)
    # deploy the artifact when the model derived a perfect map (the
    # validation report licenses the registered exact kernel); otherwise
    # fall back to the domain's ground-truth geometry.
    spec = art if art is not None and art.deployable else name
    coords = map_coordinates(spec, N, interpret=True)
    # every mapped point must be inside the domain, no duplicates
    assert dom.contains(coords).all()
    ext = dom.bounding_box_extent(N)
    mask = bb_membership(spec, ext, interpret=True)
    bc = block_counts(spec, N)
    via = "artifact" if spec is art else "ground truth"
    print(f"{dom.paper_name:22s}{N:>8,}{int(np.prod(ext)):>12,}"
          f"{bc['waste_fraction']:>8.1%}  "
          f"mapped kernel bijective over first {N:,} pts ✓ via {via} "
          f"(BB membership kernel finds {int(mask.sum()):,} valid)")

# a second client over the same store: all cells served from cache
client2 = MappingService(n_validate=20_000, sample_every=10)
for name in FRACTALS:
    client2.derive(name, MODEL, 100)
print(f"\nclient 1: {svc.stats.derivations} derivations / "
      f"{svc.stats.cache_hits} hits; client 2 (shared store): "
      f"{client2.stats.cache_hits} hits, {client2.stats.derivations} "
      f"derivations.")
print("At the paper's N=5e8 the 3D Sierpinski BB waste is 99.9986% — "
      "the mapped kernel eliminates it entirely (benchmarks/block_fractal.py).")
