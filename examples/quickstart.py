"""Quickstart: the paper's pipeline end-to-end in ~40 lines.

1. Sample the first 20 points of the 2D triangular domain,
2. "infer" the mapping with an LLM backend (offline replay of gpt-oss:120b),
3. synthesize + validate the generated map_to_coordinates over 10^5 points,
4. deploy the derived map as the Pallas attention grid (mapped vs BB).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.backends import MockLLMBackend, build_prompt
from repro.core.domains import DOMAINS
from repro.core.pipeline import derive_mapping
from repro.kernels.tri_attn.ops import causal_attention, grid_steps
from repro.kernels.tri_attn.ref import causal_attention_ref

dom = DOMAINS["tri2d"]

print("--- phase 1+2: context sampling & symbolic inference ---")
print(build_prompt(dom, 20).split("<CONTEXT>")[1].split("</CONTEXT>")[0][:300])
res = derive_mapping(dom, MockLLMBackend("OSS:120b"), stage=20,
                     n_validate=100_000)

print("--- phase 3: synthesis + validation ---")
print(res.source)
print(f"ordered={res.report.ordered_pct:.1f}%  "
      f"any-order={res.report.any_order_pct:.1f}%  "
      f"bijective={res.report.bijective}  "
      f"complexity={res.complexity_class}  "
      f"inference energy={res.inference_joules:.0f} J")
assert res.perfect

print("--- phase 4: integration — the map as the attention grid ---")
q, k, v = (jax.random.normal(kk, (1, 4, 512, 64), jnp.float32)
           for kk in jax.random.split(jax.random.PRNGKey(0), 3))
out = causal_attention(q, k, v, 128, 128, "mapped", True)   # interpret=True
err = float(jnp.max(jnp.abs(out - causal_attention_ref(q, k, v))))
bb, mp = grid_steps(512, 128, "bounding_box"), grid_steps(512, 128, "mapped")
print(f"kernel err vs oracle: {err:.2e}")
print(f"grid steps: bounding-box {bb} -> mapped {mp} "
      f"({1 - mp / bb:.0%} of sequential steps eliminated)")
am = res.amortization()
print(f"deployment: {am.speedup:.0f}x faster, {am.energy_reduction:.0f}x "
      f"less energy, amortized after {am.runs_to_break_even:.1f} runs")
