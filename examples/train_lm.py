"""End-to-end training driver: train a ~100M-parameter llama-family model
for a few hundred steps with the full substrate (sharding, checkpointing,
fault tolerance, synthetic data).

Default invocation is CPU-sized so it finishes in minutes; --full runs the
actual ~100M config (same code path, longer):

    PYTHONPATH=src python examples/train_lm.py              # ~20M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --full       # ~100M, 300 steps
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    full = "--full" in sys.argv
    argv = [a for a in sys.argv[1:] if a != "--full"]
    if full:
        # 103M params: llama3.2 family (tied embeds), d=384 L=8 ff=3072
        preset = ["--arch", "llama3.2-3b", "--d-model", "384",
                  "--n-layers", "8", "--d-ff", "3072", "--steps", "300",
                  "--batch", "8", "--seq", "512",
                  "--ckpt-dir", "/tmp/repro_train_full", "--log-every", "20"]
    else:
        preset = ["--arch", "llama3.2-3b", "--smoke", "--d-model", "128",
                  "--n-layers", "6", "--steps", "120", "--batch", "8",
                  "--seq", "128", "--ckpt-dir", "/tmp/repro_train",
                  "--log-every", "20"]
    sys.argv = [sys.argv[0]] + preset + argv
    main()
