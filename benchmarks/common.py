"""Shared benchmark utilities: timing + CSV row emission."""
from __future__ import annotations

import time

ROWS: list[tuple[str, float, str]] = []


def timed(fn, *args, repeats: int = 3, **kw):
    """Returns (result, best_us)."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return out, best


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def header(title: str):
    print(f"\n=== {title} ===")
