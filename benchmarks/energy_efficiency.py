"""Fig. 5: computational efficiency (points/joule) of the inference phase.

points/joule = correctly mapped points (any-order coverage x N) / inference
joules.  Joules come from the calibrated model-prior energy model
(MODEL_SPECS: params -> power, tps, CoT factor); accuracies from the live
pipeline, swept through ``run_grid`` so every (domain x model x stage) cell
is served from the artifact cache after its first derivation.  Reproduces
the figure's two findings: parameter-driven penalties (Qw3:235b) and
reasoning-driven penalties (R1:70b below same-size dense).
"""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.core import paper_tables as pt
from repro.core.domains import DOMAINS
from repro.core.energy import points_per_joule
from repro.core.pipeline import run_grid

FIG5_DOMAINS = ("tri2d", "gasket2d", "carpet2d", "pyramid3d",
                "sierpinski3d", "menger3d")


def run(n_validate: int = 50_000, sample_every: int = 50) -> dict:
    header("Fig. 5: inference-phase efficiency (points/joule, modeled energy)")
    findings = {}
    results = {}
    grid = run_grid(domains=FIG5_DOMAINS, models=pt.MODELS, stages=pt.STAGES,
                    n_validate=n_validate, sample_every=sample_every)
    hits = sum(1 for r in grid.values() if r.cache_hit)
    for dom_name in FIG5_DOMAINS:
        dom = DOMAINS[dom_name]
        print(f"\n-- {dom.paper_name} --")
        print(f"{'model':14s}" + "".join(f"{s:>14d}" for s in pt.STAGES))
        for model in pt.MODELS:
            vals = []
            for stage in pt.STAGES:
                res = grid[(dom_name, model, stage)]
                pts = res.report.any_order * n_validate
                vals.append(points_per_joule(pts, res.inference_joules))
                results[(dom_name, model, stage)] = vals[-1]
            print(f"{model:14s}" + "".join(f"{v:>14.1f}" for v in vals))
    print(f"\n[fig5] {hits}/{len(grid)} cells served from the artifact cache")

    # the two efficiency-profile findings of Sec. V.B
    r1 = max(results[("tri2d", "R1:70b", s)] for s in pt.STAGES)
    lla = max(results[("tri2d", "Lla3.3:70b", s)] for s in pt.STAGES)
    findings["reasoning_penalty"] = r1 < lla
    q235 = max(results[("tri2d", "Qw3:235b", s)] for s in pt.STAGES)
    q32 = max(results[("tri2d", "Qw3:32b", s)] for s in pt.STAGES)
    findings["parameter_penalty"] = q235 < q32
    print(f"[fig5] reasoning-driven penalty (R1 < Lla3.3 at equal size): "
          f"{findings['reasoning_penalty']}")
    print(f"[fig5] parameter-driven penalty (Qw3:235b < Qw3:32b): "
          f"{findings['parameter_penalty']}")
    emit("fig5_points_per_joule", 0.0,
         f"reasoning_penalty={findings['reasoning_penalty']};"
         f"parameter_penalty={findings['parameter_penalty']}")
    return findings


if __name__ == "__main__":
    run()
