"""Serving suite: HTTP derive throughput/latency against a local server,
plus store-pressure numbers for the tiered artifact store.

Boots a MappingHTTPServer (mock backend, private temp store) on an
ephemeral port, then measures the two costs a fleet client actually pays:

  * cold derive — first request for a cell: full pipeline behind HTTP;
  * hot derive  — repeat request: server-side cache hit, so the number is
    pure serving overhead (HTTP + JSON + store read);
  * hot throughput — concurrent clients hammering cached cells.

The store-pressure sub-suite isolates where a hot hit resolves:

  * memory tier — resident rehydrated result: no disk, no JSON, no HTTP;
  * disk tier   — record read + checksum verify + rehydration per hit;
  * peer tier   — full HTTP round-trip to a sibling server per hit;
  * eviction churn — throughput when the disk budget is smaller than the
    working set, so records evict and re-derive continuously.

The cluster sub-suite (``--only cluster``) measures the two transports this
fleet actually pays for:

  * keep-alive vs fresh-connection hot derive — the pooled ``http.client``
    transport against the per-request ``Connection: close`` baseline;
  * owner-routed vs forwarded derive on a real 3-node ring — the client
    hashing locally and hitting the owner, against the server-side
    forwarding hop a ring-naive client pays.

The evaluate sub-suite (``--only evaluate``) measures the evaluation
plane added in PR 6:

  * cold vs warm query — first evaluation of a cell pays the pallas
    trace + XLA compile; repeats hit the compiled-executable cache, so
    the number is pure dispatch + transfer (acceptance: warm p50 at
    least 10x below cold);
  * batched heterogeneous request vs a sequential per-query loop over
    HTTP — grouping + one round-trip must beat N round-trips;
  * roofline sanity — the measured per-block cost against the TPU v5e
    projection from ``core/energy.py`` (an idealized lower bound; the
    ratio is recorded, not optimized).

The wire sub-suite (``--only wire``) measures what the PR 10 binary wire
format buys the evaluation hot path:

  * codec-level — encode+decode round-trip of one evaluation result at
    1k/100k/1M points, JSON (``wire_result``/``hydrate_result``) vs the
    binary frame (``wire.encode_frame``/``decode_frame``, zero-copy
    ``np.frombuffer`` hydration), with payload sizes;
  * end-to-end — served p50 for the same queries over the async
    frontend, one keep-alive client per format (acceptance: binary
    >= 3x JSON at 100k points, byte-identical decoded arrays);
  * warm single-query served p50 over binary wire — the request-plane
    floor a compiled-executable hit actually ships under.

The concurrency sub-suite (``--only concurrency``) races the two frontends
added across PR 1-7 head to head:

  * hot-path derive throughput + p50/p95 at increasing keep-alive
    connection counts (16/64/256 open connections), threaded
    one-thread-per-connection server vs the asyncio event loop — the
    acceptance bar is the async frontend sustaining the top connection
    count at >= 2x the threaded hot-path throughput.

The routing sub-suite (``--only routing``) measures the PR 9 load-aware
replica scheduler against the static ring-order baseline:

  * loaded vs static forwarding with one slowed replica — three
    storeful ring nodes plus a store-less routing edge; the *primary*
    owner of the hot cell sleeps ``serve_delay`` per derive, and 64
    keep-alive connections hammer the cell through the edge so every
    request pays the server-side routing hop (the edge can never serve
    from residency).  Static policy walks owners in ring order (always
    lands on the slow primary); the loaded policy's EWMA-latency +
    queue-depth selector shifts to the healthy replica after the first
    probes.  Acceptance: loaded sustains >= 2x the static hot-derive
    throughput;
  * ring vs rendezvous placement balance — primary-ownership share over
    a fixed keyset on a 5-node fleet for both placements (max/ideal and
    min/ideal recorded for each; a number, not an assertion).

The observability sub-suite (``--only observability``) measures what the
PR 8 tracing plane costs on the async hot path: hot-derive p50 with
request tracing enabled vs disabled (metrics stay on in both — only span
recording + trace-ID propagation differ).  Acceptance: tracing adds at
most 5% to the hot-derive p50 (plus a small absolute jitter floor, since
a hot derive is tens of microseconds and scheduler noise alone exceeds
5% of that).

Run metrics (cache hits, coalescing, p50/p95 from the server's own
/metrics, per-tier store counters) land in ``LAST_METRICS`` so ``run.py
--json`` can emit them.
"""
from __future__ import annotations

import concurrent.futures
import statistics
import tempfile
import threading
import time

from benchmarks.common import emit, header
from repro.core.artifact import ArtifactCache
from repro.core.backends import MockLLMBackend
from repro.core.store import DiskStore, PeerStore, TieredStore, build_store
from repro.serving import (
    AsyncMappingHTTPServer, MappingHTTPServer, MappingService,
    RemoteMappingService, batching_factory,
)

MODEL = "OSS:120b"
#: populated by run(); run.py --json folds this into BENCH_serving.json
LAST_METRICS: dict = {}


def run(n_hot: int = 50, n_clients: int = 8) -> dict:
    header("serving: HTTP derive latency/throughput (local server)")
    cache = ArtifactCache(tempfile.mkdtemp(prefix="bench_serving_"))
    factory = batching_factory(MockLLMBackend, max_batch=8, max_wait=0.005)
    service = MappingService(cache=cache, backend_factory=factory,
                             n_validate=20_000, sample_every=10)
    with MappingHTTPServer(service) as server:
        client = RemoteMappingService(server.url)

        # cold: one full derivation per domain, behind HTTP
        cold_us = []
        for domain in ("tri2d", "gasket2d", "msimplex3"):
            t0 = time.perf_counter()
            res = client.derive(domain, MODEL, 100)
            cold_us.append((time.perf_counter() - t0) * 1e6)
            assert res.compiled and not res.cache_hit
        emit("serving_derive_cold", statistics.median(cold_us), "http")

        # hot: repeats are server-side cache hits — serving overhead only
        hot_us = []
        for _ in range(n_hot):
            t0 = time.perf_counter()
            res = client.derive("tri2d", MODEL, 100)
            hot_us.append((time.perf_counter() - t0) * 1e6)
            assert res.cache_hit
        hot_us.sort()
        emit("serving_derive_hot_p50", hot_us[len(hot_us) // 2], "http")
        emit("serving_derive_hot_p95", hot_us[int(len(hot_us) * 0.95)], "http")

        # hot throughput: concurrent clients on cached cells
        def one_client(_):
            c = RemoteMappingService(server.url)
            for _ in range(n_hot // n_clients or 1):
                assert c.derive("gasket2d", MODEL, 100).cache_hit
            return c.stats.server_cache_hits

        t0 = time.perf_counter()
        with concurrent.futures.ThreadPoolExecutor(n_clients) as pool:
            hits = sum(pool.map(one_client, range(n_clients)))
        dt = time.perf_counter() - t0
        emit("serving_derive_hot_throughput", dt / hits * 1e6,
             f"{hits / dt:.0f}rps")

        metrics = client.metrics()
    LAST_METRICS.clear()
    LAST_METRICS.update({
        "server": metrics,
        "client_stats": client.stats.as_dict(),
        "cold_us": cold_us,
        "hot_p50_us": hot_us[len(hot_us) // 2],
        "hot_p95_us": hot_us[int(len(hot_us) * 0.95)],
        "hot_rps": hits / dt,
    })
    svc_stats = metrics["service"]
    print(f"(server: {svc_stats['derivations']} derivations, "
          f"{svc_stats['cache_hits']} cache hits, "
          f"hit ratio {svc_stats['cache_hit_ratio']:.2f})")
    store_pressure()
    return LAST_METRICS


def _hot_us(svc, domain: str, n: int) -> list[float]:
    """Median-friendly per-hit latencies after a warmup request."""
    svc.derive(domain, MODEL, 100)
    out = []
    for _ in range(n):
        t0 = time.perf_counter()
        res = svc.derive(domain, MODEL, 100)
        out.append((time.perf_counter() - t0) * 1e6)
        assert res.cache_hit
    return out


def store_pressure(n_hot: int = 30, n_churn: int = 24) -> dict:
    """Hot-hit latency per store tier + throughput under eviction churn."""
    header("serving: store pressure (per-tier hot hits, eviction churn)")
    root = tempfile.mkdtemp(prefix="bench_store_")
    kw = dict(n_validate=20_000, sample_every=10)

    # memory tier: resident rehydrated result (the intended steady state)
    svc_mem = MappingService(store=build_store(root=f"{root}/mem"), **kw)
    svc_mem.derive("tri2d", MODEL, 100)
    mem_us = _hot_us(svc_mem, "tri2d", n_hot)
    assert svc_mem.store.disk.reads <= 2  # hot hits never touched disk
    emit("store_hot_memory_tier", statistics.median(mem_us), "lru")

    # disk tier: no memory tier, every hit reads + verifies + rehydrates
    svc_disk = MappingService(
        store=TieredStore(disk=DiskStore(f"{root}/disk")), **kw)
    svc_disk.derive("tri2d", MODEL, 100)
    disk_us = _hot_us(svc_disk, "tri2d", n_hot)
    emit("store_hot_disk_tier", statistics.median(disk_us), "checksum")

    # peer tier: every hit is an HTTP round-trip to the sibling that holds
    # the record (a peer-only store has no local tier to promote into)
    svc_origin = MappingService(store=build_store(root=f"{root}/origin"), **kw)
    svc_origin.derive("tri2d", MODEL, 100)
    with MappingHTTPServer(svc_origin) as origin:
        svc_peer = MappingService(
            store=TieredStore(peers=PeerStore([origin.url])), **kw)
        peer_us = _hot_us(svc_peer, "tri2d", n_hot)
    emit("store_hot_peer_tier", statistics.median(peer_us), "http")

    # eviction churn: working set > disk budget, so serves keep paying
    # eviction + re-derivation — the worst-case sustained throughput
    probe = DiskStore(f"{root}/probe")
    svc_probe = MappingService(store=TieredStore(disk=probe),
                               n_validate=2000, sample_every=1)
    rec_bytes = probe.path(
        svc_probe.derive("tri2d", MODEL, 100).cache_key).stat().st_size
    churn_store = build_store(root=f"{root}/churn",
                              max_bytes=int(rec_bytes * 2.5),
                              memory_entries=2)
    svc_churn = MappingService(store=churn_store, n_validate=2000,
                               sample_every=1)
    cells = [("tri2d", 20), ("tri2d", 50), ("tri2d", 100),
             ("gasket2d", 20), ("gasket2d", 50), ("gasket2d", 100)]
    t0 = time.perf_counter()
    for i in range(n_churn):
        domain, stage = cells[i % len(cells)]
        svc_churn.derive(domain, MODEL, stage)
    dt = time.perf_counter() - t0
    evicted = (churn_store.disk.evictions_bytes +
               churn_store.disk.evictions_ttl)
    emit("store_churn_throughput", dt / n_churn * 1e6,
         f"{n_churn / dt:.0f}ops")

    pressure = {
        "memory_p50_us": statistics.median(mem_us),
        "disk_p50_us": statistics.median(disk_us),
        "peer_p50_us": statistics.median(peer_us),
        "churn_ops_per_s": n_churn / dt,
        "churn_evictions": evicted,
        "churn_rederivations": svc_churn.stats.derivations,
        "memory_store_stats": svc_mem.store_stats(),
        "churn_store_stats": svc_churn.store_stats(),
    }
    LAST_METRICS["store_pressure"] = pressure
    print(f"(tiers p50: memory {pressure['memory_p50_us']:.0f}us, disk "
          f"{pressure['disk_p50_us']:.0f}us, peer "
          f"{pressure['peer_p50_us']:.0f}us; churn "
          f"{pressure['churn_ops_per_s']:.0f}ops/s with {evicted} evictions, "
          f"{svc_churn.stats.derivations} re-derivations)")
    return pressure


def _timed_derives(client, domain: str, stage: int, n: int,
                   before_each=None) -> list[float]:
    out = []
    for _ in range(n):
        if before_each is not None:
            before_each()
        t0 = time.perf_counter()
        res = client.derive(domain, MODEL, stage)
        out.append((time.perf_counter() - t0) * 1e6)
        assert res.cache_hit
    out.sort()
    return out


def cluster_suite(n_hot: int = 60) -> dict:
    """Keep-alive vs fresh-connection hot derive, and owner-routed vs
    forwarded derive latency on a 3-node consistent-hash ring."""
    header("serving: cluster (keep-alive transport, ring routing)")
    from repro.serving.cluster import ClusterMembership

    kw = dict(n_validate=20_000, sample_every=10)

    # -- keep-alive vs fresh connection (one server, hot cell) -------------
    svc = MappingService(store=build_store(
        root=tempfile.mkdtemp(prefix="bench_cluster_")), **kw)
    with MappingHTTPServer(svc) as server:
        pooled = RemoteMappingService(server.url)
        fresh = RemoteMappingService(server.url, keep_alive=False)
        pooled.derive("tri2d", MODEL, 100)  # derive once, then all hot
        keep_us = _timed_derives(pooled, "tri2d", 100, n_hot)
        fresh_us = _timed_derives(fresh, "tri2d", 100, n_hot)
    emit("cluster_hot_keepalive_p50", keep_us[len(keep_us) // 2], "pooled")
    emit("cluster_hot_keepalive_p95", keep_us[int(len(keep_us) * 0.95)],
         "pooled")
    emit("cluster_hot_fresh_p50", fresh_us[len(fresh_us) // 2], "tcp/req")
    emit("cluster_hot_fresh_p95", fresh_us[int(len(fresh_us) * 0.95)],
         "tcp/req")

    # -- owner-routed vs forwarded derive (3-node ring) --------------------
    root = tempfile.mkdtemp(prefix="bench_ring_")
    servers = []
    seeds = []
    for i in range(3):
        node = MappingHTTPServer(
            MappingService(store=build_store(root=f"{root}/n{i}"),
                           **kw)).start()
        node.attach_cluster(ClusterMembership(
            node.url, seeds=seeds, replicas=2, vnodes=64,
            heartbeat_interval=0.1, down_after=2.0, sync_interval=5.0))
        seeds = seeds or [node.url]
        servers.append(node)
    deadline = time.perf_counter() + 20
    while any(len(s.cluster.ring.nodes) < 3 for s in servers):
        assert time.perf_counter() < deadline, "ring never converged"
        time.sleep(0.05)
    try:
        key = servers[0].service.request_key("gasket2d", MODEL, 100)
        owners = servers[0].cluster.owners(key)
        non_owner = next(s for s in servers if s.url not in owners)
        client = RemoteMappingService(non_owner.url)
        client.derive("gasket2d", MODEL, 100)  # derive + learn the key
        cell = ("gasket2d", MODEL, 100)
        # forwarded: forget the key each time, so every request pays the
        # server-side hop from the non-owner to the ring owner
        fwd_us = _timed_derives(
            client, "gasket2d", 100, n_hot,
            before_each=lambda: client._cell_keys.pop(cell, None))
        # owner-routed: the client hashes locally and hits the owner
        client.derive("gasket2d", MODEL, 100)  # re-learn the key
        routed_us = _timed_derives(client, "gasket2d", 100, n_hot)
        assert client.stats.routed >= n_hot
        forwarded_total = non_owner.forwarded
    finally:
        for s in servers:
            s.close()
    emit("cluster_derive_forwarded_p50", fwd_us[len(fwd_us) // 2], "2hop")
    emit("cluster_derive_owner_routed_p50",
         routed_us[len(routed_us) // 2], "direct")

    cluster = {
        "keepalive_p50_us": keep_us[len(keep_us) // 2],
        "keepalive_p95_us": keep_us[int(len(keep_us) * 0.95)],
        "fresh_p50_us": fresh_us[len(fresh_us) // 2],
        "fresh_p95_us": fresh_us[int(len(fresh_us) * 0.95)],
        "keepalive_saving_p50_us": (fresh_us[len(fresh_us) // 2] -
                                    keep_us[len(keep_us) // 2]),
        "forwarded_p50_us": fwd_us[len(fwd_us) // 2],
        "owner_routed_p50_us": routed_us[len(routed_us) // 2],
        "forwarding_hop_cost_us": (fwd_us[len(fwd_us) // 2] -
                                   routed_us[len(routed_us) // 2]),
        "forwarded_requests": forwarded_total,
        "client_stats": client.stats.as_dict(),
    }
    LAST_METRICS["cluster"] = cluster
    print(f"(keep-alive p50 {cluster['keepalive_p50_us']:.0f}us vs fresh "
          f"{cluster['fresh_p50_us']:.0f}us; owner-routed p50 "
          f"{cluster['owner_routed_p50_us']:.0f}us vs forwarded "
          f"{cluster['forwarded_p50_us']:.0f}us)")
    return cluster


def _hammer_routed(entry, cell: tuple, n_conns: int,
                   per_conn: int) -> dict:
    """Like ``_hammer``, but every request forgets the client-side cell
    key first, so it lands on the non-owner entry node and pays the
    server-side hop through ``entry.router`` (a ring-aware client would
    otherwise learn the key and self-route straight to the owners)."""
    lat: list[float] = []
    mu = threading.Lock()
    gate = threading.Barrier(n_conns + 1)

    def worker():
        c = RemoteMappingService(entry.url)
        c.derive(*cell)  # opens + warms this thread's connection
        gate.wait()
        times = []
        for _ in range(per_conn):
            c._cell_keys.pop(cell, None)
            t0 = time.perf_counter()
            assert c.derive(*cell).cache_hit
            times.append(time.perf_counter() - t0)
        with mu:
            lat.extend(times)

    threads = [threading.Thread(target=worker) for _ in range(n_conns)]
    for t in threads:
        t.start()
    gate.wait()  # every connection is open before the clock starts
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    lat.sort()
    return {
        "connections": n_conns,
        "requests": n_conns * per_conn,
        "rps": n_conns * per_conn / dt,
        "p50_us": lat[len(lat) // 2] * 1e6,
        "p95_us": lat[int(len(lat) * 0.95)] * 1e6,
    }


def routing_suite(n_conns: int = 64, per_conn: int = 6,
                  serve_delay: float = 1.5) -> dict:
    """Load-aware ("loaded") vs static ring-order replica selection with
    one artificially slowed replica, plus ring-vs-rendezvous placement
    balance.  Acceptance: loaded sustains >= 2x the static hot-derive
    throughput at ``n_conns`` keep-alive connections."""
    header("serving: routing (load-aware replica selection, placement)")
    from repro.serving.cluster import (ClusterMembership, HashRing,
                                       RendezvousHash)
    from repro.serving.router import RequestRouter

    kw = dict(n_validate=20_000, sample_every=10)
    root = tempfile.mkdtemp(prefix="bench_routing_")
    servers: list = []
    seeds: list = []
    for i in range(4):
        # the 4th node is a store-less routing edge: it can never satisfy
        # the hot cell from residency, so every request it receives pays
        # the router dispatch + forward hop the suite is measuring
        store = build_store(root=f"{root}/n{i}") if i < 3 else None
        node = MappingHTTPServer(
            MappingService(store=store, **kw)).start()
        node.attach_cluster(ClusterMembership(
            node.url, seeds=seeds, replicas=2, vnodes=64,
            heartbeat_interval=0.1, down_after=2.0, sync_interval=5.0,
            weight=1.0 if i < 3 else 1e-9))
        seeds = seeds or [node.url]
        servers.append(node)
    deadline = time.perf_counter() + 20
    while any(len(s.cluster.ring.nodes) < 4 for s in servers):
        assert time.perf_counter() < deadline, "ring never converged"
        time.sleep(0.05)

    results: dict = {"connections": n_conns,
                     "serve_delay_s": serve_delay}
    try:
        entry = servers[3]
        # the edge holds one token-weight vnode, so a given cell has a
        # tiny chance of hashing to it — scan a few cells for one whose
        # owners are both storeful nodes (ports randomize the hashes, so
        # this must be computed per run, not hardcoded)
        cell = next(
            c for c in [(d, MODEL, s) for s in (100, 50, 20)
                        for d in ("tri2d", "gasket2d", "carpet2d")]
            if entry.url not in servers[0].cluster.owners(
                servers[0].service.request_key(*c)))
        results["cell"] = list(cell)
        key = servers[0].service.request_key(*cell)
        owners = servers[0].cluster.owners(key)
        slow = next(s for s in servers if s.url == owners[0])
        # derive once so both phases measure pure forwarded cache hits
        RemoteMappingService(entry.url).derive(*cell)
        slow.serve_delay = serve_delay  # the *primary* owner goes hot:
        # static ring-order forwarding lands every request on it
        for policy in ("static", "loaded"):
            # fresh router per phase: no learned state leaks from the
            # static baseline into the loaded run.  epsilon 0 keeps both
            # deterministic — measured latency + advertised depth do the
            # steering; exploration buys nothing with one candidate pair
            entry.router = RequestRouter(policy=policy, epsilon=0.0,
                                         seed=0)
            entry.cluster.load_provider = entry.router.load
            entry.cluster.on_load = entry.router.advertise
            row = _hammer_routed(entry, cell, n_conns, per_conn)
            row["selections"] = {
                url: snap["selections"] for url, snap in
                entry.router.selector.snapshot().items()}
            results[policy] = row
            emit(f"routing_{policy}_hot_fwd_p50", row["p50_us"],
                 f"{row['rps']:.0f}rps")
        slow.serve_delay = 0.0
    finally:
        for s in servers:
            s.close()

    speedup = results["loaded"]["rps"] / results["static"]["rps"]
    results["loaded_speedup"] = speedup

    # -- ring vs rendezvous placement balance (pure data structures) ------
    nodes = [f"http://10.0.0.{i}:8080" for i in range(1, 6)]
    keys = [f"cell-{i:04d}" for i in range(512)]
    ideal = len(keys) / len(nodes)
    balance: dict = {}
    for kind, placement in (
            ("ring", HashRing(nodes, vnodes=64, replicas=2)),
            ("rendezvous", RendezvousHash(nodes, replicas=2))):
        counts = {n: 0 for n in nodes}
        for k in keys:
            counts[placement.owners(k)[0]] += 1
        balance[kind] = {
            "max_over_ideal": max(counts.values()) / ideal,
            "min_over_ideal": min(counts.values()) / ideal,
        }
        emit(f"routing_balance_{kind}",
             balance[kind]["max_over_ideal"],
             f"min {balance[kind]['min_over_ideal']:.2f}x ideal")
    results["balance"] = balance

    LAST_METRICS["routing"] = results
    print(f"(loaded {results['loaded']['rps']:.0f}rps vs static "
          f"{results['static']['rps']:.0f}rps = {speedup:.1f}x with the "
          f"primary owner sleeping {serve_delay * 1e3:.0f}ms; balance "
          f"max/ideal ring {balance['ring']['max_over_ideal']:.2f}x vs "
          f"rendezvous {balance['rendezvous']['max_over_ideal']:.2f}x)")
    # acceptance: with one slowed replica, load-aware selection sustains
    # >= 2x the static ring-order hot-derive throughput
    assert speedup >= 2.0, (
        f"loaded policy only {speedup:.2f}x static with a slowed replica "
        f"at {n_conns} connections")
    return results


def evaluate_suite(n_warm: int = 30, n_loops: int = 3) -> dict:
    """Evaluation-plane numbers: cold trace vs warm compiled-cache hit,
    batched heterogeneous /v1/evaluate vs a sequential per-query loop,
    and the measured per-block cost against the energy-model roofline."""
    header("serving: evaluate (compile cache, batched hot path)")
    from repro.core import compile_cache as cc
    from repro.core.domains import DOMAINS
    from repro.core.energy import tpu_block_projection
    from repro.serving.evaluate import EvaluationService

    # -- cold trace vs warm hit (private cache, no HTTP in the way) --------
    local = EvaluationService(compile_cache=cc.CompileCache(max_entries=64))
    probes = [
        {"domain": "tri2d", "n_points": 4096},
        {"domain": "gasket2d", "n_points": 2048},
        {"domain": "msimplex3", "n_points": 1024},
        {"domain": "tri2d", "tier": "membership", "extent": [48, 48]},
    ]
    cold_us = []
    for q in probes:
        t0 = time.perf_counter()
        res = local.evaluate(q)
        cold_us.append((time.perf_counter() - t0) * 1e6)
        assert res["executable"] == "miss"
    warm_us = []
    for _ in range(n_warm):
        for q in probes:
            t0 = time.perf_counter()
            res = local.evaluate(q)
            warm_us.append((time.perf_counter() - t0) * 1e6)
            assert res["executable"] == "hit"
    warm_us.sort()
    cold_p50 = statistics.median(cold_us)
    warm_p50 = warm_us[len(warm_us) // 2]
    warm_p95 = warm_us[int(len(warm_us) * 0.95)]
    warm_speedup = cold_p50 / warm_p50
    emit("evaluate_cold_p50", cold_p50, "trace")
    emit("evaluate_warm_p50", warm_p50, "cached")
    emit("evaluate_warm_p95", warm_p95, "cached")
    assert warm_speedup >= 10, (
        f"warm path only {warm_speedup:.1f}x below cold (need >= 10x)")

    # -- batched heterogeneous request vs sequential loop, over HTTP -------
    cache = ArtifactCache(tempfile.mkdtemp(prefix="bench_evaluate_"))
    factory = batching_factory(MockLLMBackend, max_batch=8, max_wait=0.005)
    service = MappingService(cache=cache, backend_factory=factory,
                             n_validate=20_000, sample_every=10)
    hetero = [
        {"domain": "tri2d", "n_points": 512},
        {"domain": "tri2d", "n_points": 1024},
        {"domain": "tri2d", "n_points": 2048},
        {"domain": "gasket2d", "n_points": 512},
        {"domain": "gasket2d", "n_points": 1024},
        {"domain": "gasket2d", "n_points": 2048},
        {"domain": "msimplex3", "n_points": 512},
        {"domain": "msimplex3", "n_points": 1024},
        {"domain": "tri2d", "tier": "membership", "extent": [32, 32]},
        {"domain": "tri2d", "tier": "membership", "extent": [32, 32]},
        {"domain": "gasket2d", "tier": "membership", "extent": [27, 27]},
        {"domain": "msimplex3", "tier": "membership", "extent": [9, 9, 9]},
    ]

    def seq_pass(client) -> float:
        t0 = time.perf_counter()
        for q in hetero:
            if q.get("tier") == "membership":
                client.evaluate(q["domain"], tier="membership",
                                extent=q["extent"])
            else:
                client.evaluate(q["domain"], n_points=q["n_points"])
        return time.perf_counter() - t0

    with MappingHTTPServer(service) as server:
        client = RemoteMappingService(server.url)
        # warm both code paths: the batch uses group-padded executables,
        # the loop uses per-query ones — distinct cache entries
        batch_res = client.evaluate_batch(hetero)
        seq_pass(client)
        seq_s = min(seq_pass(client) for _ in range(n_loops))
        t_batch = []
        for _ in range(n_loops):
            t0 = time.perf_counter()
            batch_res = client.evaluate_batch(hetero)
            t_batch.append(time.perf_counter() - t0)
        batch_s = min(t_batch)
        groups = len({r["group"] for r in batch_res}) \
            if all("group" in r for r in batch_res) else 0
        metrics = client.metrics()
    batch_speedup = seq_s / batch_s
    emit("evaluate_seq_loop", seq_s / len(hetero) * 1e6, "n*http")
    emit("evaluate_batched", batch_s / len(hetero) * 1e6, "1*http")
    assert batch_speedup > 1, (
        f"batched request slower than sequential loop ({batch_speedup:.2f}x)")

    # -- roofline sanity: measured per-block cost vs TPU v5e projection ----
    n_points, block_n = 65_536, 1024
    roof_q = {"domain": "tri2d", "n_points": n_points, "block_n": block_n}
    local.evaluate(roof_q)  # compile
    t0 = time.perf_counter()
    roof_res = local.evaluate(roof_q)
    roof_s = time.perf_counter() - t0
    assert roof_res["executable"] == "hit"
    n_blocks = roof_res["padded"] // block_n
    dim = DOMAINS["tri2d"].dim
    # per-point work model: ~12 integer ops per digit of the address
    # computation, (dim coords + λ) words of traffic
    proj = tpu_block_projection(
        flops_per_block=block_n * roof_res["ndigits"] * 12,
        bytes_per_block=block_n * (dim + 1) * 4,
        n_blocks=n_blocks)
    measured_block_us = roof_s / n_blocks * 1e6
    roofline_block_us = proj["time_s"] / n_blocks * 1e6
    emit("evaluate_block_measured", measured_block_us, "warm")
    emit("evaluate_block_roofline", roofline_block_us, proj["bound"])
    # the projection is an idealized accelerator lower bound — a measured
    # interpret-mode CPU number below it would mean the model is broken
    assert measured_block_us >= roofline_block_us

    ev = {
        "cold_p50_us": cold_p50,
        "warm_p50_us": warm_p50,
        "warm_p95_us": warm_p95,
        "warm_speedup": warm_speedup,
        "seq_loop_s": seq_s,
        "batch_s": batch_s,
        "batch_speedup": batch_speedup,
        "batch_queries": len(hetero),
        "batch_groups": groups,
        "roofline": {
            "n_blocks": n_blocks,
            "measured_block_us": measured_block_us,
            "roofline_block_us": roofline_block_us,
            "bound": proj["bound"],
            "ratio": measured_block_us / roofline_block_us,
        },
        "local_stats": local.stats_dict(),
        "server_metrics": {k: metrics.get(k)
                           for k in ("evaluate", "compile_cache", "http")},
        "client_stats": client.stats.as_dict(),
    }
    LAST_METRICS["evaluate"] = ev
    print(f"(cold p50 {cold_p50 / 1e3:.1f}ms vs warm p50 "
          f"{warm_p50:.0f}us = {warm_speedup:.0f}x; batch of {len(hetero)} "
          f"in {groups} groups {batch_speedup:.1f}x faster than the loop; "
          f"measured/roofline per-block {ev['roofline']['ratio']:.0f}x)")
    return ev


def wire_suite(reps: int = 9, n_single: int = 100) -> dict:
    """Binary vs JSON evaluation wire: codec-level encode+decode cost,
    end-to-end served p50 at 1k/100k/1M points, and the warm single-query
    served p50 (the number the compiled-executable win was drowning under
    JSON).  Asserts binary end-to-end >= 3x JSON on the 100k row with
    byte-identical decoded arrays."""
    header("serving: wire (binary vs JSON evaluation framing)")
    import json

    import numpy as np

    from repro.core import compile_cache as cc
    from repro.serving import wire
    from repro.serving.evaluate import (
        EvaluationService, hydrate_result, wire_result,
    )
    from benchmarks.common import timed

    sizes = (1_000, 100_000, 1_000_000)
    local = EvaluationService(compile_cache=cc.CompileCache(max_entries=16))
    codec: dict = {}
    for n in sizes:
        res = local.evaluate({"domain": "tri2d", "n_points": n})
        blob_j = json.dumps(wire_result(res), default=str).encode()
        blob_b = wire.encode_frame(res)
        back_j = hydrate_result(json.loads(blob_j))
        back_b = wire.decode_frame(blob_b)
        # byte-identity: both framings return exactly what was computed
        np.testing.assert_array_equal(back_b["coords"], res["coords"])
        np.testing.assert_array_equal(back_j["coords"], res["coords"])
        assert back_b["coords"].dtype == res["coords"].dtype
        assert back_j["coords"].dtype == res["coords"].dtype
        _, je = timed(lambda r=res: json.dumps(
            wire_result(r), default=str).encode())
        _, jd = timed(lambda b=blob_j: hydrate_result(json.loads(b)))
        _, be = timed(lambda r=res: wire.encode_frame(r))
        _, bd = timed(lambda b=blob_b: wire.decode_frame(b))
        emit(f"wire_codec_json_{n}", je + jd, f"{len(blob_j)}B")
        emit(f"wire_codec_bin_{n}", be + bd, f"{len(blob_b)}B")
        codec[n] = {"json_us": je + jd, "bin_us": be + bd,
                    "json_bytes": len(blob_j), "bin_bytes": len(blob_b),
                    "codec_speedup": (je + jd) / (be + bd)}

    # -- end-to-end over the async frontend (the default serving shape) ----
    service = MappingService(store=None)
    e2e: dict = {}
    with AsyncMappingHTTPServer(service) as server:
        cli_b = RemoteMappingService(server.url)
        cli_j = RemoteMappingService(server.url, binary=False)
        for n in sizes:
            reps_n = reps if n < 1_000_000 else 3
            p50s = {}
            for name, cli in (("json", cli_j), ("bin", cli_b)):
                cli.evaluate("tri2d", n_points=n)  # warm: compile + blob LRU
                xs = []
                for _ in range(reps_n):
                    t0 = time.perf_counter()
                    got = cli.evaluate("tri2d", n_points=n)
                    xs.append(time.perf_counter() - t0)
                xs.sort()
                p50s[name] = xs[len(xs) // 2]
                p50s[name + "_res"] = got
            np.testing.assert_array_equal(p50s["bin_res"]["coords"],
                                          p50s["json_res"]["coords"])
            assert p50s["bin_res"]["coords"].dtype == \
                p50s["json_res"]["coords"].dtype
            emit(f"wire_e2e_json_{n}", p50s["json"] * 1e6, "p50")
            emit(f"wire_e2e_bin_{n}", p50s["bin"] * 1e6, "p50")
            e2e[n] = {"json_p50_us": p50s["json"] * 1e6,
                      "bin_p50_us": p50s["bin"] * 1e6,
                      "speedup": p50s["json"] / p50s["bin"]}
        # warm single-query served p50: one typical-size query on a hot
        # keep-alive connection, binary wire, measured at the socket (a
        # prebuilt request + minimal response parse) so the number is the
        # server's turnaround + binary decode — the request-plane floor a
        # compiled-executable hit actually ships under.  http.client's own
        # header-parsing tax lands in the pooled-client row next to it.
        single_p50 = _raw_single_p50(server.host, server.port, n_single)
        emit("wire_single_warm_p50", single_p50, "bin+socket")
        singles = []
        for _ in range(n_single):
            t0 = time.perf_counter()
            cli_b.evaluate("tri2d", n_points=1024)
            singles.append(time.perf_counter() - t0)
        singles.sort()
        client_p50 = singles[len(singles) // 2] * 1e6
        emit("wire_single_client_p50", client_p50, "bin+pooled")
        metrics = cli_b.metrics()
        cli_b.close()
        cli_j.close()
    speedup_100k = e2e[100_000]["speedup"]
    assert speedup_100k >= 3, (
        f"binary only {speedup_100k:.2f}x faster than JSON at 100k points "
        "(need >= 3x)")
    out = {
        "codec": codec,
        "e2e": e2e,
        "speedup_100k": speedup_100k,
        "single_warm_p50_us": single_p50,
        "single_client_p50_us": client_p50,
        "eval_wire_cache": metrics.get("evaluate_wire"),
        "aio": metrics.get("aio"),
    }
    LAST_METRICS["wire"] = out
    print(f"(100k e2e: JSON {e2e[100_000]['json_p50_us'] / 1e3:.1f}ms vs "
          f"binary {e2e[100_000]['bin_p50_us'] / 1e3:.2f}ms = "
          f"{speedup_100k:.1f}x; 1M e2e "
          f"{e2e[1_000_000]['speedup']:.1f}x; warm single-query served p50 "
          f"{single_p50:.0f}us, {client_p50:.0f}us through the pooled "
          "client)")
    return out


def _raw_single_p50(host: str, port: int, n: int,
                    n_points: int = 1024) -> float:
    """Served p50 (us) for one warm binary evaluate on a hot keep-alive
    socket: prebuilt request bytes in, status line + headers + body out,
    ``wire.decode_frame`` on the payload.  Asserts the timed responses
    come off the compiled-executable cache."""
    import json
    import socket

    from repro.serving import wire

    body = json.dumps({"domain": "tri2d", "n_points": n_points}).encode()
    req = (b"POST /v1/evaluate HTTP/1.1\r\nHost: bench\r\n"
           b"Content-Type: application/json\r\n"
           b"Accept: " + wire.CONTENT_TYPE.encode() + b"\r\n"
           b"Content-Length: " + str(len(body)).encode() + b"\r\n\r\n"
           + body)
    sock = socket.create_connection((host, port))
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    reader = sock.makefile("rb")

    def once() -> bytes:
        sock.sendall(req)
        clen = 0
        reader.readline()  # status line
        while True:
            line = reader.readline()
            if line in (b"\r\n", b""):
                break
            if line.lower().startswith(b"content-length:"):
                clen = int(line.split(b":", 1)[1])
        return reader.read(clen)

    try:
        once()  # compile + wire-LRU warmup
        wire.decode_frame(once())
        xs = []
        for _ in range(n):
            t0 = time.perf_counter()
            res = wire.decode_frame(once())
            xs.append((time.perf_counter() - t0) * 1e6)
        assert res["executable"] == "hit"
    finally:
        reader.close()
        sock.close()
    xs.sort()
    return xs[len(xs) // 2]


def _hammer(server, n_conns: int, per_conn: int) -> dict:
    """n_conns keep-alive connections (one pooled client each) hammering a
    hot cell: aggregate throughput + per-request p50/p95."""
    lat: list[float] = []
    mu = threading.Lock()
    gate = threading.Barrier(n_conns + 1)

    def worker():
        c = RemoteMappingService(server.url)
        c.derive("tri2d", MODEL, 100)  # opens + warms this thread's conn
        gate.wait()
        times = []
        for _ in range(per_conn):
            t0 = time.perf_counter()
            assert c.derive("tri2d", MODEL, 100).cache_hit
            times.append(time.perf_counter() - t0)
        with mu:
            lat.extend(times)

    threads = [threading.Thread(target=worker) for _ in range(n_conns)]
    for t in threads:
        t.start()
    gate.wait()  # every connection is open before the clock starts
    server_conns = getattr(server, "connections", n_conns)
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    lat.sort()
    return {
        "connections": n_conns,
        "server_connections": server_conns,
        "requests": n_conns * per_conn,
        "rps": n_conns * per_conn / dt,
        "p50_us": lat[len(lat) // 2] * 1e6,
        "p95_us": lat[int(len(lat) * 0.95)] * 1e6,
    }


def concurrency_suite(levels=(16, 64, 256), total: int = 2048) -> dict:
    """Threaded vs async frontend under rising connection counts.

    Both serve the same hot cell from identical private stores; every
    request is a server-side cache hit, so the numbers are pure frontend
    cost — thread-per-connection scheduling vs the event loop's inline
    fast path."""
    header("serving: concurrency (threaded vs async frontend)")
    results: dict = {"levels": list(levels), "threaded": {}, "async": {}}
    kw = dict(n_validate=20_000, sample_every=10)
    for kind in ("threaded", "async"):
        cache = ArtifactCache(tempfile.mkdtemp(prefix=f"bench_conc_{kind}_"))
        factory = batching_factory(MockLLMBackend, max_batch=8,
                                   max_wait=0.005)
        service = MappingService(cache=cache, backend_factory=factory, **kw)
        server = MappingHTTPServer(service) if kind == "threaded" \
            else AsyncMappingHTTPServer(service)
        with server:
            RemoteMappingService(server.url).derive("tri2d", MODEL, 100)
            for n in levels:
                row = _hammer(server, n, max(4, total // n))
                results[kind][n] = row
                emit(f"concurrency_{kind}_{n}conn", row["p50_us"],
                     f"{row['rps']:.0f}rps")

    top = levels[-1]
    speedup = (results["async"][top]["rps"] /
               results["threaded"][top]["rps"])
    results["top_connections"] = top
    results["async_speedup_at_top"] = speedup
    LAST_METRICS["concurrency"] = results
    print(f"(at {top} connections: async "
          f"{results['async'][top]['rps']:.0f}rps vs threaded "
          f"{results['threaded'][top]['rps']:.0f}rps = {speedup:.1f}x; "
          f"async p95 {results['async'][top]['p95_us'] / 1e3:.1f}ms)")
    # acceptance: the event loop sustains the top connection count at
    # >= 2x the threaded hot-path throughput
    assert results["async"][top]["server_connections"] >= top, (
        f"async frontend held {results['async'][top]['server_connections']} "
        f"of {top} connections")
    assert speedup >= 2.0, (
        f"async frontend only {speedup:.2f}x threaded at {top} connections "
        f"(need >= 2x)")
    return results


def observability_suite(n_conns: int = 8, per_conn: int = 160,
                        repeats: int = 3) -> dict:
    """Instrumentation overhead on the async hot path: hot-derive p50 with
    request tracing on vs off.  Interleaved A/B repeats, best-of-N per arm,
    so a background hiccup can't land entirely on one side."""
    header("serving: observability overhead (async hot derive, "
           "tracing on vs off)")
    kw = dict(n_validate=20_000, sample_every=10)
    best = {True: float("inf"), False: float("inf")}
    rows = {}
    for _ in range(repeats):
        for enabled in (True, False):
            cache = ArtifactCache(tempfile.mkdtemp(prefix="bench_obs_"))
            factory = batching_factory(MockLLMBackend, max_batch=8,
                                       max_wait=0.005)
            service = MappingService(cache=cache, backend_factory=factory,
                                     **kw)
            with AsyncMappingHTTPServer(service,
                                        observability=enabled) as server:
                RemoteMappingService(server.url).derive("tri2d", MODEL, 100)
                row = _hammer(server, n_conns, per_conn)
            if row["p50_us"] < best[enabled]:
                best[enabled] = row["p50_us"]
                rows[enabled] = row
    p50_on, p50_off = best[True], best[False]
    overhead = p50_on / p50_off - 1.0
    results = {
        "tracing_on": rows[True],
        "tracing_off": rows[False],
        "p50_on_us": p50_on,
        "p50_off_us": p50_off,
        "overhead_frac": overhead,
    }
    emit("observability_on_hot_p50", p50_on, f"{overhead * 100:+.1f}%")
    emit("observability_off_hot_p50", p50_off, "baseline")
    LAST_METRICS["observability"] = results
    print(f"(hot derive p50: tracing on {p50_on:.0f}us vs off "
          f"{p50_off:.0f}us = {overhead * 100:+.1f}% overhead)")
    # acceptance: tracing costs <= 5% of the hot-path p50, with a 25us
    # absolute floor — at tens-of-us latencies, scheduler jitter alone can
    # exceed a pure percentage bound
    assert p50_on <= p50_off * 1.05 + 25.0, (
        f"observability overhead too high: p50 {p50_on:.1f}us with tracing "
        f"vs {p50_off:.1f}us without (bound: 5% + 25us)")
    return results


def loadgen_suite(requests: int = 200, concurrency: int = 8) -> dict:
    """Zipf trace replay against a self-hosted 2-node async fleet — the SLO
    harness exercised end to end (see ``benchmarks/loadgen.py``)."""
    from benchmarks import loadgen

    header("serving: trace-driven load generation (2-node fleet, zipf)")
    spec = loadgen.LoadSpec(requests=requests, concurrency=concurrency,
                            trace_sample=0.1)
    urls, close = loadgen._self_fleet(2)
    try:
        _, report = loadgen.run(urls, spec)
    finally:
        close()
    emit("loadgen_p50", report["p50_ms"] * 1e3,
         f"{report['throughput_rps']:.0f}rps")
    emit("loadgen_p99", report["p99_ms"] * 1e3,
         f"shed_rate={report['shed_rate']:.3f}")
    LAST_METRICS["loadgen"] = report
    print(f"(replayed {report['requests']} requests at "
          f"{report['throughput_rps']:.0f}rps: p50 {report['p50_ms']:.1f}ms "
          f"p99 {report['p99_ms']:.1f}ms, sheds {report['sheds']}, "
          f"errors {report['errors']})")
    assert report["error_rate"] == 0.0, \
        f"loadgen replay saw errors: {report}"
    return report


if __name__ == "__main__":
    run()
    cluster_suite()
    evaluate_suite()
    concurrency_suite()
    observability_suite()
    loadgen_suite()
