"""Serving suite: HTTP derive throughput/latency against a local server,
plus store-pressure numbers for the tiered artifact store.

Boots a MappingHTTPServer (mock backend, private temp store) on an
ephemeral port, then measures the two costs a fleet client actually pays:

  * cold derive — first request for a cell: full pipeline behind HTTP;
  * hot derive  — repeat request: server-side cache hit, so the number is
    pure serving overhead (HTTP + JSON + store read);
  * hot throughput — concurrent clients hammering cached cells.

The store-pressure sub-suite isolates where a hot hit resolves:

  * memory tier — resident rehydrated result: no disk, no JSON, no HTTP;
  * disk tier   — record read + checksum verify + rehydration per hit;
  * peer tier   — full HTTP round-trip to a sibling server per hit;
  * eviction churn — throughput when the disk budget is smaller than the
    working set, so records evict and re-derive continuously.

Run metrics (cache hits, coalescing, p50/p95 from the server's own
/metrics, per-tier store counters) land in ``LAST_METRICS`` so ``run.py
--json`` can emit them.
"""
from __future__ import annotations

import concurrent.futures
import statistics
import tempfile
import time

from benchmarks.common import emit, header
from repro.core.artifact import ArtifactCache
from repro.core.backends import MockLLMBackend
from repro.core.store import DiskStore, PeerStore, TieredStore, build_store
from repro.serving import (
    MappingHTTPServer, MappingService, RemoteMappingService, batching_factory,
)

MODEL = "OSS:120b"
#: populated by run(); run.py --json folds this into BENCH_serving.json
LAST_METRICS: dict = {}


def run(n_hot: int = 50, n_clients: int = 8) -> dict:
    header("serving: HTTP derive latency/throughput (local server)")
    cache = ArtifactCache(tempfile.mkdtemp(prefix="bench_serving_"))
    factory = batching_factory(MockLLMBackend, max_batch=8, max_wait=0.005)
    service = MappingService(cache=cache, backend_factory=factory,
                             n_validate=20_000, sample_every=10)
    with MappingHTTPServer(service) as server:
        client = RemoteMappingService(server.url)

        # cold: one full derivation per domain, behind HTTP
        cold_us = []
        for domain in ("tri2d", "gasket2d", "msimplex3"):
            t0 = time.perf_counter()
            res = client.derive(domain, MODEL, 100)
            cold_us.append((time.perf_counter() - t0) * 1e6)
            assert res.compiled and not res.cache_hit
        emit("serving_derive_cold", statistics.median(cold_us), "http")

        # hot: repeats are server-side cache hits — serving overhead only
        hot_us = []
        for _ in range(n_hot):
            t0 = time.perf_counter()
            res = client.derive("tri2d", MODEL, 100)
            hot_us.append((time.perf_counter() - t0) * 1e6)
            assert res.cache_hit
        hot_us.sort()
        emit("serving_derive_hot_p50", hot_us[len(hot_us) // 2], "http")
        emit("serving_derive_hot_p95", hot_us[int(len(hot_us) * 0.95)], "http")

        # hot throughput: concurrent clients on cached cells
        def one_client(_):
            c = RemoteMappingService(server.url)
            for _ in range(n_hot // n_clients or 1):
                assert c.derive("gasket2d", MODEL, 100).cache_hit
            return c.stats.server_cache_hits

        t0 = time.perf_counter()
        with concurrent.futures.ThreadPoolExecutor(n_clients) as pool:
            hits = sum(pool.map(one_client, range(n_clients)))
        dt = time.perf_counter() - t0
        emit("serving_derive_hot_throughput", dt / hits * 1e6,
             f"{hits / dt:.0f}rps")

        metrics = client.metrics()
    LAST_METRICS.clear()
    LAST_METRICS.update({
        "server": metrics,
        "client_stats": client.stats.as_dict(),
        "cold_us": cold_us,
        "hot_p50_us": hot_us[len(hot_us) // 2],
        "hot_p95_us": hot_us[int(len(hot_us) * 0.95)],
        "hot_rps": hits / dt,
    })
    svc_stats = metrics["service"]
    print(f"(server: {svc_stats['derivations']} derivations, "
          f"{svc_stats['cache_hits']} cache hits, "
          f"hit ratio {svc_stats['cache_hit_ratio']:.2f})")
    store_pressure()
    return LAST_METRICS


def _hot_us(svc, domain: str, n: int) -> list[float]:
    """Median-friendly per-hit latencies after a warmup request."""
    svc.derive(domain, MODEL, 100)
    out = []
    for _ in range(n):
        t0 = time.perf_counter()
        res = svc.derive(domain, MODEL, 100)
        out.append((time.perf_counter() - t0) * 1e6)
        assert res.cache_hit
    return out


def store_pressure(n_hot: int = 30, n_churn: int = 24) -> dict:
    """Hot-hit latency per store tier + throughput under eviction churn."""
    header("serving: store pressure (per-tier hot hits, eviction churn)")
    root = tempfile.mkdtemp(prefix="bench_store_")
    kw = dict(n_validate=20_000, sample_every=10)

    # memory tier: resident rehydrated result (the intended steady state)
    svc_mem = MappingService(store=build_store(root=f"{root}/mem"), **kw)
    svc_mem.derive("tri2d", MODEL, 100)
    mem_us = _hot_us(svc_mem, "tri2d", n_hot)
    assert svc_mem.store.disk.reads <= 2  # hot hits never touched disk
    emit("store_hot_memory_tier", statistics.median(mem_us), "lru")

    # disk tier: no memory tier, every hit reads + verifies + rehydrates
    svc_disk = MappingService(
        store=TieredStore(disk=DiskStore(f"{root}/disk")), **kw)
    svc_disk.derive("tri2d", MODEL, 100)
    disk_us = _hot_us(svc_disk, "tri2d", n_hot)
    emit("store_hot_disk_tier", statistics.median(disk_us), "checksum")

    # peer tier: every hit is an HTTP round-trip to the sibling that holds
    # the record (a peer-only store has no local tier to promote into)
    svc_origin = MappingService(store=build_store(root=f"{root}/origin"), **kw)
    svc_origin.derive("tri2d", MODEL, 100)
    with MappingHTTPServer(svc_origin) as origin:
        svc_peer = MappingService(
            store=TieredStore(peers=PeerStore([origin.url])), **kw)
        peer_us = _hot_us(svc_peer, "tri2d", n_hot)
    emit("store_hot_peer_tier", statistics.median(peer_us), "http")

    # eviction churn: working set > disk budget, so serves keep paying
    # eviction + re-derivation — the worst-case sustained throughput
    probe = DiskStore(f"{root}/probe")
    svc_probe = MappingService(store=TieredStore(disk=probe),
                               n_validate=2000, sample_every=1)
    rec_bytes = probe.path(
        svc_probe.derive("tri2d", MODEL, 100).cache_key).stat().st_size
    churn_store = build_store(root=f"{root}/churn",
                              max_bytes=int(rec_bytes * 2.5),
                              memory_entries=2)
    svc_churn = MappingService(store=churn_store, n_validate=2000,
                               sample_every=1)
    cells = [("tri2d", 20), ("tri2d", 50), ("tri2d", 100),
             ("gasket2d", 20), ("gasket2d", 50), ("gasket2d", 100)]
    t0 = time.perf_counter()
    for i in range(n_churn):
        domain, stage = cells[i % len(cells)]
        svc_churn.derive(domain, MODEL, stage)
    dt = time.perf_counter() - t0
    evicted = (churn_store.disk.evictions_bytes +
               churn_store.disk.evictions_ttl)
    emit("store_churn_throughput", dt / n_churn * 1e6,
         f"{n_churn / dt:.0f}ops")

    pressure = {
        "memory_p50_us": statistics.median(mem_us),
        "disk_p50_us": statistics.median(disk_us),
        "peer_p50_us": statistics.median(peer_us),
        "churn_ops_per_s": n_churn / dt,
        "churn_evictions": evicted,
        "churn_rederivations": svc_churn.stats.derivations,
        "memory_store_stats": svc_mem.store_stats(),
        "churn_store_stats": svc_churn.store_stats(),
    }
    LAST_METRICS["store_pressure"] = pressure
    print(f"(tiers p50: memory {pressure['memory_p50_us']:.0f}us, disk "
          f"{pressure['disk_p50_us']:.0f}us, peer "
          f"{pressure['peer_p50_us']:.0f}us; churn "
          f"{pressure['churn_ops_per_s']:.0f}ops/s with {evicted} evictions, "
          f"{svc_churn.stats.derivations} re-derivations)")
    return pressure


if __name__ == "__main__":
    run()
