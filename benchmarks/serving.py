"""Serving suite: HTTP derive throughput/latency against a local server.

Boots a MappingHTTPServer (mock backend, private temp store) on an
ephemeral port, then measures the two costs a fleet client actually pays:

  * cold derive — first request for a cell: full pipeline behind HTTP;
  * hot derive  — repeat request: server-side cache hit, so the number is
    pure serving overhead (HTTP + JSON + store read);
  * hot throughput — concurrent clients hammering cached cells.

Run metrics (cache hits, coalescing, p50/p95 from the server's own
/metrics) land in ``LAST_METRICS`` so ``run.py --json`` can emit them.
"""
from __future__ import annotations

import concurrent.futures
import statistics
import tempfile
import time

from benchmarks.common import emit, header
from repro.core.artifact import ArtifactCache
from repro.core.backends import MockLLMBackend
from repro.serving import (
    MappingHTTPServer, MappingService, RemoteMappingService, batching_factory,
)

MODEL = "OSS:120b"
#: populated by run(); run.py --json folds this into BENCH_serving.json
LAST_METRICS: dict = {}


def run(n_hot: int = 50, n_clients: int = 8) -> dict:
    header("serving: HTTP derive latency/throughput (local server)")
    cache = ArtifactCache(tempfile.mkdtemp(prefix="bench_serving_"))
    factory = batching_factory(MockLLMBackend, max_batch=8, max_wait=0.005)
    service = MappingService(cache=cache, backend_factory=factory,
                             n_validate=20_000, sample_every=10)
    with MappingHTTPServer(service) as server:
        client = RemoteMappingService(server.url)

        # cold: one full derivation per domain, behind HTTP
        cold_us = []
        for domain in ("tri2d", "gasket2d", "msimplex3"):
            t0 = time.perf_counter()
            res = client.derive(domain, MODEL, 100)
            cold_us.append((time.perf_counter() - t0) * 1e6)
            assert res.compiled and not res.cache_hit
        emit("serving_derive_cold", statistics.median(cold_us), "http")

        # hot: repeats are server-side cache hits — serving overhead only
        hot_us = []
        for _ in range(n_hot):
            t0 = time.perf_counter()
            res = client.derive("tri2d", MODEL, 100)
            hot_us.append((time.perf_counter() - t0) * 1e6)
            assert res.cache_hit
        hot_us.sort()
        emit("serving_derive_hot_p50", hot_us[len(hot_us) // 2], "http")
        emit("serving_derive_hot_p95", hot_us[int(len(hot_us) * 0.95)], "http")

        # hot throughput: concurrent clients on cached cells
        def one_client(_):
            c = RemoteMappingService(server.url)
            for _ in range(n_hot // n_clients or 1):
                assert c.derive("gasket2d", MODEL, 100).cache_hit
            return c.stats.server_cache_hits

        t0 = time.perf_counter()
        with concurrent.futures.ThreadPoolExecutor(n_clients) as pool:
            hits = sum(pool.map(one_client, range(n_clients)))
        dt = time.perf_counter() - t0
        emit("serving_derive_hot_throughput", dt / hits * 1e6,
             f"{hits / dt:.0f}rps")

        metrics = client.metrics()
    LAST_METRICS.clear()
    LAST_METRICS.update({
        "server": metrics,
        "client_stats": client.stats.as_dict(),
        "cold_us": cold_us,
        "hot_p50_us": hot_us[len(hot_us) // 2],
        "hot_p95_us": hot_us[int(len(hot_us) * 0.95)],
        "hot_rps": hits / dt,
    })
    svc_stats = metrics["service"]
    print(f"(server: {svc_stats['derivations']} derivations, "
          f"{svc_stats['cache_hits']} cache hits, "
          f"hit ratio {svc_stats['cache_hit_ratio']:.2f})")
    return LAST_METRICS


if __name__ == "__main__":
    run()
