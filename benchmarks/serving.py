"""Serving suite: HTTP derive throughput/latency against a local server,
plus store-pressure numbers for the tiered artifact store.

Boots a MappingHTTPServer (mock backend, private temp store) on an
ephemeral port, then measures the two costs a fleet client actually pays:

  * cold derive — first request for a cell: full pipeline behind HTTP;
  * hot derive  — repeat request: server-side cache hit, so the number is
    pure serving overhead (HTTP + JSON + store read);
  * hot throughput — concurrent clients hammering cached cells.

The store-pressure sub-suite isolates where a hot hit resolves:

  * memory tier — resident rehydrated result: no disk, no JSON, no HTTP;
  * disk tier   — record read + checksum verify + rehydration per hit;
  * peer tier   — full HTTP round-trip to a sibling server per hit;
  * eviction churn — throughput when the disk budget is smaller than the
    working set, so records evict and re-derive continuously.

The cluster sub-suite (``--only cluster``) measures the two transports this
fleet actually pays for:

  * keep-alive vs fresh-connection hot derive — the pooled ``http.client``
    transport against the per-request ``Connection: close`` baseline;
  * owner-routed vs forwarded derive on a real 3-node ring — the client
    hashing locally and hitting the owner, against the server-side
    forwarding hop a ring-naive client pays.

Run metrics (cache hits, coalescing, p50/p95 from the server's own
/metrics, per-tier store counters) land in ``LAST_METRICS`` so ``run.py
--json`` can emit them.
"""
from __future__ import annotations

import concurrent.futures
import statistics
import tempfile
import time

from benchmarks.common import emit, header
from repro.core.artifact import ArtifactCache
from repro.core.backends import MockLLMBackend
from repro.core.store import DiskStore, PeerStore, TieredStore, build_store
from repro.serving import (
    MappingHTTPServer, MappingService, RemoteMappingService, batching_factory,
)

MODEL = "OSS:120b"
#: populated by run(); run.py --json folds this into BENCH_serving.json
LAST_METRICS: dict = {}


def run(n_hot: int = 50, n_clients: int = 8) -> dict:
    header("serving: HTTP derive latency/throughput (local server)")
    cache = ArtifactCache(tempfile.mkdtemp(prefix="bench_serving_"))
    factory = batching_factory(MockLLMBackend, max_batch=8, max_wait=0.005)
    service = MappingService(cache=cache, backend_factory=factory,
                             n_validate=20_000, sample_every=10)
    with MappingHTTPServer(service) as server:
        client = RemoteMappingService(server.url)

        # cold: one full derivation per domain, behind HTTP
        cold_us = []
        for domain in ("tri2d", "gasket2d", "msimplex3"):
            t0 = time.perf_counter()
            res = client.derive(domain, MODEL, 100)
            cold_us.append((time.perf_counter() - t0) * 1e6)
            assert res.compiled and not res.cache_hit
        emit("serving_derive_cold", statistics.median(cold_us), "http")

        # hot: repeats are server-side cache hits — serving overhead only
        hot_us = []
        for _ in range(n_hot):
            t0 = time.perf_counter()
            res = client.derive("tri2d", MODEL, 100)
            hot_us.append((time.perf_counter() - t0) * 1e6)
            assert res.cache_hit
        hot_us.sort()
        emit("serving_derive_hot_p50", hot_us[len(hot_us) // 2], "http")
        emit("serving_derive_hot_p95", hot_us[int(len(hot_us) * 0.95)], "http")

        # hot throughput: concurrent clients on cached cells
        def one_client(_):
            c = RemoteMappingService(server.url)
            for _ in range(n_hot // n_clients or 1):
                assert c.derive("gasket2d", MODEL, 100).cache_hit
            return c.stats.server_cache_hits

        t0 = time.perf_counter()
        with concurrent.futures.ThreadPoolExecutor(n_clients) as pool:
            hits = sum(pool.map(one_client, range(n_clients)))
        dt = time.perf_counter() - t0
        emit("serving_derive_hot_throughput", dt / hits * 1e6,
             f"{hits / dt:.0f}rps")

        metrics = client.metrics()
    LAST_METRICS.clear()
    LAST_METRICS.update({
        "server": metrics,
        "client_stats": client.stats.as_dict(),
        "cold_us": cold_us,
        "hot_p50_us": hot_us[len(hot_us) // 2],
        "hot_p95_us": hot_us[int(len(hot_us) * 0.95)],
        "hot_rps": hits / dt,
    })
    svc_stats = metrics["service"]
    print(f"(server: {svc_stats['derivations']} derivations, "
          f"{svc_stats['cache_hits']} cache hits, "
          f"hit ratio {svc_stats['cache_hit_ratio']:.2f})")
    store_pressure()
    return LAST_METRICS


def _hot_us(svc, domain: str, n: int) -> list[float]:
    """Median-friendly per-hit latencies after a warmup request."""
    svc.derive(domain, MODEL, 100)
    out = []
    for _ in range(n):
        t0 = time.perf_counter()
        res = svc.derive(domain, MODEL, 100)
        out.append((time.perf_counter() - t0) * 1e6)
        assert res.cache_hit
    return out


def store_pressure(n_hot: int = 30, n_churn: int = 24) -> dict:
    """Hot-hit latency per store tier + throughput under eviction churn."""
    header("serving: store pressure (per-tier hot hits, eviction churn)")
    root = tempfile.mkdtemp(prefix="bench_store_")
    kw = dict(n_validate=20_000, sample_every=10)

    # memory tier: resident rehydrated result (the intended steady state)
    svc_mem = MappingService(store=build_store(root=f"{root}/mem"), **kw)
    svc_mem.derive("tri2d", MODEL, 100)
    mem_us = _hot_us(svc_mem, "tri2d", n_hot)
    assert svc_mem.store.disk.reads <= 2  # hot hits never touched disk
    emit("store_hot_memory_tier", statistics.median(mem_us), "lru")

    # disk tier: no memory tier, every hit reads + verifies + rehydrates
    svc_disk = MappingService(
        store=TieredStore(disk=DiskStore(f"{root}/disk")), **kw)
    svc_disk.derive("tri2d", MODEL, 100)
    disk_us = _hot_us(svc_disk, "tri2d", n_hot)
    emit("store_hot_disk_tier", statistics.median(disk_us), "checksum")

    # peer tier: every hit is an HTTP round-trip to the sibling that holds
    # the record (a peer-only store has no local tier to promote into)
    svc_origin = MappingService(store=build_store(root=f"{root}/origin"), **kw)
    svc_origin.derive("tri2d", MODEL, 100)
    with MappingHTTPServer(svc_origin) as origin:
        svc_peer = MappingService(
            store=TieredStore(peers=PeerStore([origin.url])), **kw)
        peer_us = _hot_us(svc_peer, "tri2d", n_hot)
    emit("store_hot_peer_tier", statistics.median(peer_us), "http")

    # eviction churn: working set > disk budget, so serves keep paying
    # eviction + re-derivation — the worst-case sustained throughput
    probe = DiskStore(f"{root}/probe")
    svc_probe = MappingService(store=TieredStore(disk=probe),
                               n_validate=2000, sample_every=1)
    rec_bytes = probe.path(
        svc_probe.derive("tri2d", MODEL, 100).cache_key).stat().st_size
    churn_store = build_store(root=f"{root}/churn",
                              max_bytes=int(rec_bytes * 2.5),
                              memory_entries=2)
    svc_churn = MappingService(store=churn_store, n_validate=2000,
                               sample_every=1)
    cells = [("tri2d", 20), ("tri2d", 50), ("tri2d", 100),
             ("gasket2d", 20), ("gasket2d", 50), ("gasket2d", 100)]
    t0 = time.perf_counter()
    for i in range(n_churn):
        domain, stage = cells[i % len(cells)]
        svc_churn.derive(domain, MODEL, stage)
    dt = time.perf_counter() - t0
    evicted = (churn_store.disk.evictions_bytes +
               churn_store.disk.evictions_ttl)
    emit("store_churn_throughput", dt / n_churn * 1e6,
         f"{n_churn / dt:.0f}ops")

    pressure = {
        "memory_p50_us": statistics.median(mem_us),
        "disk_p50_us": statistics.median(disk_us),
        "peer_p50_us": statistics.median(peer_us),
        "churn_ops_per_s": n_churn / dt,
        "churn_evictions": evicted,
        "churn_rederivations": svc_churn.stats.derivations,
        "memory_store_stats": svc_mem.store_stats(),
        "churn_store_stats": svc_churn.store_stats(),
    }
    LAST_METRICS["store_pressure"] = pressure
    print(f"(tiers p50: memory {pressure['memory_p50_us']:.0f}us, disk "
          f"{pressure['disk_p50_us']:.0f}us, peer "
          f"{pressure['peer_p50_us']:.0f}us; churn "
          f"{pressure['churn_ops_per_s']:.0f}ops/s with {evicted} evictions, "
          f"{svc_churn.stats.derivations} re-derivations)")
    return pressure


def _timed_derives(client, domain: str, stage: int, n: int,
                   before_each=None) -> list[float]:
    out = []
    for _ in range(n):
        if before_each is not None:
            before_each()
        t0 = time.perf_counter()
        res = client.derive(domain, MODEL, stage)
        out.append((time.perf_counter() - t0) * 1e6)
        assert res.cache_hit
    out.sort()
    return out


def cluster_suite(n_hot: int = 60) -> dict:
    """Keep-alive vs fresh-connection hot derive, and owner-routed vs
    forwarded derive latency on a 3-node consistent-hash ring."""
    header("serving: cluster (keep-alive transport, ring routing)")
    from repro.serving.cluster import ClusterMembership

    kw = dict(n_validate=20_000, sample_every=10)

    # -- keep-alive vs fresh connection (one server, hot cell) -------------
    svc = MappingService(store=build_store(
        root=tempfile.mkdtemp(prefix="bench_cluster_")), **kw)
    with MappingHTTPServer(svc) as server:
        pooled = RemoteMappingService(server.url)
        fresh = RemoteMappingService(server.url, keep_alive=False)
        pooled.derive("tri2d", MODEL, 100)  # derive once, then all hot
        keep_us = _timed_derives(pooled, "tri2d", 100, n_hot)
        fresh_us = _timed_derives(fresh, "tri2d", 100, n_hot)
    emit("cluster_hot_keepalive_p50", keep_us[len(keep_us) // 2], "pooled")
    emit("cluster_hot_keepalive_p95", keep_us[int(len(keep_us) * 0.95)],
         "pooled")
    emit("cluster_hot_fresh_p50", fresh_us[len(fresh_us) // 2], "tcp/req")
    emit("cluster_hot_fresh_p95", fresh_us[int(len(fresh_us) * 0.95)],
         "tcp/req")

    # -- owner-routed vs forwarded derive (3-node ring) --------------------
    root = tempfile.mkdtemp(prefix="bench_ring_")
    servers = []
    seeds = []
    for i in range(3):
        node = MappingHTTPServer(
            MappingService(store=build_store(root=f"{root}/n{i}"),
                           **kw)).start()
        node.attach_cluster(ClusterMembership(
            node.url, seeds=seeds, replicas=2, vnodes=64,
            heartbeat_interval=0.1, down_after=2.0, sync_interval=5.0))
        seeds = seeds or [node.url]
        servers.append(node)
    deadline = time.perf_counter() + 20
    while any(len(s.cluster.ring.nodes) < 3 for s in servers):
        assert time.perf_counter() < deadline, "ring never converged"
        time.sleep(0.05)
    try:
        key = servers[0].service.request_key("gasket2d", MODEL, 100)
        owners = servers[0].cluster.owners(key)
        non_owner = next(s for s in servers if s.url not in owners)
        client = RemoteMappingService(non_owner.url)
        client.derive("gasket2d", MODEL, 100)  # derive + learn the key
        cell = ("gasket2d", MODEL, 100)
        # forwarded: forget the key each time, so every request pays the
        # server-side hop from the non-owner to the ring owner
        fwd_us = _timed_derives(
            client, "gasket2d", 100, n_hot,
            before_each=lambda: client._cell_keys.pop(cell, None))
        # owner-routed: the client hashes locally and hits the owner
        client.derive("gasket2d", MODEL, 100)  # re-learn the key
        routed_us = _timed_derives(client, "gasket2d", 100, n_hot)
        assert client.stats.routed >= n_hot
        forwarded_total = non_owner.forwarded
    finally:
        for s in servers:
            s.close()
    emit("cluster_derive_forwarded_p50", fwd_us[len(fwd_us) // 2], "2hop")
    emit("cluster_derive_owner_routed_p50",
         routed_us[len(routed_us) // 2], "direct")

    cluster = {
        "keepalive_p50_us": keep_us[len(keep_us) // 2],
        "keepalive_p95_us": keep_us[int(len(keep_us) * 0.95)],
        "fresh_p50_us": fresh_us[len(fresh_us) // 2],
        "fresh_p95_us": fresh_us[int(len(fresh_us) * 0.95)],
        "keepalive_saving_p50_us": (fresh_us[len(fresh_us) // 2] -
                                    keep_us[len(keep_us) // 2]),
        "forwarded_p50_us": fwd_us[len(fwd_us) // 2],
        "owner_routed_p50_us": routed_us[len(routed_us) // 2],
        "forwarding_hop_cost_us": (fwd_us[len(fwd_us) // 2] -
                                   routed_us[len(routed_us) // 2]),
        "forwarded_requests": forwarded_total,
        "client_stats": client.stats.as_dict(),
    }
    LAST_METRICS["cluster"] = cluster
    print(f"(keep-alive p50 {cluster['keepalive_p50_us']:.0f}us vs fresh "
          f"{cluster['fresh_p50_us']:.0f}us; owner-routed p50 "
          f"{cluster['owner_routed_p50_us']:.0f}us vs forwarded "
          f"{cluster['forwarded_p50_us']:.0f}us)")
    return cluster


if __name__ == "__main__":
    run()
    cluster_suite()
