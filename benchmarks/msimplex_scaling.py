"""Beyond-paper extension: bounding-box waste vs simplex dimension.

The paper measures m=2 (~50% waste) and m=3 (~83%); the generalized
m-simplex map (core/msimplex.py) shows the mapped kernel's advantage grows
as 1 - 1/m! — at m=5 the BB strategy wastes >99% of blocks.
"""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.core.msimplex import block_accounting_msimplex, map_msimplex


def run(n_points: int = 1_000_000) -> dict:
    header("m-simplex generalization: BB waste vs dimension (N = 1e6)")
    print(f"{'m':>3s}{'side':>7s}{'valid blk':>11s}{'bb blk':>14s}"
          f"{'waste':>9s}{'1-1/m!':>9s}")
    out = {}
    for m in (2, 3, 4, 5, 6):
        acc = block_accounting_msimplex(n_points, m)
        print(f"{m:>3d}{acc['side']:>7d}{acc['valid_blocks']:>11,}"
              f"{acc['bb_blocks']:>14,}{acc['waste_fraction']:>9.2%}"
              f"{acc['asymptotic_waste']:>9.2%}")
        out[m] = acc["waste_fraction"]
        # map sanity at this dimension
        assert map_msimplex(0, m) == (0,) * m
    emit("msimplex_waste_scaling", 0.0,
         ";".join(f"m{m}={w:.3f}" for m, w in out.items()))
    return out


if __name__ == "__main__":
    run()
