"""Beyond-paper extension: bounding-box waste vs simplex dimension.

The paper measures m=2 (~50% waste) and m=3 (~83%); the m-simplex family
(registered as first-class domains ``msimplex2..5``) shows the mapped
kernel's advantage grows as 1 - 1/m! — at m=5 the BB strategy wastes >99%
of blocks.

All numbers resolve through the deployed tier: domains come from the Domain
registry, maps from the MapRegistry's ground-truth entries, derivations from
the served grid (``MappingService.run_grid``), and the cost model from the
registry-driven deployment analytics — nothing calls ``core/msimplex.py``
directly, so the table reflects exactly what a client of the artifact store
would get.
"""
from __future__ import annotations

import math

import numpy as np

from benchmarks.common import emit, header
from repro.core.domains import DOMAINS, MSIMPLEX_MS
from repro.core.registry import REGISTRY
from repro.launch.analytic import map_deployment_analytics
from repro.serving import MappingService

MODEL = "OSS:120b"


def run(n_points: int = 1_000_000) -> dict:
    header("m-simplex generalization: BB waste vs dimension (N = 1e6)")
    names = [f"msimplex{m}" for m in MSIMPLEX_MS]
    # one served derivation per family member — repeat runs are cache hits
    svc = MappingService(n_validate=20_000, sample_every=10)
    grid = {r.domain: r
            for r in svc.run_grid(domains=names, models=[MODEL], stages=(100,))}

    print(f"{'m':>3s}{'side':>7s}{'valid blk':>11s}{'bb blk':>14s}"
          f"{'waste':>9s}{'1-1/m!':>9s}{'deployed':>10s}")
    out = {}
    for m, name in zip(MSIMPLEX_MS, names):
        dom = DOMAINS[name]
        entry = REGISTRY.ground_truth(name)
        acc = dom.block_accounting(n_points)
        asym = 1.0 - 1.0 / math.factorial(m)
        res = grid[name]
        deployed = "artifact" if (res.artifact is not None
                                  and res.artifact.deployable) else "--"
        print(f"{m:>3d}{dom.level_for_points(n_points):>7d}"
              f"{acc['valid_blocks']:>11,}{acc['bb_blocks']:>14,}"
              f"{acc['waste_fraction']:>9.2%}{asym:>9.2%}{deployed:>10s}")
        # deployed-tier sanity: registry numpy tier must match the domain's
        # independent canonical enumeration
        lams = np.arange(2048, dtype=np.int64)
        np.testing.assert_array_equal(
            REGISTRY.tier(name, None, "numpy")(lams),
            dom.enumerate_points(2048))
        dep = map_deployment_analytics(entry, n_points)
        out[m] = {"waste_fraction": acc["waste_fraction"],
                  "speedup": dep["speedup"],
                  "cache_hit": res.cache_hit}
    emit("msimplex_waste_scaling", 0.0,
         ";".join(f"m{m}={v['waste_fraction']:.3f}" for m, v in out.items()))
    hits = sum(1 for v in out.values() if v["cache_hit"])
    print(f"({hits}/{len(out)} derivations served from the artifact cache)")
    return out


if __name__ == "__main__":
    run()
