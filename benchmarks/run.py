"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Emits one ``name,us_per_call,derived`` CSV row per benchmark (benchmarks
also print their human-readable tables above the CSV rows).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="validate at the paper's 10^6 points (slower)")
    p.add_argument("--only", default=None,
                   help="accuracy|fig5|dense|fractal|attn")
    args = p.parse_args()

    n_val = 1_000_000 if args.full else 100_000
    sample = 200 if args.full else 50

    from benchmarks import (  # noqa: PLC0415
        accuracy_tables, attn_kernel, block_dense, block_fractal,
        energy_efficiency, msimplex_scaling,
    )

    t0 = time.time()
    print("name,us_per_call,derived")
    failures = []
    suites = {
        "accuracy": lambda: accuracy_tables.run(n_val, sample),
        "fig5": lambda: energy_efficiency.run(min(n_val, 50_000), sample),
        "dense": block_dense.run,
        "fractal": block_fractal.run,
        "attn": attn_kernel.run,
        "msimplex": msimplex_scaling.run,
    }
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            fn()
        except Exception as e:  # pragma: no cover
            import traceback

            traceback.print_exc()
            failures.append((name, repr(e)))
    print(f"\n[benchmarks] done in {time.time() - t0:.1f}s")
    if failures:
        print(f"[benchmarks] FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
