"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] \
        [--json BENCH_serving.json]

Emits one ``name,us_per_call,derived`` CSV row per benchmark (benchmarks
also print their human-readable tables above the CSV rows).  ``--json PATH``
additionally writes a machine-readable report: per-suite rows + wall time +
artifact-cache hit/miss deltas, and the serving suite's HTTP latency/
throughput metrics.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _cache_counts():
    from repro.core.artifact import default_cache

    cache = default_cache()
    if cache is None:
        return {"hits": 0, "misses": 0}
    return {"hits": cache.hits, "misses": cache.misses}


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="validate at the paper's 10^6 points (slower)")
    p.add_argument("--only", default=None,
                   help="comma-separated subset of: accuracy|fig5|dense"
                        "|fractal|attn|msimplex|serving|cluster|routing"
                        "|evaluate|wire|concurrency|observability|loadgen")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write a machine-readable per-suite report "
                        "(e.g. BENCH_serving.json)")
    args = p.parse_args()
    only = set(args.only.split(",")) if args.only else None

    n_val = 1_000_000 if args.full else 100_000
    sample = 200 if args.full else 50

    from benchmarks import (  # noqa: PLC0415
        accuracy_tables, attn_kernel, block_dense, block_fractal, common,
        energy_efficiency, msimplex_scaling, serving,
    )

    t0 = time.time()
    print("name,us_per_call,derived")
    failures = []
    suites = {
        "accuracy": lambda: accuracy_tables.run(n_val, sample),
        "fig5": lambda: energy_efficiency.run(min(n_val, 50_000), sample),
        "dense": block_dense.run,
        "fractal": block_fractal.run,
        "attn": attn_kernel.run,
        "msimplex": msimplex_scaling.run,
        "serving": serving.run,
        "cluster": serving.cluster_suite,
        "routing": serving.routing_suite,
        "evaluate": serving.evaluate_suite,
        "wire": serving.wire_suite,
        "concurrency": serving.concurrency_suite,
        "observability": serving.observability_suite,
        "loadgen": serving.loadgen_suite,
    }
    report: dict = {"suites": {}, "args": {"full": args.full}}
    for name, fn in suites.items():
        if only and name not in only:
            continue
        rows_before = len(common.ROWS)
        cache_before = _cache_counts()
        suite_t0 = time.time()
        try:
            fn()
        except Exception as e:  # pragma: no cover
            import traceback

            traceback.print_exc()
            failures.append((name, repr(e)))
        cache_after = _cache_counts()
        report["suites"][name] = {
            "seconds": time.time() - suite_t0,
            "rows": [{"name": row_name, "us_per_call": us, "derived": derived}
                     for row_name, us, derived in common.ROWS[rows_before:]],
            "cache_hits": cache_after["hits"] - cache_before["hits"],
            "cache_misses": cache_after["misses"] - cache_before["misses"],
            "failed": any(f[0] == name for f in failures),
        }
    if serving.LAST_METRICS and ("serving" in report["suites"]
                                 or "cluster" in report["suites"]
                                 or "routing" in report["suites"]
                                 or "evaluate" in report["suites"]
                                 or "wire" in report["suites"]
                                 or "concurrency" in report["suites"]
                                 or "observability" in report["suites"]
                                 or "loadgen" in report["suites"]):
        report["serving"] = serving.LAST_METRICS
        # the serving suite runs against its own private store, invisible to
        # default_cache() — take its hit/miss deltas from the server's own
        # counters instead
        if "serving" in report["suites"] and "server" in serving.LAST_METRICS:
            store = serving.LAST_METRICS["server"].get("store", {})
            report["suites"]["serving"]["cache_hits"] = store.get("hits", 0)
            report["suites"]["serving"]["cache_misses"] = store.get(
                "misses", 0)
    report["wall_seconds"] = time.time() - t0
    report["failures"] = failures

    print(f"\n[benchmarks] done in {time.time() - t0:.1f}s")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1, default=str)
        print(f"[benchmarks] wrote {args.json}")
    if failures:
        print(f"[benchmarks] FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
