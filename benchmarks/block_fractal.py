"""Table IX: block-level performance/energy, fractal geometries (N = 5e8).

The headline result: BB over the 3D Sierpinski box wastes >99.99% of blocks;
the mapped kernel reduces ~16s / ~1.6kJ to ~3.3ms / ~0.55J (paper: 4833x /
2890x with their projected BB count; our exact accounting is even larger —
both are reported).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, header, timed
from repro.core import paper_tables as pt
from repro.core.domains import DOMAINS
from repro.core.energy import estimate_bounding_box, estimate_mapped
from repro.core.registry import REGISTRY
from repro.kernels.domain_map.ops import bb_membership, map_coordinates

N_PAPER = 500_000_000


def run(measure_n: int = 65_536) -> dict:
    out = {}
    for dom_name in ("gasket2d", "sierpinski3d"):
        entry = REGISTRY.ground_truth(dom_name)
        logic = entry.logic
        dom = DOMAINS[dom_name]
        header(f"Table IX: {dom.paper_name}  (N = 5e8)")
        bb = estimate_bounding_box(dom, N_PAPER)
        mp = estimate_mapped(dom, logic, N_PAPER)
        paper = pt.TABLE_IX[dom_name]
        print(f"{'entry':34s}{'time ms':>12s}{'blocks':>18s}{'energy J':>10s}")
        print(f"{'Bounding Box (exact accounting)':34s}{bb.time_ms:>12.2f}"
              f"{bb.total_blocks:>18,}{bb.energy_j:>10.2f}")
        print(f"{'Bounding Box (paper, projected)':34s}"
              f"{paper['bounding_box']['time_ms']:>12.2f}"
              f"{paper['bounding_box']['total_blocks']:>18,}"
              f"{paper['bounding_box']['energy_j']:>10.2f}")
        print(f"{'Mapped (bitwise O(log N))':34s}{mp.time_ms:>12.2f}"
              f"{mp.total_blocks:>18,}{mp.energy_j:>10.2f}")
        speed_paper = paper["bounding_box"]["time_ms"] / mp.time_ms
        ered_paper = paper["bounding_box"]["energy_j"] / mp.energy_j
        speed_exact = bb.time_ms / mp.time_ms
        print(f"--> paper-accounting speedup {speed_paper:.0f}x / energy "
              f"{ered_paper:.0f}x   (paper claims "
              f"{pt.CLAIM_SPEEDUP:.0f}x / {pt.CLAIM_ENERGY_REDUCTION:.0f}x)")
        print(f"--> exact-accounting speedup {speed_exact:.0f}x "
              f"(BB block count not projected)")
        assert mp.total_blocks == paper["paper"]["total_blocks"]

        ext = dom.bounding_box_extent(measure_n)
        _, us_map = timed(map_coordinates, entry, measure_n,
                          interpret=True, repeats=2)
        _, us_bb = timed(bb_membership, entry, ext, interpret=True,
                         repeats=2)
        print(f"measured interpret-mode @N={measure_n:,}: mapped "
              f"{us_map / 1e3:.1f}ms vs BB-box {us_bb / 1e3:.1f}ms over "
              f"{int(np.prod(ext)):,} candidate points")
        emit(f"table_IX_{dom_name}", us_map,
             f"paper_speedup={speed_paper:.0f}x;exact_speedup={speed_exact:.0f}x")
        out[dom_name] = {"speedup_paper_accounting": speed_paper,
                         "speedup_exact": speed_exact}
    # headline claim check (3D Sierpinski)
    s3 = out["sierpinski3d"]
    ok = s3["speedup_paper_accounting"] > 4000
    print(f"\n[claim] 3D fractal speedup ~{s3['speedup_paper_accounting']:.0f}x"
          f" vs paper 4833x: {'OK' if ok else 'MISMATCH'}")
    return out


if __name__ == "__main__":
    run()
