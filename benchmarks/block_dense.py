"""Table VIII: block-level performance/energy, dense geometries (N = 5e8).

Three evidence tiers per row:
  1. exact block accounting (device-independent; Total/Wasted columns),
  2. calibrated A100 cost model (reproduces the paper's ms/J anchors),
  3. measured interpret-mode Pallas kernel ratios at reduced N (CPU) plus a
     TPU-v5e roofline projection for the mapped kernel.

Every row resolves its logic class through the MapRegistry — an unregistered
(domain, logic) pair fails loudly instead of silently mispricing a row.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, header, timed
from repro.core import paper_tables as pt
from repro.core.domains import DOMAINS
from repro.core.energy import estimate_bounding_box, estimate_mapped
from repro.core.registry import REGISTRY
from repro.kernels.domain_map.ops import bb_membership, map_coordinates

N_PAPER = 500_000_000
ROWS_VIII = {
    "tri2d": [
        ("Paper (Navarro 2014)", "analytical"),
        ("R1:70b (S20/S50) / OSS:120b / Lla3.3 / Nemo", "analytical"),
        ("R1:70b (S100)", "sqrt_loop"),
        ("OSS:20b (S50/S100)", "approx_if"),
        ("Qw3:32b (S50)", "binsearch"),
    ],
    "pyramid3d": [
        ("Paper (Navarro 2016)", "analytical"),
        ("R1:70b (S50) / Qw3:32b (all)", "cbrt_loop"),
        ("OSS:120b (S100) / Qw3:235b (S20)", "binsearch"),
        ("OSS:120b (S50)", "binsearch_linear"),
        ("OSS:120b (S20)", "linear"),
    ],
}

# cost-model-only rows with no distinct scalar implementation in the registry
_COST_MODEL_ONLY = {"binsearch_linear"}


def run(measure_n: int = 65_536) -> dict:
    out = {}
    for dom_name, rows in ROWS_VIII.items():
        dom = DOMAINS[dom_name]
        entry = REGISTRY.ground_truth(dom_name)
        header(f"Table VIII: {dom.paper_name}  (N = 5e8, A100-calibrated)")
        bb = estimate_bounding_box(dom, N_PAPER)
        paper_bb = (pt.TABLE_VIII[dom_name]["bounding_box"])
        print(f"{'entry':44s}{'time ms':>10s}{'blocks':>14s}{'wasted':>14s}"
              f"{'energy J':>10s}  logic")
        print(f"{'Bounding Box (baseline)':44s}{bb.time_ms:>10.2f}"
              f"{bb.total_blocks:>14,}{bb.wasted_blocks:>14,}"
              f"{bb.energy_j:>10.2f}  if O(1)"
              f"   [paper: {paper_bb['time_ms']}ms {paper_bb['energy_j']}J]")
        for label, logic in rows:
            if logic not in _COST_MODEL_ONLY:
                REGISTRY.resolve(dom_name, logic)  # must be registered
            est = estimate_mapped(dom, logic, N_PAPER)
            print(f"{label:44s}{est.time_ms:>10.2f}{est.total_blocks:>14,}"
                  f"{0:>14,}{est.energy_j:>10.2f}  {logic}")
        best = estimate_mapped(dom, rows[0][1], N_PAPER)
        speedup = bb.time_ms / best.time_ms
        ered = bb.energy_j / best.energy_j
        print(f"--> speedup {speedup:.0f}x, energy reduction {ered:.0f}x, "
              f"valid blocks = {best.total_blocks:,} "
              f"(paper: {pt.TABLE_VIII[dom_name]['paper']['total_blocks']:,})")
        assert best.total_blocks == \
            pt.TABLE_VIII[dom_name]["paper"]["total_blocks"]

        # measured (CPU interpret): mapped map-eval vs BB membership+filter,
        # geometry resolved from the registry entry
        ext = dom.bounding_box_extent(measure_n)
        _, us_map = timed(map_coordinates, entry, measure_n,
                          interpret=True, repeats=2)
        _, us_bb = timed(bb_membership, entry, ext, interpret=True,
                         repeats=2)
        work_ratio = int(np.prod(ext)) / measure_n
        print(f"measured interpret-mode @N={measure_n:,}: mapped "
              f"{us_map / 1e3:.1f}ms vs BB {us_bb / 1e3:.1f}ms "
              f"(BB touches {work_ratio:.2f}x the points)")
        emit(f"table_VIII_{dom_name}", us_map,
             f"speedup={speedup:.0f}x;energy_red={ered:.0f}x;"
             f"valid_blocks={best.total_blocks}")
        out[dom_name] = {"speedup": speedup, "energy_reduction": ered}
    return out


if __name__ == "__main__":
    run()
