"""Framework-integration benchmark: mapped vs bounding-box attention grids.

This is the paper's technique deployed inside the LM framework (causal
attention = 2D triangular block domain).  Reports:
  * sequential grid-step accounting at the production shapes,
  * measured interpret-mode kernel times at a reduced shape,
  * TPU-v5e roofline projection of the per-core step cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header, timed
from repro.core.energy import TPU_PEAK_FLOPS
from repro.kernels.tri_attn.ops import causal_attention, grid_steps
from repro.kernels.tri_attn.ref import causal_attention_ref


def run() -> dict:
    header("Attention grid mapping: mapped λ-grid vs bounding box")
    print(f"{'seq':>8s}{'block':>7s}{'bb steps':>10s}{'mapped':>10s}"
          f"{'saved':>8s}{'tpu bb ms':>11s}{'tpu map ms':>11s}")
    out = {}
    for seq, blk in ((4096, 128), (4096, 256), (32768, 128), (32768, 256),
                     (32768, 512)):
        bb = grid_steps(seq, blk, "bounding_box")
        mp = grid_steps(seq, blk, "mapped")
        # per-step cost on v5e: 2 matmuls of (blk x d) @ (d x blk), d=128
        step_flops = 2 * 2 * blk * blk * 128
        t_bb = bb * step_flops / TPU_PEAK_FLOPS * 1e3
        t_mp = mp * step_flops / TPU_PEAK_FLOPS * 1e3
        print(f"{seq:>8d}{blk:>7d}{bb:>10,}{mp:>10,}"
              f"{1 - mp / bb:>8.1%}{t_bb:>11.4f}{t_mp:>11.4f}")
        out[(seq, blk)] = 1 - mp / bb
    emit("attn_grid_steps", 0.0,
         f"saved_32k_b128={out[(32768, 128)]:.3f}")

    # measured (interpret mode, CPU) at a reduced shape
    b, h, s, d, blk = 1, 2, 512, 64, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32) for kk in ks)
    ref = causal_attention_ref(q, k, v)

    def run_mode(mode):
        return causal_attention(q, k, v, blk, blk, mode, True)

    out_m, us_m = timed(jax.block_until_ready, run_mode("mapped"), repeats=1)
    _, us_m = timed(lambda: jax.block_until_ready(run_mode("mapped")),
                    repeats=3)
    _, us_b = timed(lambda: jax.block_until_ready(run_mode("bounding_box")),
                    repeats=3)
    err = float(jnp.max(jnp.abs(run_mode("mapped") - ref)))
    print(f"\ninterpret-mode @(b{b} h{h} s{s} d{d} blk{blk}): "
          f"mapped {us_m / 1e3:.1f}ms vs bb {us_b / 1e3:.1f}ms, "
          f"max err vs oracle {err:.2e}")
    emit("attn_kernel_interpret", us_m, f"bb_us={us_b:.0f};err={err:.1e}")
    return {"step_savings": out, "err": err}


if __name__ == "__main__":
    run()
