"""Tables II–VII: symbolic-inference accuracy, published vs live-measured.

For every (domain, model, stage) cell the replay backend emits the code class
the paper observed; we run the full pipeline (prompt -> generate ->
synthesize -> validate over N points) and print live Ordered/Any-order next
to the published numbers.  Perfect and (NC) cells must match the paper
exactly; partial cells replay a canonical failure mode (live numbers shown
for transparency — the paper's garbage outputs are not bit-reproducible).
"""
from __future__ import annotations

import time

from benchmarks.common import emit, header
from repro.core import paper_tables as pt
from repro.core.domains import DOMAINS
from repro.core.pipeline import run_grid

TABLE_OF = {
    "tri2d": "II", "gasket2d": "III", "carpet2d": "IV",
    "pyramid3d": "V", "sierpinski3d": "VI", "menger3d": "VII",
}


def run(n_validate: int = 100_000, sample_every: int = 50) -> dict:
    mismatches = 0
    checked = 0
    for dom_name in ("tri2d", "gasket2d", "carpet2d", "pyramid3d",
                     "sierpinski3d", "menger3d"):
        dom = DOMAINS[dom_name]
        header(f"Table {TABLE_OF[dom_name]}: {dom.paper_name} "
               f"(live validation over {n_validate:,} pts)")
        print(f"{'model':14s}{'stage':>6s} {'pub ord':>9s}{'pub any':>9s}"
              f"{'live ord':>10s}{'live any':>10s}  status")
        t0 = time.perf_counter()
        grid = run_grid(domains=[dom_name], models=pt.MODELS,
                        stages=pt.STAGES, n_validate=n_validate,
                        sample_every=sample_every)
        for model in pt.MODELS:
            for si, stage in enumerate(pt.STAGES):
                pub_o, pub_a, pub_ok = pt.ACCURACY[dom_name][model][si]
                res = grid[(dom_name, model, stage)]
                live_o = res.report.ordered_pct
                live_a = res.report.any_order_pct
                checked += 1
                if pub_ok and pub_o >= 100:
                    ok = res.perfect
                elif not pub_ok:
                    ok = not res.compiled
                else:
                    ok = live_o < 100.0  # partial cells must not be perfect
                if not ok:
                    mismatches += 1
                flag = "" if ok else "  <-- MISMATCH"
                nc = "" if res.compiled else " (NC)"
                print(f"{model:14s}{stage:>6d} {pub_o:>8.2f}%{pub_a:>8.2f}%"
                      f"{live_o:>9.2f}%{live_a:>9.2f}%{nc}{flag}")
        dt_us = (time.perf_counter() - t0) * 1e6 / (len(pt.MODELS) * 3)
        emit(f"accuracy_table_{TABLE_OF[dom_name]}", dt_us,
             f"cells={len(pt.MODELS) * 3};mismatches={mismatches}")
    print(f"\n[accuracy] {checked} cells checked, {mismatches} class "
          f"mismatches vs published tables")
    return {"checked": checked, "mismatches": mismatches}


if __name__ == "__main__":
    run()
