"""Trace-driven load generator + SLO harness for a live mapping fleet.

Synthesizes a request trace with the skew real caches live under — cell
popularity follows a zipf(s) law, so a handful of hot cells dominate while
a long tail stays cold — mixes in the other serving ops (evaluate, grid
sweeps, artifact fetches), optionally shapes arrivals into bursts, then
replays the trace against one or more server URLs from a closed-loop
worker pool.  Every request yields a latency record; the run folds them
into an SLO report (p50/p95/p99, shed rate, error rate, per-op breakdown)
that the CLI can *enforce*: a violated ``--slo-p99-ms`` / ``--max-shed-rate``
/ ``--max-error-rate`` bound exits non-zero, which is what makes the CI
loadgen leg a regression gate rather than a dashboard.  ``--ramp`` steps
the offered rate up until admission control sheds and reports the
goodput-vs-offered-load knee, bounding the p99 of *accepted* requests
(overload must shed, not stretch the latency of what it accepts).

Programmatic:

    from benchmarks.loadgen import LoadSpec, run
    records, report = run(["http://127.0.0.1:8000"], LoadSpec(requests=500))

CLI (against a running fleet, or self-hosting one with ``--nodes``):

    PYTHONPATH=src:. python -m benchmarks.loadgen --url http://host:8000 \
        --requests 500 --concurrency 8 --slo-p99-ms 250 --json slo.json
    PYTHONPATH=src:. python -m benchmarks.loadgen --nodes 2 --requests 400 \
        --slo-p99-ms 500 --max-shed-rate 0 --json slo.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import queue
import random
import sys
import threading
import time

MODEL = "OSS:120b"

#: default domains for synthesized cells (kept small so warmup is cheap)
DEFAULT_DOMAINS = ("tri2d", "gasket2d", "carpet2d", "pyramid3d")


@dataclasses.dataclass
class LoadSpec:
    """One load run's shape.

    ``mix`` weights are normalized; ops other than ``derive`` degrade to a
    derive when their preconditions are missing (no warmed artifact key
    yet).  ``rate`` paces arrivals open-loop (requests/second across all
    workers, ``None`` = closed loop: every worker fires as fast as replies
    come back).  ``burst_every``/``burst_size`` inject zero-gap bursts into
    a paced schedule — the shape that exposes admission-control sheds."""

    requests: int = 200
    concurrency: int = 8
    zipf_s: float = 1.1          # popularity skew (higher = hotter head)
    cells: int = 12              # distinct (domain, model, stage) cells
    domains: tuple = DEFAULT_DOMAINS
    model: str = MODEL
    stages: tuple = (100, 50)    # must be stages the mock bank carries
    mix: dict = dataclasses.field(default_factory=lambda: {
        "derive": 0.85, "evaluate": 0.05, "grid": 0.02, "artifact": 0.08})
    rate: float | None = None    # req/s arrival pacing (None = closed loop)
    burst_every: float = 0.0     # seconds between bursts (0 = no bursts)
    burst_size: int = 0          # extra zero-gap requests per burst
    eval_points: int = 4096      # n_points per evaluate op
    trace_sample: float = 0.0    # fraction of derives sent with a trace ID
    warmup: bool = True          # derive each cell once before measuring
    timeout: float = 30.0
    seed: int = 0


def zipf_weights(n: int, s: float) -> list[float]:
    """Normalized zipf(s) popularity over ranks 1..n."""
    raw = [1.0 / (rank ** s) for rank in range(1, n + 1)]
    total = sum(raw)
    return [w / total for w in raw]


def synth_cells(spec: LoadSpec) -> list[tuple[str, str, int]]:
    """The distinct cells the trace draws from, hottest first."""
    out = []
    for i in range(spec.cells):
        domain = spec.domains[i % len(spec.domains)]
        stage = spec.stages[(i // len(spec.domains)) % len(spec.stages)]
        out.append((domain, spec.model, stage))
    return out


def synth_trace(spec: LoadSpec) -> list[dict]:
    """The replayable trace: one op dict per request, zipf-skewed cells,
    mixed op types, deterministic under ``spec.seed``."""
    rng = random.Random(spec.seed)
    cells = synth_cells(spec)
    weights = zipf_weights(len(cells), spec.zipf_s)
    ops = list(spec.mix)
    op_weights = [max(0.0, spec.mix[o]) for o in ops]
    trace = []
    for i in range(spec.requests):
        cell = rng.choices(cells, weights=weights)[0]
        op = rng.choices(ops, weights=op_weights)[0]
        rec: dict = {"op": op, "cell": cell}
        if op == "derive" and spec.trace_sample > 0 \
                and rng.random() < spec.trace_sample:
            rec["trace_id"] = "%032x" % rng.getrandbits(128)
        trace.append(rec)
    return trace


def arrival_offsets(spec: LoadSpec) -> list[float] | None:
    """Per-request start offsets (seconds from t0) for a paced run, with
    optional zero-gap bursts; None for a closed-loop run."""
    if spec.rate is None:
        return None
    offsets, t, since_burst = [], 0.0, 0.0
    gap = 1.0 / spec.rate
    i = 0
    while i < spec.requests:
        if spec.burst_every > 0 and spec.burst_size > 0 \
                and since_burst >= spec.burst_every:
            since_burst = 0.0
            for _ in range(min(spec.burst_size, spec.requests - i)):
                offsets.append(t)
                i += 1
            continue
        offsets.append(t)
        i += 1
        t += gap
        since_burst += gap
    return offsets


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[idx]


def _execute(client, op: dict, spec: LoadSpec, keys: dict) -> dict:
    """Run one trace op; returns its latency record."""
    from repro.serving.client import (
        RemoteBusyError, RemoteServiceError, RemoteTimeoutError,
    )

    name, (domain, model, stage) = op["op"], op["cell"]
    t0 = time.perf_counter()
    rec = {"op": name, "cell": f"{domain}/{model}/{stage}", "ok": True,
           "shed": False}
    try:
        if name == "artifact" and op["cell"] in keys:
            client.fetch_artifact(keys[op["cell"]])
        elif name == "evaluate":
            client.evaluate(domain=domain, n_points=spec.eval_points)
        elif name == "grid":
            for _ in client.run_grid(domains=[domain], models=[model],
                                     stages=[stage]):
                pass
        else:  # derive — also the degraded form of a keyless artifact op
            res = client.derive(domain, model, stage,
                                trace_id=op.get("trace_id"))
            if res.cache_key:
                keys[op["cell"]] = res.cache_key
            if op.get("trace_id"):
                rec["trace_id"] = op["trace_id"]
    except (RemoteBusyError, RemoteTimeoutError) as e:
        rec.update(ok=False, shed=True, error=type(e).__name__)
    except RemoteServiceError as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}")
    except Exception as e:  # noqa: BLE001 — a load run records, never dies
        rec.update(ok=False, error=f"{type(e).__name__}: {e}")
    rec["seconds"] = time.perf_counter() - t0
    return rec


def replay(urls: list[str], spec: LoadSpec,
           trace: list[dict] | None = None) -> list[dict]:
    """Replay a trace against the fleet from ``spec.concurrency`` worker
    threads (requests round-robin across ``urls``); returns one latency
    record per request."""
    from repro.serving.client import RemoteMappingService

    trace = trace if trace is not None else synth_trace(spec)
    # retries=0: a shed must surface as a shed, not hide inside backoff
    clients = [RemoteMappingService(u, timeout=spec.timeout, retries=0)
               for u in urls]
    keys: dict = {}
    if spec.warmup:
        for i, cell in enumerate(synth_cells(spec)):
            res = clients[i % len(clients)].derive(*cell)
            if res.cache_key:
                keys[cell] = res.cache_key
    offsets = arrival_offsets(spec)
    work: "queue.Queue[tuple[int, dict]]" = queue.Queue()
    for item in enumerate(trace):
        work.put(item)
    records: list[dict | None] = [None] * len(trace)
    t_start = time.perf_counter()

    def worker(wid: int) -> None:
        client = clients[wid % len(clients)]
        while True:
            try:
                i, op = work.get_nowait()
            except queue.Empty:
                return
            if offsets is not None:
                delay = offsets[i] - (time.perf_counter() - t_start)
                if delay > 0:
                    time.sleep(delay)
            rec = _execute(client, op, spec, keys)
            rec["node"] = client.url
            records[i] = rec

    threads = [threading.Thread(target=worker, args=(w,), daemon=True)
               for w in range(spec.concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t_start
    done = [r for r in records if r is not None]
    for r in done:
        r["wall_seconds"] = wall
    for client in clients:
        client.close()
    return done


def slo_report(records: list[dict], spec: LoadSpec) -> dict:
    """Fold latency records into the SLO summary the CLI enforces."""
    lat = sorted(r["seconds"] for r in records)
    sheds = sum(1 for r in records if r.get("shed"))
    errors = sum(1 for r in records if not r["ok"] and not r.get("shed"))
    n = max(1, len(records))
    wall = records[0]["wall_seconds"] if records else 0.0
    per_op: dict = {}
    for r in records:
        bucket = per_op.setdefault(
            r["op"], {"requests": 0, "errors": 0, "sheds": 0, "lat": []})
        bucket["requests"] += 1
        bucket["lat"].append(r["seconds"])
        if r.get("shed"):
            bucket["sheds"] += 1
        elif not r["ok"]:
            bucket["errors"] += 1
    for bucket in per_op.values():
        vals = sorted(bucket.pop("lat"))
        bucket["p50_ms"] = _percentile(vals, 0.50) * 1e3
        bucket["p95_ms"] = _percentile(vals, 0.95) * 1e3
    return {
        "requests": len(records),
        "concurrency": spec.concurrency,
        "zipf_s": spec.zipf_s,
        "cells": spec.cells,
        "wall_seconds": wall,
        "throughput_rps": len(records) / wall if wall > 0 else 0.0,
        "p50_ms": _percentile(lat, 0.50) * 1e3,
        "p95_ms": _percentile(lat, 0.95) * 1e3,
        "p99_ms": _percentile(lat, 0.99) * 1e3,
        "max_ms": (lat[-1] * 1e3) if lat else 0.0,
        "sheds": sheds,
        "shed_rate": sheds / n,
        "errors": errors,
        "error_rate": errors / n,
        "per_op": per_op,
    }


def run(urls: list[str], spec: LoadSpec | None = None,
        ) -> tuple[list[dict], dict]:
    """Synthesize + replay + summarize in one call (the programmatic and
    benchmark-suite entry point)."""
    spec = spec or LoadSpec()
    records = replay(urls, spec)
    return records, slo_report(records, spec)


def ramp(urls: list[str], spec: LoadSpec | None = None,
         start_rate: float = 50.0, step_factor: float = 2.0,
         max_steps: int = 6, runner=None) -> dict:
    """Offered-load ramp: replay the trace at geometrically increasing
    paced rates until admission control starts shedding (or ``max_steps``
    runs out), then report the goodput-vs-offered-load knee — the highest
    offered rate the fleet absorbed shed-free — alongside the p99 of
    *accepted* requests at every step.  The point of the assertion pair:
    past saturation a healthy fleet sheds early (503) instead of
    stretching the latency of the requests it does accept, so
    ``accepted_p99_ms`` must stay bounded even on the shedding step.

    ``runner(urls, spec) -> (records, report)`` is injectable for tests;
    it defaults to :func:`run`."""
    spec = spec or LoadSpec()
    runner = runner or run
    steps: list[dict] = []
    rate = float(start_rate)
    for _ in range(max(1, max_steps)):
        step_spec = dataclasses.replace(
            spec, rate=rate, warmup=spec.warmup and not steps)
        records, report = runner(urls, step_spec)
        accepted = sorted(r["seconds"] for r in records
                          if r["ok"] and not r.get("shed"))
        wall = report["wall_seconds"] or 1e-9
        steps.append({
            "offered_rps": rate,
            "achieved_rps": report["throughput_rps"],
            "goodput_rps": len(accepted) / wall,
            "accepted": len(accepted),
            "sheds": report["sheds"],
            "shed_rate": report["shed_rate"],
            "errors": report["errors"],
            "accepted_p99_ms": _percentile(accepted, 0.99) * 1e3,
        })
        if report["sheds"] > 0:
            break  # found the shed onset: the previous step is the knee
        rate *= step_factor
    absorbed = [s for s in steps if s["sheds"] == 0]
    knee = absorbed[-1] if absorbed else None
    return {
        "mode": "ramp",
        "steps": steps,
        "saturated": steps[-1]["sheds"] > 0,
        "knee_offered_rps": knee["offered_rps"] if knee else 0.0,
        "knee_goodput_rps": knee["goodput_rps"] if knee else 0.0,
        "accepted_p99_ms": max(s["accepted_p99_ms"] for s in steps),
    }


def check_slo(report: dict, slo_p99_ms: float | None,
              max_shed_rate: float | None,
              max_error_rate: float | None) -> list[str]:
    """The violated bounds, as human-readable strings (empty = SLO met)."""
    out = []
    if slo_p99_ms is not None and report["p99_ms"] > slo_p99_ms:
        out.append(f"p99 {report['p99_ms']:.1f}ms > SLO {slo_p99_ms:.1f}ms")
    if max_shed_rate is not None and report["shed_rate"] > max_shed_rate:
        out.append(f"shed rate {report['shed_rate']:.3f} > "
                   f"{max_shed_rate:.3f} ({report['sheds']} sheds)")
    if max_error_rate is not None and report["error_rate"] > max_error_rate:
        out.append(f"error rate {report['error_rate']:.3f} > "
                   f"{max_error_rate:.3f} ({report['errors']} errors)")
    return out


def _self_fleet(nodes: int):
    """Boot an in-process fleet (async frontend, mock backend, private
    store tree) for self-contained runs — the CI leg's fleet."""
    import tempfile

    from repro.core.store import build_store
    from repro.serving import AsyncMappingHTTPServer, MappingService
    from repro.serving.cluster import ClusterMembership

    tmp = tempfile.TemporaryDirectory(prefix="loadgen-fleet-")
    servers = []
    seeds: list[str] = []
    for i in range(nodes):
        store = build_store(root=f"{tmp.name}/node{i}")
        server = AsyncMappingHTTPServer(MappingService(store=store))
        server.start()
        if nodes > 1:
            server.attach_cluster(ClusterMembership(
                self_url=server.url, seeds=seeds or [server.url],
                heartbeat_interval=0.2, sync_interval=0.5))
        seeds.append(server.url)
        servers.append(server)
    if nodes > 1:
        deadline = time.time() + 10.0
        while time.time() < deadline:
            if all(len(s.cluster.live_peers()) == nodes - 1
                   for s in servers):
                break
            time.sleep(0.05)

    def close() -> None:
        for server in servers:
            server.close()
        tmp.cleanup()

    return [s.url for s in servers], close


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    p.add_argument("--url", action="append", default=None,
                   help="fleet node URL (repeatable; round-robin)")
    p.add_argument("--nodes", type=int, default=0,
                   help="boot an in-process N-node fleet instead of --url "
                        "(async frontend, mock backend)")
    p.add_argument("--requests", type=int, default=200)
    p.add_argument("--concurrency", type=int, default=8)
    p.add_argument("--zipf-s", type=float, default=1.1)
    p.add_argument("--cells", type=int, default=12)
    p.add_argument("--rate", type=float, default=None,
                   help="paced arrival rate in req/s (default: closed loop; "
                        "with --ramp: the starting offered rate)")
    p.add_argument("--ramp", action="store_true",
                   help="step the offered rate up (x --ramp-step each run) "
                        "until admission control sheds, then report the "
                        "goodput-vs-offered-load knee; --slo-p99-ms bounds "
                        "the p99 of ACCEPTED requests across every step")
    p.add_argument("--ramp-step", type=float, default=2.0,
                   help="multiplicative rate step between ramp runs")
    p.add_argument("--ramp-max-steps", type=int, default=6,
                   help="give up ramping after this many runs without a shed")
    p.add_argument("--burst-every", type=float, default=0.0)
    p.add_argument("--burst-size", type=int, default=0)
    p.add_argument("--trace-sample", type=float, default=0.0,
                   help="fraction of derives sent with an explicit "
                        "X-Repro-Trace-Id")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--no-warmup", dest="warmup", action="store_false")
    p.add_argument("--slo-p99-ms", type=float, default=None)
    p.add_argument("--max-shed-rate", type=float, default=None)
    p.add_argument("--max-error-rate", type=float, default=None)
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write the SLO report (+ per-request records) here")
    args = p.parse_args()

    if bool(args.url) == bool(args.nodes):
        p.error("exactly one of --url or --nodes is required")
    close = None
    if args.nodes:
        urls, close = _self_fleet(args.nodes)
    else:
        urls = args.url
    spec = LoadSpec(requests=args.requests, concurrency=args.concurrency,
                    zipf_s=args.zipf_s, cells=args.cells, rate=args.rate,
                    burst_every=args.burst_every, burst_size=args.burst_size,
                    trace_sample=args.trace_sample, seed=args.seed,
                    warmup=args.warmup)
    try:
        if args.ramp:
            records = []
            report = ramp(urls, spec, start_rate=args.rate or 50.0,
                          step_factor=args.ramp_step,
                          max_steps=args.ramp_max_steps)
        else:
            records, report = run(urls, spec)
    finally:
        if close is not None:
            close()
    report["urls"] = urls
    if args.ramp:
        print(json.dumps({k: v for k, v in report.items() if k != "steps"},
                         indent=1))
        for step in report["steps"]:
            print(f"  offered={step['offered_rps']:.1f}rps "
                  f"goodput={step['goodput_rps']:.1f}rps "
                  f"sheds={step['sheds']} "
                  f"accepted_p99={step['accepted_p99_ms']:.1f}ms")
        violations = []
        if args.slo_p99_ms is not None \
                and report["accepted_p99_ms"] > args.slo_p99_ms:
            violations.append(
                f"accepted p99 {report['accepted_p99_ms']:.1f}ms > "
                f"SLO {args.slo_p99_ms:.1f}ms")
        if not report["saturated"]:
            print("[loadgen] ramp never shed — raise --ramp-max-steps or "
                  "the starting --rate to find the knee")
    else:
        print(json.dumps({k: v for k, v in report.items() if k != "per_op"},
                         indent=1))
        for op, stats in sorted(report["per_op"].items()):
            print(f"  {op}: {stats}")
        violations = check_slo(report, args.slo_p99_ms, args.max_shed_rate,
                               args.max_error_rate)
    report["slo_violations"] = violations
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"report": report, "records": records}, f, indent=1,
                      default=str)
        print(f"[loadgen] wrote {args.json}")
    if violations:
        print(f"[loadgen] SLO VIOLATED: {violations}")
        sys.exit(1)
    print("[loadgen] SLO met")


if __name__ == "__main__":
    main()
