"""Ground-truth mapping functions lambda -> coordinates (Table I).

Three tiers per domain:
  * scalar  — exact python-int reference (`map_*`), the "Paper" gold standard,
  * numpy   — vectorized exact evaluation for 10^6-point validation,
  * jnp     — traceable versions usable inside jitted code / Pallas kernels.

Also the *variant logic classes* observed in the paper's Tables VIII/IX
(Sqrt+Loop, BinSearch O(log N), Linear O(N^{1/3}), Approx+If): functionally
correct alternatives with different cost profiles — these are what several
LLMs emitted instead of the closed form, and the deployment benchmarks need
them to reproduce the performance stratification.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core import inverse as inv
from repro.core.domains import DOMAINS, Domain, get_domain

# ---------------------------------------------------------------------------
# Dense domains — scalar (exact)
# ---------------------------------------------------------------------------


def map_tri2d(lam: int) -> tuple[int, int]:
    """x = floor(sqrt(1/4 + 2*lam) - 1/2), y = lam - x(x+1)/2  (Table I)."""
    x = inv.tri_row(lam)
    return x, lam - inv.tri(x)


def unmap_tri2d(x: int, y: int) -> int:
    return inv.tri(x) + y


def map_pyramid3d(lam: int) -> tuple[int, int, int]:
    """z from tetrahedral-number inversion, then the 2D map on the residual."""
    z = inv.tet_layer(lam)
    x, y = map_tri2d(lam - inv.tet(z))
    return x, y, z


def unmap_pyramid3d(x: int, y: int, z: int) -> int:
    return inv.tet(z) + unmap_tri2d(x, y)


# -- variant logic classes (Tables VIII/IX "Logic" column) -------------------


def map_tri2d_sqrt_loop(lam: int) -> tuple[int, int]:
    """R1:70b (Stage 100): float sqrt seed then while-loop correction."""
    x = int((2.0 * lam) ** 0.5)
    while (x + 1) * (x + 2) // 2 <= lam:
        x += 1
    while x * (x + 1) // 2 > lam:
        x -= 1
    return x, lam - x * (x + 1) // 2


def map_tri2d_binsearch(lam: int) -> tuple[int, int]:
    """Qw3:32b (Stage 50): O(log N) binary search over rows."""
    lo, hi = 0, 1
    while hi * (hi + 1) // 2 <= lam:
        hi *= 2
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if mid * (mid + 1) // 2 <= lam:
            lo = mid
        else:
            hi = mid - 1
    return lo, lam - lo * (lo + 1) // 2


def map_tri2d_approx_if(lam: int) -> tuple[int, int]:
    """OSS:20b: float closed form + a single boundary fix-up `if`."""
    x = int(((8.0 * lam + 1.0) ** 0.5 - 1.0) / 2.0)
    if (x + 1) * (x + 2) // 2 <= lam:
        x += 1
    if x * (x + 1) // 2 > lam:
        x -= 1
    return x, lam - x * (x + 1) // 2


def map_pyramid3d_cbrt_loop(lam: int) -> tuple[int, int, int]:
    """R1:70b / Qw3:32b: cbrt seed + short correction loop (still O(1))."""
    z = int(round((6.0 * lam) ** (1.0 / 3.0)))
    while (z + 1) * (z + 2) * (z + 3) // 6 <= lam:
        z += 1
    while z > 0 and z * (z + 1) * (z + 2) // 6 > lam:
        z -= 1
    x, y = map_tri2d(lam - z * (z + 1) * (z + 2) // 6)
    return x, y, z


def map_pyramid3d_binsearch(lam: int) -> tuple[int, int, int]:
    """OSS:120b (Stage 100) / Qw3:235b: O(log N) binary search over layers."""
    lo, hi = 0, 1
    while hi * (hi + 1) * (hi + 2) // 6 <= lam:
        hi *= 2
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if mid * (mid + 1) * (mid + 2) // 6 <= lam:
            lo = mid
        else:
            hi = mid - 1
    x, y = map_tri2d(lam - lo * (lo + 1) * (lo + 2) // 6)
    return x, y, lo


def map_pyramid3d_linear(lam: int) -> tuple[int, int, int]:
    """OSS:120b (Stage 20): O(N^{1/3}) linear scan over candidate layers."""
    z = 0
    while (z + 1) * (z + 2) * (z + 3) // 6 <= lam:
        z += 1
    x, y = map_tri2d(lam - z * (z + 1) * (z + 2) // 6)
    return x, y, z


# ---------------------------------------------------------------------------
# Fractal domains — scalar (exact): base-B digit decomposition
# ---------------------------------------------------------------------------


def map_fractal(domain: Domain, lam: int) -> tuple[int, ...]:
    """c = sum_i vec(d_i) * scale^i  where  lam = sum_i d_i * B^i."""
    c = [0] * domain.dim
    s = 1
    while lam > 0:
        d = lam % domain.base
        v = domain.vecs[d]
        for k in range(domain.dim):
            c[k] += v[k] * s
        lam //= domain.base
        s *= domain.scale
    return tuple(c)


def unmap_fractal(domain: Domain, c: tuple[int, ...]) -> int:
    """Inverse: coordinates -> lambda (digit recovery per level)."""
    c = list(c)
    lam = 0
    bpow = 1
    vec_to_digit = {tuple(v): d for d, v in enumerate(domain.vecs)}
    while any(c):
        key = tuple(x % domain.scale for x in c)
        lam += vec_to_digit[key] * bpow
        c = [x // domain.scale for x in c]
        bpow *= domain.base
    return lam


def map_gasket2d(lam: int):
    return map_fractal(DOMAINS["gasket2d"], lam)


def map_carpet2d(lam: int):
    return map_fractal(DOMAINS["carpet2d"], lam)


def map_sierpinski3d(lam: int):
    return map_fractal(DOMAINS["sierpinski3d"], lam)


def map_menger3d(lam: int):
    return map_fractal(DOMAINS["menger3d"], lam)


# ---------------------------------------------------------------------------
# numpy vectorized (exact int64) — validation at N = 10^6
# ---------------------------------------------------------------------------


def np_map_tri2d(lams: np.ndarray) -> np.ndarray:
    lams = np.asarray(lams, dtype=np.int64)
    x = inv.np_tri_row(lams)
    y = lams - x * (x + 1) // 2
    return np.stack([x, y], axis=-1)


def np_map_pyramid3d(lams: np.ndarray) -> np.ndarray:
    lams = np.asarray(lams, dtype=np.int64)
    z = inv.np_tet_layer(lams)
    rem = lams - z * (z + 1) * (z + 2) // 6
    xy = np_map_tri2d(rem)
    return np.concatenate([xy, z[:, None]], axis=-1)


def np_map_fractal(domain: Domain, lams: np.ndarray) -> np.ndarray:
    lams = np.asarray(lams, dtype=np.int64)
    ndig = max(domain.level_for_points(int(lams.max()) + 1), 1) if lams.size else 1
    vecs = np.asarray(domain.vecs, dtype=np.int64)  # (B, dim)
    out = np.zeros((len(lams), domain.dim), dtype=np.int64)
    rem = lams.copy()
    s = 1
    for _ in range(ndig):
        d = rem % domain.base
        out += vecs[d] * s
        rem //= domain.base
        s *= domain.scale
    return out


def np_map(domain_name: str, lams: np.ndarray) -> np.ndarray:
    d = get_domain(domain_name)
    if d.name == "tri2d":
        return np_map_tri2d(lams)
    if d.name == "pyramid3d":
        return np_map_pyramid3d(lams)
    return np_map_fractal(d, lams)


# ---------------------------------------------------------------------------
# jnp vectorized (traceable) — kernel / on-device use
# ---------------------------------------------------------------------------


def jnp_map_tri2d(lams: jnp.ndarray) -> jnp.ndarray:
    x = inv.jnp_tri_row(lams)
    y = lams - x * (x + 1) // 2
    return jnp.stack([x, y], axis=-1)


def jnp_map_pyramid3d(lams: jnp.ndarray) -> jnp.ndarray:
    z = inv.jnp_tet_layer(lams)
    rem = lams - z * (z + 1) * (z + 2) // 6
    xy = jnp_map_tri2d(rem)
    return jnp.concatenate([xy, z[:, None]], axis=-1)


def jnp_map_fractal(domain: Domain, lams: jnp.ndarray, ndigits: int) -> jnp.ndarray:
    """Fixed digit count (static) so the loop unrolls inside kernels."""
    vecs = jnp.asarray(np.asarray(domain.vecs), dtype=lams.dtype)  # (B, dim)
    out = jnp.zeros(lams.shape + (domain.dim,), dtype=lams.dtype)
    rem = lams
    s = 1
    for _ in range(ndigits):
        d = rem % domain.base
        out = out + vecs[d] * s
        rem = rem // domain.base
        s *= domain.scale
    return out


def jnp_map(domain_name: str, lams: jnp.ndarray, ndigits: int = 13) -> jnp.ndarray:
    d = get_domain(domain_name)
    if d.name == "tri2d":
        return jnp_map_tri2d(lams)
    if d.name == "pyramid3d":
        return jnp_map_pyramid3d(lams)
    return jnp_map_fractal(d, lams, ndigits)


# ---------------------------------------------------------------------------
# Registry of scalar maps (ground truth + variants), used by backends/benches
# ---------------------------------------------------------------------------

SCALAR_MAPS: dict[str, Callable] = {
    "tri2d": map_tri2d,
    "pyramid3d": map_pyramid3d,
    "gasket2d": map_gasket2d,
    "carpet2d": map_carpet2d,
    "sierpinski3d": map_sierpinski3d,
    "menger3d": map_menger3d,
}

# (domain, logic-class) -> scalar callable; "analytical" is the paper map.
VARIANT_MAPS: dict[tuple[str, str], Callable] = {
    ("tri2d", "analytical"): map_tri2d,
    ("tri2d", "sqrt_loop"): map_tri2d_sqrt_loop,
    ("tri2d", "binsearch"): map_tri2d_binsearch,
    ("tri2d", "approx_if"): map_tri2d_approx_if,
    ("pyramid3d", "analytical"): map_pyramid3d,
    ("pyramid3d", "cbrt_loop"): map_pyramid3d_cbrt_loop,
    ("pyramid3d", "binsearch"): map_pyramid3d_binsearch,
    ("pyramid3d", "linear"): map_pyramid3d_linear,
    ("gasket2d", "bitwise"): map_gasket2d,
    ("carpet2d", "bitwise"): map_carpet2d,
    ("sierpinski3d", "bitwise"): map_sierpinski3d,
    ("menger3d", "bitwise"): map_menger3d,
}
