"""MappingArtifact — the persistent, registered product of a derivation.

The paper's economic claim is that LLM derivation is a one-time upfront
investment amortized across every subsequent launch.  This module makes that
literal: a successful (domain, model, stage) derivation becomes a
``MappingArtifact`` — validated source, accuracy report digest, complexity
class, inference-energy metadata, and a scalar callable rebuilt on demand
from the validated source.  The pipeline persists each cell (successes and
NC failures alike) as a JSON derivation record in the content-addressed
on-disk cache below, so repeated pipeline calls skip inference *and* the
10^6-point validation entirely; ``MappingArtifact.to_record``/``from_record``
additionally serialize a standalone artifact for export (e.g. serving a
shared artifact store).

Cache layout:    <root>/<key>.json            (schema-versioned records)
Cache root:      $REPRO_ARTIFACT_CACHE, else ~/.cache/repro_thread_maps
Concurrency:     records publish via atomic rename (readers are lock-free);
                 writers serialize per key through <root>/<key>.lock
                 (:class:`FileLock`, with stale-lock recovery) — see
                 ``serving/map_service.py`` for the many-clients front end
Key:             sha256 over {domain, model, stage, sha256(prompt),
                 n_validate, sample_every} — any change to the prompt
                 template, sampling stage or validation spec changes the key,
                 which is the cache's only invalidation rule (plus the schema
                 version stored in each record).
Opt out:         REPRO_ARTIFACT_CACHE=off  (or "0" / "none")
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
import time
from pathlib import Path
from typing import Any, Callable

from repro.core import synthesis, validate
from repro.core.domains import Domain, get_domain
from repro.core.registry import REGISTRY, MapRegistry

SCHEMA_VERSION = 1

#: complexity class -> calibrated logic-class table key (Sec. V.C costs).
_DENSE_LOGIC = {
    "O(1)": "analytical",
    "O(log N)": "binsearch",
    "O(N^1/3)": "linear",
    "O(N^1/2)": "linear",
    "O(N)": "linear",
}
_FRACTAL_LOGIC = {
    "O(1)": "bitwise",
    "O(log N)": "bitwise",
    "O(N^1/3)": "linear",
    "O(N^1/2)": "linear",
    "O(N)": "linear",
}


def logic_for(complexity_class: str | None, domain: Domain) -> str:
    """Map a measured complexity class onto the calibrated logic table."""
    table = _DENSE_LOGIC if domain.kind == "dense" else _FRACTAL_LOGIC
    default = "analytical" if domain.kind == "dense" else "bitwise"
    return table.get(complexity_class or "", default)


# ---------------------------------------------------------------------------
# MappingArtifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MappingArtifact:
    """A validated thread map plus everything deployment needs to trust it."""

    domain: str
    model: str
    stage: int
    source: str                       # validated map_to_coordinates source
    complexity_class: str | None
    report: validate.ValidationReport
    inference_joules: float
    inference_seconds: float          # derivation wall time (one-time cost)
    cache_key: str | None = None
    created_unix: float = dataclasses.field(default_factory=time.time)
    _scalar: Callable | None = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- identity ----------------------------------------------------------
    @property
    def domainobj(self) -> Domain:
        return get_domain(self.domain)

    @property
    def logic(self) -> str:
        return logic_for(self.complexity_class, self.domainobj)

    @property
    def report_digest(self) -> str:
        payload = json.dumps(dataclasses.asdict(self.report), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    @property
    def deployable(self) -> bool:
        """Only a 100%-ordered map may drive the mapped-grid kernel."""
        return self.report.error is None and self.report.ordered >= 1.0

    # -- tiers -------------------------------------------------------------
    def scalar_fn(self) -> Callable:
        """Exact scalar callable, rebuilt from the validated source on first
        use (compile + probe only — the cached report vouches for accuracy)."""
        if self._scalar is None:
            self._scalar = synthesis.synthesize(self.source).fn
        return self._scalar

    def registered_entry(self):
        """The registry's ground-truth entry this artifact deploys through
        (vectorized/pallas tiers are per-domain geometry, licensed by the
        artifact's validation report)."""
        if not self.deployable:
            raise ValueError(
                f"artifact ({self.domain}, {self.model}, s{self.stage}) is "
                f"not deployable: ordered={self.report.ordered_pct:.2f}% "
                f"(error={self.report.error!r})")
        return REGISTRY.ground_truth(self.domain)

    def register(self, registry: MapRegistry | None = None,
                 logic: str | None = None):
        """Expose the derived scalar map through a registry under a
        model-attributed logic key (default ``derived:<model>:s<stage>``)."""
        reg = registry if registry is not None else REGISTRY
        logic = logic or f"derived:{self.model}:s{self.stage}"
        return reg.register(
            self.domain, logic,
            tiers={"scalar": self.scalar_fn()},
            complexity_class=self.complexity_class,
        )

    # -- (de)serialization -------------------------------------------------
    def to_record(self) -> dict[str, Any]:
        return {
            "domain": self.domain, "model": self.model, "stage": self.stage,
            "source": self.source, "complexity_class": self.complexity_class,
            "report": dataclasses.asdict(self.report),
            "report_digest": self.report_digest,
            "inference_joules": self.inference_joules,
            "inference_seconds": self.inference_seconds,
            "cache_key": self.cache_key, "created_unix": self.created_unix,
        }

    @classmethod
    def from_record(cls, rec: dict[str, Any]) -> "MappingArtifact":
        return cls(
            domain=rec["domain"], model=rec["model"], stage=rec["stage"],
            source=rec["source"], complexity_class=rec["complexity_class"],
            report=validate.ValidationReport(**rec["report"]),
            inference_joules=rec["inference_joules"],
            inference_seconds=rec["inference_seconds"],
            cache_key=rec.get("cache_key"),
            created_unix=rec.get("created_unix", 0.0),
        )


def resolve_spec(spec) -> tuple[str, str | None]:
    """(domain, logic|None) from a str | Domain | MapEntry | MappingArtifact.

    Artifacts must be deployable (100% ordered) — this is the integration
    gate of the paper's Phase 4.  MapEntry/artifact specs carry their logic
    class so consumers can prefer a logic-specific tier when one exists."""
    if isinstance(spec, str):
        return spec, None
    if isinstance(spec, MappingArtifact):
        spec.registered_entry()  # raises if not deployable
        return spec.domain, spec.logic
    domain = getattr(spec, "domain", None)
    if isinstance(domain, str):  # MapEntry
        return domain, getattr(spec, "logic", None)
    name = getattr(spec, "name", None)
    if isinstance(name, str):    # Domain
        return name, None
    raise TypeError(f"cannot resolve a domain from {spec!r}")


def resolve_domain(spec) -> str:
    return resolve_spec(spec)[0]


# ---------------------------------------------------------------------------
# File locking — many clients, one artifact store
# ---------------------------------------------------------------------------


class FileLock:
    """Advisory cross-process lock: an O_CREAT|O_EXCL sentinel file.

    Combined with the cache's atomic-rename publish this makes the store
    safe for concurrent writers: the lock serializes *derivation* of one key
    across processes while readers stay lock-free (they only ever see a
    fully-published record or a miss).

    Ownership: each acquirer writes a unique token into the sentinel.  A
    heartbeat thread refreshes the sentinel's mtime while held, so only a
    genuinely crashed holder ever looks stale; a stale lock is broken by
    atomic rename (exactly one contender wins the break), and ``release``
    verifies the token so a holder whose lock *was* broken never deletes the
    next holder's sentinel.  All I/O degrades gracefully — an unwritable
    store yields an unlocked no-op lock, matching the cache's read-only
    degradation."""

    def __init__(self, path: str | Path, timeout: float = 30.0,
                 poll: float = 0.02, stale_seconds: float = 60.0):
        self.path = Path(path)
        self.timeout = timeout
        self.poll = poll
        self.stale_seconds = stale_seconds
        self.locked = False
        self.broke_stale = False
        self.token = f"{os.getpid()}-{os.urandom(8).hex()}"
        self._hb_stop: "threading.Event | None" = None
        self._hb_thread: "threading.Thread | None" = None

    def acquire(self) -> "FileLock":
        deadline = time.monotonic() + self.timeout
        while True:
            created = False
            try:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                fd = os.open(self.path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                created = True
                with os.fdopen(fd, "w") as f:
                    f.write(self.token)
                self.locked = True
                self._start_heartbeat()
                return self
            except FileExistsError:
                if self._break_if_stale():
                    continue
                if time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"lock {self.path} held past {self.timeout}s "
                        f"(stale threshold {self.stale_seconds}s)")
                time.sleep(self.poll)
            except OSError:
                # unwritable store: proceed unlocked (read-only degradation);
                # never leave an ownerless sentinel behind if the open
                # succeeded but the token write failed (e.g. ENOSPC)
                if created:
                    try:
                        self.path.unlink()
                    except OSError:
                        pass
                return self

    def _start_heartbeat(self) -> None:
        """Refresh the sentinel's mtime while held, so contenders never
        mistake a long-running live derivation for a crashed holder."""
        self._hb_stop = stop = threading.Event()
        interval = max(self.stale_seconds / 4.0, 0.05)

        def beat(path=self.path):
            while not stop.wait(interval):
                try:
                    os.utime(path)
                except OSError:
                    return  # lock gone (broken or released) — stop beating

        self._hb_thread = threading.Thread(
            target=beat, name=f"filelock-hb-{self.path.name}", daemon=True)
        self._hb_thread.start()

    def _break_if_stale(self) -> bool:
        try:
            age = time.time() - self.path.stat().st_mtime
        except OSError:
            return True  # holder released between our open and stat
        if age <= self.stale_seconds:
            return False
        # atomic rename: of N contenders observing the same stale sentinel,
        # exactly one wins the break — the losers see ENOENT and re-contend
        # without ever touching the winner's fresh lock.
        grave = self.path.with_name(
            f"{self.path.name}.stale-{os.urandom(4).hex()}")
        try:
            os.replace(self.path, grave)
        except OSError:
            return True  # someone else broke or released it first
        self.broke_stale = True
        try:
            grave.unlink()
        except OSError:
            pass
        return True

    def release(self) -> None:
        if not self.locked:
            return
        self.locked = False
        if self._hb_stop is not None:
            self._hb_stop.set()
            self._hb_thread.join()
        try:
            if self.path.read_text() == self.token:  # still ours?
                self.path.unlink()
        except OSError:
            pass

    def __enter__(self) -> "FileLock":
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


# ---------------------------------------------------------------------------
# Content-addressed derivation cache
# ---------------------------------------------------------------------------


def cache_key(domain: str, model: str, stage: int, prompt: str,
              **extra: Any) -> str:
    """Content address of one derivation cell."""
    payload = {
        "domain": domain, "model": model, "stage": stage,
        "prompt_sha256": hashlib.sha256(prompt.encode()).hexdigest(),
        **extra,
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


class ArtifactCache:
    """Content-addressed on-disk store of derivation records.

    Keys come from :func:`cache_key`; values are JSON records (see
    ``pipeline.py`` for the record schema).  All I/O degrades gracefully:
    a read-only or corrupt cache behaves like a miss."""

    def __init__(self, root: str | Path | None = None):
        if root is None:
            root = os.environ.get("REPRO_ARTIFACT_CACHE") or (
                Path.home() / ".cache" / "repro_thread_maps")
        self.root = Path(root)
        self.hits = 0
        self.misses = 0

    def path(self, key: str) -> Path:
        return self.root / f"{key}.json"

    def lock(self, key: str, timeout: float = 30.0,
             stale_seconds: float = 60.0) -> FileLock:
        """Cross-process writer lock for one key (see :class:`FileLock`).
        Readers never need it — ``store`` publishes via atomic rename."""
        return FileLock(self.root / f"{key}.lock", timeout=timeout,
                        stale_seconds=stale_seconds)

    def load(self, key: str) -> dict[str, Any] | None:
        try:
            rec = json.loads(self.path(key).read_text())
        except (OSError, ValueError):
            self.misses += 1
            return None
        if rec.get("schema") != SCHEMA_VERSION:
            self.misses += 1
            return None
        self.hits += 1
        return rec

    def store(self, key: str, record: dict[str, Any]) -> Path | None:
        record = {"schema": SCHEMA_VERSION, "key": key, **record}
        path = self.path(key)
        tmp = None
        published = False
        try:
            self.root.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(record, f, indent=1)
            os.replace(tmp, path)  # atomic publish
            published = True
        except OSError:
            return None
        finally:
            if tmp is not None and not published:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
        return path

    def __contains__(self, key: str) -> bool:
        return self.path(key).exists()

    def __len__(self) -> int:
        try:
            return sum(1 for _ in self.root.glob("*.json"))
        except OSError:
            return 0

    def clear(self) -> int:
        n = 0
        for p in self.root.glob("*.json"):
            try:
                p.unlink()
                n += 1
            except OSError:
                pass
        return n


_DEFAULT_CACHES: dict[str, ArtifactCache] = {}


def default_cache() -> ArtifactCache | None:
    """Process-default cache honoring $REPRO_ARTIFACT_CACHE (opt-out with
    "off"/"0"/"none").  One instance per resolved root, so hit/miss counters
    accumulate across calls."""
    env = os.environ.get("REPRO_ARTIFACT_CACHE", "")
    if env.strip().lower() in ("off", "0", "none", "disabled"):
        return None
    root = env or str(Path.home() / ".cache" / "repro_thread_maps")
    if root not in _DEFAULT_CACHES:
        _DEFAULT_CACHES[root] = ArtifactCache(root)
    return _DEFAULT_CACHES[root]
