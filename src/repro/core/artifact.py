"""MappingArtifact — the persistent, registered product of a derivation.

The paper's economic claim is that LLM derivation is a one-time upfront
investment amortized across every subsequent launch.  This module makes that
literal: a successful (domain, model, stage) derivation becomes a
``MappingArtifact`` — validated source, accuracy report digest, complexity
class, inference-energy metadata, and a scalar callable rebuilt on demand
from the validated source.  The pipeline persists each cell (successes and
NC failures alike) as a JSON derivation record in the tiered artifact store
(``core/store.py``: memory LRU -> checksummed disk with TTL/size eviction
-> peer replication), so repeated pipeline calls skip inference *and* the
10^6-point validation entirely; ``MappingArtifact.to_record``/``from_record``
additionally serialize a standalone artifact for export (e.g. serving a
shared artifact store).

Storage:    see :mod:`repro.core.store` — disk root $REPRO_ARTIFACT_CACHE,
            else ~/.cache/repro_thread_maps; opt out with
            REPRO_ARTIFACT_CACHE=off (or "0" / "none").
Key:        sha256 over {domain, model, stage, sha256(prompt), n_validate,
            sample_every} — any change to the prompt template, sampling
            stage or validation spec changes the key, which (plus the
            schema version + checksum in each record) is the entire
            invalidation story.

``ArtifactCache`` is the historical name of the disk tier; it and the
locking/keying primitives re-export here so existing imports keep working.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any, Callable

from repro.core import synthesis, validate
from repro.core.domains import Domain, get_domain
from repro.core.registry import REGISTRY, MapRegistry
from repro.core.store import (  # noqa: F401 — storage layer re-exports
    SCHEMA_VERSION, ArtifactStore, DiskStore, FileLock, MemoryStore,
    PeerStore, TieredStore, build_store, cache_key, default_store,
)

#: historical name for the disk tier (PR 1..3 call sites and tests)
ArtifactCache = DiskStore

#: historical name for the process-default store
default_cache = default_store

#: complexity class -> calibrated logic-class table key (Sec. V.C costs).
_DENSE_LOGIC = {
    "O(1)": "analytical",
    "O(log N)": "binsearch",
    "O(N^1/3)": "linear",
    "O(N^1/2)": "linear",
    "O(N)": "linear",
}
_FRACTAL_LOGIC = {
    "O(1)": "bitwise",
    "O(log N)": "bitwise",
    "O(N^1/3)": "linear",
    "O(N^1/2)": "linear",
    "O(N)": "linear",
}


def logic_for(complexity_class: str | None, domain: Domain) -> str:
    """Map a measured complexity class onto the calibrated logic table."""
    table = _DENSE_LOGIC if domain.kind == "dense" else _FRACTAL_LOGIC
    default = "analytical" if domain.kind == "dense" else "bitwise"
    return table.get(complexity_class or "", default)


# ---------------------------------------------------------------------------
# MappingArtifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MappingArtifact:
    """A validated thread map plus everything deployment needs to trust it."""

    domain: str
    model: str
    stage: int
    source: str                       # validated map_to_coordinates source
    complexity_class: str | None
    report: validate.ValidationReport
    inference_joules: float
    inference_seconds: float          # derivation wall time (one-time cost)
    cache_key: str | None = None
    created_unix: float = dataclasses.field(default_factory=time.time)
    _scalar: Callable | None = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- identity ----------------------------------------------------------
    @property
    def domainobj(self) -> Domain:
        return get_domain(self.domain)

    @property
    def logic(self) -> str:
        return logic_for(self.complexity_class, self.domainobj)

    @property
    def report_digest(self) -> str:
        payload = json.dumps(dataclasses.asdict(self.report), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    @property
    def deployable(self) -> bool:
        """Only a 100%-ordered map may drive the mapped-grid kernel."""
        return self.report.error is None and self.report.ordered >= 1.0

    # -- tiers -------------------------------------------------------------
    def scalar_fn(self) -> Callable:
        """Exact scalar callable, rebuilt from the validated source on first
        use (compile + probe only — the cached report vouches for accuracy)."""
        if self._scalar is None:
            self._scalar = synthesis.synthesize(self.source).fn
        return self._scalar

    def registered_entry(self):
        """The registry's ground-truth entry this artifact deploys through
        (vectorized/pallas tiers are per-domain geometry, licensed by the
        artifact's validation report)."""
        if not self.deployable:
            raise ValueError(
                f"artifact ({self.domain}, {self.model}, s{self.stage}) is "
                f"not deployable: ordered={self.report.ordered_pct:.2f}% "
                f"(error={self.report.error!r})")
        return REGISTRY.ground_truth(self.domain)

    def register(self, registry: MapRegistry | None = None,
                 logic: str | None = None):
        """Expose the derived scalar map through a registry under a
        model-attributed logic key (default ``derived:<model>:s<stage>``)."""
        reg = registry if registry is not None else REGISTRY
        logic = logic or f"derived:{self.model}:s{self.stage}"
        return reg.register(
            self.domain, logic,
            tiers={"scalar": self.scalar_fn()},
            complexity_class=self.complexity_class,
        )

    # -- (de)serialization -------------------------------------------------
    def to_record(self) -> dict[str, Any]:
        return {
            "domain": self.domain, "model": self.model, "stage": self.stage,
            "source": self.source, "complexity_class": self.complexity_class,
            "report": dataclasses.asdict(self.report),
            "report_digest": self.report_digest,
            "inference_joules": self.inference_joules,
            "inference_seconds": self.inference_seconds,
            "cache_key": self.cache_key, "created_unix": self.created_unix,
        }

    @classmethod
    def from_record(cls, rec: dict[str, Any]) -> "MappingArtifact":
        return cls(
            domain=rec["domain"], model=rec["model"], stage=rec["stage"],
            source=rec["source"], complexity_class=rec["complexity_class"],
            report=validate.ValidationReport(**rec["report"]),
            inference_joules=rec["inference_joules"],
            inference_seconds=rec["inference_seconds"],
            cache_key=rec.get("cache_key"),
            created_unix=rec.get("created_unix", 0.0),
        )


def resolve_spec(spec) -> tuple[str, str | None]:
    """(domain, logic|None) from a str | Domain | MapEntry | MappingArtifact.

    Artifacts must be deployable (100% ordered) — this is the integration
    gate of the paper's Phase 4.  MapEntry/artifact specs carry their logic
    class so consumers can prefer a logic-specific tier when one exists."""
    if isinstance(spec, str):
        return spec, None
    if isinstance(spec, MappingArtifact):
        spec.registered_entry()  # raises if not deployable
        return spec.domain, spec.logic
    domain = getattr(spec, "domain", None)
    if isinstance(domain, str):  # MapEntry
        return domain, getattr(spec, "logic", None)
    name = getattr(spec, "name", None)
    if isinstance(name, str):    # Domain
        return name, None
    raise TypeError(f"cannot resolve a domain from {spec!r}")


def resolve_domain(spec) -> str:
    return resolve_spec(spec)[0]
