"""Phase 3 — Algorithmic Synthesis (Fig. 3).

Takes raw LLM output text, extracts the Python code block, statically checks
it against the paper's <RULES> (Appendix A: single function named
`map_to_coordinates`, no hardcoded lookup chains over n, integer outputs),
and compiles it in a restricted namespace.  A candidate that fails any step is
classified (NC) — non-compiling — exactly as in the paper's tables.
"""
from __future__ import annotations

import ast
import dataclasses
import math
import re
from typing import Callable

_CODE_FENCE = re.compile(r"```(?:python)?\s*\n(.*?)```", re.DOTALL)

#: builtins the synthesized function may use (paper's candidates only ever
#: needed arithmetic + a handful of helpers).
_SAFE_BUILTINS = {
    "abs": abs, "int": int, "float": float, "round": round, "min": min,
    "max": max, "len": len, "range": range, "sum": sum, "divmod": divmod,
    "tuple": tuple, "list": list, "enumerate": enumerate, "pow": pow,
    "ValueError": ValueError, "TypeError": TypeError, "isinstance": isinstance,
    "bool": bool, "reversed": reversed, "zip": zip, "map": map, "set": set,
    "dict": dict, "sorted": sorted, "Exception": Exception,
}


def _restricted_import(name, globals=None, locals=None, fromlist=(), level=0):
    if name.split(".")[0] != "math":
        raise ImportError(f"import of {name!r} is not allowed in synthesized code")
    return math


_SAFE_BUILTINS["__import__"] = _restricted_import


class SynthesisError(Exception):
    pass


@dataclasses.dataclass
class SynthesizedMap:
    fn: Callable[[int], tuple]
    source: str
    rule_violations: list[str]

    def __call__(self, n: int) -> tuple:
        return self.fn(n)


def extract_code(text: str) -> str:
    """Pull the Python code out of an LLM response (fenced block or raw)."""
    m = _CODE_FENCE.search(text)
    code = m.group(1) if m else text
    return code.strip()


def check_rules(code: str) -> list[str]:
    """Static checks for the paper's Appendix-A <RULES>. Returns violations."""
    violations: list[str] = []
    try:
        tree = ast.parse(code)
    except SyntaxError as e:
        return [f"syntax error: {e}"]
    fns = [n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)]
    names = [f.name for f in fns]
    if "map_to_coordinates" not in names:
        violations.append("missing function map_to_coordinates(n)")
    # forbidden: long if/elif chains comparing n against integer constants
    # (hardcoded lookup) — count equality comparisons to literals.
    hardcoded = 0
    for node in ast.walk(tree):
        if isinstance(node, ast.Compare):
            if (
                isinstance(node.left, ast.Name)
                and node.left.id == "n"
                and any(isinstance(op, ast.Eq) for op in node.ops)
                and any(isinstance(c, ast.Constant) for c in node.comparators)
            ):
                hardcoded += 1
        if isinstance(node, (ast.Dict, ast.List)) and len(
            getattr(node, "keys", getattr(node, "elts", []))
        ) > 30:
            violations.append("large literal lookup table")
            break
    if hardcoded > 3:
        violations.append(f"hardcoded if n == <const> chain ({hardcoded} arms)")
    # forbidden imports of anything beyond math
    for node in ast.walk(tree):
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            mods = (
                [a.name for a in node.names]
                if isinstance(node, ast.Import)
                else [node.module or ""]
            )
            for mod in mods:
                if mod.split(".")[0] != "math":
                    violations.append(f"forbidden import: {mod}")
    return violations


def synthesize(text: str, max_nodes: int = 4000) -> SynthesizedMap:
    """LLM response text -> callable map, or raise SynthesisError (=> NC)."""
    code = extract_code(text)
    if not code:
        raise SynthesisError("empty response")
    violations = check_rules(code)
    fatal = [v for v in violations if "syntax error" in v or "missing function" in v
             or "forbidden import" in v]
    if fatal:
        raise SynthesisError("; ".join(fatal))
    tree = ast.parse(code)
    if sum(1 for _ in ast.walk(tree)) > max_nodes:
        raise SynthesisError("program too large")
    ns: dict = {"__builtins__": _SAFE_BUILTINS, "math": math}
    try:
        exec(compile(tree, "<synthesized>", "exec"), ns)  # noqa: S102 — sandboxed
    except Exception as e:
        raise SynthesisError(f"exec failed: {e!r}") from e
    fn = ns.get("map_to_coordinates")
    if not callable(fn):
        raise SynthesisError("map_to_coordinates is not callable")
    # probe: must return an int tuple for a trivial input and reject negatives
    try:
        out = fn(0)
    except Exception as e:
        raise SynthesisError(f"probe call failed: {e!r}") from e
    if not isinstance(out, (tuple, list)) or not all(
        isinstance(v, (int,)) or (isinstance(v, float) and float(v).is_integer())
        for v in out
    ):
        raise SynthesisError(f"probe output not an integer tuple: {out!r}")
    return SynthesizedMap(fn=fn, source=code, rule_violations=violations)
