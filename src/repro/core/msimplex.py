"""Generalized m-simplex block-space maps (paper refs [5], [8]; future-work
direction "more heterogeneous HPC topologies").

The m-simplex domain is {(x_1..x_m) : 0 <= x_1 <= x_2 <= ... <= x_m}; its
size at side n is the binomial C(n+m-1, m) (m=2: triangular numbers, m=3:
tetrahedral — paper Table I rows 1-2 are the m=2,3 specializations).

The linear map peels one coordinate per level: the largest x_m with
simplex_size(x_m, m) <= lambda, recursing on the remainder with m-1 — each
level inverted by a float seed (the paper's sqrt/cbrt generalizes to the
m-th root) plus an exact integer correction.
"""
from __future__ import annotations

import math

import numpy as np


def simplex_size(n: int, m: int) -> int:
    """|m-simplex| with side n: C(n+m-1, m)."""
    return math.comb(n + m - 1, m)


def simplex_layer(lam: int, m: int) -> int:
    """Largest x with simplex_size(x, m) <= lam.

    Float seed x ~ (m! * lam)^(1/m) (the generalized sqrt/cbrt of Table I),
    then an exact ladder — the paper's analytical O(1) structure for any m.
    """
    if lam < 0:
        raise ValueError("negative lambda")
    if m == 1:
        return lam
    x = int(round((math.factorial(m) * lam) ** (1.0 / m)))
    while simplex_size(x + 1, m) <= lam:
        x += 1
    while x > 0 and simplex_size(x, m) > lam:
        x -= 1
    return x


def map_msimplex(lam: int, m: int) -> tuple[int, ...]:
    """lambda -> (x_1 <= x_2 <= ... <= x_m), the canonical enumeration."""
    coords = []
    for level in range(m, 0, -1):
        x = simplex_layer(lam, level)
        coords.append(x)
        lam -= simplex_size(x, level)
    return tuple(reversed(coords))


def unmap_msimplex(coords: tuple[int, ...]) -> int:
    """(x_1 <= ... <= x_m) -> lambda (rank in canonical order)."""
    lam = 0
    for level, x in enumerate(reversed(coords), start=0):
        lam += simplex_size(x, len(coords) - level)
    return lam


def enumerate_msimplex(n_points: int, m: int) -> np.ndarray:
    """First n_points of the canonical enumeration, (N, m) — independent
    nested-loop construction for validating the map."""
    out = np.empty((n_points, m), dtype=np.int64)

    def gen(m_left, bound):
        """Yield tuples (x_1 <= ... <= x_{m_left}) with x_{m_left} <= bound,
        outermost coordinate slowest (canonical order)."""
        if m_left == 0:
            yield ()
            return
        for x in range(bound + 1):
            for rest in gen(m_left - 1, x):
                yield rest + (x,)

    idx = 0
    x_outer = 0
    while idx < n_points:
        for rest in gen(m - 1, x_outer):
            if idx >= n_points:
                break
            out[idx] = rest + (x_outer,)
            idx += 1
        x_outer += 1
    return out


# ---------------------------------------------------------------------------
# Vectorized tiers (module-generic: works for numpy int64 and jax.numpy int32)
# ---------------------------------------------------------------------------


def vec_simplex_size(xp, x, m: int):
    """C(x+m-1, m) elementwise, with division interleaved stepwise so the
    running value stays a binomial coefficient: after step i the register
    holds C(x+i-1, i), and C(x+i-2, i-1)*(x+i-1) = i*C(x+i-1, i) makes each
    division exact.  Intermediates are bounded by ~m*C(x+m-1, m), so in an
    int32 kernel the tier is exact for lambda up to ~2^31/m (the same order
    as the existing dense tiers' 8*lam+1 / z^3 seeds) instead of the
    ~(2^31)^(1/m) a naive full product would allow."""
    r = xp.ones_like(x)
    for i in range(1, m + 1):
        r = r * (x + i - 1) // i
    return r


def vec_simplex_layer(xp, lam, m: int):
    """Vectorized `simplex_layer`: float m-th-root seed (the generalized
    sqrt/cbrt of Table I) + exact integer correction ladder."""
    if m == 1:
        return lam
    ftype = xp.float64 if xp is np else xp.float32
    seed = xp.power(lam.astype(ftype) * float(math.factorial(m)), 1.0 / m)
    x = seed.astype(lam.dtype)
    for _ in range(4):
        x = xp.where(vec_simplex_size(xp, x + 1, m) <= lam, x + 1, x)
        x = xp.where((x > 0) & (vec_simplex_size(xp, x, m) > lam), x - 1, x)
    return xp.maximum(x, 0)


def vec_map_msimplex(xp, lams, m: int):
    """Vectorized `map_msimplex`: (N,) lambdas -> (N, m) sorted coords.

    `xp` is the array module — numpy (exact int64, the validation tier) or
    jax.numpy (traceable int32, the jitted tier)."""
    rem = xp.asarray(lams)
    cols = []
    for level in range(m, 0, -1):
        x = vec_simplex_layer(xp, rem, level)
        cols.append(x)
        rem = rem - vec_simplex_size(xp, x, level)
    return xp.stack(list(reversed(cols)), axis=-1)


def np_map_msimplex(lams: np.ndarray, m: int) -> np.ndarray:
    """Exact vectorized int64 map (the 10^6-point validation tier)."""
    return vec_map_msimplex(np, np.asarray(lams, dtype=np.int64), m)


def block_accounting_msimplex(n_points: int, m: int, block: int = 256) -> dict:
    """BB waste for the m-simplex: the box is n^m vs C(n+m-1, m) ~ n^m/m!.

    The waste fraction approaches 1 - 1/m! — the paper's 2D ~50% and 3D ~83%
    generalize to 96% (m=4), 99.2% (m=5): the mapped kernel's advantage
    GROWS with dimension.
    """
    n = 0
    while simplex_size(n, m) < n_points:
        n += 1
    valid = -(-n_points // block)
    bb = -(-(n ** m) // block)
    return {
        "side": n, "valid_blocks": valid, "bb_blocks": bb,
        "waste_fraction": (bb - valid) / bb if bb else 0.0,
        "asymptotic_waste": 1.0 - 1.0 / math.factorial(m),
    }
