"""Phase 2 — Symbolic Inference backends.

`LLMBackend` is the pluggable protocol; `MockLLMBackend` deterministically
replays the behaviour the paper measured per (model, domain, stage) cell so
the whole pipeline — prompt building, code extraction, synthesis, validation,
energy accounting, deployment — runs end-to-end offline.  `OllamaBackend`
shows the production wiring for real local models (paper Sec. V ran GGUF
models under default parameters).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Protocol

import numpy as np

from repro.core import paper_tables as pt
from repro.core.domains import Domain

# ---------------------------------------------------------------------------
# Appendix A prompt
# ---------------------------------------------------------------------------

PROMPT_TEMPLATE = """<ROLE>
Act as an expert in mathematics and cryptography, specializing in the reverse
engineering of algorithms and the identification of complex patterns in
multidimensional spaces. Your goal is SOLELY to generate the Python code
requested.
</ROLE>

<TASK>
Analyze the mapping data in the <CONTEXT> to find the underlying mathematical
algorithm.

Then, generate the complete source code for a single Python function that
implements this general algorithm.
</TASK>

<CONTEXT>
# Mapping Data
{mapping_data}
</CONTEXT>

<RULES>
- Function name must be exactly `map_to_coordinates(n)`.
- Input: 'n' (non-negative integer).
- Output: tuple of integers representing coordinates.
- Each integer within the returned coordinate tuple must be greater than or
  equal to 0.
- Validate input 'n' (non-negative integer), raise 'ValueError' if invalid.
- **CRITICAL ALGORITHM CONSTRAINT:** The function MUST implement a general
  mathematical algorithm that works for ANY non-negative integer 'n', not just
  the examples provided.
- **DO NOT use hardcoded values, lookup tables, or long 'if/elif' chains based
  on ranges of 'n'.**
- **CRITICAL OUTPUT CONSTRAINT:** Your response MUST contain ONLY the Python
  code block for the function.
- **DO NOT include ANY introductory text, explanations, reasoning, thought
  processes, or comments.**
- Do NOT include an 'if __name__ == "__main__":' block.
</RULES>

<RESPONSE>
"""


def sample_context(domain: Domain, stage: int) -> np.ndarray:
    """Phase 1 — Context Sampling: first `stage` points (sequential CPU)."""
    return domain.enumerate_points(stage)


def build_prompt(domain: Domain, stage: int) -> str:
    pts = sample_context(domain, stage)
    lines = [f"{i} -> {tuple(int(v) for v in p)}" for i, p in enumerate(pts)]
    return PROMPT_TEMPLATE.format(mapping_data="\n".join(lines))


# ---------------------------------------------------------------------------
# Backend protocol + typed errors
# ---------------------------------------------------------------------------


class LLMError(RuntimeError):
    """Base class for backend failures the serving tier maps onto wire
    codes — anything else escaping a backend is a plain 500."""


class LLMBusyError(LLMError):
    """Generation admission is saturated: shed now, retry later (HTTP 503).

    The batching layer's ``AdmissionError`` subclasses this, so every
    admission-control path in the stack speaks one retryable error type."""


class LLMTimeoutError(LLMError):
    """Generation exceeded its configured deadline (HTTP 504).  Retryable:
    the work was cancelled, not answered — a repeat is safe (derivations
    are idempotent by content address)."""


class LLMUnavailableError(LLMError):
    """The configured backend cannot be reached at all (HTTP 503)."""


@dataclasses.dataclass
class LLMResponse:
    text: str
    model: str
    tokens_in: int
    tokens_out: int
    seconds: float
    joules: float


class LLMBackend(Protocol):
    name: str

    def generate(self, prompt: str, *, meta: dict) -> LLMResponse: ...


class AsyncLLMBackend(Protocol):
    """Async backend protocol for event-loop serving (``serving/aio.py``).

    Lifecycle mirrors the sync protocol's implicit one, made explicit so a
    server can manage it: ``start`` loads weights / spawns workers,
    ``warm`` primes compilation with a throwaway generate, ``health_check``
    answers liveness probes without generating, ``close`` releases
    everything.  ``generate`` raises the typed errors above
    (:class:`LLMBusyError` when admission is saturated,
    :class:`LLMTimeoutError` past the deadline) so the HTTP layer can map
    them to 503/504 without string matching."""

    name: str

    async def start(self) -> None: ...

    async def close(self) -> None: ...

    async def health_check(self) -> bool: ...

    async def warm(self, timeout_s: float = 120.0) -> None: ...

    async def generate(self, prompt: str, *, meta: dict) -> LLMResponse: ...


class AsyncBackendAdapter:
    """Wrap any sync :class:`LLMBackend` into the async protocol by
    offloading ``generate`` to the running loop's default executor — the
    bridge that lets the mock/ollama backends serve behind the asyncio
    frontend without their own async implementations."""

    def __init__(self, inner: LLMBackend):
        self.inner = inner
        self.name = inner.name

    @property
    def cache_fingerprint(self):
        return getattr(self.inner, "cache_fingerprint", None)

    async def start(self) -> None:
        start = getattr(self.inner, "start", None)
        if callable(start):
            start()

    async def close(self) -> None:
        close = getattr(self.inner, "close", None)
        if callable(close):
            close()

    async def health_check(self) -> bool:
        return True

    async def warm(self, timeout_s: float = 120.0) -> None:
        return None

    async def generate(self, prompt: str, *, meta: dict) -> LLMResponse:
        import asyncio
        import functools

        loop = asyncio.get_running_loop()
        return await loop.run_in_executor(
            None, functools.partial(self.inner.generate, prompt, meta=meta))


# ---------------------------------------------------------------------------
# Code templates the mock backend emits, per (domain, logic-class)
# ---------------------------------------------------------------------------

_HDR = (
    "def map_to_coordinates(n):\n"
    "    if not isinstance(n, int) or isinstance(n, bool) or n < 0:\n"
    "        raise ValueError('n must be a non-negative integer')\n"
)

CODE_TEMPLATES: dict[tuple[str, str], str] = {
    ("tri2d", "analytical"): (
        "import math\n" + _HDR +
        "    x = (math.isqrt(8 * n + 1) - 1) // 2\n"
        "    y = n - x * (x + 1) // 2\n"
        "    return (x, y)\n"
    ),
    ("tri2d", "sqrt_loop"): (
        _HDR +
        "    x = int((2.0 * n) ** 0.5)\n"
        "    while (x + 1) * (x + 2) // 2 <= n:\n"
        "        x += 1\n"
        "    while x * (x + 1) // 2 > n:\n"
        "        x -= 1\n"
        "    return (x, n - x * (x + 1) // 2)\n"
    ),
    ("tri2d", "binsearch"): (
        _HDR +
        "    lo, hi = 0, 1\n"
        "    while hi * (hi + 1) // 2 <= n:\n"
        "        hi *= 2\n"
        "    while lo < hi:\n"
        "        mid = (lo + hi + 1) // 2\n"
        "        if mid * (mid + 1) // 2 <= n:\n"
        "            lo = mid\n"
        "        else:\n"
        "            hi = mid - 1\n"
        "    return (lo, n - lo * (lo + 1) // 2)\n"
    ),
    ("tri2d", "approx_if"): (
        _HDR +
        "    x = int(((8.0 * n + 1.0) ** 0.5 - 1.0) / 2.0)\n"
        "    if (x + 1) * (x + 2) // 2 <= n:\n"
        "        x += 1\n"
        "    if x * (x + 1) // 2 > n:\n"
        "        x -= 1\n"
        "    return (x, n - x * (x + 1) // 2)\n"
    ),
    ("pyramid3d", "analytical"): (
        "import math\n" + _HDR +
        "    z = int((6.0 * n) ** (1.0 / 3.0))\n"
        "    if (z + 1) * (z + 2) * (z + 3) // 6 <= n:\n"
        "        z += 1\n"
        "    if z > 0 and z * (z + 1) * (z + 2) // 6 > n:\n"
        "        z -= 1\n"
        "    if z > 0 and z * (z + 1) * (z + 2) // 6 > n:\n"
        "        z -= 1\n"
        "    r = n - z * (z + 1) * (z + 2) // 6\n"
        "    x = (math.isqrt(8 * r + 1) - 1) // 2\n"
        "    y = r - x * (x + 1) // 2\n"
        "    return (x, y, z)\n"
    ),
    ("pyramid3d", "cbrt_loop"): (
        "import math\n" + _HDR +
        "    z = int(round((6.0 * n) ** (1.0 / 3.0)))\n"
        "    while (z + 1) * (z + 2) * (z + 3) // 6 <= n:\n"
        "        z += 1\n"
        "    while z > 0 and z * (z + 1) * (z + 2) // 6 > n:\n"
        "        z -= 1\n"
        "    r = n - z * (z + 1) * (z + 2) // 6\n"
        "    x = (math.isqrt(8 * r + 1) - 1) // 2\n"
        "    return (x, r - x * (x + 1) // 2, z)\n"
    ),
    ("pyramid3d", "binsearch"): (
        "import math\n" + _HDR +
        "    lo, hi = 0, 1\n"
        "    while hi * (hi + 1) * (hi + 2) // 6 <= n:\n"
        "        hi *= 2\n"
        "    while lo < hi:\n"
        "        mid = (lo + hi + 1) // 2\n"
        "        if mid * (mid + 1) * (mid + 2) // 6 <= n:\n"
        "            lo = mid\n"
        "        else:\n"
        "            hi = mid - 1\n"
        "    r = n - lo * (lo + 1) * (lo + 2) // 6\n"
        "    x = (math.isqrt(8 * r + 1) - 1) // 2\n"
        "    return (x, r - x * (x + 1) // 2, lo)\n"
    ),
    ("pyramid3d", "binsearch_linear"): (
        "import math\n" + _HDR +
        "    hi = 1\n"
        "    while hi * (hi + 1) * (hi + 2) // 6 <= n:\n"
        "        hi *= 2\n"
        "    z = 0\n"
        "    while (z + 1) * (z + 2) * (z + 3) // 6 <= n:\n"
        "        z += 1\n"
        "    r = n - z * (z + 1) * (z + 2) // 6\n"
        "    y = 0\n"
        "    while (y + 1) * (y + 2) // 2 <= r:\n"
        "        y += 1\n"
        "    return (y, r - y * (y + 1) // 2, z)\n"
    ),
    ("pyramid3d", "linear"): (
        _HDR +
        "    z = 0\n"
        "    while (z + 1) * (z + 2) * (z + 3) // 6 <= n:\n"
        "        z += 1\n"
        "    r = n - z * (z + 1) * (z + 2) // 6\n"
        "    x = 0\n"
        "    while (x + 1) * (x + 2) // 2 <= r:\n"
        "        x += 1\n"
        "    return (x, r - x * (x + 1) // 2, z)\n"
    ),
    ("gasket2d", "bitwise"): (
        _HDR +
        "    x = 0\n    y = 0\n    s = 1\n    m = n\n"
        "    while m > 0:\n"
        "        d = m % 3\n"
        "        if d == 1:\n            x += s\n"
        "        elif d == 2:\n            y += s\n"
        "        m //= 3\n        s *= 2\n"
        "    return (x, y)\n"
    ),
    ("carpet2d", "bitwise"): (
        _HDR +
        "    cells = ((0, 0), (0, 1), (0, 2), (1, 0), (1, 2), (2, 0), (2, 1), (2, 2))\n"
        "    x = 0\n    y = 0\n    s = 1\n    m = n\n"
        "    while m > 0:\n"
        "        vx, vy = cells[m % 8]\n"
        "        x += vx * s\n        y += vy * s\n"
        "        m //= 8\n        s *= 3\n"
        "    return (x, y)\n"
    ),
    ("sierpinski3d", "bitwise"): (
        _HDR +
        "    x = 0\n    y = 0\n    z = 0\n    s = 1\n    m = n\n"
        "    while m > 0:\n"
        "        d = m % 4\n"
        "        if d == 1:\n            x += s\n"
        "        elif d == 2:\n            y += s\n"
        "        elif d == 3:\n            z += s\n"
        "        m //= 4\n        s *= 2\n"
        "    return (x, y, z)\n"
    ),
    ("menger3d", "bitwise"): (
        _HDR +
        "    cells = ((0, 0, 0), (0, 0, 1), (0, 0, 2), (0, 1, 0), (0, 1, 2),\n"
        "             (0, 2, 0), (0, 2, 1), (0, 2, 2), (1, 0, 0), (1, 0, 2),\n"
        "             (1, 2, 0), (1, 2, 2), (2, 0, 0), (2, 0, 1), (2, 0, 2),\n"
        "             (2, 1, 0), (2, 1, 2), (2, 2, 0), (2, 2, 1), (2, 2, 2))\n"
        "    x = 0\n    y = 0\n    z = 0\n    s = 1\n    m = n\n"
        "    while m > 0:\n"
        "        vx, vy, vz = cells[m % 20]\n"
        "        x += vx * s\n        y += vy * s\n        z += vz * s\n"
        "        m //= 20\n        s *= 3\n"
        "    return (x, y, z)\n"
    ),
}

# canonical *failure* modes for non-perfect cells ---------------------------

_FAIL_2D_ROWMAJOR = (
    _HDR +
    "    width = 1000\n"
    "    return (n // width, n % width)\n"
)
_FAIL_3D_ROWMAJOR = (
    _HDR +
    "    side = 100\n"
    "    return (n // (side * side), (n // side) % side, n % side)\n"
)
_FAIL_WRONG_BASE_2D = (
    _HDR +
    "    x = 0\n    y = 0\n    s = 1\n    m = n\n"
    "    while m > 0:\n"
    "        d = m % 4\n"
    "        if d == 1:\n            x += s\n"
    "        elif d == 2:\n            y += s\n"
    "        elif d == 3:\n            x += s\n            y += s\n"
    "        m //= 4\n        s *= 2\n"
    "    return (x, y)\n"
)
_FAIL_WRONG_BASE_3D = (
    _HDR +
    "    x = 0\n    y = 0\n    z = 0\n    s = 1\n    m = n\n"
    "    while m > 0:\n"
    "        d = m % 8\n"
    "        x += (d & 1) * s\n"
    "        y += ((d >> 1) & 1) * s\n"
    "        z += ((d >> 2) & 1) * s\n"
    "        m //= 8\n        s *= 2\n"
    "    return (x, y, z)\n"
)
# correct geometry, permuted traversal order ("silver standard")
_PERMUTED = {
    "tri2d": (
        "import math\n" + _HDR +
        "    x = (math.isqrt(8 * n + 1) - 1) // 2\n"
        "    y = n - x * (x + 1) // 2\n"
        "    return (x, x - y)\n"  # column order reversed within each row
    ),
    "pyramid3d": (
        "import math\n" + _HDR +
        "    z = int(round((6.0 * n) ** (1.0 / 3.0)))\n"
        "    while (z + 1) * (z + 2) * (z + 3) // 6 <= n:\n"
        "        z += 1\n"
        "    while z > 0 and z * (z + 1) * (z + 2) // 6 > n:\n"
        "        z -= 1\n"
        "    r = n - z * (z + 1) * (z + 2) // 6\n"
        "    x = (math.isqrt(8 * r + 1) - 1) // 2\n"
        "    y = r - x * (x + 1) // 2\n"
        "    return (x, x - y, z)\n"
    ),
    "gasket2d": (
        _HDR +
        "    x = 0\n    y = 0\n    s = 1\n    m = n\n"
        "    while m > 0:\n"
        "        d = m % 3\n"
        "        if d == 1:\n            y += s\n"  # axes swapped
        "        elif d == 2:\n            x += s\n"
        "        m //= 3\n        s *= 2\n"
        "    return (x, y)\n"
    ),
    "carpet2d": (
        _HDR +
        "    cells = ((0, 0), (1, 0), (2, 0), (0, 1), (2, 1), (0, 2), (1, 2), (2, 2))\n"
        "    x = 0\n    y = 0\n    s = 1\n    m = n\n"
        "    while m > 0:\n"
        "        vx, vy = cells[m % 8]\n"
        "        x += vx * s\n        y += vy * s\n"
        "        m //= 8\n        s *= 3\n"
        "    return (x, y)\n"
    ),
    "sierpinski3d": (
        _HDR +
        "    x = 0\n    y = 0\n    z = 0\n    s = 1\n    m = n\n"
        "    while m > 0:\n"
        "        d = m % 4\n"
        "        if d == 1:\n            z += s\n"
        "        elif d == 2:\n            y += s\n"
        "        elif d == 3:\n            x += s\n"
        "        m //= 4\n        s *= 2\n"
        "    return (x, y, z)\n"
    ),
    "menger3d": (
        _HDR +
        "    cells = ((0, 0, 0), (1, 0, 0), (2, 0, 0), (0, 1, 0), (2, 1, 0),\n"
        "             (0, 2, 0), (1, 2, 0), (2, 2, 0), (0, 0, 1), (2, 0, 1),\n"
        "             (0, 2, 1), (2, 2, 1), (0, 0, 2), (1, 0, 2), (2, 0, 2),\n"
        "             (0, 1, 2), (2, 1, 2), (0, 2, 2), (1, 2, 2), (2, 2, 2))\n"
        "    x = 0\n    y = 0\n    z = 0\n    s = 1\n    m = n\n"
        "    while m > 0:\n"
        "        vx, vy, vz = cells[m % 20]\n"
        "        x += vx * s\n        y += vy * s\n        z += vz * s\n"
        "        m //= 20\n        s *= 3\n"
        "    return (x, y, z)\n"
    ),
}
_NONCOMPILING = "def map_to_coordinates(n:\n    return (n,\n"

# --- extension domains (not in the paper's tables) -------------------------
# The m-simplex and embedded-fractal families are beyond-paper scenarios; the
# replay bank emits the canonical derivation for them (every model "solves"
# them), so extension cells exercise the full synthesize/validate/deploy path
# without inventing unmeasured failure tables.


def _simplex_template(m: int) -> str:
    """Canonical m-level peel: float m-th-root seed + exact ladder."""
    return (
        "import math\n" + _HDR +
        "    lam = n\n"
        "    coords = []\n"
        f"    for level in range({m}, 0, -1):\n"
        "        x = int(round((math.factorial(level) * lam) "
        "** (1.0 / level)))\n"
        "        while math.comb(x + level, level) <= lam:\n"
        "            x += 1\n"
        "        while x > 0 and math.comb(x + level - 1, level) > lam:\n"
        "            x -= 1\n"
        "        coords.append(x)\n"
        "        lam -= math.comb(x + level - 1, level)\n"
        "    return tuple(reversed(coords))\n"
    )


def _digit_fractal_template(base: int, scale: int, vecs) -> str:
    """Canonical digit decomposition over the generator cell table."""
    cells = ", ".join(repr(tuple(int(x) for x in v)) for v in vecs)
    dim = len(vecs[0])
    names = ["x", "y", "z"][:dim]
    unpack = ", ".join(f"v{k}" for k in range(dim))
    return (
        _HDR +
        f"    cells = ({cells})\n"
        + "".join(f"    {nm} = 0\n" for nm in names)
        + "    s = 1\n    m = n\n"
        "    while m > 0:\n"
        f"        {unpack}{',' if dim == 1 else ''} = cells[m % {base}]\n"
        + "".join(f"        {nm} += v{k} * s\n"
                  for k, nm in enumerate(names))
        + f"        m //= {base}\n        s *= {scale}\n"
        f"    return ({', '.join(names)})\n"
    )


def extension_behavior(domain: str) -> tuple[str, str]:
    """(logic-class, code) for a beyond-paper domain, generated from the
    Domain's own geometry metadata — no per-domain table entries needed."""
    from repro.core.domains import SimplexDomain, get_domain

    d = get_domain(domain)
    if isinstance(d, SimplexDomain):
        return "analytical", _simplex_template(d.m)
    if d.kind == "fractal":
        return "bitwise", _digit_fractal_template(d.base, d.scale, d.vecs)
    raise KeyError(f"no replay behavior for extension domain {domain!r}")


def mock_behavior(domain: str, model: str, stage: int) -> tuple[str, str]:
    """(behavior-class, code) the replay bank emits for one table cell."""
    if domain not in pt.ACCURACY:
        return extension_behavior(domain)
    stage_idx = pt.STAGES.index(stage)
    ordered, any_order, compiled = pt.ACCURACY[domain][model][stage_idx]
    if not compiled:
        return "noncompiling", _NONCOMPILING
    if ordered >= 100.0:
        logic = pt.LOGIC_CLASS_OVERRIDES.get(
            (domain, model, stage),
            "analytical" if domain in ("tri2d", "pyramid3d") else "bitwise",
        )
        return logic, CODE_TEMPLATES[(domain, logic)]
    if any_order >= 5.0:  # geometry mostly right, order wrong
        return "permuted", _PERMUTED[domain]
    if domain in ("tri2d", "pyramid3d"):
        return "rowmajor_fit", (_FAIL_2D_ROWMAJOR if domain == "tri2d"
                                else _FAIL_3D_ROWMAJOR)
    return "wrong_base", (_FAIL_WRONG_BASE_2D if domain in ("gasket2d", "carpet2d")
                          else _FAIL_WRONG_BASE_3D)


# ---------------------------------------------------------------------------
# Model priors for the energy/time model of the inference phase (Sec. V.B).
# params in billions; tps = generation tok/s on 4xA100 (modeled priors);
# reasoning models multiply emitted tokens by the CoT factor.
# ---------------------------------------------------------------------------

MODEL_SPECS = {
    "R1:70b":      dict(params_b=70.6, tps=28.0, cot_factor=12.0, power_w=1100.0),
    "Gem3:12b":    dict(params_b=12.2, tps=95.0, cot_factor=1.0, power_w=700.0),
    "Gem3:27b":    dict(params_b=27.4, tps=55.0, cot_factor=1.0, power_w=850.0),
    "OSS:120b":    dict(params_b=116.8, tps=45.0, cot_factor=3.0, power_w=1250.0),
    "OSS:20b":     dict(params_b=20.9, tps=120.0, cot_factor=3.0, power_w=750.0),
    "Lla3.3:70b":  dict(params_b=70.6, tps=30.0, cot_factor=1.0, power_w=1100.0),
    "Lla4:16x17b": dict(params_b=108.6, tps=60.0, cot_factor=1.0, power_w=1200.0),
    "Mist-N:12b":  dict(params_b=12.2, tps=100.0, cot_factor=1.0, power_w=700.0),
    "Nemo:70b":    dict(params_b=70.6, tps=30.0, cot_factor=1.0, power_w=1100.0),
    "Qw3:235b":    dict(params_b=235.1, tps=18.0, cot_factor=4.0, power_w=1400.0),
    "Qw3:32b":     dict(params_b=32.8, tps=50.0, cot_factor=4.0, power_w=900.0),
}


_REPLAY_BANK_FINGERPRINTS: dict[tuple, str] = {}


def replay_bank_fingerprint() -> str:
    """Content hash of the mock replay bank; folded into artifact-cache keys
    so edits to the measured tables / code templates invalidate cached
    derivations instead of silently replaying stale results.

    The generated extension templates are bank content too — the emitted
    code itself is hashed, so a generator edit invalidates those cells, and
    the memo is keyed by the registered extension-domain set so late plugin
    registrations are picked up rather than frozen out."""
    from repro.core.domains import DOMAINS

    ext_names = tuple(sorted(set(DOMAINS) - set(pt.ACCURACY)))
    if ext_names not in _REPLAY_BANK_FINGERPRINTS:
        ext = []
        for name in ext_names:
            try:
                ext.append((name, *extension_behavior(name)))
            except KeyError:
                pass  # a domain the replay bank cannot serve — see mock_behavior
        payload = repr((pt.ACCURACY, pt.LOGIC_CLASS_OVERRIDES, CODE_TEMPLATES,
                        _PERMUTED, _FAIL_2D_ROWMAJOR, _FAIL_3D_ROWMAJOR,
                        _FAIL_WRONG_BASE_2D, _FAIL_WRONG_BASE_3D,
                        _NONCOMPILING, MODEL_SPECS, tuple(ext)))
        _REPLAY_BANK_FINGERPRINTS[ext_names] = hashlib.sha256(
            payload.encode()).hexdigest()[:16]
    return _REPLAY_BANK_FINGERPRINTS[ext_names]


class MockLLMBackend:
    """Deterministic replay of the paper's measured per-cell behaviour."""

    def __init__(self, model: str):
        if model not in pt.MODELS:
            raise ValueError(f"unknown model {model!r}; have {pt.MODELS}")
        self.name = model
        self.spec = MODEL_SPECS[model]

    @property
    def cache_fingerprint(self) -> str:
        return replay_bank_fingerprint()

    def generate(self, prompt: str, *, meta: dict) -> LLMResponse:
        domain, stage = meta["domain"], meta["stage"]
        _, code = mock_behavior(domain, self.name, stage)
        tokens_in = max(len(prompt) // 4, 1)
        code_tokens = max(len(code) // 4, 1)
        tokens_out = int(code_tokens * self.spec["cot_factor"])
        seconds = tokens_out / self.spec["tps"] + tokens_in / (self.spec["tps"] * 8)
        joules = seconds * self.spec["power_w"]
        return LLMResponse(
            text=f"```python\n{code}```", model=self.name,
            tokens_in=tokens_in, tokens_out=tokens_out,
            seconds=seconds, joules=joules,
        )


def canonical_code(domain: str) -> str:
    """The canonical perfect derivation for any registered domain — the
    paper domains' analytical/bitwise templates, or the geometry-generated
    template for extension families."""
    if domain in pt.ACCURACY:
        logic = "analytical" if domain in ("tri2d", "pyramid3d") else "bitwise"
        return CODE_TEMPLATES[(domain, logic)]
    return extension_behavior(domain)[1]


class EngineBackend:
    """LLMBackend over the in-repo batched serving engine (`serving/engine`).

    This is the 'real backend' wiring: a smoke-config transformer runs true
    prefill + step-wise decode over the (byte-tokenized) Appendix-A prompt —
    deterministic because params come from a fixed seed and decoding is
    greedy by default.  The smoke model is untrained, so its sampled text
    essentially never synthesizes into a valid ``map_to_coordinates``; when
    synthesis of the sampled text fails, the backend falls back to the
    canonical derivation for the requested domain, exactly as the mock's
    extension path does — so the pipeline downstream (synthesis, validation,
    artifact publish) always exercises its real code path, while the
    inference cost (wall seconds, modeled joules) is *measured* from the
    actual prefill/decode run rather than replayed from priors.

    ``generate_batch`` pads a group of prompts to one (B, S) call — one
    prefill for the whole batch — which is what the serving layer's
    ``BatchingBackend`` drives when concurrent derive requests for the same
    model are admitted together.
    """

    def __init__(self, model: str, arch: str = "yi-6b",
                 prompt_tokens: int = 48, max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0,
                 power_w: float | None = None):
        self.name = model
        self.arch = arch
        self.prompt_tokens = prompt_tokens
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.seed = seed
        spec = MODEL_SPECS.get(model)
        self.power_w = power_w if power_w is not None else (
            spec["power_w"] if spec else 1000.0)
        self._engine = None  # (params, cfg) built lazily: jax import + init

    @property
    def cache_fingerprint(self) -> str:
        """Engine cells must never collide with mock cells for the same
        (domain, model, stage): the fingerprint carries the backend kind,
        the arch + decode knobs, and the canonical-fallback bank hash."""
        knobs = (self.arch, self.prompt_tokens, self.max_new_tokens,
                 self.temperature, self.seed)
        return f"engine:{knobs!r}:{replay_bank_fingerprint()}"

    def _ensure_engine(self):
        if self._engine is None:
            import jax

            from repro.configs import get_smoke_config
            from repro.models import transformer as T

            cfg = get_smoke_config(self.arch).replace(
                max_seq=self.prompt_tokens + self.max_new_tokens)
            params = T.init_params(jax.random.PRNGKey(0), cfg)
            self._engine = (params, cfg)
        return self._engine

    def _tokenize(self, prompt: str, vocab: int) -> np.ndarray:
        """Byte-level tokens from the prompt *tail* (the mapping-data lines —
        the part that varies per (domain, stage)), fixed length so a batch
        needs no ragged padding."""
        raw = prompt.encode()[-self.prompt_tokens:]
        ids = np.frombuffer(raw, dtype=np.uint8).astype(np.int32) % vocab
        if len(ids) < self.prompt_tokens:
            ids = np.pad(ids, (self.prompt_tokens - len(ids), 0))
        return ids

    @staticmethod
    def _detokenize(ids) -> str:
        return "".join(chr(i) if 32 <= i < 127 else " "
                       for i in np.asarray(ids).tolist())

    def generate(self, prompt: str, *, meta: dict) -> LLMResponse:
        return self.generate_batch([prompt], [meta])[0]

    def generate_batch(self, prompts: list[str],
                       metas: list[dict]) -> list[LLMResponse]:
        """One padded prefill + shared decode loop for the whole group."""
        import time

        import jax.numpy as jnp

        from repro.core import synthesis
        from repro.serving import engine

        params, cfg = self._ensure_engine()
        toks = np.stack([self._tokenize(p, cfg.vocab_size) for p in prompts])
        t0 = time.monotonic()
        res = engine.generate(params, cfg, jnp.asarray(toks),
                              self.max_new_tokens,
                              temperature=self.temperature, seed=self.seed)
        per_seconds = (time.monotonic() - t0) / len(prompts)
        sampled = np.asarray(res.tokens)[:, self.prompt_tokens:]
        out = []
        for prompt, meta, row in zip(prompts, metas, sampled):
            text = self._detokenize(row)
            try:
                synthesis.synthesize(text)
            except synthesis.SynthesisError:
                # the smoke model can't derive maps — fall back to the
                # canonical derivation so downstream stages stay live
                text = f"```python\n{canonical_code(meta['domain'])}```"
            out.append(LLMResponse(
                text=text, model=self.name,
                tokens_in=toks.shape[1], tokens_out=int(res.steps),
                seconds=per_seconds, joules=per_seconds * self.power_w,
            ))
        return out


class OllamaBackend:
    """Production wiring for real local GGUF models (offline-unavailable)."""

    def __init__(self, model: str, host: str = "http://localhost:11434",
                 power_w: float = 1000.0):
        self.name = model
        self.host = host
        self.power_w = power_w

    def generate(self, prompt: str, *, meta: dict) -> LLMResponse:
        import socket
        import time
        import urllib.error
        import urllib.request

        body = json.dumps(
            {"model": self.name, "prompt": prompt, "stream": False}
        ).encode()
        req = urllib.request.Request(
            f"{self.host}/api/generate", data=body,
            headers={"Content-Type": "application/json"},
        )
        t0 = time.monotonic()
        try:
            with urllib.request.urlopen(req, timeout=600) as resp:  # noqa: S310
                payload = json.loads(resp.read())
        except (TimeoutError, socket.timeout) as e:
            raise LLMTimeoutError(
                f"ollama generate on {self.name!r} timed out") from e
        except urllib.error.URLError as e:
            if isinstance(e.reason, (TimeoutError, socket.timeout)):
                raise LLMTimeoutError(
                    f"ollama generate on {self.name!r} timed out") from e
            raise LLMUnavailableError(
                f"ollama at {self.host} unreachable: {e.reason}") from e
        dt = time.monotonic() - t0
        return LLMResponse(
            text=payload.get("response", ""), model=self.name,
            tokens_in=payload.get("prompt_eval_count", 0),
            tokens_out=payload.get("eval_count", 0),
            seconds=dt, joules=dt * self.power_w,
        )


def response_fingerprint(resp: LLMResponse) -> str:
    return hashlib.sha256(resp.text.encode()).hexdigest()[:16]
