"""Core library: the paper's contribution — automated derivation and
deployment of exact thread-mapping functions for non-box domains."""
from repro.core.artifact import (  # noqa: F401
    ArtifactCache, MappingArtifact, cache_key, default_cache,
)
from repro.core.store import (  # noqa: F401
    ArtifactStore, DiskStore, MemoryStore, PeerStore, TieredStore,
    build_store, default_store,
)
from repro.core.domains import DOMAINS, Domain, get_domain  # noqa: F401
from repro.core.maps import SCALAR_MAPS, VARIANT_MAPS, jnp_map, np_map  # noqa: F401
from repro.core.pipeline import (  # noqa: F401
    DerivationResult, derive_mapping, run_grid,
)
from repro.core.registry import (  # noqa: F401
    REGISTRY, MapEntry, MapRegistry, get_registry, register_map,
)
from repro.core.validate import ValidationReport  # noqa: F401
