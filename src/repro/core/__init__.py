"""Core library: the paper's contribution — automated derivation and
deployment of exact thread-mapping functions for non-box domains."""
from repro.core.domains import DOMAINS, Domain, get_domain  # noqa: F401
from repro.core.maps import SCALAR_MAPS, VARIANT_MAPS, jnp_map, np_map  # noqa: F401
from repro.core.pipeline import DerivationResult, derive_mapping  # noqa: F401
from repro.core.validate import ValidationReport  # noqa: F401
