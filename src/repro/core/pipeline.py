"""The four-phase automated discovery pipeline (Fig. 3), as composable
stages.

  1. Context Sampling      — first N domain points (N in {20, 50, 100}),
  2. Symbolic Inference    — backend.generate over the Appendix-A prompt,
  3. Algorithmic Synthesis — code extraction + sandboxed compile + rule check,
  4. Integration           — validated map handed to the deployment layer
                             (Pallas index_map / block-space kernels) as a
                             MappingArtifact.

Each phase is an explicit stage function — ``prepare_request`` (phases 1+2
prep: context sampling, prompt, content address), ``stage_inference``,
``stage_synthesis``, ``stage_validation`` — and ``derive_mapping`` is their
composition plus the cache check.  The stages are what ``serving/
map_service.py`` fronts with locking and request coalescing; the content
address computed by ``prepare_request`` is the coalescing key, so the local
path and the served path can never disagree on cache identity.

Derivation is a one-time upfront investment: every cell is content-addressed
(domain + model + stage + prompt + validation spec) into the artifact cache,
so a repeated ``derive_mapping`` call is served from disk with zero backend
``generate`` calls and zero re-validation.  ``run_grid`` sweeps whole
(domain x model x stage) grids through the same cache.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core import complexity, energy, synthesis, validate
from repro.core.artifact import MappingArtifact, cache_key, logic_for
from repro.core.backends import LLMBackend, LLMResponse, build_prompt
from repro.core.domains import DOMAINS, Domain
from repro.core.store import ArtifactStore, default_store
from repro.obs import trace as obs_trace

_USE_DEFAULT_CACHE = object()  # sentinel: "resolve default_store() at call"


@dataclasses.dataclass
class DerivationResult:
    domain: str
    model: str
    stage: int
    response: LLMResponse
    compiled: bool
    source: str | None
    report: validate.ValidationReport
    complexity_class: str | None
    wall_seconds: float
    inference_joules: float
    domainobj: Domain
    error: str | None = None
    cache_hit: bool = False
    cache_key: str | None = None
    created_unix: float = dataclasses.field(default_factory=time.time)

    @property
    def perfect(self) -> bool:
        return self.compiled and self.report.ordered >= 1.0

    @property
    def silver(self) -> bool:  # geometry right, order permuted
        return self.compiled and not self.perfect and self.report.any_order >= 0.999

    @property
    def logic(self) -> str:
        """Calibrated logic class implied by the measured complexity."""
        return logic_for(self.complexity_class, self.domainobj)

    @functools.cached_property
    def artifact(self) -> MappingArtifact | None:
        """The persistent product of this derivation (None if it failed).
        Memoized so repeated access shares one instance (and its compiled
        scalar callable)."""
        if not self.compiled or self.source is None:
            return None
        return MappingArtifact(
            domain=self.domain, model=self.model, stage=self.stage,
            source=self.source, complexity_class=self.complexity_class,
            report=self.report, inference_joules=self.inference_joules,
            inference_seconds=self.wall_seconds, cache_key=self.cache_key,
            created_unix=self.created_unix,
        )

    def amortization(self, n_points: int = 500_000_000):
        if not self.compiled or self.complexity_class is None:
            return None
        return energy.amortization(self.domainobj, self.logic,
                                   self.inference_joules, n_points)


# ---------------------------------------------------------------------------
# Cache record <-> result
# ---------------------------------------------------------------------------


def _record_from_result(res: DerivationResult) -> dict:
    r = res.response
    return {
        "domain": res.domain, "model": res.model, "stage": res.stage,
        "compiled": res.compiled, "source": res.source, "error": res.error,
        "complexity_class": res.complexity_class,
        "wall_seconds": res.wall_seconds,
        "report": dataclasses.asdict(res.report),
        "response": {
            "text": r.text, "model": r.model, "tokens_in": r.tokens_in,
            "tokens_out": r.tokens_out, "seconds": r.seconds,
            "joules": r.joules,
        },
        "created_unix": res.created_unix,
    }


def _result_from_record(rec: dict, domain: Domain, key: str) -> DerivationResult:
    """Rehydrate a cached derivation record (the serving layer's read path)."""
    return DerivationResult(
        domain=rec["domain"], model=rec["model"], stage=rec["stage"],
        response=LLMResponse(**rec["response"]),
        compiled=rec["compiled"], source=rec["source"],
        report=validate.ValidationReport(**rec["report"]),
        complexity_class=rec["complexity_class"],
        wall_seconds=rec["wall_seconds"],
        inference_joules=rec["response"]["joules"],
        domainobj=domain, error=rec["error"],
        cache_hit=True, cache_key=key,
        created_unix=rec.get("created_unix", 0.0),
    )


#: public names for the serving layer (same record schema, one code path)
record_from_result = _record_from_result
result_from_record = _result_from_record


# ---------------------------------------------------------------------------
# JSON wire schema (the HTTP serving layer's payloads)
# ---------------------------------------------------------------------------

#: bumped when the wire payload shape changes; the client refuses a
#: mismatched server rather than mis-parsing it.
WIRE_VERSION = 1


def wire_from_result(res: DerivationResult) -> dict:
    """One served cell as a self-describing JSON payload: the derivation
    record (the exact schema the cache stores) plus the envelope the remote
    client needs — content address, whether the server resolved it from its
    store, and the artifact record when the cell is deployable."""
    art = res.artifact
    return {
        "wire": WIRE_VERSION,
        "key": res.cache_key,
        "cache_hit": res.cache_hit,
        "record": _record_from_result(res),
        "artifact": art.to_record() if art is not None else None,
    }


def result_from_wire(payload: dict, domain: Domain | None = None) -> DerivationResult:
    """Rehydrate a wire payload into a DerivationResult (the remote client's
    read path).  The domain object is resolved locally — client and server
    share the domain registry, and the content address in the payload ties
    the record to the exact (domain, model, stage, prompt) cell."""
    if payload.get("wire") != WIRE_VERSION:
        raise ValueError(
            f"wire version mismatch: got {payload.get('wire')!r}, "
            f"want {WIRE_VERSION}")
    rec = payload["record"]
    if domain is None:
        domain = DOMAINS[rec["domain"]]
    res = _result_from_record(rec, domain, payload["key"])
    res.cache_hit = bool(payload["cache_hit"])
    return res


# ---------------------------------------------------------------------------
# Composable stages (one cell = prepare -> inference -> synthesis ->
# validation; the cache check wraps the whole chain)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DerivationRequest:
    """One fully-addressed pipeline cell: everything phases 2-4 need, plus
    the content address that identifies it in the cache and in the serving
    layer's coalescing table."""

    domain: Domain
    backend: LLMBackend
    stage: int
    n_validate: int
    sample_every: int
    prompt: str
    key: str


def prepare_request(
    domain: Domain,
    backend: LLMBackend,
    stage: int = 100,
    n_validate: int = 1_000_000,
    sample_every: int = 1,
) -> DerivationRequest:
    """Phases 1+2 prep: sample context, build the Appendix-A prompt, and
    content-address the cell.  The prompt is part of the address, so a
    prompt-template change invalidates the cache; backends may expose a
    content fingerprint (e.g. the mock replay bank) so behavior edits
    invalidate their cached cells too."""
    prompt = build_prompt(domain, stage)
    key = cache_key(domain.name, backend.name, stage, prompt,
                    n_validate=n_validate, sample_every=sample_every,
                    backend_fingerprint=getattr(backend, "cache_fingerprint",
                                                None))
    return DerivationRequest(domain=domain, backend=backend, stage=stage,
                             n_validate=n_validate, sample_every=sample_every,
                             prompt=prompt, key=key)


def stage_inference(req: DerivationRequest) -> LLMResponse:
    """Phase 2 — Symbolic Inference over the prepared prompt."""
    # the meta dict additionally snapshots the active request trace so a
    # shared batcher thread can attribute its generate work (see obs.trace)
    with obs_trace.span("inference", model=req.backend.name):
        return req.backend.generate(
            req.prompt, meta={"domain": req.domain.name, "stage": req.stage,
                              **obs_trace.meta_context()})


def stage_synthesis(resp: LLMResponse) -> synthesis.SynthesizedMap:
    """Phase 3 — extraction + rule check + sandboxed compile (raises
    ``SynthesisError`` => the cell is (NC))."""
    return synthesis.synthesize(resp.text)


def stage_validation(
    req: DerivationRequest,
    synth: synthesis.SynthesizedMap,
    gt: np.ndarray | None = None,
) -> tuple[validate.ValidationReport, str | None]:
    """Phase 3b — the paper's 10^6-point ground-truth check, plus the
    complexity classification of validated candidates."""
    rep = validate.validate_scalar_fn(
        synth.fn, req.domain, n_points=req.n_validate, gt=gt,
        sample_every=req.sample_every)
    cls = complexity.classify(synth.fn)["class"] if rep.error is None else None
    return rep, cls


def run_stages(
    req: DerivationRequest,
    gt: np.ndarray | Callable[[], np.ndarray] | None = None,
) -> DerivationResult:
    """Phases 2-4 for one prepared cell (no cache interaction)."""
    t0 = time.monotonic()
    resp = stage_inference(req)
    try:
        synth = stage_synthesis(resp)
    except synthesis.SynthesisError as e:
        return DerivationResult(
            domain=req.domain.name, model=req.backend.name, stage=req.stage,
            response=resp, compiled=False, source=None,
            report=validate.FAILED(req.n_validate, str(e)),
            complexity_class=None, wall_seconds=time.monotonic() - t0,
            inference_joules=resp.joules, domainobj=req.domain,
            error=str(e), cache_key=req.key,
        )
    if callable(gt):
        gt = gt()
    with obs_trace.span("validation", n_points=req.n_validate):
        rep, cls = stage_validation(req, synth, gt)
    return DerivationResult(
        domain=req.domain.name, model=req.backend.name, stage=req.stage,
        response=resp, compiled=True, source=synth.source, report=rep,
        complexity_class=cls, wall_seconds=time.monotonic() - t0,
        inference_joules=resp.joules, domainobj=req.domain, cache_key=req.key,
    )


def derive_mapping(
    domain: Domain,
    backend: LLMBackend,
    stage: int = 100,
    n_validate: int = 1_000_000,
    gt: np.ndarray | Callable[[], np.ndarray] | None = None,
    sample_every: int = 1,
    cache: ArtifactStore | None = _USE_DEFAULT_CACHE,  # type: ignore[assignment]
) -> DerivationResult:
    """Run the full pipeline for one (domain, model, stage) cell.

    ``cache`` accepts any :class:`~repro.core.store.ArtifactStore` and
    defaults to the process-wide tiered store (``store.default_store()``):
    library callers and the served path share one memory -> disk -> peer
    scheme, so a cell derived here is a hot memory hit for the service and
    vice versa.  Pass ``cache=None`` to force a live derivation.  ``gt``
    may be the ground-truth array or a zero-arg callable producing it — the
    callable is only invoked on a cache miss, so cached sweeps never
    enumerate."""
    if cache is _USE_DEFAULT_CACHE:
        cache = default_store()
    req = prepare_request(domain, backend, stage, n_validate, sample_every)
    if cache is not None:
        rec = cache.load(req.key)
        if rec is not None:
            return _result_from_record(rec, domain, req.key)
    res = run_stages(req, gt)
    if cache is not None:
        cache.store(req.key, _record_from_result(res))
    return res


# ---------------------------------------------------------------------------
# Grid orchestrator
# ---------------------------------------------------------------------------


def run_grid(
    domains: Iterable[str] | None = None,
    models: Iterable[str] | None = None,
    stages: Sequence[int] | None = None,
    *,
    backend_factory: Callable[[str], LLMBackend] | None = None,
    n_validate: int = 100_000,
    sample_every: int = 50,
    cache: ArtifactStore | None = _USE_DEFAULT_CACHE,  # type: ignore[assignment]
    progress: Callable[[DerivationResult], None] | None = None,
) -> dict[tuple[str, str, int], DerivationResult]:
    """Sweep every (domain x model x stage) cell through the artifact cache.

    Ground truth is enumerated once per domain and shared across the sweep;
    cells already in the cache cost one JSON read.  Defaults sweep the
    paper's measured grid (the six Table-II..VII domains x 11 models x 3
    stages); extension domains (m-simplex, embedded fractals) are swept by
    passing them explicitly.  Returns a dict keyed (domain, model, stage)."""
    from repro.core import paper_tables as pt
    from repro.core.backends import MockLLMBackend

    domains = list(domains) if domains is not None else sorted(pt.ACCURACY)
    models = list(models) if models is not None else list(pt.MODELS)
    stages = list(stages) if stages is not None else list(pt.STAGES)
    backend_factory = backend_factory or MockLLMBackend
    if cache is _USE_DEFAULT_CACHE:
        cache = default_store()

    out: dict[tuple[str, str, int], DerivationResult] = {}
    for dom_name in domains:
        dom = DOMAINS[dom_name] if isinstance(dom_name, str) else dom_name
        gt_memo: dict[str, np.ndarray] = {}

        def lazy_gt(d=dom):  # enumerated once per domain, only on a miss
            if "gt" not in gt_memo:
                gt_memo["gt"] = d.enumerate_points(n_validate)
            return gt_memo["gt"]

        for model in models:
            backend = backend_factory(model)
            for stage in stages:
                res = derive_mapping(
                    dom, backend, stage, n_validate=n_validate, gt=lazy_gt,
                    sample_every=sample_every, cache=cache)
                out[(dom.name, model, stage)] = res
                if progress is not None:
                    progress(res)
    return out
