"""The four-phase automated discovery pipeline (Fig. 3).

  1. Context Sampling      — first N domain points (N in {20, 50, 100}),
  2. Symbolic Inference    — backend.generate over the Appendix-A prompt,
  3. Algorithmic Synthesis — code extraction + sandboxed compile + rule check,
  4. Integration           — validated map handed to the deployment layer
                             (Pallas index_map / block-space kernels).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import complexity, energy, synthesis, validate
from repro.core.backends import LLMBackend, LLMResponse, build_prompt
from repro.core.domains import Domain


@dataclasses.dataclass
class DerivationResult:
    domain: str
    model: str
    stage: int
    response: LLMResponse
    compiled: bool
    source: str | None
    report: validate.ValidationReport
    complexity_class: str | None
    wall_seconds: float
    inference_joules: float
    error: str | None = None

    @property
    def perfect(self) -> bool:
        return self.compiled and self.report.ordered >= 1.0

    @property
    def silver(self) -> bool:  # geometry right, order permuted
        return self.compiled and not self.perfect and self.report.any_order >= 0.999

    def amortization(self, n_points: int = 500_000_000):
        if not self.compiled or self.complexity_class is None:
            return None
        # map complexity class back onto the calibrated logic table
        logic = {
            "O(1)": "analytical",
            "O(log N)": "binsearch" if self.domainobj.kind == "dense" else "bitwise",
            "O(N^1/3)": "linear",
            "O(N^1/2)": "linear",
            "O(N)": "linear",
        }[self.complexity_class]
        return energy.amortization(self.domainobj, logic, self.inference_joules,
                                   n_points)

    domainobj: Domain = None  # set by derive_mapping


def derive_mapping(
    domain: Domain,
    backend: LLMBackend,
    stage: int = 100,
    n_validate: int = 1_000_000,
    gt: np.ndarray | None = None,
    sample_every: int = 1,
) -> DerivationResult:
    """Run the full pipeline for one (domain, model, stage) cell."""
    t0 = time.monotonic()
    # Phase 1+2: sample context, build prompt, call the model
    prompt = build_prompt(domain, stage)
    resp = backend.generate(prompt, meta={"domain": domain.name, "stage": stage})
    # Phase 3: synthesis
    try:
        synth = synthesis.synthesize(resp.text)
    except synthesis.SynthesisError as e:
        rep = validate.FAILED(n_validate, str(e))
        res = DerivationResult(
            domain=domain.name, model=backend.name, stage=stage, response=resp,
            compiled=False, source=None, report=rep, complexity_class=None,
            wall_seconds=time.monotonic() - t0, inference_joules=resp.joules,
            error=str(e),
        )
        res.domainobj = domain
        return res
    # Phase 3b: validation against ground truth (the paper's 10^6-point check)
    rep = validate.validate_scalar_fn(
        synth.fn, domain, n_points=n_validate, gt=gt, sample_every=sample_every
    )
    cls = complexity.classify(synth.fn)["class"] if rep.error is None else None
    res = DerivationResult(
        domain=domain.name, model=backend.name, stage=stage, response=resp,
        compiled=True, source=synth.source, report=rep, complexity_class=cls,
        wall_seconds=time.monotonic() - t0, inference_joules=resp.joules,
    )
    res.domainobj = domain
    return res
