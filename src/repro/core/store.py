"""Tiered ArtifactStore — the storage layer behind derivation serving.

The paper's economics are "derive once, serve forever": the one-time LLM
inference cost amortizes only while derived mappings stay cheap to fetch
under heavy traffic.  This module turns the original flat on-disk cache
into an :class:`ArtifactStore` interface with composable tiers:

  * :class:`MemoryStore`  — bounded LRU of parsed records (plus the
    rehydrated ``DerivationResult`` once a consumer attaches it), so a hot
    hit costs a dict lookup: no disk I/O, no JSON parse, no rehydration;
  * :class:`DiskStore`    — the content-addressed directory, now with a
    schema-versioned + checksummed record format, TTL and max-bytes
    eviction driven by an access-time index, quarantine of
    checksum-mismatched records, and in-place migration of pre-versioned
    (schema 1) records;
  * :class:`PeerStore`    — read-through fetch from sibling servers over
    the HTTP surface (``GET /v1/replicate/<key>``), with best-effort
    write-back push (``POST /v1/replicate/<key>``) on local publish, so a
    fleet of servers shares one derivation;
  * :class:`TieredStore`  — memory -> disk -> peers composition with
    read-through promotion and per-tier hit/miss/eviction stats.

Record format (schema 2):  ``{"schema": 2, "key": <addr>, "checksum":
sha256(payload), ...payload}`` — the checksum covers everything except the
envelope fields, so silent corruption is detected (and quarantined) rather
than served.  Schema-1 records (pre-PR-4 caches) are migrated on first
read.

All I/O degrades gracefully: a read-only disk, an unreachable peer, or a
corrupt record each behave like a miss — never an exception on the serving
path.
"""
from __future__ import annotations

import collections
import hashlib
import json
import os
import re
import tempfile
import threading
import time
import urllib.error
import urllib.request
import warnings
from pathlib import Path
from typing import Any, Callable, Iterable

from repro.obs import trace as obs_trace

SCHEMA_VERSION = 2
_ENVELOPE_FIELDS = ("schema", "key", "checksum")

# a writer holds a publish .tmp for milliseconds; one this old was
# abandoned by a crashed process and is reclaimable garbage
_ORPHAN_TMP_SECONDS = 3600.0

# Content addresses are sha256 hex digests (see cache_key) — anything else
# is rejected before it can reach a filesystem path, so a wire-supplied key
# like "../../etc/passwd" can never escape a store root.
KEY_RE = re.compile(r"[0-9a-f]{64}")


def valid_key(key: str) -> bool:
    """True iff ``key`` is a well-formed content address."""
    return isinstance(key, str) and KEY_RE.fullmatch(key) is not None


def cache_key(domain: str, model: str, stage: int, prompt: str,
              **extra: Any) -> str:
    """Content address of one derivation cell."""
    payload = {
        "domain": domain, "model": model, "stage": stage,
        "prompt_sha256": hashlib.sha256(prompt.encode()).hexdigest(),
        **extra,
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def record_checksum(record: dict[str, Any]) -> str:
    """sha256 over the record payload (envelope fields excluded), so the
    checksum survives re-keying and schema stamping."""
    payload = {k: v for k, v in record.items() if k not in _ENVELOPE_FIELDS}
    blob = json.dumps(payload, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()


def verify_envelope(key: str, rec: Any) -> bool:
    """The full envelope check applied at every trust boundary (peer
    reads, replication pushes): a dict stamped with the current schema,
    keyed as expected, whose checksum matches its payload.  One helper so
    a future schema bump can't weaken one boundary while tightening
    another."""
    return (isinstance(rec, dict)
            and rec.get("schema") == SCHEMA_VERSION
            and rec.get("key") == key
            and rec.get("checksum") == record_checksum(rec))


def finalize_record(key: str, record: dict[str, Any]) -> dict[str, Any]:
    """Stamp the storage envelope (schema version, key, payload checksum).
    Idempotent: an already-finalized record passes through untouched, so
    tier-to-tier promotion never re-hashes."""
    if (record.get("schema") == SCHEMA_VERSION and record.get("key") == key
            and "checksum" in record):
        return record
    payload = {k: v for k, v in record.items() if k not in _ENVELOPE_FIELDS}
    return {"schema": SCHEMA_VERSION, "key": key,
            "checksum": record_checksum(payload), **payload}


# ---------------------------------------------------------------------------
# File locking — many clients, one artifact store
# ---------------------------------------------------------------------------


class FileLock:
    """Advisory cross-process lock: an O_CREAT|O_EXCL sentinel file.

    Combined with the store's atomic-rename publish this makes the store
    safe for concurrent writers: the lock serializes *derivation* of one key
    across processes while readers stay lock-free (they only ever see a
    fully-published record or a miss).

    Ownership: each acquirer writes a unique token into the sentinel.  A
    heartbeat thread refreshes the sentinel's mtime while held, so only a
    genuinely crashed holder ever looks stale; a stale lock is broken by
    atomic rename (exactly one contender wins the break), and ``release``
    verifies the token so a holder whose lock *was* broken never deletes the
    next holder's sentinel.  All I/O degrades gracefully — an unwritable
    store yields an unlocked no-op lock, matching the store's read-only
    degradation."""

    def __init__(self, path: str | Path, timeout: float = 30.0,
                 poll: float = 0.02, stale_seconds: float = 60.0):
        self.path = Path(path)
        self.timeout = timeout
        self.poll = poll
        self.stale_seconds = stale_seconds
        self.locked = False
        self.broke_stale = False
        self.token = f"{os.getpid()}-{os.urandom(8).hex()}"
        self._hb_stop: "threading.Event | None" = None
        self._hb_thread: "threading.Thread | None" = None

    def acquire(self) -> "FileLock":
        deadline = time.monotonic() + self.timeout
        while True:
            created = False
            try:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                fd = os.open(self.path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                created = True
                with os.fdopen(fd, "w") as f:
                    f.write(self.token)
                self.locked = True
                self._start_heartbeat()
                return self
            except FileExistsError:
                if self._break_if_stale():
                    continue
                if time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"lock {self.path} held past {self.timeout}s "
                        f"(stale threshold {self.stale_seconds}s)")
                time.sleep(self.poll)
            except OSError:
                # unwritable store: proceed unlocked (read-only degradation);
                # never leave an ownerless sentinel behind if the open
                # succeeded but the token write failed (e.g. ENOSPC)
                if created:
                    try:
                        self.path.unlink()
                    except OSError:
                        pass
                return self

    def _start_heartbeat(self) -> None:
        """Refresh the sentinel's mtime while held, so contenders never
        mistake a long-running live derivation for a crashed holder."""
        self._hb_stop = stop = threading.Event()
        interval = max(self.stale_seconds / 4.0, 0.05)

        def beat(path=self.path):
            while not stop.wait(interval):
                try:
                    os.utime(path)
                except OSError:
                    return  # lock gone (broken or released) — stop beating

        self._hb_thread = threading.Thread(
            target=beat, name=f"filelock-hb-{self.path.name}", daemon=True)
        self._hb_thread.start()

    def _break_if_stale(self) -> bool:
        try:
            age = time.time() - self.path.stat().st_mtime
        except OSError:
            return True  # holder released between our open and stat
        if age <= self.stale_seconds:
            return False
        # atomic rename: of N contenders observing the same stale sentinel,
        # exactly one wins the break — the losers see ENOENT and re-contend
        # without ever touching the winner's fresh lock.
        grave = self.path.with_name(
            f"{self.path.name}.stale-{os.urandom(4).hex()}")
        try:
            os.replace(self.path, grave)
        except OSError:
            return True  # someone else broke or released it first
        self.broke_stale = True
        try:
            grave.unlink()
        except OSError:
            pass
        return True

    def release(self) -> None:
        if not self.locked:
            return
        self.locked = False
        if self._hb_stop is not None:
            self._hb_stop.set()
            self._hb_thread.join()
        try:
            if self.path.read_text() == self.token:  # still ours?
                self.path.unlink()
        except OSError:
            pass

    def __enter__(self) -> "FileLock":
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()


class NullLock:
    """No-op stand-in for stores without a disk tier (same surface as
    :class:`FileLock` so the serving layer never branches)."""

    locked = False
    broke_stale = False

    def acquire(self) -> "NullLock":
        return self

    def release(self) -> None:
        pass

    def __enter__(self) -> "NullLock":
        return self

    def __exit__(self, *exc) -> None:
        pass


# ---------------------------------------------------------------------------
# The store interface
# ---------------------------------------------------------------------------


class ArtifactStore:
    """Interface every tier implements.  Values are JSON-able derivation
    records (see ``pipeline.record_from_result``); keys are content
    addresses from :func:`cache_key`.  All methods are miss-on-failure."""

    def load(self, key: str) -> dict[str, Any] | None:
        raise NotImplementedError

    def store(self, key: str, record: dict[str, Any]):
        raise NotImplementedError

    def delete(self, key: str) -> bool:
        return False

    def clear(self) -> int:
        return 0

    def stats(self) -> dict[str, Any]:
        return {}

    def keys(self) -> list[str]:
        """The tier's resident content addresses (the anti-entropy manifest
        surface).  Remote tiers return [] — a manifest is always local."""
        return []

    # -- optional fast paths (memory tier) ---------------------------------
    def load_result(self, key: str):
        """Rehydrated-result fast path: the object a previous consumer
        attached via :meth:`remember_result`, or None."""
        return None

    def remember_result(self, key: str, result) -> None:
        """Attach a rehydrated result to a resident entry (no-op unless a
        memory tier holds the key)."""

    def note_access(self, key: str) -> None:
        """Record access recency without serving the record (no-op unless
        the tier keeps an eviction index, like :class:`DiskStore`)."""

    # -- optional coordination (disk tier) ---------------------------------
    def lock(self, key: str, timeout: float = 30.0,
             stale_seconds: float = 60.0):
        return NullLock()

    def __contains__(self, key: str) -> bool:
        return self.load(key) is not None


# ---------------------------------------------------------------------------
# MemoryStore — bounded LRU hot tier
# ---------------------------------------------------------------------------


class _MemEntry:
    __slots__ = ("record", "result")

    def __init__(self, record: dict[str, Any]):
        self.record = record
        self.result = None  # rehydrated DerivationResult, attached lazily


class MemoryStore(ArtifactStore):
    """Bounded LRU of parsed records + rehydrated results.

    A hot hit here skips disk I/O and JSON parsing entirely; once the
    serving layer attaches the rehydrated result it also skips dataclass
    reconstruction.  ``max_entries <= 0`` disables the tier (every load is
    a miss, stores are dropped) so one code path serves memory-less
    configurations."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._entries: "collections.OrderedDict[str, _MemEntry]" = \
            collections.OrderedDict()
        self._mu = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.result_hits = 0
        self.evictions = 0

    def load(self, key: str) -> dict[str, Any] | None:
        with self._mu:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return entry.record

    def load_result(self, key: str):
        with self._mu:
            entry = self._entries.get(key)
            if entry is None or entry.result is None:
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            self.result_hits += 1
            return entry.result

    def remember_result(self, key: str, result) -> None:
        with self._mu:
            entry = self._entries.get(key)
            if entry is not None:
                entry.result = result

    def store(self, key: str, record: dict[str, Any]) -> None:
        if self.max_entries <= 0:
            return
        record = finalize_record(key, record)
        with self._mu:
            self._entries.pop(key, None)
            self._entries[key] = _MemEntry(record)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)  # least recently used
                self.evictions += 1

    def delete(self, key: str) -> bool:
        with self._mu:
            return self._entries.pop(key, None) is not None

    def clear(self) -> int:
        with self._mu:
            n = len(self._entries)
            self._entries.clear()
            return n

    def keys(self) -> list[str]:
        """LRU order, least-recent first (test/introspection surface)."""
        with self._mu:
            return list(self._entries)

    def __len__(self) -> int:
        with self._mu:
            return len(self._entries)

    def __contains__(self, key: str) -> bool:
        with self._mu:
            return key in self._entries

    def stats(self) -> dict[str, Any]:
        with self._mu:
            entries = len(self._entries)
        return {"hits": self.hits, "misses": self.misses,
                "result_hits": self.result_hits, "evictions": self.evictions,
                "entries": entries, "max_entries": self.max_entries}


# ---------------------------------------------------------------------------
# DiskStore — versioned, checksummed, evicting content-addressed directory
# ---------------------------------------------------------------------------


class DiskStore(ArtifactStore):
    """Content-addressed on-disk store of derivation records.

    Layout:     ``<root>/<key>.json`` (schema-2 records: envelope + payload
    checksum); ``<key>.lock`` writer sentinels; ``<key>.quarantined``
    checksum-mismatched records set aside for inspection.

    Lifecycle:  ``ttl_seconds`` evicts records idle past the threshold;
    ``max_bytes`` evicts least-recently-accessed records until the store
    fits.  Both are driven by an access-time index: in-process access times
    backed by the record file's mtime (``load`` touches the file, so access
    recency survives process restarts and is shared across processes).
    Eviction runs opportunistically after each publish and via ``evict()``.

    Migration:  schema-1 records (pre-checksum caches) are upgraded in
    place on first read.  Unknown future schemas are a miss.

    All I/O degrades gracefully: a read-only or corrupt store behaves like
    a miss."""

    def __init__(self, root: str | Path | None = None,
                 ttl_seconds: float | None = None,
                 max_bytes: int | None = None):
        if root is None:
            # a constructor can't opt out — under REPRO_ARTIFACT_CACHE=off
            # the *callers* (build_store/default_store) return None instead
            root = resolve_root() or (
                Path.home() / ".cache" / "repro_thread_maps")
        self.root = Path(root)
        self.ttl_seconds = ttl_seconds
        self.max_bytes = max_bytes
        self._access: dict[str, float] = {}
        # eviction amortization: approximate on-disk byte total maintained
        # incrementally (None = unknown, next publish runs a full scan) and
        # the time of the last TTL sweep — so a publish is O(1) unless a
        # budget may actually be exceeded (see store()/evict())
        self._approx_bytes: int | None = None
        self._last_ttl_scan = 0.0
        self._mu = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.reads = 0          # actual record-file reads (hot-path probe)
        self.migrated = 0
        self.quarantined = 0
        self.evictions_ttl = 0
        self.evictions_bytes = 0
        self.deletes = 0

    # -- paths -------------------------------------------------------------
    def path(self, key: str) -> Path:
        return self.root / f"{key}.json"

    def lock(self, key: str, timeout: float = 30.0,
             stale_seconds: float = 60.0) -> FileLock:
        """Cross-process writer lock for one key (see :class:`FileLock`).
        Readers never need it — ``store`` publishes via atomic rename."""
        return FileLock(self.root / f"{key}.lock", timeout=timeout,
                        stale_seconds=stale_seconds)

    # -- read path ---------------------------------------------------------
    def load(self, key: str) -> dict[str, Any] | None:
        path = self.path(key)
        self.reads += 1
        try:
            rec = json.loads(path.read_text())
        except (OSError, ValueError):
            self.misses += 1
            return None
        schema = rec.get("schema")
        if schema == 1:
            rec = self._migrate(key, rec)
        elif schema != SCHEMA_VERSION:
            self.misses += 1
            return None
        elif rec.get("checksum") != record_checksum(rec):
            self._quarantine(key, path)
            self.misses += 1
            return None
        self._touch(key, path)
        self.hits += 1
        return rec

    def _migrate(self, key: str, rec: dict[str, Any]) -> dict[str, Any]:
        """Upgrade a schema-1 record in place: same payload, new envelope
        (version + checksum).  The rewrite is best-effort — a read-only
        store still serves the migrated record from memory."""
        payload = {k: v for k, v in rec.items() if k not in _ENVELOPE_FIELDS}
        rec = finalize_record(key, payload)
        self._publish(key, rec)
        self.migrated += 1
        return rec

    def _quarantine(self, key: str, path: Path) -> None:
        """Set a checksum-mismatched record aside (never serve, never
        silently destroy — the bytes may matter for diagnosis)."""
        try:
            os.replace(path, self.root / f"{key}.quarantined")
        except OSError:
            pass
        with self._mu:
            self._access.pop(key, None)
        self.quarantined += 1

    def _touch(self, key: str, path: Path) -> None:
        with self._mu:
            self._access[key] = time.time()
        try:
            os.utime(path)  # persist access recency across processes
        except OSError:
            pass

    def note_access(self, key: str) -> None:
        """Record access recency without any disk I/O (in-process index
        only).  The memory tier calls this on hot hits so the hottest
        records don't look coldest to this process's TTL/max-bytes
        eviction; cross-process recency still comes from ``load``'s mtime
        touch."""
        with self._mu:
            self._access[key] = time.time()

    # -- write path --------------------------------------------------------
    def store(self, key: str, record: dict[str, Any]) -> Path | None:
        record = finalize_record(key, record)
        prev_size = 0
        if self.max_bytes is not None:
            # a republish overwrites: count the delta, not the full size,
            # or the running total inflates until every publish scans
            try:
                prev_size = self.path(key).stat().st_size
            except OSError:
                prev_size = 0
        path = self._publish(key, record)
        if path is not None:
            now = time.time()
            with self._mu:
                self._access[key] = now
            if self._needs_evict_scan(path, now, prev_size):
                self.evict(now)
        return path

    def _needs_evict_scan(self, published: Path, now: float,
                          prev_size: int) -> bool:
        """Whether this publish must pay a full directory sweep.  The
        running byte total and last-TTL-sweep clock keep the common case
        O(1): scan only when the approximate total may exceed the budget,
        a TTL window has elapsed since the last sweep, or the total is
        unknown (first publish / after clear())."""
        if self.ttl_seconds is None and self.max_bytes is None:
            return False
        if self.ttl_seconds is not None \
                and now - self._last_ttl_scan >= self.ttl_seconds:
            return True
        if self.max_bytes is None:
            return False
        try:
            size = published.stat().st_size
        except OSError:
            return True  # can't track incrementally — fall back to a scan
        with self._mu:
            if self._approx_bytes is None:
                return True
            self._approx_bytes += size - prev_size
            return self._approx_bytes > self.max_bytes

    def _publish(self, key: str, record: dict[str, Any]) -> Path | None:
        path = self.path(key)
        tmp = None
        published = False
        try:
            self.root.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                # default=str matches record_checksum's serialization, so any
                # value that checksummed is also publishable; the broad except
                # keeps the never-raise contract for whatever still slips
                # through (e.g. a circular payload)
                json.dump(record, f, indent=1, default=str)
            os.replace(tmp, path)  # atomic publish
            published = True
        except (OSError, TypeError, ValueError):
            return None
        finally:
            if tmp is not None and not published:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
        return path

    def delete(self, key: str) -> bool:
        path = self.path(key)
        try:
            size = path.stat().st_size
        except OSError:
            size = None
        with self._mu:
            self._access.pop(key, None)
        try:
            path.unlink()
        except OSError:
            return False
        with self._mu:
            if self._approx_bytes is not None:
                self._approx_bytes = (max(0, self._approx_bytes - size)
                                      if size is not None else None)
        self.deletes += 1
        return True

    # -- lifecycle ---------------------------------------------------------
    def evict(self, now: float | None = None) -> dict[str, int]:
        """Apply TTL then max-bytes eviction over the access-time index.
        Candidates are published ``*.json`` records and ``*.quarantined``
        files (the budget covers real disk use; quarantined bytes must not
        accumulate past it) — live ``.lock`` sentinels and in-flight
        ``.tmp`` files are never touched, but a ``.tmp`` abandoned by a
        crashed writer (hours old) is reclaimed so repeated crashes can't
        leak disk forever.  Under byte pressure quarantined files go first
        (they are never served), then records in least-recently-accessed
        order.  Returns per-policy counts."""
        now = time.time() if now is None else now
        removed = {"ttl": 0, "bytes": 0, "tmp": 0}
        self._last_ttl_scan = now
        try:
            for p in self.root.glob("*.tmp"):
                try:
                    if now - p.stat().st_mtime > _ORPHAN_TMP_SECONDS:
                        p.unlink()
                        removed["tmp"] += 1
                except OSError:
                    pass
        except OSError:
            pass

        def scan(pattern: str, indexed: bool) -> list | None:
            out = []
            try:
                globbed = list(self.root.glob(pattern))
            except OSError:
                return None
            for p in globbed:
                try:
                    st = p.stat()
                except OSError:
                    continue
                atime = st.st_mtime
                if indexed:
                    with self._mu:
                        atime = max(atime, self._access.get(p.stem, 0.0))
                out.append((atime, st.st_size, p.stem, p))
            return out

        records = scan("*.json", indexed=True)
        quarantined = scan("*.quarantined", indexed=False)
        if records is None or quarantined is None:
            return removed

        def drop(key: str, path: Path) -> bool:
            with self._mu:
                self._access.pop(key, None)
            try:
                path.unlink()
            except OSError:
                return False
            return True

        if self.ttl_seconds is not None:
            for bucket in (records, quarantined):
                survivors = []
                for atime, size, key, p in bucket:
                    if now - atime > self.ttl_seconds and drop(key, p):
                        removed["ttl"] += 1
                        self.evictions_ttl += 1
                        continue
                    survivors.append((atime, size, key, p))
                bucket[:] = survivors
        total = sum(size for bucket in (records, quarantined)
                    for _, size, _, _ in bucket)
        if self.max_bytes is not None:
            # quarantined first (oldest first), then records by LRA order
            for atime, size, key, p in sorted(quarantined) + sorted(records):
                if total <= self.max_bytes:
                    break
                if drop(key, p):
                    total -= size
                    removed["bytes"] += 1
                    self.evictions_bytes += 1
        with self._mu:
            self._approx_bytes = total  # exact again after a full sweep
        return removed

    def clear(self) -> int:
        """Drop every published record.  Live ``.lock`` sentinels and
        in-flight ``.tmp`` files are never touched — a concurrent writer's
        publish must not race a clear into a crash."""
        n = 0
        for p in self.root.glob("*.json"):
            try:
                p.unlink()
                n += 1
            except OSError:
                pass
        with self._mu:
            self._access.clear()
            self._approx_bytes = None  # quarantined files remain: rescan
        return n

    # -- introspection -----------------------------------------------------
    def usage(self) -> dict[str, int]:
        """Record + quarantine counts and byte totals (globs the directory
        — ops endpoint, not the metrics scrape path).  ``total_bytes`` is
        what the ``max_bytes`` budget is enforced against."""
        out = {"records": 0, "bytes": 0,
               "quarantined_records": 0, "quarantined_bytes": 0}
        for pattern, n_key, b_key in (
                ("*.json", "records", "bytes"),
                ("*.quarantined", "quarantined_records",
                 "quarantined_bytes")):
            try:
                for p in self.root.glob(pattern):
                    try:
                        out[b_key] += p.stat().st_size
                        out[n_key] += 1
                    except OSError:
                        pass
            except OSError:
                pass
        out["total_bytes"] = out["bytes"] + out["quarantined_bytes"]
        return out

    def keys(self) -> list[str]:
        """Published content addresses (quarantined records excluded — a
        manifest must only advertise records the node would actually serve)."""
        try:
            return sorted(p.stem for p in self.root.glob("*.json")
                          if valid_key(p.stem))
        except OSError:
            return []

    def __contains__(self, key: str) -> bool:
        return self.path(key).exists()

    def __len__(self) -> int:
        try:
            return sum(1 for _ in self.root.glob("*.json"))
        except OSError:
            return 0

    def stats(self) -> dict[str, Any]:
        return {"hits": self.hits, "misses": self.misses, "reads": self.reads,
                "migrated": self.migrated, "quarantined": self.quarantined,
                "evictions_ttl": self.evictions_ttl,
                "evictions_bytes": self.evictions_bytes,
                "deletes": self.deletes, "ttl_seconds": self.ttl_seconds,
                "max_bytes": self.max_bytes}


# ---------------------------------------------------------------------------
# PeerStore — replication across sibling servers
# ---------------------------------------------------------------------------


class PeerStore(ArtifactStore):
    """Read-through fetch from sibling mapping servers, with best-effort
    write-back push on local publish.

    ``load`` asks each peer's replication-pull endpoint in order and
    returns the first checksum-verified record; every failure mode
    (unreachable peer, 404, corrupt payload) moves on to the next peer and
    ultimately degrades to a miss.  ``store`` POSTs the freshly-published
    record so siblings converge without waiting for a pull; push failures
    are counted, never raised — replication is an optimization, not a
    correctness requirement.

    Topology comes from one of two places: the static ``peers`` list (PR 4's
    broadcast mesh — every pull probes everyone, every push lands
    everywhere) or, when a ``router`` is attached
    (:meth:`repro.serving.cluster.ClusterMembership.replica_peers`), the
    consistent-hash ring: pulls route to the key's owners and pushes are
    scoped to the K replicas instead of the whole fleet.  The router is
    authoritative while set — an empty owner list means "nobody else should
    hold this key", not "fall back to broadcasting"."""

    def __init__(self, peers: Iterable[str] = (), timeout: float = 2.0,
                 push: bool = True,
                 router: "Callable[[str], list[str]] | None" = None):
        self.peers = [u.rstrip("/") for u in peers if u]
        self.timeout = timeout
        self.push = push
        self.router = router
        self.hits = 0
        self.misses = 0
        self.errors = 0
        self.pushes = 0
        self.push_errors = 0

    def targets(self, key: str) -> list[str]:
        """The sibling URLs a pull/push for ``key`` addresses: the ring
        owners when a router is attached, else every static peer."""
        if self.router is not None:
            return [u.rstrip("/") for u in self.router(key) if u]
        return self.peers

    def load(self, key: str) -> dict[str, Any] | None:
        with obs_trace.span("store_peer") as sp:
            rec = self._load(key, sp)
            sp["hit"] = rec is not None
        return rec

    def _load(self, key: str, sp: dict) -> dict[str, Any] | None:
        for peer in self.targets(key):
            req = urllib.request.Request(
                f"{peer}/v1/replicate/{key}",
                # carry the active trace across the pull so the sibling's
                # replicate_pull span lands under the same ID
                headers=obs_trace.wire_headers())
            try:
                with urllib.request.urlopen(  # noqa: S310 — operator-set URL
                        req, timeout=self.timeout) as resp:
                    rec = json.loads(resp.read())
            except urllib.error.HTTPError as e:
                if e.code != 404:
                    self.errors += 1
                continue
            except (urllib.error.URLError, ConnectionError, TimeoutError,
                    OSError, ValueError):
                self.errors += 1
                continue
            if not verify_envelope(key, rec):
                # peer served junk — or a record for a *different* cell (the
                # checksum covers only the payload, so a mis-keyed response
                # would otherwise verify and then be re-stamped under the
                # requested key by store_local, permanently caching the
                # wrong mapping).  Either way: don't replicate it.
                self.errors += 1
                continue
            self.hits += 1
            sp["peer"] = peer
            return rec
        self.misses += 1
        return None

    def store(self, key: str, record: dict[str, Any]) -> None:
        targets = self.targets(key) if self.push else []
        if not targets:
            return
        try:
            body = json.dumps(finalize_record(key, record),
                              default=str).encode()
        except (TypeError, ValueError):
            # unserializable record: every peer push fails, none raises —
            # same degradation as DiskStore._publish
            self.push_errors += len(targets)
            return
        for peer in targets:
            req = urllib.request.Request(
                f"{peer}/v1/replicate/{key}", data=body, method="POST",
                headers={"Content-Type": "application/json"})
            try:
                with urllib.request.urlopen(  # noqa: S310
                        req, timeout=self.timeout):
                    pass
                self.pushes += 1
            except (urllib.error.URLError, ConnectionError, TimeoutError,
                    OSError):
                self.push_errors += 1

    def stats(self) -> dict[str, Any]:
        return {"hits": self.hits, "misses": self.misses,
                "errors": self.errors, "pushes": self.pushes,
                "push_errors": self.push_errors, "peers": list(self.peers),
                "routed": self.router is not None}


# ---------------------------------------------------------------------------
# TieredStore — memory -> disk -> peers, with read-through promotion
# ---------------------------------------------------------------------------


class TieredStore(ArtifactStore):
    """Composition of the tiers: loads fall through memory -> disk ->
    peers, promoting the record into every faster tier on the way back up;
    stores publish to memory + disk and push to peers (write-back).

    ``load_local``/``store_local`` stop at the machine boundary — they are
    what the replication endpoints use, so two peers asking each other can
    never recurse."""

    def __init__(self, memory: MemoryStore | None = None,
                 disk: DiskStore | None = None,
                 peers: PeerStore | None = None):
        self.memory = memory
        self.disk = disk
        self.peer = peers
        self.hits = 0    # aggregate: resolved by any tier
        self.misses = 0  # aggregate: missed every tier

    # -- identity / compat -------------------------------------------------
    @property
    def root(self) -> Path | None:
        return self.disk.root if self.disk is not None else None

    def path(self, key: str) -> Path | None:
        return self.disk.path(key) if self.disk is not None else None

    def lock(self, key: str, timeout: float = 30.0,
             stale_seconds: float = 60.0):
        if self.disk is not None:
            return self.disk.lock(key, timeout=timeout,
                                  stale_seconds=stale_seconds)
        return NullLock()

    # -- read path ---------------------------------------------------------
    def load_local(self, key: str) -> dict[str, Any] | None:
        """Memory -> disk only (the replication-pull surface: a peer's
        question must never trigger our own peer fetch)."""
        if self.memory is not None:
            with obs_trace.span("store_memory") as sp:
                rec = self.memory.load(key)
                sp["hit"] = rec is not None
            if rec is not None:
                if self.disk is not None:
                    # keep the disk tier's eviction index truthful for
                    # memory-shielded hits (index write only — hot hits
                    # still do zero disk I/O)
                    self.disk.note_access(key)
                return rec
        if self.disk is not None:
            with obs_trace.span("store_disk") as sp:
                rec = self.disk.load(key)
                sp["hit"] = rec is not None
            if rec is not None:
                if self.memory is not None:
                    self.memory.store(key, rec)
                return rec
        return None

    def load(self, key: str,
             local_only: bool = False) -> dict[str, Any] | None:
        """Full read-through (``local_only=True`` skips the peer tier —
        the serving layer's lock-free fast path uses it so N concurrent
        cold requests don't each pay the peer probe; the coalescing leader
        probes peers exactly once)."""
        rec = self.load_local(key)
        if rec is None and not local_only and self.peer is not None:
            rec = self.peer.load(key)
            if rec is not None:
                self.store_local(key, rec)  # replicate onto this node
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def load_result(self, key: str):
        if self.memory is None:
            return None
        res = self.memory.load_result(key)
        if res is not None:
            if self.disk is not None:
                self.disk.note_access(key)
            self.hits += 1
        return res

    def remember_result(self, key: str, result) -> None:
        if self.memory is not None:
            self.memory.remember_result(key, result)

    # -- write path --------------------------------------------------------
    def store_local(self, key: str, record: dict[str, Any]) -> Path | None:
        record = finalize_record(key, record)
        path = None
        if self.disk is not None:
            path = self.disk.store(key, record)
        if self.memory is not None:
            self.memory.store(key, record)
        return path

    def store(self, key: str, record: dict[str, Any]) -> Path | None:
        record = finalize_record(key, record)
        path = self.store_local(key, record)
        if self.peer is not None:
            self.peer.store(key, record)  # write-back to siblings
        return path

    def delete(self, key: str) -> bool:
        """Drop the record from this node's tiers (peers keep theirs —
        DELETE is a per-node ops action, not a cluster broadcast)."""
        dropped = False
        if self.memory is not None:
            dropped = self.memory.delete(key) or dropped
        if self.disk is not None:
            dropped = self.disk.delete(key) or dropped
        return dropped

    def clear(self) -> int:
        n = 0
        if self.memory is not None:
            self.memory.clear()
        if self.disk is not None:
            n = self.disk.clear()
        return n

    def evict(self) -> dict[str, int]:
        if self.disk is not None:
            return self.disk.evict()
        return {"ttl": 0, "bytes": 0, "tmp": 0}

    # -- introspection -----------------------------------------------------
    def keys(self) -> list[str]:
        """This node's manifest: every content address resident in a local
        tier (what ``GET /v1/replicate/manifest`` advertises to peers —
        the peer tier is deliberately excluded, a manifest never proxies)."""
        out = set(self.disk.keys()) if self.disk is not None else set()
        if self.memory is not None:
            out.update(k for k in self.memory.keys() if valid_key(k))
        return sorted(out)

    def __contains__(self, key: str) -> bool:
        if self.memory is not None and key in self.memory:
            return True
        return self.disk is not None and key in self.disk

    def __len__(self) -> int:
        if self.disk is not None:
            return len(self.disk)
        return len(self.memory) if self.memory is not None else 0

    def usage(self) -> dict[str, int]:
        return self.disk.usage() if self.disk is not None else \
            {"records": len(self), "bytes": 0}

    def stats(self) -> dict[str, Any]:
        return {
            "hits": self.hits, "misses": self.misses,
            "memory": self.memory.stats() if self.memory is not None else None,
            "disk": self.disk.stats() if self.disk is not None else None,
            "peer": self.peer.stats() if self.peer is not None else None,
        }


# ---------------------------------------------------------------------------
# Construction helpers
# ---------------------------------------------------------------------------


def resolve_root(root: str | Path | None = None) -> Path | None:
    """The disk root for a store: an explicit argument wins, else
    $REPRO_ARTIFACT_CACHE, else the home-cache default.  None means the
    operator opted out of persistence entirely."""
    if root is not None:
        return Path(root)
    env = os.environ.get("REPRO_ARTIFACT_CACHE", "")
    if env.strip().lower() in ("off", "0", "none", "disabled"):
        return None
    return Path(env) if env else Path.home() / ".cache" / "repro_thread_maps"


def split_peers(spec: "str | Iterable[str] | None") -> list[str]:
    """Peer URLs from a comma-separated string (the CLI/env surface) or an
    iterable, empty entries dropped."""
    if spec is None:
        return []
    if isinstance(spec, str):
        spec = spec.split(",")
    return [u.strip() for u in spec if u and u.strip()]


def as_tiered(store, memory_entries: int = 256) -> "TieredStore | None":
    """Normalize any store-ish object into a TieredStore (None passes
    through).  A bare disk-level store gains a memory hot tier; an existing
    TieredStore is used as-is."""
    if store is None or isinstance(store, TieredStore):
        return store
    if isinstance(store, MemoryStore):
        return TieredStore(memory=store)
    memory = MemoryStore(memory_entries) if memory_entries > 0 else None
    return TieredStore(memory=memory, disk=store)


def build_store(root: str | Path | None = None,
                ttl_seconds: float | None = None,
                max_bytes: int | None = None,
                memory_entries: int = 256,
                peers: "str | Iterable[str] | None" = (),
                peer_timeout: float = 2.0,
                peer_push: bool = True) -> TieredStore | None:
    """Assemble a TieredStore from knobs (the CLI / env surface).  Returns
    None when the root resolves to the cache opt-out and no peers are
    configured; opt-out *with* peers builds a diskless memory+peer node
    (read-through replication without local persistence)."""
    root = resolve_root(root)
    peers = split_peers(peers)
    if root is None and not peers:
        return None
    return TieredStore(
        memory=MemoryStore(memory_entries) if memory_entries > 0 else None,
        disk=DiskStore(root, ttl_seconds=ttl_seconds, max_bytes=max_bytes)
        if root is not None else None,
        peers=PeerStore(peers, timeout=peer_timeout,
                        push=peer_push) if peers else None,
    )


_DEFAULT_STORES: dict[tuple, TieredStore] = {}


def _env_float(name: str) -> float | None:
    val = os.environ.get(name, "").strip()
    if not val:
        return None
    try:
        return float(val)
    except ValueError:
        # a malformed knob degrades to unset, it must not crash every
        # store construction in the process (same never-raise contract as
        # the I/O paths) — but say so, the operator meant something
        warnings.warn(f"ignoring malformed {name}={val!r}: expected a number",
                      stacklevel=2)
        return None


def _env_int(name: str, default: int | None = None) -> int | None:
    val = os.environ.get(name, "").strip()
    if not val:
        return default
    try:
        return int(val)
    except ValueError:
        warnings.warn(f"ignoring malformed {name}={val!r}: expected an "
                      "integer", stacklevel=2)
        return default


def env_knobs() -> dict[str, Any]:
    """The documented lifecycle env surface, parsed once for every
    consumer (``default_store`` and the serve CLI, so flag/env pairs can
    never diverge):

    ``REPRO_STORE_TTL``        idle-eviction threshold, seconds,
    ``REPRO_STORE_MAX_BYTES``  disk budget, bytes,
    ``REPRO_MEMORY_ENTRIES``   LRU hot-tier capacity (0 disables),
    ``REPRO_PEERS``            comma-separated sibling server URLs."""
    return {
        "ttl_seconds": _env_float("REPRO_STORE_TTL"),
        "max_bytes": _env_int("REPRO_STORE_MAX_BYTES"),
        "memory_entries": _env_int("REPRO_MEMORY_ENTRIES", 256),
        "peers": split_peers(os.environ.get("REPRO_PEERS")),
    }


def default_store() -> TieredStore | None:
    """Process-default tiered store honoring ``REPRO_ARTIFACT_CACHE``
    (disk root; opt-out with "off"/"0"/"none") plus the :func:`env_knobs`
    lifecycle surface.

    One instance per knob combination, so tier counters accumulate across
    calls (and across `derive_mapping` / `MappingService` / benchmarks in
    one process)."""
    root = resolve_root()
    knobs = env_knobs()
    if root is None and not knobs["peers"]:
        return None  # full opt-out: no persistence and nobody to ask
    memo = (str(root), knobs["ttl_seconds"], knobs["max_bytes"],
            knobs["memory_entries"], tuple(knobs["peers"]))
    if memo not in _DEFAULT_STORES:
        _DEFAULT_STORES[memo] = build_store(root, **knobs)
    return _DEFAULT_STORES[memo]
