"""Validation against ground truth (Sec. IV.2/IV.3 of the paper).

Given a candidate `map_to_coordinates(n)` we verify over N points (default
10^6) that the induced mapping is bijective onto the domain, and score it with
the paper's two accuracy criteria:

  * Ordered   — % of indices where candidate(lambda) == ground_truth(lambda),
  * Any-order — % of unique ground-truth coordinates covered by the candidate
                regardless of traversal order ("silver standard").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.domains import Domain

_ENC_SHIFT = 21  # coords < 2^21 per axis at N <= 1e6 for every dim<=3 domain


def encode_coords(coords: np.ndarray) -> np.ndarray:
    """Pack (N, dim) non-negative int coords into unique int64 keys.

    dim <= 3 uses 21 bits per axis; higher-dimensional domains (the
    m-simplex family) split the 63 bits evenly — their coordinates shrink
    as ~N^(1/m), so 15 (m=4) / 12 (m=5) bits per axis stay exact far past
    the 10^6-point validation scale."""
    c = np.asarray(coords, dtype=np.int64)
    shift = min(_ENC_SHIFT, 63 // c.shape[1])
    key = np.zeros(len(c), dtype=np.int64)
    for k in range(c.shape[1]):
        key = (key << shift) | (c[:, k] & ((1 << shift) - 1))
    return key


@dataclasses.dataclass(frozen=True)
class ValidationReport:
    n_points: int
    ordered: float          # fraction in [0, 1]
    any_order: float        # fraction in [0, 1]
    bijective: bool         # candidate visits every GT coord exactly once
    duplicates: int         # candidate outputs repeated coords
    out_of_domain: int      # candidate outputs not in GT set
    compiled: bool = True   # False => (NC) in the paper's tables
    error: str | None = None

    @property
    def ordered_pct(self) -> float:
        return 100.0 * self.ordered

    @property
    def any_order_pct(self) -> float:
        return 100.0 * self.any_order


FAILED = lambda n, err: ValidationReport(  # noqa: E731
    n_points=n, ordered=0.0, any_order=0.0, bijective=False,
    duplicates=0, out_of_domain=0, compiled=False, error=err,
)


def evaluate_candidate_array(
    pred: np.ndarray, gt: np.ndarray, n_points: int
) -> ValidationReport:
    """Score a candidate's coordinate array against ground truth."""
    if pred.shape != gt.shape:
        return FAILED(n_points, f"shape mismatch {pred.shape} vs {gt.shape}")
    if (pred < 0).any():
        return FAILED(n_points, "negative coordinates")
    ordered = float(np.mean(np.all(pred == gt, axis=1)))
    pk, gk = encode_coords(pred), encode_coords(gt)
    uniq_pred = np.unique(pk)
    uniq_gt = np.unique(gk)  # == n_points (GT enumeration never repeats)
    covered = np.intersect1d(uniq_pred, uniq_gt, assume_unique=True)
    any_order = float(len(covered)) / float(len(uniq_gt))
    duplicates = int(len(pk) - len(uniq_pred))
    out_of_domain = int(len(uniq_pred) - len(covered))
    bijective = duplicates == 0 and out_of_domain == 0 and len(covered) == len(uniq_gt)
    return ValidationReport(
        n_points=n_points, ordered=ordered, any_order=any_order,
        bijective=bijective, duplicates=duplicates, out_of_domain=out_of_domain,
    )


def validate_scalar_fn(
    fn: Callable[[int], Sequence[int]],
    domain: Domain,
    n_points: int = 1_000_000,
    gt: np.ndarray | None = None,
    sample_every: int = 1,
) -> ValidationReport:
    """Validate a scalar candidate `map_to_coordinates(n)` over [0, n_points).

    sample_every > 1 subsamples indices for expensive pure-python candidates;
    ordered/any-order are then estimates over the sampled set.
    """
    if gt is None:
        gt = domain.enumerate_points(n_points)
    idx = np.arange(0, n_points, sample_every, dtype=np.int64)
    try:
        rows = [fn(int(i)) for i in idx]
    except Exception as e:  # candidate raised at runtime
        return FAILED(n_points, f"runtime error: {e!r}")
    try:
        pred = np.asarray(rows, dtype=np.int64)
    except (ValueError, TypeError) as e:
        return FAILED(n_points, f"non-integer output: {e!r}")
    if pred.ndim != 2 or pred.shape[1] != domain.dim:
        return FAILED(n_points, f"wrong output arity {pred.shape}")
    return evaluate_candidate_array(pred, gt[idx], len(idx))


def validate_vectorized(
    np_fn: Callable[[np.ndarray], np.ndarray],
    domain: Domain,
    n_points: int = 1_000_000,
    gt: np.ndarray | None = None,
) -> ValidationReport:
    """Validate a numpy-vectorized candidate over the full [0, n_points)."""
    if gt is None:
        gt = domain.enumerate_points(n_points)
    lams = np.arange(n_points, dtype=np.int64)
    try:
        pred = np.asarray(np_fn(lams), dtype=np.int64)
    except Exception as e:
        return FAILED(n_points, f"runtime error: {e!r}")
    if pred.shape != (n_points, domain.dim):
        return FAILED(n_points, f"wrong output shape {pred.shape}")
    return evaluate_candidate_array(pred, gt, n_points)
