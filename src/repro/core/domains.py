"""The six computational domains of the paper (Table I / Fig. 4).

Each Domain knows how to:
  * enumerate its first N points in canonical order (the ground-truth dataset
    of Sec. IV — generated *independently* of the analytical maps so the maps
    can be validated against it),
  * test membership (vectorized) — the bounding-box baseline's `if`,
  * report exact sizes, bounding boxes and block-waste accounting.

Canonical orders:
  dense domains   — row-major nested loops (lambda = rank in loop order),
  fractal domains — recursive construction, most-significant digit outermost
                    (identical to ascending base-B digit order of lambda).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.inverse import tet, tri

# ---------------------------------------------------------------------------
# Fractal digit -> translation-vector tables (Table I, rightmost column)
# ---------------------------------------------------------------------------

GASKET_VECS = ((0, 0), (1, 0), (0, 1))  # base 3, spatial scale 2
CARPET_VECS = tuple(
    (x, y) for x in range(3) for y in range(3) if not (x == 1 and y == 1)
)  # base 8, spatial scale 3
SIERP3D_VECS = ((0, 0, 0), (1, 0, 0), (0, 1, 0), (0, 0, 1))  # base 4, scale 2
MENGER_VECS = tuple(
    (x, y, z)
    for x in range(3)
    for y in range(3)
    for z in range(3)
    if (x == 1) + (y == 1) + (z == 1) < 2
)  # base 20 (27 - 7 voids), spatial scale 3
MENGER_VOIDS = tuple(
    (x, y, z)
    for x in range(3)
    for y in range(3)
    for z in range(3)
    if (x == 1) + (y == 1) + (z == 1) >= 2
)

assert len(CARPET_VECS) == 8 and len(MENGER_VECS) == 20 and len(MENGER_VOIDS) == 7


@dataclasses.dataclass(frozen=True)
class Domain:
    """A computational domain with canonical enumeration + membership."""

    name: str          # internal id
    paper_name: str    # name used in the paper's tables
    dim: int
    kind: str          # "dense" | "fractal"
    complexity: str    # ground-truth map cost class, e.g. "O(1)", "O(log3 N)"
    base: int | None = None       # fractal digit base B
    scale: int | None = None      # fractal spatial scale per level
    vecs: Sequence[tuple] | None = None  # fractal digit->vector table

    # -- sizes ------------------------------------------------------------
    def size(self, n: int) -> int:
        """|domain| for structural parameter n (rows / layers / levels)."""
        if self.name == "tri2d":
            return tri(n)
        if self.name == "pyramid3d":
            return tet(n)
        return self.base ** n  # fractal level n

    def level_for_points(self, n_points: int) -> int:
        """Smallest structural parameter whose domain holds >= n_points."""
        n = 0
        while self.size(n) < n_points:
            n += 1
        return n

    # -- canonical enumeration (ground truth) ------------------------------
    def enumerate_points(self, n_points: int) -> np.ndarray:
        """First n_points coordinates in canonical order, shape (N, dim)."""
        if self.name == "tri2d":
            out = np.empty((n_points, 2), dtype=np.int64)
            i = 0
            x = 0
            while i < n_points:
                for y in range(x + 1):
                    if i >= n_points:
                        break
                    out[i] = (x, y)
                    i += 1
                x += 1
            return out
        if self.name == "pyramid3d":
            out = np.empty((n_points, 3), dtype=np.int64)
            i = 0
            z = 0
            while i < n_points:
                for x in range(z + 1):
                    for y in range(x + 1):
                        if i >= n_points:
                            break
                        out[i] = (x, y, z)
                        i += 1
                    if i >= n_points:
                        break
                z += 1
            return out
        # fractal: iterative digit construction, vectorized over levels.
        # point(lam) = sum_i vec(d_i) * scale^i — build by levels to keep the
        # construction independent from maps.py (no shared code path).
        level = self.level_for_points(n_points)
        pts = np.zeros((1, self.dim), dtype=np.int64)
        vecs = np.asarray(self.vecs, dtype=np.int64)
        for lev in range(level):
            # prepend digit at position `lev` as the *least* significant digit
            # of the next level: new = vec(d) * scale^lev + old  with d slowest?
            # canonical order: most-significant digit outermost =>
            # new_points = concat_d [ vec(d)*scale^lev + pts ] where lev grows
            # and d is the *new most significant* digit.
            offs = vecs * (self.scale ** lev)
            pts = (offs[:, None, :] + pts[None, :, :]).reshape(-1, self.dim)
            if len(pts) >= n_points:
                break
        return pts[:n_points]

    # -- membership (the bounding-box `if`) --------------------------------
    def contains(self, coords: np.ndarray) -> np.ndarray:
        """Vectorized membership test for (N, dim) int coords."""
        c = np.asarray(coords, dtype=np.int64)
        if self.name == "tri2d":
            return (c[:, 1] >= 0) & (c[:, 1] <= c[:, 0])
        if self.name == "pyramid3d":
            return (c[:, 1] >= 0) & (c[:, 1] <= c[:, 0]) & (c[:, 0] <= c[:, 2])
        if self.name == "gasket2d":
            return (c[:, 0] & c[:, 1]) == 0
        if self.name == "sierpinski3d":
            x, y, z = c[:, 0], c[:, 1], c[:, 2]
            return ((x & y) | (x & z) | (y & z)) == 0
        if self.name == "carpet2d":
            x, y = c[:, 0].copy(), c[:, 1].copy()
            ok = np.ones(len(c), dtype=bool)
            while (x > 0).any() or (y > 0).any():
                ok &= ~((x % 3 == 1) & (y % 3 == 1))
                x //= 3
                y //= 3
            return ok
        if self.name == "menger3d":
            x, y, z = c[:, 0].copy(), c[:, 1].copy(), c[:, 2].copy()
            ok = np.ones(len(c), dtype=bool)
            while (x > 0).any() or (y > 0).any() or (z > 0).any():
                ones = (x % 3 == 1).astype(np.int64) + (y % 3 == 1) + (z % 3 == 1)
                ok &= ones < 2
                x //= 3
                y //= 3
                z //= 3
            return ok
        raise ValueError(self.name)

    # -- bounding box accounting (Table VIII/IX baselines) ------------------
    def bounding_box_extent(self, n_points: int) -> tuple[int, ...]:
        """Per-axis extent of the minimal axis-aligned box holding the first
        n_points canonical points."""
        if self.name == "tri2d":
            rows = int(np.ceil((np.sqrt(8.0 * n_points + 1) - 1) / 2))
            return (rows, rows)
        if self.name == "pyramid3d":
            z = self.level_for_points(n_points)
            return (z, z, z)
        level = self.level_for_points(n_points)
        ext = self.scale ** level
        return (ext,) * self.dim

    def block_accounting(self, n_points: int, block: int = 256) -> dict:
        """Blocks launched by the bounding-box strategy vs the mapped strategy.

        Matches the paper's Tables VIII/IX accounting: the mapped (block-space)
        kernel launches ceil(N / block) linear blocks; the BB kernel launches a
        grid over the bounding box with sqrt/cbrt-shaped CUDA blocks
        (16x16 in 2D, 8x8x4 in 3D -> 256 threads).
        """
        valid = -(-n_points // block)
        ext = self.bounding_box_extent(n_points)
        if self.dim == 2:
            bdims = (16, 16)
        else:
            bdims = (8, 8, 4)
        bb = 1
        for e, b in zip(ext, bdims):
            bb *= -(-e // b)
        return {
            "valid_blocks": valid,
            "bb_blocks": bb,
            "wasted_blocks": max(bb - valid, 0),
            "waste_fraction": max(bb - valid, 0) / bb if bb else 0.0,
        }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

TRI2D = Domain("tri2d", "2D Triangular", 2, "dense", "O(1)")
PYRAMID3D = Domain("pyramid3d", "3D Pyramid", 3, "dense", "O(1)")
GASKET2D = Domain(
    "gasket2d", "2D Sierpinski Gasket", 2, "fractal", "O(log3 N)",
    base=3, scale=2, vecs=GASKET_VECS,
)
CARPET2D = Domain(
    "carpet2d", "2D Sierpinski Carpet", 2, "fractal", "O(log8 N)",
    base=8, scale=3, vecs=CARPET_VECS,
)
SIERPINSKI3D = Domain(
    "sierpinski3d", "3D Sierpinski Pyramid", 3, "fractal", "O(log4 N)",
    base=4, scale=2, vecs=SIERP3D_VECS,
)
MENGER3D = Domain(
    "menger3d", "3D Menger Sponge", 3, "fractal", "O(log20 N)",
    base=20, scale=3, vecs=MENGER_VECS,
)

DOMAINS: dict[str, Domain] = {
    d.name: d
    for d in (TRI2D, PYRAMID3D, GASKET2D, CARPET2D, SIERPINSKI3D, MENGER3D)
}


def get_domain(name: str) -> Domain:
    if name not in DOMAINS:
        raise KeyError(f"unknown domain {name!r}; have {sorted(DOMAINS)}")
    return DOMAINS[name]
