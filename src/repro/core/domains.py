"""Computational domains: the paper's six (Table I / Fig. 4) plus the
beyond-paper families — the m-simplex family (m=2..5, Navarro et al.,
arXiv:2208.11617) and the embedded-2D-fractal family (Navarro et al.,
arXiv:2004.13475).

Each Domain knows how to:
  * enumerate its first N points in canonical order (the ground-truth dataset
    of Sec. IV — generated *independently* of the analytical maps so the maps
    can be validated against it),
  * test membership (vectorized) — the bounding-box baseline's `if`,
  * report exact sizes, bounding boxes and block-waste accounting.

Geometry is supplied by subclasses (``DenseTriangularDomain``,
``DensePyramidDomain``, ``SimplexDomain``, ``DigitFractalDomain``) — adding a
domain family means adding a subclass + ``register_domain`` call, never an
if-chain over names.

Canonical orders:
  dense domains   — row-major nested loops (lambda = rank in loop order),
  simplex domains — sorted-ascending coordinates, outermost axis slowest,
  fractal domains — recursive construction, most-significant digit outermost
                    (identical to ascending base-B digit order of lambda).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import msimplex as ms
from repro.core.inverse import tet, tri

# ---------------------------------------------------------------------------
# Fractal digit -> translation-vector tables (Table I, rightmost column)
# ---------------------------------------------------------------------------

GASKET_VECS = ((0, 0), (1, 0), (0, 1))  # base 3, spatial scale 2
CARPET_VECS = tuple(
    (x, y) for x in range(3) for y in range(3) if not (x == 1 and y == 1)
)  # base 8, spatial scale 3
SIERP3D_VECS = ((0, 0, 0), (1, 0, 0), (0, 1, 0), (0, 0, 1))  # base 4, scale 2
MENGER_VECS = tuple(
    (x, y, z)
    for x in range(3)
    for y in range(3)
    for z in range(3)
    if (x == 1) + (y == 1) + (z == 1) < 2
)  # base 20 (27 - 7 voids), spatial scale 3
MENGER_VOIDS = tuple(
    (x, y, z)
    for x in range(3)
    for y in range(3)
    for z in range(3)
    if (x == 1) + (y == 1) + (z == 1) >= 2
)

# embedded-2D-fractal family generators (digit 0 must be the origin cell so
# lambda=0 maps to the origin at every recursion depth)
CANTOR2D_VECS = ((0, 0), (0, 2), (2, 0), (2, 2))        # base 4, scale 3
VICSEK2D_VECS = ((0, 0), (0, 2), (1, 1), (2, 0), (2, 2))  # base 5, scale 3

assert len(CARPET_VECS) == 8 and len(MENGER_VECS) == 20 and len(MENGER_VOIDS) == 7
assert all(v[0] == (0,) * len(v[0]) for v in
           (GASKET_VECS, CARPET_VECS, SIERP3D_VECS, MENGER_VECS,
            CANTOR2D_VECS, VICSEK2D_VECS))


def bb_block_dims(dim: int, block: int = 256) -> tuple[int, ...]:
    """CUDA-style block shape for a bounding-box launch: `block` threads
    split into `dim` near-equal power-of-two factors (16x16 in 2D, 8x8x4 in
    3D, 4x4x4x4 in 4D, ...)."""
    if block & (block - 1):
        raise ValueError(f"block must be a power of two, got {block}")
    bits = block.bit_length() - 1
    per = [bits // dim + (1 if k < bits % dim else 0) for k in range(dim)]
    return tuple(1 << b for b in per)


@dataclasses.dataclass(frozen=True)
class Domain:
    """A computational domain with canonical enumeration + membership.

    The base class carries shared metadata and the block-waste accounting;
    geometry (sizes, enumeration, membership, bounding boxes) comes from the
    subclass."""

    name: str          # internal id
    paper_name: str    # name used in the paper's tables
    dim: int
    kind: str          # "dense" | "fractal"
    complexity: str    # ground-truth map cost class, e.g. "O(1)", "O(log3 N)"
    base: int | None = None       # fractal digit base B
    scale: int | None = None      # fractal spatial scale per level
    vecs: Sequence[tuple] | None = None  # fractal digit->vector table

    # -- geometry hooks (subclass responsibility) ---------------------------
    def size(self, n: int) -> int:
        """|domain| for structural parameter n (rows / layers / levels)."""
        raise NotImplementedError(self.name)

    def enumerate_points(self, n_points: int) -> np.ndarray:
        """First n_points coordinates in canonical order, shape (N, dim)."""
        raise NotImplementedError(self.name)

    def contains(self, coords: np.ndarray) -> np.ndarray:
        """Vectorized membership test for (N, dim) int coords."""
        raise NotImplementedError(self.name)

    def bounding_box_extent(self, n_points: int) -> tuple[int, ...]:
        """Per-axis extent of the minimal axis-aligned box holding the first
        n_points canonical points."""
        raise NotImplementedError(self.name)

    # -- shared accounting --------------------------------------------------
    def level_for_points(self, n_points: int) -> int:
        """Smallest structural parameter whose domain holds >= n_points."""
        n = 0
        while self.size(n) < n_points:
            n += 1
        return n

    def block_accounting(self, n_points: int, block: int = 256) -> dict:
        """Blocks launched by the bounding-box strategy vs the mapped strategy.

        Matches the paper's Tables VIII/IX accounting: the mapped (block-space)
        kernel launches ceil(N / block) linear blocks; the BB kernel launches a
        grid over the bounding box with root-shaped CUDA blocks
        (16x16 in 2D, 8x8x4 in 3D -> 256 threads; see ``bb_block_dims``).
        """
        valid = -(-n_points // block)
        ext = self.bounding_box_extent(n_points)
        bb = 1
        for e, b in zip(ext, bb_block_dims(self.dim, block)):
            bb *= -(-e // b)
        return {
            "valid_blocks": valid,
            "bb_blocks": bb,
            "wasted_blocks": max(bb - valid, 0),
            "waste_fraction": max(bb - valid, 0) / bb if bb else 0.0,
        }


# ---------------------------------------------------------------------------
# Dense Table-I domains (row-major nested-loop canonical order)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DenseTriangularDomain(Domain):
    """2D triangular domain: {(x, y) : 0 <= y <= x}."""

    def size(self, n: int) -> int:
        return tri(n)

    def enumerate_points(self, n_points: int) -> np.ndarray:
        out = np.empty((n_points, 2), dtype=np.int64)
        i = 0
        x = 0
        while i < n_points:
            for y in range(x + 1):
                if i >= n_points:
                    break
                out[i] = (x, y)
                i += 1
            x += 1
        return out

    def contains(self, coords: np.ndarray) -> np.ndarray:
        c = np.asarray(coords, dtype=np.int64)
        return (c[:, 1] >= 0) & (c[:, 1] <= c[:, 0])

    def bounding_box_extent(self, n_points: int) -> tuple[int, ...]:
        rows = int(np.ceil((np.sqrt(8.0 * n_points + 1) - 1) / 2))
        return (rows, rows)


@dataclasses.dataclass(frozen=True)
class DensePyramidDomain(Domain):
    """3D pyramid domain: {(x, y, z) : 0 <= y <= x <= z}."""

    def size(self, n: int) -> int:
        return tet(n)

    def enumerate_points(self, n_points: int) -> np.ndarray:
        out = np.empty((n_points, 3), dtype=np.int64)
        i = 0
        z = 0
        while i < n_points:
            for x in range(z + 1):
                for y in range(x + 1):
                    if i >= n_points:
                        break
                    out[i] = (x, y, z)
                    i += 1
                if i >= n_points:
                    break
            z += 1
        return out

    def contains(self, coords: np.ndarray) -> np.ndarray:
        c = np.asarray(coords, dtype=np.int64)
        return (c[:, 1] >= 0) & (c[:, 1] <= c[:, 0]) & (c[:, 0] <= c[:, 2])

    def bounding_box_extent(self, n_points: int) -> tuple[int, ...]:
        z = self.level_for_points(n_points)
        return (z, z, z)


# ---------------------------------------------------------------------------
# m-simplex family (sorted-ascending canonical order; core/msimplex.py math)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SimplexDomain(Domain):
    """The m-simplex {(x_1..x_m) : 0 <= x_1 <= ... <= x_m}; |side n| is the
    binomial C(n+m-1, m).  m=2/3 are the paper's triangular/tetrahedral rows
    in sorted-coordinate convention; the family generalizes them upward."""

    m: int = 2

    def size(self, n: int) -> int:
        return ms.simplex_size(n, self.m)

    def enumerate_points(self, n_points: int) -> np.ndarray:
        return ms.enumerate_msimplex(n_points, self.m)

    def contains(self, coords: np.ndarray) -> np.ndarray:
        c = np.asarray(coords, dtype=np.int64)
        ok = c[:, 0] >= 0
        for k in range(self.m - 1):
            ok &= c[:, k] <= c[:, k + 1]
        return ok

    def bounding_box_extent(self, n_points: int) -> tuple[int, ...]:
        side = self.level_for_points(n_points)
        return (side,) * self.m


# ---------------------------------------------------------------------------
# Digit-decomposition fractals (paper's four + the embedded-2D family)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DigitFractalDomain(Domain):
    """Self-similar fractal built from a digit->cell generator: a point is in
    the fractal iff at every recursion level its (coord % scale) cell is one
    of the generator's `vecs`.  Covers the paper's four fractals and any
    embedded fractal with an origin-anchored generator."""

    def __post_init__(self):
        cells = {tuple(v) for v in self.vecs}
        assert len(cells) == self.base, (self.name, "duplicate generator cell")
        assert (0,) * self.dim in cells, (self.name, "generator must anchor 0")

    def size(self, n: int) -> int:
        return self.base ** n

    def enumerate_points(self, n_points: int) -> np.ndarray:
        # iterative digit construction, vectorized over levels: point(lam) =
        # sum_i vec(d_i) * scale^i — built independently from core/maps so the
        # maps can be validated against it (no shared code path).
        level = self.level_for_points(n_points)
        pts = np.zeros((1, self.dim), dtype=np.int64)
        vecs = np.asarray(self.vecs, dtype=np.int64)
        for lev in range(level):
            # new_points = concat_d [ vec(d)*scale^lev + pts ]: lev grows and
            # d becomes the new most-significant digit (canonical order).
            offs = vecs * (self.scale ** lev)
            pts = (offs[:, None, :] + pts[None, :, :]).reshape(-1, self.dim)
            if len(pts) >= n_points:
                break
        return pts[:n_points]

    def contains(self, coords: np.ndarray) -> np.ndarray:
        c = np.asarray(coords, dtype=np.int64).copy()
        # encode each level's cell as a base-`scale` code and test it against
        # the generator's allowed codes — one rule for every digit fractal.
        allowed = np.sort(np.asarray(
            [self._cell_code(v) for v in self.vecs], dtype=np.int64))
        ok = (c >= 0).all(axis=1)
        while (c > 0).any():
            code = np.zeros(len(c), dtype=np.int64)
            for k in range(self.dim):
                code = code * self.scale + (c[:, k] % self.scale)
            ok &= np.isin(code, allowed, assume_unique=False)
            c //= self.scale
        return ok

    def _cell_code(self, vec) -> int:
        code = 0
        for v in vec:
            code = code * self.scale + int(v)
        return code

    def bounding_box_extent(self, n_points: int) -> tuple[int, ...]:
        ext = self.scale ** self.level_for_points(n_points)
        return (ext,) * self.dim


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

TRI2D = DenseTriangularDomain("tri2d", "2D Triangular", 2, "dense", "O(1)")
PYRAMID3D = DensePyramidDomain("pyramid3d", "3D Pyramid", 3, "dense", "O(1)")
GASKET2D = DigitFractalDomain(
    "gasket2d", "2D Sierpinski Gasket", 2, "fractal", "O(log3 N)",
    base=3, scale=2, vecs=GASKET_VECS,
)
CARPET2D = DigitFractalDomain(
    "carpet2d", "2D Sierpinski Carpet", 2, "fractal", "O(log8 N)",
    base=8, scale=3, vecs=CARPET_VECS,
)
SIERPINSKI3D = DigitFractalDomain(
    "sierpinski3d", "3D Sierpinski Pyramid", 3, "fractal", "O(log4 N)",
    base=4, scale=2, vecs=SIERP3D_VECS,
)
MENGER3D = DigitFractalDomain(
    "menger3d", "3D Menger Sponge", 3, "fractal", "O(log20 N)",
    base=20, scale=3, vecs=MENGER_VECS,
)

#: m-simplex family (beyond-paper; m=2..5)
MSIMPLEX_MS = (2, 3, 4, 5)
MSIMPLEX_DOMAINS = tuple(
    SimplexDomain(f"msimplex{m}", f"{m}-Simplex", m, "dense", "O(1)", m=m)
    for m in MSIMPLEX_MS
)

#: embedded-2D-fractal family (beyond-paper)
CANTOR2D = DigitFractalDomain(
    "cantor2d", "2D Cantor Dust", 2, "fractal", "O(log4 N)",
    base=4, scale=3, vecs=CANTOR2D_VECS,
)
VICSEK2D = DigitFractalDomain(
    "vicsek2d", "2D Vicsek Saltire", 2, "fractal", "O(log5 N)",
    base=5, scale=3, vecs=VICSEK2D_VECS,
)
EMBEDDED_FRACTAL_DOMAINS = (CANTOR2D, VICSEK2D)

#: the six domains the paper measures (Tables II-IX)
PAPER_DOMAINS = (TRI2D, PYRAMID3D, GASKET2D, CARPET2D, SIERPINSKI3D, MENGER3D)

DOMAINS: dict[str, Domain] = {}


def register_domain(domain: Domain) -> Domain:
    """Add a domain to the global name -> Domain table (plugin entry point)."""
    DOMAINS[domain.name] = domain
    return domain


for _d in (*PAPER_DOMAINS, *MSIMPLEX_DOMAINS, *EMBEDDED_FRACTAL_DOMAINS):
    register_domain(_d)


def get_domain(name: str) -> Domain:
    if name not in DOMAINS:
        raise KeyError(f"unknown domain {name!r}; have {sorted(DOMAINS)}")
    return DOMAINS[name]
