"""Exact integer inversion helpers.

The paper's dense-domain maps (Table I) invert triangular and tetrahedral
numbers.  Floating-point sqrt/cbrt alone is not exact for large lambda, so
every helper here pairs a float seed with an integer Newton correction.
Scalar (python int) versions are the oracles; jnp versions are vectorized and
int32/int64 safe for kernel/index-map use.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Scalar (exact, python ints) — oracles
# ---------------------------------------------------------------------------


def isqrt(v: int) -> int:
    """Exact floor(sqrt(v)) for v >= 0."""
    if v < 0:
        raise ValueError("isqrt of negative value")
    return math.isqrt(v)


def tri(n: int) -> int:
    """n-th triangular number T(n) = n(n+1)/2."""
    return n * (n + 1) // 2


def tet(n: int) -> int:
    """n-th tetrahedral number Tet(n) = n(n+1)(n+2)/6."""
    return n * (n + 1) * (n + 2) // 6


def tri_row(lam: int) -> int:
    """Largest x with T(x) <= lam  (row index of linear index lam).

    x = floor(sqrt(1/4 + 2*lam) - 1/2)  ==  (isqrt(8*lam + 1) - 1) // 2
    """
    return (isqrt(8 * lam + 1) - 1) // 2


def tet_layer(lam: int) -> int:
    """Largest z with Tet(z) <= lam (layer index of linear index lam).

    Float cbrt seed (the paper's closed form) + exact integer correction.
    """
    if lam < 0:
        raise ValueError("negative lambda")
    # seed: Tet(z) ~ z^3/6  =>  z ~ cbrt(6*lam)
    z = int(round((6.0 * lam) ** (1.0 / 3.0)))
    while tet(z + 1) <= lam:
        z += 1
    while z > 0 and tet(z) > lam:
        z -= 1
    return z


# ---------------------------------------------------------------------------
# Vectorized numpy (exact via int64 correction) — validation scale (1e6 pts)
# ---------------------------------------------------------------------------


def np_isqrt(v: np.ndarray) -> np.ndarray:
    v = np.asarray(v, dtype=np.int64)
    r = np.floor(np.sqrt(v.astype(np.float64))).astype(np.int64)
    # float64 sqrt can be off by 1 ulp near perfect squares — correct both ways.
    r = np.where((r + 1) * (r + 1) <= v, r + 1, r)
    r = np.where(r * r > v, r - 1, r)
    return r


def np_tri_row(lam: np.ndarray) -> np.ndarray:
    lam = np.asarray(lam, dtype=np.int64)
    return (np_isqrt(8 * lam + 1) - 1) // 2


def np_tet_layer(lam: np.ndarray) -> np.ndarray:
    lam = np.asarray(lam, dtype=np.int64)
    z = np.cbrt(6.0 * lam.astype(np.float64)).astype(np.int64)
    # correction window of +-2 covers float64 cbrt error at any int64 lam
    for _ in range(3):
        tet_z1 = (z + 1) * (z + 2) * (z + 3) // 6
        z = np.where(tet_z1 <= lam, z + 1, z)
    for _ in range(3):
        tet_z = z * (z + 1) * (z + 2) // 6
        z = np.where((z > 0) & (tet_z > lam), z - 1, z)
    return np.maximum(z, 0)


# ---------------------------------------------------------------------------
# Vectorized jnp (traceable; int32-safe for lam < 2^31 via float32+correction,
# exact for all int32 lam) — kernel / index_map use
# ---------------------------------------------------------------------------


def jnp_isqrt(v: jnp.ndarray) -> jnp.ndarray:
    """Exact floor(sqrt(v)) for non-negative int32/int64 v (traceable)."""
    v = v.astype(jnp.int64) if v.dtype == jnp.int64 else v.astype(jnp.int32)
    r = jnp.floor(jnp.sqrt(v.astype(jnp.float32))).astype(v.dtype)
    # float32 sqrt of values up to 2^31 is off by at most a few ulps; a short
    # fixed correction ladder restores exactness (monotone, so where() is safe).
    for _ in range(4):
        r = jnp.where((r + 1) * (r + 1) <= v, r + 1, r)
    for _ in range(4):
        r = jnp.where(r * r > v, r - 1, r)
    return jnp.maximum(r, 0)


def jnp_tri_row(lam: jnp.ndarray) -> jnp.ndarray:
    lam = jnp.asarray(lam)
    return (jnp_isqrt(8 * lam + 1) - 1) // 2


def jnp_tet_layer(lam: jnp.ndarray) -> jnp.ndarray:
    lam = jnp.asarray(lam)
    z = jnp.cbrt(6.0 * lam.astype(jnp.float32)).astype(lam.dtype)
    for _ in range(4):
        z = jnp.where((z + 1) * (z + 2) * (z + 3) // 6 <= lam, z + 1, z)
    for _ in range(4):
        z = jnp.where((z > 0) & (z * (z + 1) * (z + 2) // 6 > lam), z - 1, z)
    return jnp.maximum(z, 0)
