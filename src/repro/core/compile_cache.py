"""CompileCache — process-wide cache of compiled map executables.

The deployment-side economics of the paper (Sec. V.C: the mapped kernel's
4833x speedup) only materialize if a deployed map runs at hardware speed on
*every* launch.  Until this layer existed, every ``map_coordinates`` /
``bb_membership`` call re-traced and re-jitted its Pallas call — tens to
hundreds of milliseconds of Python/XLA work in front of a ~1ms kernel.

This module caches the *compiled executable* (``jax.jit(...).lower()
.compile()``) keyed by everything that changes the lowering:

    (spec fingerprint, tier, shape, block_n, ndigits, dtype,
     interpret, device kind)

where the spec fingerprint is the artifact's content address for
LLM-derived maps (``artifact:<cache_key>``) and a registry identity for
ground-truth geometry (``domain:<name>`` / ``entry:<domain>:<logic>``).
A repeat evaluation with an identical key is therefore trace-free: it costs
one dict hit plus the device dispatch.

Persistence (optional): with a ``persist_dir``, each freshly-compiled
executable is serialized through ``jax.export`` next to its key digest, and
a cold *process* can rehydrate it without re-tracing.  Where the installed
jaxlib (or the kernel's lowering) cannot round-trip through ``jax.export``,
the cache degrades transparently to in-memory-only and counts the failure —
persistence is an optimization, never a correctness dependency.

Concurrency: per-key in-flight coalescing (the same shape the
MappingService uses for derivations) — N threads asking for one cold key
trigger exactly one trace/compile; everyone shares the executable.

Env surface (read by :func:`default_compile_cache`, overridable from
``launch/serve.py`` flags):

    REPRO_COMPILE_CACHE_ENTRIES   LRU capacity (default 128; 0 disables)
    REPRO_COMPILE_CACHE_DIR       on-disk persistence root (default: off)
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import os
import threading
import time
import warnings
from pathlib import Path
from typing import Any, Callable

DEFAULT_MAX_ENTRIES = 128

#: sentinel: "use the process-default cache" (None = bypass caching)
USE_DEFAULT = object()


def device_kind() -> str:
    """The accelerator identity baked into every key — an executable
    compiled for one device kind must never serve another."""
    import jax

    devs = jax.devices()
    return f"{devs[0].platform}:{devs[0].device_kind}"


def spec_fingerprint(spec) -> str:
    """Content identity of a map spec, for executable keying.

    * ``MappingArtifact`` -> ``artifact:<content address>`` (falls back to a
      digest of the validated source when the artifact never saw a store);
    * ``MapEntry``        -> ``entry:<domain>:<logic>``;
    * ``str`` / ``Domain``-> ``domain:<name>`` (ground-truth geometry).
    """
    from repro.core.artifact import MappingArtifact, resolve_spec

    if isinstance(spec, MappingArtifact):
        base = spec.cache_key or hashlib.sha256(
            spec.source.encode()).hexdigest()
        return f"artifact:{base}"
    domain, logic = resolve_spec(spec)
    if logic is None:
        return f"domain:{domain}"
    return f"entry:{domain}:{logic}"


@dataclasses.dataclass(frozen=True)
class ExecKey:
    """Everything that changes the lowered executable."""

    fingerprint: str          # spec_fingerprint(spec)
    tier: str                 # "map" | "membership" | "map_sharded" | ...
    shape: tuple[int, ...]    # padded output extent (and box extent)
    block_n: int
    ndigits: int
    dtype: str = "int32"
    interpret: bool = False
    device: str = dataclasses.field(default_factory=device_kind)

    def digest(self) -> str:
        """Stable file name for on-disk persistence."""
        payload = "|".join(
            str(p) for p in (self.fingerprint, self.tier, self.shape,
                             self.block_n, self.ndigits, self.dtype,
                             self.interpret, self.device))
        return hashlib.sha256(payload.encode()).hexdigest()


@dataclasses.dataclass
class CompileCacheStats:
    """Counters for the /metrics surface (all cumulative)."""

    hits: int = 0            # served from the in-memory LRU (trace-free)
    misses: int = 0          # full trace + compile paid
    coalesced: int = 0       # waited on another thread's in-flight compile
    evictions: int = 0       # LRU entries dropped at capacity
    disk_hits: int = 0       # rehydrated from persist_dir (trace-free)
    disk_stores: int = 0     # executables serialized to persist_dir
    disk_errors: int = 0     # serialize/deserialize failures (fallback)
    trace_seconds: float = 0.0   # total time spent tracing+compiling

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        total = self.hits + self.misses + self.disk_hits
        d["hit_ratio"] = ((self.hits + self.disk_hits) / total
                          if total else 0.0)
        return d


class _Compiled:
    """One cached executable + its provenance."""

    __slots__ = ("fn", "trace_seconds", "source")

    def __init__(self, fn: Callable, trace_seconds: float, source: str):
        self.fn = fn
        self.trace_seconds = trace_seconds
        self.source = source  # "compile" | "disk"


class _InFlight:
    __slots__ = ("event", "entry", "error")

    def __init__(self):
        self.event = threading.Event()
        self.entry: _Compiled | None = None
        self.error: BaseException | None = None


class CompileCache:
    """Bounded LRU of compiled zero-arg executables.

    ``get(key, build)`` returns a callable whose invocation runs the
    compiled program; ``build`` is a zero-arg *jittable* callable (e.g. the
    thunk ``build_map_call`` returns) that is traced at most once per key
    per process — or zero times, when the persist dir already holds it."""

    def __init__(self, max_entries: int = DEFAULT_MAX_ENTRIES,
                 persist_dir: str | Path | None = None):
        self.max_entries = max_entries
        self.persist_dir = Path(persist_dir) if persist_dir else None
        if self.persist_dir is not None:
            self.persist_dir.mkdir(parents=True, exist_ok=True)
        self.stats = CompileCacheStats()
        self._entries: collections.OrderedDict[ExecKey, _Compiled] = \
            collections.OrderedDict()
        self._inflight: dict[ExecKey, _InFlight] = {}
        self._mu = threading.Lock()

    def __len__(self) -> int:
        with self._mu:
            return len(self._entries)

    def __contains__(self, key: ExecKey) -> bool:
        with self._mu:
            return key in self._entries

    def keys(self) -> list[ExecKey]:
        with self._mu:
            return list(self._entries)

    # -- lookup ------------------------------------------------------------
    def get(self, key: ExecKey, build: Callable[[], Callable]) -> Callable:
        """The compiled executable for ``key`` (tracing via ``build()`` at
        most once per process, coalescing concurrent cold callers)."""
        with self._mu:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                return entry.fn
            fl = self._inflight.get(key)
            leader = fl is None
            if leader:
                fl = self._inflight[key] = _InFlight()
        if not leader:
            fl.event.wait()
            with self._mu:
                self.stats.coalesced += 1
            if fl.error is not None:
                raise fl.error
            return fl.entry.fn  # type: ignore[union-attr]
        try:
            entry = self._load_persisted(key)
            if entry is None:
                entry = self._compile(key, build)
            self._insert(key, entry)
            fl.entry = entry
            return entry.fn
        except BaseException as e:
            fl.error = e
            raise
        finally:
            with self._mu:
                self._inflight.pop(key, None)
            fl.event.set()

    def _insert(self, key: ExecKey, entry: _Compiled) -> None:
        with self._mu:
            self._entries[key] = entry
            self._entries.move_to_end(key)
            while len(self._entries) > max(self.max_entries, 1):
                self._entries.popitem(last=False)
                self.stats.evictions += 1

    # -- compile path ------------------------------------------------------
    def _compile(self, key: ExecKey, build: Callable[[], Callable]
                 ) -> _Compiled:
        import jax

        t0 = time.perf_counter()
        jitted = jax.jit(build())
        compiled = jitted.lower().compile()
        dt = time.perf_counter() - t0
        with self._mu:
            self.stats.misses += 1
            self.stats.trace_seconds += dt
        self._persist(key, jitted)
        return _Compiled(compiled, dt, "compile")

    # -- persistence -------------------------------------------------------
    def _path(self, key: ExecKey) -> Path | None:
        if self.persist_dir is None:
            return None
        return self.persist_dir / f"{key.digest()}.jaxexec"

    def _persist(self, key: ExecKey, jitted) -> None:
        """Best-effort AOT export of a freshly-jitted thunk.  Any failure
        (unsupported lowering, old jaxlib, full disk) degrades to
        in-memory-only and is counted, never raised."""
        path = self._path(key)
        if path is None or path.exists():
            return
        try:
            from jax import export

            data = export.export(jitted)().serialize()
            tmp = path.with_suffix(".tmp")
            tmp.write_bytes(data)
            os.replace(tmp, path)
            with self._mu:
                self.stats.disk_stores += 1
        except Exception:  # noqa: BLE001 — persistence is an optimization
            with self._mu:
                self.stats.disk_errors += 1

    def _load_persisted(self, key: ExecKey) -> _Compiled | None:
        path = self._path(key)
        if path is None or not path.exists():
            return None
        t0 = time.perf_counter()
        try:
            from jax import export

            exported = export.deserialize(bytearray(path.read_bytes()))
            fn = exported.call
        except Exception:  # noqa: BLE001 — corrupt/incompatible: recompile
            with self._mu:
                self.stats.disk_errors += 1
            try:
                path.unlink()
            except OSError:
                pass
            return None
        with self._mu:
            self.stats.disk_hits += 1
        return _Compiled(fn, time.perf_counter() - t0, "disk")

    # -- introspection -----------------------------------------------------
    def clear(self) -> int:
        with self._mu:
            n = len(self._entries)
            self._entries.clear()
        return n

    def stats_dict(self) -> dict[str, Any]:
        with self._mu:
            out = self.stats.as_dict()
            out["entries"] = len(self._entries)
        out["max_entries"] = self.max_entries
        out["persist_dir"] = str(self.persist_dir) if self.persist_dir \
            else None
        return out


# ---------------------------------------------------------------------------
# process default
# ---------------------------------------------------------------------------

_default: CompileCache | None = None
_default_off = False  # configure_default(0) disables the process default
_default_mu = threading.Lock()


def _env_int(name: str, fallback: int) -> int:
    raw = os.environ.get(name, "").strip()
    if not raw:
        return fallback
    try:
        return int(raw)
    except ValueError:
        warnings.warn(f"ignoring malformed {name}={raw!r}", stacklevel=2)
        return fallback


def default_compile_cache() -> CompileCache | None:
    """The process-wide cache (REPRO_COMPILE_CACHE_* env knobs).  Returns
    None when REPRO_COMPILE_CACHE_ENTRIES=0 — caching explicitly off."""
    global _default
    with _default_mu:
        if _default_off:
            return None
        if _default is None:
            entries = _env_int("REPRO_COMPILE_CACHE_ENTRIES",
                               DEFAULT_MAX_ENTRIES)
            if entries <= 0:
                return None
            persist = os.environ.get("REPRO_COMPILE_CACHE_DIR", "").strip() \
                or None
            _default = CompileCache(max_entries=entries, persist_dir=persist)
        return _default


def configure_default(max_entries: int | None = None,
                      persist_dir: str | Path | None = None
                      ) -> CompileCache | None:
    """Rebuild the process default from explicit knobs (the serve CLI path).
    ``max_entries=0`` disables caching process-wide."""
    global _default, _default_off
    with _default_mu:
        entries = max_entries if max_entries is not None else _env_int(
            "REPRO_COMPILE_CACHE_ENTRIES", DEFAULT_MAX_ENTRIES)
        if entries <= 0:
            _default = None
            _default_off = True
            return None
        if persist_dir is None:
            persist_dir = os.environ.get(
                "REPRO_COMPILE_CACHE_DIR", "").strip() or None
        _default_off = False
        _default = CompileCache(max_entries=entries, persist_dir=persist_dir)
        return _default


def resolve(cache) -> CompileCache | None:
    """Normalize a ``compile_cache=`` argument: the USE_DEFAULT sentinel ->
    process default, None -> bypass, a CompileCache -> itself."""
    if cache is USE_DEFAULT:
        return default_compile_cache()
    return cache
