"""MapRegistry — unified per-(domain, logic) registration of thread maps.

Replaces the string-keyed if-chains and ad-hoc ``SCALAR_MAPS``/``VARIANT_MAPS``
dicts that used to live in ``core/maps.py`` and the Pallas kernels.  Every
mapping implementation — ground truth or LLM-derived variant — registers one
or more *tiers* under a ``(domain, logic)`` key:

  scalar      exact python-int reference ``f(lam) -> coords`` (the gold tier),
  unmap       exact inverse ``f(*coords) -> lam``,
  numpy       vectorized exact int64 ``f(lams) -> (N, dim)`` (10^6 validation),
  jnp         traceable ``f(lams, ndigits=13) -> (N, dim)`` for jitted code,
  pallas      in-kernel coordinate emitter ``f(lam_block, ndigits) -> [axes]``,
  membership  in-kernel BB discard test ``f(axes, ndigits) -> bool mask``.

A new geometry is a one-file addition: define the tier callables and call
:func:`register_map` (see ``core/maps/fractal.py`` for the pattern).  Known
plugin modules are imported lazily on the first lookup miss so consumers can
import the registry alone and still resolve every built-in domain.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Iterable, Mapping

TIERS = ("scalar", "unmap", "numpy", "jnp", "pallas", "membership")

#: modules that register the built-in domains/tiers when imported.
DEFAULT_PLUGINS = (
    "repro.core.maps",                      # scalar/unmap/numpy/jnp tiers
    "repro.kernels.domain_map.geometry",    # pallas/membership tiers
)


@dataclasses.dataclass
class MapEntry:
    """All registered tiers + metadata for one (domain, logic) pair."""

    domain: str
    logic: str
    tiers: dict[str, Callable]
    complexity_class: str | None = None
    ground_truth: bool = False

    def tier(self, name: str) -> Callable:
        if name not in self.tiers:
            raise KeyError(
                f"({self.domain!r}, {self.logic!r}) has no {name!r} tier; "
                f"registered: {sorted(self.tiers)}")
        return self.tiers[name]

    @property
    def scalar(self) -> Callable:
        return self.tier("scalar")


class MapRegistry:
    """Plugin registry mapping (domain, logic) -> tiered map implementations."""

    def __init__(self, plugins: Iterable[str] = ()):
        self._entries: dict[tuple[str, str], MapEntry] = {}
        self._ground_truth: dict[str, str] = {}  # domain -> canonical logic
        self._plugins = tuple(plugins)
        self._plugins_loaded = False

    # -- registration ------------------------------------------------------
    def register(
        self,
        domain: str,
        logic: str,
        *,
        tiers: Mapping[str, Callable],
        complexity_class: str | None = None,
        ground_truth: bool = False,
        overwrite: bool = False,
    ) -> MapEntry:
        """Register (or merge into) the entry for (domain, logic)."""
        unknown = set(tiers) - set(TIERS)
        if unknown:
            raise ValueError(f"unknown tiers {sorted(unknown)}; have {TIERS}")
        key = (domain, logic)
        entry = self._entries.get(key)
        if entry is None:
            entry = MapEntry(domain=domain, logic=logic, tiers={},
                             complexity_class=complexity_class,
                             ground_truth=ground_truth)
            self._entries[key] = entry
        for name, fn in tiers.items():
            if name in entry.tiers and not overwrite:
                raise ValueError(
                    f"tier {name!r} already registered for {key}; "
                    f"pass overwrite=True to replace")
            entry.tiers[name] = fn
        if complexity_class is not None:
            entry.complexity_class = complexity_class
        if ground_truth:
            current = self._ground_truth.get(domain, logic)
            if current != logic and not overwrite:
                raise ValueError(
                    f"domain {domain!r} already has ground-truth logic "
                    f"{current!r}; pass overwrite=True to replace it with "
                    f"{logic!r}")
            entry.ground_truth = True
            self._ground_truth[domain] = logic
        return entry

    # -- plugin loading ----------------------------------------------------
    def _load_plugins(self) -> None:
        if self._plugins_loaded:
            return
        for mod in self._plugins:
            importlib.import_module(mod)
        # marked only after every import succeeds, so a failed plugin import
        # surfaces again (as the ImportError) on the next lookup instead of
        # degrading into misleading missing-tier KeyErrors
        self._plugins_loaded = True

    # -- resolution --------------------------------------------------------
    def resolve(self, domain: str, logic: str | None = None) -> MapEntry:
        """Entry for (domain, logic); logic=None -> the ground-truth entry."""
        if logic is None:
            if domain not in self._ground_truth:
                self._load_plugins()
            if domain not in self._ground_truth:
                raise KeyError(
                    f"no ground-truth map registered for domain {domain!r}; "
                    f"have {sorted(self._ground_truth)}")
            logic = self._ground_truth[domain]
        key = (domain, logic)
        if key not in self._entries:
            self._load_plugins()
        if key not in self._entries:
            raise KeyError(
                f"no map registered for {key}; have {sorted(self._entries)}")
        return self._entries[key]

    def tier(self, domain: str, logic: str | None, tier_name: str) -> Callable:
        """Resolve one tier callable, loading plugin modules if needed."""
        entry = self.resolve(domain, logic)
        if tier_name not in entry.tiers:
            # the tier may live in a not-yet-imported plugin (e.g. pallas
            # tiers register from the kernels package) — load and retry.
            self._load_plugins()
            entry = self.resolve(domain, logic)
        return entry.tier(tier_name)

    def ground_truth(self, domain: str) -> MapEntry:
        return self.resolve(domain, None)

    def logics(self, domain: str) -> list[str]:
        """All logic classes registered for a domain (ground truth first)."""
        self._load_plugins()
        found = sorted(l for (d, l) in self._entries if d == domain)
        gt = self._ground_truth.get(domain)
        if gt in found:
            found.remove(gt)
            found.insert(0, gt)
        return found

    def domains(self) -> list[str]:
        self._load_plugins()
        return sorted({d for (d, _) in self._entries})

    def items(self) -> list[tuple[tuple[str, str], MapEntry]]:
        self._load_plugins()
        return sorted(self._entries.items())

    def snapshot(self) -> dict[tuple[str, str], MapEntry]:
        """Currently registered entries WITHOUT triggering plugin loading
        (used by plugin modules themselves to build compatibility views)."""
        return dict(self._entries)

    def __contains__(self, key: tuple[str, str]) -> bool:
        self._load_plugins()
        return tuple(key) in self._entries

    def __len__(self) -> int:
        self._load_plugins()
        return len(self._entries)


#: process-global registry every production consumer resolves through.
REGISTRY = MapRegistry(plugins=DEFAULT_PLUGINS)


def get_registry() -> MapRegistry:
    return REGISTRY


def register_map(
    domain: str,
    logic: str,
    *,
    tier: str = "scalar",
    tiers: Mapping[str, Callable] | None = None,
    complexity_class: str | None = None,
    ground_truth: bool = False,
    overwrite: bool = False,
    registry: MapRegistry | None = None,
):
    """Register a map implementation.

    Two forms:

      # direct — register several tiers at once:
      register_map("gasket2d", "bitwise", ground_truth=True,
                   tiers={"scalar": f, "numpy": g, "jnp": h})

      # decorator — register the decorated callable under one tier:
      @register_map("tri2d", "sqrt_loop", tier="scalar",
                    complexity_class="O(1)")
      def map_tri2d_sqrt_loop(lam): ...
    """
    reg = registry if registry is not None else REGISTRY
    if tiers is not None:
        return reg.register(domain, logic, tiers=dict(tiers),
                            complexity_class=complexity_class,
                            ground_truth=ground_truth, overwrite=overwrite)

    def decorate(fn: Callable) -> Callable:
        reg.register(domain, logic, tiers={tier: fn},
                     complexity_class=complexity_class,
                     ground_truth=ground_truth, overwrite=overwrite)
        return fn

    return decorate
