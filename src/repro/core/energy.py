"""Energy & time accounting (Sec. V.B / V.C).

Two ledgers:
  * inference ledger — one-time joules spent deriving the map (from the
    backend's LLMResponse),
  * deployment ledger — per-run block-level time/energy, with three sources:
      (a) exact block-count accounting (device independent — matches the
          paper's Total/Wasted columns),
      (b) an A100 cost model calibrated on the paper's measured Tables
          VIII/IX entries (per-logic per-block costs),
      (c) a TPU-v5e roofline projection for the Pallas deployment.

The amortization calculator reproduces the paper's "instantly amortized on
the very first execution" claim for fractal domains.
"""
from __future__ import annotations

import dataclasses

from repro.core import paper_tables as pt
from repro.core.domains import Domain

# --- A100 cost model, calibrated directly on Table VIII/IX measurements ----
# per-block kernel time (ns/block) for the *mapped* kernel by logic class,
# derived as time / total_blocks of the corresponding table entries.
_NS = 1e6  # ms -> ns over 1.953125e6 blocks  =>  ms * 1e6 / blocks
_VALID = 1_953_125.0

A100_NS_PER_BLOCK = {
    "analytical_2d": 1.46 * _NS / _VALID,       # 0.75 ns
    "sqrt_loop": 1.97 * _NS / _VALID,
    "approx_if": 1.51 * _NS / _VALID,
    "binsearch_2d": 14.86 * _NS / _VALID,
    "analytical_3d": 3.84 * _NS / _VALID,
    "cbrt_loop": 6.21 * _NS / _VALID,
    "binsearch_3d": 29.31 * _NS / _VALID,
    "binsearch_linear": 51.57 * _NS / _VALID,
    "linear": 117.03 * _NS / _VALID,
    "bitwise_2d": 8.62 * _NS / _VALID,
    "bitwise_3d": 3.30 * _NS / _VALID,
    # bounding-box kernels: cost per *launched* block (waste included),
    # calibrated per domain class from the baseline rows.
    "bb_tri2d": 747.45 * _NS / 3_912_484.0,
    "bb_pyramid3d": 2530.65 * _NS / 12_008_989.0,
    "bb_gasket2d": 65.78 * _NS / 88_736_400.0,
    "bb_sierpinski3d": 15_949.00 * _NS / 8_000_000_000.0,
}

# gross energy per block (J/block), calibrated on the same table rows —
# gross draw folds in the idle baseline, so per-logic anchors beat a single
# power constant.
A100_J_PER_BLOCK = {
    "analytical_2d": 0.44 / _VALID,
    "sqrt_loop": 0.70 / _VALID,
    "approx_if": 0.51 / _VALID,
    "binsearch_2d": 3.21 / _VALID,
    "analytical_3d": 0.92 / _VALID,
    "cbrt_loop": 1.44 / _VALID,
    "binsearch_3d": 5.99 / _VALID,
    "binsearch_linear": 9.12 / _VALID,
    "linear": 22.25 / _VALID,
    "bitwise_2d": 1.39 / _VALID,
    "bitwise_3d": 0.55 / _VALID,
    "bb_tri2d": 83.27 / 3_912_484.0,
    "bb_pyramid3d": 282.67 / 12_008_989.0,
    "bb_gasket2d": 6.73 / 88_736_400.0,
    "bb_sierpinski3d": 1591.71 / 8_000_000_000.0,
}

# average gross power (W) during kernel execution (fallback when a logic
# class has no direct energy anchor).
A100_POWER_W = {"mapped": 295.0, "bounding_box": 108.0}

# TPU v5e single-chip peaks (given hardware constants of the assignment)
TPU_PEAK_FLOPS = 197e12       # bf16 FLOP/s
TPU_HBM_BW = 819e9            # B/s
TPU_ICI_BW = 50e9             # B/s per link
TPU_POWER_W = 170.0           # chip TDP-class estimate for energy projection


def _logic_key(logic: str, domain: Domain) -> str:
    if logic in ("analytical",):
        return "analytical_2d" if domain.dim == 2 else "analytical_3d"
    if logic in ("binsearch",):
        return "binsearch_2d" if domain.dim == 2 else "binsearch_3d"
    if logic in ("bitwise", "permuted"):
        return "bitwise_2d" if domain.dim == 2 else "bitwise_3d"
    return logic


@dataclasses.dataclass(frozen=True)
class DeploymentEstimate:
    strategy: str            # "mapped" | "bounding_box"
    logic: str
    n_points: int
    total_blocks: int
    wasted_blocks: int
    time_ms: float
    energy_j: float

    @property
    def waste_fraction(self) -> float:
        return self.wasted_blocks / self.total_blocks if self.total_blocks else 0.0


def estimate_mapped(domain: Domain, logic: str, n_points: int,
                    block: int = 256) -> DeploymentEstimate:
    blocks = -(-n_points // block)
    key = _logic_key(logic, domain)
    ns = A100_NS_PER_BLOCK[key] * blocks
    t_ms = ns / 1e6
    if key in A100_J_PER_BLOCK:
        energy = A100_J_PER_BLOCK[key] * blocks
    else:
        energy = t_ms / 1e3 * A100_POWER_W["mapped"]
    return DeploymentEstimate(
        strategy="mapped", logic=logic, n_points=n_points,
        total_blocks=blocks, wasted_blocks=0,
        time_ms=t_ms, energy_j=energy,
    )


def estimate_bounding_box(domain: Domain, n_points: int,
                          block: int = 256) -> DeploymentEstimate:
    acc = domain.block_accounting(n_points, block)
    key = f"bb_{domain.name}"
    # calibration exists for the 4 domains the paper measured; others fall
    # back to the same-dimensionality dense calibration.
    if key not in A100_NS_PER_BLOCK:
        key = "bb_tri2d" if domain.dim == 2 else "bb_pyramid3d"
    ns = A100_NS_PER_BLOCK[key] * acc["bb_blocks"]
    t_ms = ns / 1e6
    energy = A100_J_PER_BLOCK.get(key, 0.0) * acc["bb_blocks"] \
        if key in A100_J_PER_BLOCK else t_ms / 1e3 * A100_POWER_W["bounding_box"]
    return DeploymentEstimate(
        strategy="bounding_box", logic="if_O1", n_points=n_points,
        total_blocks=acc["bb_blocks"], wasted_blocks=acc["wasted_blocks"],
        time_ms=t_ms, energy_j=energy,
    )


@dataclasses.dataclass(frozen=True)
class Amortization:
    inference_j: float
    bb_energy_j: float
    mapped_energy_j: float
    savings_per_run_j: float
    runs_to_break_even: float
    speedup: float
    energy_reduction: float


def amortization(domain: Domain, logic: str, inference_j: float,
                 n_points: int = 500_000_000, *,
                 bb: DeploymentEstimate | None = None,
                 mapped: DeploymentEstimate | None = None) -> Amortization:
    """The paper's upfront-cost-vs-permanent-savings calculus (Sec. III.B).

    Callers that already hold the two deployment estimates pass them via
    ``bb``/``mapped`` to avoid recomputing."""
    bb = bb if bb is not None else estimate_bounding_box(domain, n_points)
    mp = mapped if mapped is not None else estimate_mapped(domain, logic,
                                                          n_points)
    savings = bb.energy_j - mp.energy_j
    return Amortization(
        inference_j=inference_j,
        bb_energy_j=bb.energy_j,
        mapped_energy_j=mp.energy_j,
        savings_per_run_j=savings,
        runs_to_break_even=(inference_j / savings) if savings > 0 else float("inf"),
        speedup=bb.time_ms / mp.time_ms if mp.time_ms > 0 else float("inf"),
        energy_reduction=bb.energy_j / mp.energy_j if mp.energy_j > 0 else float("inf"),
    )


def tpu_block_projection(flops_per_block: float, bytes_per_block: float,
                         n_blocks: int) -> dict:
    """Roofline time/energy of a block workload on one TPU v5e chip."""
    t_compute = flops_per_block * n_blocks / TPU_PEAK_FLOPS
    t_memory = bytes_per_block * n_blocks / TPU_HBM_BW
    t = max(t_compute, t_memory)
    return {
        "time_s": t,
        "bound": "compute" if t_compute >= t_memory else "memory",
        "energy_j": t * TPU_POWER_W,
    }


def points_per_joule(valid_points: int, joules: float) -> float:
    """Fig. 5 efficiency metric: correctly mapped points per joule."""
    return valid_points / joules if joules > 0 else 0.0
