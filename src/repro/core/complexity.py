"""Big-O efficiency analysis of synthesized maps (Sec. IV.3 metric 3).

Dynamic analysis: count executed python lines (sys.settrace) of the candidate
at geometrically spaced lambda and fit the growth against the candidate cost
classes the paper observed — O(1), O(log N), O(N^{1/3}), O(N^{1/2}), O(N).
"""
from __future__ import annotations

import sys
from typing import Callable

import numpy as np

PROBE_LAMBDAS = (10**2, 10**3, 10**4, 10**5, 10**6)

_CLASSES: dict[str, Callable[[np.ndarray], np.ndarray]] = {
    "O(1)": lambda lam: np.ones_like(lam, dtype=float),
    "O(log N)": lambda lam: np.log2(lam.astype(float)),
    "O(N^1/3)": lambda lam: lam.astype(float) ** (1.0 / 3.0),
    "O(N^1/2)": lambda lam: lam.astype(float) ** 0.5,
    "O(N)": lambda lam: lam.astype(float),
}


def count_steps(fn: Callable[[int], tuple], lam: int) -> int:
    """Number of line events executed by fn(lam)."""
    counter = 0

    def tracer(frame, event, arg):
        nonlocal counter
        if event == "line":
            counter += 1
        return tracer

    old = sys.gettrace()
    sys.settrace(tracer)
    try:
        fn(lam)
    finally:
        sys.settrace(old)
    return counter


def classify(fn: Callable[[int], tuple],
             probes: tuple[int, ...] = PROBE_LAMBDAS) -> dict:
    """Fit step counts to a cost class; returns class + fit diagnostics.

    A candidate of class f(N) has steps(lambda) ~ a*f(lambda), so the ratio
    steps/f(lambda) is near-constant exactly for the right class — we pick the
    class minimizing the coefficient of variation of that ratio.
    """
    lams = np.asarray(probes, dtype=np.int64)
    steps = np.asarray([count_steps(fn, int(l)) for l in lams], dtype=float)
    cvs: dict[str, float] = {}
    for name, shape in _CLASSES.items():
        ratio = steps / shape(lams)
        cvs[name] = float(ratio.std() / (ratio.mean() + 1e-12))
    best = min(cvs, key=cvs.get)  # type: ignore[arg-type]
    return {
        "class": best,
        "steps": dict(zip((int(l) for l in lams), (int(s) for s in steps))),
        "cvs": cvs,
    }
