"""Ground-truth mapping functions lambda -> coordinates (Table I).

Facade over the per-tier modules — ``dense`` (closed-form Table-I maps),
``fractal`` (base-B digit engine + per-geometry plugins), ``variants``
(the Tables VIII/IX logic classes), ``simplex`` (the m-simplex family) and
``embedded`` (the embedded-2D-fractal family).  Importing this package registers every
built-in map into the :mod:`repro.core.registry`; the dispatch helpers below
(``np_map``/``jnp_map``) and the compatibility dicts (``SCALAR_MAPS``/
``VARIANT_MAPS``) all resolve through that registry — no string-keyed
if-chains remain.
"""
from __future__ import annotations

from collections.abc import Mapping

import jax.numpy as jnp
import numpy as np

from repro.core.maps.dense import (  # noqa: F401
    jnp_map_pyramid3d, jnp_map_tri2d, map_pyramid3d, map_tri2d,
    np_map_pyramid3d, np_map_tri2d, unmap_pyramid3d, unmap_tri2d,
)
from repro.core.maps.embedded import (  # noqa: F401
    map_cantor2d, map_vicsek2d,
)
from repro.core.maps.fractal import (  # noqa: F401
    jnp_map_fractal, map_carpet2d, map_fractal, map_gasket2d, map_menger3d,
    map_sierpinski3d, np_map_fractal, register_fractal_domain, unmap_fractal,
)
from repro.core.maps.simplex import (  # noqa: F401
    jnp_map_msimplex, map_msimplex, np_map_msimplex, register_simplex_domain,
    unmap_msimplex,
)
from repro.core.maps.variants import (  # noqa: F401
    map_pyramid3d_binsearch, map_pyramid3d_cbrt_loop, map_pyramid3d_linear,
    map_tri2d_approx_if, map_tri2d_binsearch, map_tri2d_sqrt_loop,
)
from repro.core.registry import REGISTRY

# ---------------------------------------------------------------------------
# Registry-driven dispatch (previously per-domain if-chains)
# ---------------------------------------------------------------------------


def np_map(domain_name: str, lams: np.ndarray) -> np.ndarray:
    """Vectorized exact int64 ground-truth map for any registered domain."""
    return REGISTRY.tier(domain_name, None, "numpy")(lams)


def jnp_map(domain_name: str, lams: jnp.ndarray, ndigits: int = 13) -> jnp.ndarray:
    """Traceable ground-truth map for any registered domain."""
    return REGISTRY.tier(domain_name, None, "jnp")(lams, ndigits)


def scalar_map(domain_name: str, logic: str | None = None):
    """Exact scalar map for (domain, logic); logic=None -> ground truth."""
    return REGISTRY.tier(domain_name, logic, "scalar")


def unmap(domain_name: str, logic: str | None = None):
    """Exact inverse coords -> lambda for a registered domain."""
    return REGISTRY.tier(domain_name, logic, "unmap")


# ---------------------------------------------------------------------------
# Backward-compatible views of the registry
# ---------------------------------------------------------------------------

class _RegistryView(Mapping):
    """Live read-only dict view over the registry's scalar tiers — maps
    registered after import (plugins, derived artifacts) appear too."""

    def __init__(self, build):
        self._build = build

    def __getitem__(self, key):
        return self._build()[key]

    def __iter__(self):
        return iter(self._build())

    def __len__(self):
        return len(self._build())


#: domain -> ground-truth scalar callable.
SCALAR_MAPS = _RegistryView(lambda: {
    entry.domain: entry.scalar
    for entry in REGISTRY.snapshot().values()
    if entry.ground_truth and "scalar" in entry.tiers
})

#: (domain, logic-class) -> scalar callable; "analytical" is the paper map.
VARIANT_MAPS = _RegistryView(lambda: {
    key: entry.tiers["scalar"]
    for key, entry in sorted(REGISTRY.snapshot().items())
    if "scalar" in entry.tiers
})
