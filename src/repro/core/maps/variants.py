"""Variant logic classes observed in the paper's Tables VIII/IX.

Sqrt+Loop, BinSearch O(log N), Linear O(N^{1/3}), Approx+If: functionally
correct alternatives with different cost profiles — what several LLMs emitted
instead of the closed form.  The deployment benchmarks need them to reproduce
the performance stratification; each registers a scalar tier under its
(domain, logic) key.
"""
from __future__ import annotations

from repro.core.maps.dense import map_tri2d
from repro.core.registry import register_map


@register_map("tri2d", "sqrt_loop", tier="scalar", complexity_class="O(1)")
def map_tri2d_sqrt_loop(lam: int) -> tuple[int, int]:
    """R1:70b (Stage 100): float sqrt seed then while-loop correction."""
    x = int((2.0 * lam) ** 0.5)
    while (x + 1) * (x + 2) // 2 <= lam:
        x += 1
    while x * (x + 1) // 2 > lam:
        x -= 1
    return x, lam - x * (x + 1) // 2


@register_map("tri2d", "binsearch", tier="scalar", complexity_class="O(log N)")
def map_tri2d_binsearch(lam: int) -> tuple[int, int]:
    """Qw3:32b (Stage 50): O(log N) binary search over rows."""
    lo, hi = 0, 1
    while hi * (hi + 1) // 2 <= lam:
        hi *= 2
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if mid * (mid + 1) // 2 <= lam:
            lo = mid
        else:
            hi = mid - 1
    return lo, lam - lo * (lo + 1) // 2


@register_map("tri2d", "approx_if", tier="scalar", complexity_class="O(1)")
def map_tri2d_approx_if(lam: int) -> tuple[int, int]:
    """OSS:20b: float closed form + a single boundary fix-up `if`."""
    x = int(((8.0 * lam + 1.0) ** 0.5 - 1.0) / 2.0)
    if (x + 1) * (x + 2) // 2 <= lam:
        x += 1
    if x * (x + 1) // 2 > lam:
        x -= 1
    return x, lam - x * (x + 1) // 2


@register_map("pyramid3d", "cbrt_loop", tier="scalar", complexity_class="O(1)")
def map_pyramid3d_cbrt_loop(lam: int) -> tuple[int, int, int]:
    """R1:70b / Qw3:32b: cbrt seed + short correction loop (still O(1))."""
    z = int(round((6.0 * lam) ** (1.0 / 3.0)))
    while (z + 1) * (z + 2) * (z + 3) // 6 <= lam:
        z += 1
    while z > 0 and z * (z + 1) * (z + 2) // 6 > lam:
        z -= 1
    x, y = map_tri2d(lam - z * (z + 1) * (z + 2) // 6)
    return x, y, z


@register_map("pyramid3d", "binsearch", tier="scalar",
              complexity_class="O(log N)")
def map_pyramid3d_binsearch(lam: int) -> tuple[int, int, int]:
    """OSS:120b (Stage 100) / Qw3:235b: O(log N) binary search over layers."""
    lo, hi = 0, 1
    while hi * (hi + 1) * (hi + 2) // 6 <= lam:
        hi *= 2
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if mid * (mid + 1) * (mid + 2) // 6 <= lam:
            lo = mid
        else:
            hi = mid - 1
    x, y = map_tri2d(lam - lo * (lo + 1) * (lo + 2) // 6)
    return x, y, lo


@register_map("pyramid3d", "linear", tier="scalar",
              complexity_class="O(N^1/3)")
def map_pyramid3d_linear(lam: int) -> tuple[int, int, int]:
    """OSS:120b (Stage 20): O(N^{1/3}) linear scan over candidate layers."""
    z = 0
    while (z + 1) * (z + 2) * (z + 3) // 6 <= lam:
        z += 1
    x, y = map_tri2d(lam - z * (z + 1) * (z + 2) // 6)
    return x, y, z
