"""m-simplex family plugin (m=2..5) — registry tiers over ``core/msimplex``.

The math lives in :mod:`repro.core.msimplex` (scalar peel + the vectorized
float-seed/exact-ladder layer inversion); this module is the one-file
registration that makes each family member a first-class domain: scalar,
unmap, numpy and jnp tiers under the ``analytical`` logic class (the
generalized sqrt/cbrt of Table I is O(1) per level).  The in-kernel pallas
and membership tiers register from ``kernels/domain_map/geometry.py``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import msimplex as ms
from repro.core.domains import MSIMPLEX_MS
from repro.core.registry import MapRegistry, register_map


def jnp_map_msimplex(lams: jnp.ndarray, m: int, ndigits: int = 13) -> jnp.ndarray:
    """Traceable map for jitted code (digits are a fractal concept)."""
    del ndigits
    return ms.vec_map_msimplex(jnp, lams, m)


def register_simplex_domain(m: int, *, registry: MapRegistry | None = None):
    """Register all scalar/unmap/numpy/jnp tiers for the m-simplex in one
    call (the plugin path for new family members)."""
    return register_map(
        f"msimplex{m}", "analytical",
        complexity_class="O(1)", ground_truth=True, registry=registry,
        tiers={
            "scalar": lambda lam, _m=m: ms.map_msimplex(lam, _m),
            "unmap": lambda *c: ms.unmap_msimplex(c),
            "numpy": lambda lams, _m=m: ms.np_map_msimplex(lams, _m),
            "jnp": lambda lams, ndigits=13, _m=m: jnp_map_msimplex(
                lams, _m, ndigits),
        },
    )


for _m in MSIMPLEX_MS:
    register_simplex_domain(_m)

# backward-compatible named scalar maps
map_msimplex = ms.map_msimplex
unmap_msimplex = ms.unmap_msimplex
np_map_msimplex = ms.np_map_msimplex
