"""Ground-truth maps for the fractal domains: base-B digit decomposition.

One generic digit engine covers every self-similar geometry; a concrete
fractal domain is a *one-call* plugin registration
(:func:`register_fractal_domain`), which is how the four paper fractals below
are wired and how future geometries (e.g. the embedded-2D-fractal family)
plug in.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core.domains import DOMAINS, Domain
from repro.core.registry import MapRegistry, register_map

# ---------------------------------------------------------------------------
# Generic digit engine (all tiers)
# ---------------------------------------------------------------------------


def map_fractal(domain: Domain, lam: int) -> tuple[int, ...]:
    """c = sum_i vec(d_i) * scale^i  where  lam = sum_i d_i * B^i."""
    c = [0] * domain.dim
    s = 1
    while lam > 0:
        d = lam % domain.base
        v = domain.vecs[d]
        for k in range(domain.dim):
            c[k] += v[k] * s
        lam //= domain.base
        s *= domain.scale
    return tuple(c)


def unmap_fractal(domain: Domain, c: tuple[int, ...]) -> int:
    """Inverse: coordinates -> lambda (digit recovery per level)."""
    c = list(c)
    lam = 0
    bpow = 1
    vec_to_digit = {tuple(v): d for d, v in enumerate(domain.vecs)}
    while any(c):
        key = tuple(x % domain.scale for x in c)
        lam += vec_to_digit[key] * bpow
        c = [x // domain.scale for x in c]
        bpow *= domain.base
    return lam


def np_map_fractal(domain: Domain, lams: np.ndarray) -> np.ndarray:
    lams = np.asarray(lams, dtype=np.int64)
    ndig = max(domain.level_for_points(int(lams.max()) + 1), 1) if lams.size else 1
    vecs = np.asarray(domain.vecs, dtype=np.int64)  # (B, dim)
    out = np.zeros((len(lams), domain.dim), dtype=np.int64)
    rem = lams.copy()
    s = 1
    for _ in range(ndig):
        d = rem % domain.base
        out += vecs[d] * s
        rem //= domain.base
        s *= domain.scale
    return out


def jnp_map_fractal(domain: Domain, lams: jnp.ndarray, ndigits: int) -> jnp.ndarray:
    """Fixed digit count (static) so the loop unrolls inside kernels."""
    vecs = jnp.asarray(np.asarray(domain.vecs), dtype=lams.dtype)  # (B, dim)
    out = jnp.zeros(lams.shape + (domain.dim,), dtype=lams.dtype)
    rem = lams
    s = 1
    for _ in range(ndigits):
        d = rem % domain.base
        out = out + vecs[d] * s
        rem = rem // domain.base
        s *= domain.scale
    return out


# ---------------------------------------------------------------------------
# Plugin registration — one call per geometry
# ---------------------------------------------------------------------------


def register_fractal_domain(
    domain: Domain,
    *,
    logic: str = "bitwise",
    complexity_class: str = "O(log N)",
    registry: MapRegistry | None = None,
):
    """Register all scalar/unmap/numpy/jnp tiers for a digit-decomposition
    fractal domain in one call (the plugin path for new geometries)."""
    return register_map(
        domain.name, logic,
        complexity_class=complexity_class, ground_truth=True,
        registry=registry,
        tiers={
            "scalar": functools.partial(map_fractal, domain),
            "unmap": lambda *c, _d=domain: unmap_fractal(_d, c),
            "numpy": functools.partial(np_map_fractal, domain),
            "jnp": functools.partial(jnp_map_fractal, domain),
        },
    )


for _name in ("gasket2d", "carpet2d", "sierpinski3d", "menger3d"):
    register_fractal_domain(DOMAINS[_name])

# backward-compatible named scalar maps
map_gasket2d = functools.partial(map_fractal, DOMAINS["gasket2d"])
map_carpet2d = functools.partial(map_fractal, DOMAINS["carpet2d"])
map_sierpinski3d = functools.partial(map_fractal, DOMAINS["sierpinski3d"])
map_menger3d = functools.partial(map_fractal, DOMAINS["menger3d"])
