"""Embedded-2D-fractal family plugin (related work: efficient GPU thread
mapping on embedded self-similar fractals).

Each family member is a digit-decomposition fractal with an origin-anchored
generator inside a ``scale x scale`` cell grid, so the generic digit engine
in :mod:`repro.core.maps.fractal` covers every tier — registration is one
``register_fractal_domain`` call per member.  The in-kernel pallas and
membership tiers register generically from
``kernels/domain_map/geometry.py``.
"""
from __future__ import annotations

import functools

from repro.core.domains import EMBEDDED_FRACTAL_DOMAINS
from repro.core.maps.fractal import map_fractal, register_fractal_domain

for _d in EMBEDDED_FRACTAL_DOMAINS:
    register_fractal_domain(_d, complexity_class="O(log N)")

# backward-compatible named scalar maps
map_cantor2d = functools.partial(
    map_fractal, next(d for d in EMBEDDED_FRACTAL_DOMAINS
                      if d.name == "cantor2d"))
map_vicsek2d = functools.partial(
    map_fractal, next(d for d in EMBEDDED_FRACTAL_DOMAINS
                      if d.name == "vicsek2d"))
