"""Ground-truth maps for the dense domains (Table I rows 1-2).

Three tiers per domain — scalar (exact python int), numpy (vectorized exact
int64 for the 10^6-point validation) and jnp (traceable for jitted code) —
plus the exact inverse, all registered into the MapRegistry under the
``analytical`` logic class.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import inverse as inv
from repro.core.registry import register_map

# ---------------------------------------------------------------------------
# 2D triangular
# ---------------------------------------------------------------------------


def map_tri2d(lam: int) -> tuple[int, int]:
    """x = floor(sqrt(1/4 + 2*lam) - 1/2), y = lam - x(x+1)/2  (Table I)."""
    x = inv.tri_row(lam)
    return x, lam - inv.tri(x)


def unmap_tri2d(x: int, y: int) -> int:
    return inv.tri(x) + y


def np_map_tri2d(lams: np.ndarray) -> np.ndarray:
    lams = np.asarray(lams, dtype=np.int64)
    x = inv.np_tri_row(lams)
    y = lams - x * (x + 1) // 2
    return np.stack([x, y], axis=-1)


def jnp_map_tri2d(lams: jnp.ndarray, ndigits: int = 13) -> jnp.ndarray:
    del ndigits  # dense maps are closed-form; digits are a fractal concept
    x = inv.jnp_tri_row(lams)
    y = lams - x * (x + 1) // 2
    return jnp.stack([x, y], axis=-1)


# ---------------------------------------------------------------------------
# 3D pyramid
# ---------------------------------------------------------------------------


def map_pyramid3d(lam: int) -> tuple[int, int, int]:
    """z from tetrahedral-number inversion, then the 2D map on the residual."""
    z = inv.tet_layer(lam)
    x, y = map_tri2d(lam - inv.tet(z))
    return x, y, z


def unmap_pyramid3d(x: int, y: int, z: int) -> int:
    return inv.tet(z) + unmap_tri2d(x, y)


def np_map_pyramid3d(lams: np.ndarray) -> np.ndarray:
    lams = np.asarray(lams, dtype=np.int64)
    z = inv.np_tet_layer(lams)
    rem = lams - z * (z + 1) * (z + 2) // 6
    xy = np_map_tri2d(rem)
    return np.concatenate([xy, z[:, None]], axis=-1)


def jnp_map_pyramid3d(lams: jnp.ndarray, ndigits: int = 13) -> jnp.ndarray:
    del ndigits
    z = inv.jnp_tet_layer(lams)
    rem = lams - z * (z + 1) * (z + 2) // 6
    xy = jnp_map_tri2d(rem)
    return jnp.concatenate([xy, z[:, None]], axis=-1)


# ---------------------------------------------------------------------------
# Registration
# ---------------------------------------------------------------------------

register_map("tri2d", "analytical", complexity_class="O(1)", ground_truth=True,
             tiers={"scalar": map_tri2d, "unmap": unmap_tri2d,
                    "numpy": np_map_tri2d, "jnp": jnp_map_tri2d})
register_map("pyramid3d", "analytical", complexity_class="O(1)",
             ground_truth=True,
             tiers={"scalar": map_pyramid3d, "unmap": unmap_pyramid3d,
                    "numpy": np_map_pyramid3d, "jnp": jnp_map_pyramid3d})
