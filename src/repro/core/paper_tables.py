"""Published per-cell results transcribed from the paper (Tables II–IX).

These are the calibration targets for the replay backend and the
claims-validation benchmarks.  Accuracy entries are
(ordered_pct, any_order_pct, compiled); `compiled=False` reproduces the (NC)
cells.  Stages are the in-context sample sizes {20, 50, 100}.
"""
from __future__ import annotations

MODELS = [
    "R1:70b", "Gem3:12b", "Gem3:27b", "OSS:120b", "OSS:20b", "Lla3.3:70b",
    "Lla4:16x17b", "Mist-N:12b", "Nemo:70b", "Qw3:235b", "Qw3:32b",
]

MODEL_FULL_NAMES = {
    "R1:70b": "deepseek-r1:70b", "Gem3:12b": "gemma3:12b",
    "Gem3:27b": "gemma3:27b", "OSS:120b": "gpt-oss:120b",
    "OSS:20b": "gpt-oss:20b", "Lla3.3:70b": "llama3.3:70b",
    "Lla4:16x17b": "llama4:16x17b", "Mist-N:12b": "mistral-nemo:12b",
    "Nemo:70b": "nemotron:70b", "Qw3:235b": "qwen3:235b", "Qw3:32b": "qwen3:32b",
}

STAGES = (20, 50, 100)

_O = True   # compiled ok
_N = False  # (NC)

# domain -> model -> ((ord20, any20, ok), (ord50, any50, ok), (ord100, any100, ok))
ACCURACY: dict[str, dict[str, tuple]] = {
    # ------------------------------------------------ Table II: 2D Triangular
    "tri2d": {
        "R1:70b":      ((100, 100, _O), (100, 100, _O), (100, 100, _O)),
        "Gem3:12b":    ((0, 0, _O), (0, 1.27, _O), (0, 1.83, _O)),
        "Gem3:27b":    ((0, 50.05, _O), (0, 1.27, _O), (0, 50.05, _O)),
        "OSS:120b":    ((100, 100, _O), (100, 100, _O), (100, 100, _O)),
        "OSS:20b":     ((0, 0.71, _O), (100, 100, _O), (100, 100, _O)),
        "Lla3.3:70b":  ((100, 100, _O), (0, 0, _O), (0, 0.14, _O)),
        "Lla4:16x17b": ((0, 0.71, _O), (0, 1.27, _O), (0, 0.01, _O)),
        "Mist-N:12b":  ((0, 0.71, _O), (0, 1.27, _O), (0, 1.69, _O)),
        "Nemo:70b":    ((0, 0, _O), (0, 0.14, _O), (100, 100, _O)),
        "Qw3:235b":    ((100, 100, _O), (0.14, 0.14, _O), (0, 0, _N)),
        "Qw3:32b":     ((100, 100, _O), (100, 100, _O), (100, 100, _O)),
    },
    # ------------------------------------------- Table III: Sierpinski Gasket
    "gasket2d": {
        "R1:70b":      ((0, 8.10, _O), (4.57, 21.30, _O), (0, 1.52, _O)),
        "Gem3:12b":    ((0, 1.03, _O), (0, 1.55, _O), (0, 0.69, _O)),
        "Gem3:27b":    ((0, 1.03, _O), (0, 5.22, _O), (0, 5.22, _O)),
        "OSS:120b":    ((0, 8.10, _O), (100, 100, _O), (100, 100, _O)),
        "OSS:20b":     ((100, 100, _O), (0, 0, _N), (100, 100, _O)),
        "Lla3.3:70b":  ((0, 7.96, _O), (0, 1.17, _O), (0, 3.19, _O)),
        "Lla4:16x17b": ((0, 0.34, _O), (0, 0, _O), (0, 0.01, _O)),
        "Mist-N:12b":  ((0, 0, _O), (0, 3.09, _O), (0, 0.01, _O)),
        "Nemo:70b":    ((0, 8.10, _O), (0, 8.10, _O), (0, 8.10, _O)),
        "Qw3:235b":    ((0, 0, _N), (0, 0, _O), (0, 0, _N)),
        "Qw3:32b":     ((0, 8.10, _O), (0, 0.01, _O), (0, 0, _N)),
    },
    # -------------------------------------------- Table IV: Sierpinski Carpet
    "carpet2d": {
        "R1:70b":      ((0, 0.58, _O), (0, 0, _O), (0, 37.08, _O)),
        "Gem3:12b":    ((0, 0.58, _O), (0, 0.39, _O), (0, 0.58, _O)),
        "Gem3:27b":    ((0, 0.39, _O), (0, 0.20, _N), (0, 1.04, _O)),
        "OSS:120b":    ((0, 0.58, _O), (0.01, 1.04, _O), (100, 100, _O)),
        "OSS:20b":     ((0, 0.58, _O), (0, 0, _N), (0, 0.58, _O)),
        "Lla3.3:70b":  ((0, 0.39, _O), (0, 0.39, _O), (0, 0.46, _O)),
        "Lla4:16x17b": ((0, 0.58, _O), (0, 1.04, _O), (0, 1.56, _O)),
        "Mist-N:12b":  ((0, 0.39, _O), (0, 1.04, _O), (0, 1.30, _O)),
        "Nemo:70b":    ((0, 0, _O), (0, 0.58, _O), (0, 0.10, _O)),
        "Qw3:235b":    ((100, 100, _O), (100, 100, _O), (0, 0, _N)),
        "Qw3:32b":     ((0, 0, _O), (0, 0.03, _O), (0, 0.58, _O)),
    },
    # ------------------------------- Table V: 3D Triangular (tetra / pyramid)
    "pyramid3d": {
        "R1:70b":      ((0.11, 82.70, _O), (100, 100, _O), (0, 0, _O)),
        "Gem3:12b":    ((0, 0.02, _O), (0, 0.02, _O), (0, 0.02, _O)),
        "Gem3:27b":    ((0, 0, _O), (0, 0, _O), (0, 17.17, _O)),
        "OSS:120b":    ((100, 100, _O), (100, 100, _O), (100, 100, _O)),
        "OSS:20b":     ((0, 0, _N), (100, 100, _O), (100, 100, _O)),
        "Lla3.3:70b":  ((0, 0, _O), (0, 17.16, _O), (0, 0, _O)),
        "Lla4:16x17b": ((0, 0, _O), (0, 0, _O), (0, 0, _O)),
        "Mist-N:12b":  ((0, 0.05, _O), (0, 0.18, _O), (0, 0, _O)),
        "Nemo:70b":    ((0, 0.14, _O), (0, 0, _O), (0, 0, _O)),
        "Qw3:235b":    ((100, 100, _O), (0, 16.96, _O), (100, 100, _O)),
        "Qw3:32b":     ((100, 100, _O), (100, 100, _O), (100, 100, _O)),
    },
    # ------------------------------------- Table VI: 3D Sierpinski Pyramid
    "sierpinski3d": {
        "R1:70b":      ((0, 0, _O), (0, 0, _O), (0, 0, _O)),
        "Gem3:12b":    ((0, 0.20, _O), (0, 0.10, _O), (0, 0, _N)),
        "Gem3:27b":    ((0, 0.31, _O), (0, 0.18, _O), (0, 0, _O)),
        "OSS:120b":    ((100, 100, _O), (0, 1.23, _O), (100, 100, _O)),
        "OSS:20b":     ((0, 0, _N), (0, 0, _N), (0, 0, _N)),
        "Lla3.3:70b":  ((0, 0.59, _N), (0, 0, _N), (0, 0.28, _O)),
        "Lla4:16x17b": ((0, 0.01, _O), (0, 1.87, _O), (0, 0, _N)),
        "Mist-N:12b":  ((0, 0.49, _O), (0, 0, _O), (0, 0, _O)),
        "Nemo:70b":    ((0, 0, _N), (0, 0, _N), (0, 2.52, _O)),
        "Qw3:235b":    ((0, 0, _N), (0, 0, _N), (0, 0, _N)),
        "Qw3:32b":     ((0, 0.01, _O), (0, 0.52, _O), (0, 0, _N)),
    },
    # ------------------------------------------ Table VII: 3D Menger Sponge
    "menger3d": {
        "R1:70b":      ((0, 0.05, _O), (0, 0, _N), (0, 0.05, _O)),
        "Gem3:12b":    ((0, 0.05, _O), (0, 0.36, _O), (0, 0.05, _O)),
        "Gem3:27b":    ((0, 0.05, _O), (0, 0.05, _O), (0, 0.05, _O)),
        "OSS:120b":    ((0, 0, _O), (0.01, 0.16, _O), (0.01, 0.36, _O)),
        "OSS:20b":     ((0, 0, _O), (0.01, 0.16, _O), (0, 0, _O)),
        "Lla3.3:70b":  ((0, 0.05, _O), (0, 0.04, _O), (0, 0.36, _O)),
        "Lla4:16x17b": ((0, 0.06, _O), (0, 0.16, _O), (0, 0.16, _O)),
        "Mist-N:12b":  ((0, 0.03, _O), (0, 0, _O), (0, 0.11, _O)),
        "Nemo:70b":    ((0, 0, _N), (0, 0.05, _O), (0, 0.01, _O)),
        "Qw3:235b":    ((0, 0.05, _O), (0.01, 0.16, _O), (0, 0, _N)),
        "Qw3:32b":     ((0, 0, _O), (0, 0.04, _O), (0, 0.14, _O)),
    },
}

# (domain, model, stage) -> logic class emitted, for the 100%-ordered cells
# whose implementation style the paper identifies in Tables VIII/IX.
LOGIC_CLASS_OVERRIDES: dict[tuple[str, str, int], str] = {
    ("tri2d", "R1:70b", 100): "sqrt_loop",
    ("tri2d", "OSS:20b", 50): "approx_if",
    ("tri2d", "OSS:20b", 100): "approx_if",
    ("tri2d", "Qw3:32b", 50): "binsearch",
    ("pyramid3d", "R1:70b", 50): "cbrt_loop",
    ("pyramid3d", "Qw3:32b", 20): "cbrt_loop",
    ("pyramid3d", "Qw3:32b", 50): "cbrt_loop",
    ("pyramid3d", "Qw3:32b", 100): "cbrt_loop",
    ("pyramid3d", "OSS:120b", 100): "binsearch",
    ("pyramid3d", "Qw3:235b", 20): "binsearch",
    ("pyramid3d", "OSS:120b", 50): "binsearch_linear",
    ("pyramid3d", "OSS:120b", 20): "linear",
}

# --------------------------------------------------------------------------
# Table VIII — dense geometries, block-level deployment (N = 500e6, A100)
# time in ms, energy in J.
# --------------------------------------------------------------------------
TABLE_VIII = {
    "tri2d": {
        "bounding_box": dict(time_ms=747.45, total_blocks=3_912_484,
                             wasted=1_959_359, energy_j=83.27, logic="if_O1"),
        "paper": dict(time_ms=1.46, total_blocks=1_953_125, wasted=0,
                      energy_j=0.44, logic="analytical"),
        "R1:70b@20": dict(time_ms=1.46, energy_j=0.45, logic="analytical"),
        "R1:70b@50": dict(time_ms=1.46, energy_j=0.45, logic="analytical"),
        "OSS:120b@all": dict(time_ms=1.46, energy_j=0.45, logic="analytical"),
        "Lla3.3:70b@20": dict(time_ms=1.46, energy_j=0.45, logic="analytical"),
        "R1:70b@100": dict(time_ms=1.97, energy_j=0.70, logic="sqrt_loop"),
        "OSS:20b@50": dict(time_ms=1.51, energy_j=0.51, logic="approx_if"),
        "OSS:20b@100": dict(time_ms=1.51, energy_j=0.51, logic="approx_if"),
        "Qw3:32b@50": dict(time_ms=14.86, energy_j=3.21, logic="binsearch"),
    },
    "pyramid3d": {
        "bounding_box": dict(time_ms=2530.65, total_blocks=12_008_989,
                             wasted=10_055_864, energy_j=282.67, logic="if_O1"),
        "paper": dict(time_ms=3.84, total_blocks=1_953_125, wasted=0,
                      energy_j=0.92, logic="analytical"),
        "R1:70b@50": dict(time_ms=6.21, energy_j=1.44, logic="cbrt_loop"),
        "Qw3:32b@all": dict(time_ms=6.21, energy_j=1.44, logic="cbrt_loop"),
        "OSS:120b@100": dict(time_ms=29.31, energy_j=5.99, logic="binsearch"),
        "Qw3:235b@20": dict(time_ms=29.31, energy_j=5.99, logic="binsearch"),
        "OSS:120b@50": dict(time_ms=51.57, energy_j=9.12, logic="binsearch_linear"),
        "OSS:120b@20": dict(time_ms=117.03, energy_j=22.25, logic="linear"),
    },
}

# --------------------------------------------------------------------------
# Table IX — fractal geometries, block-level deployment (N = 500e6, A100)
# --------------------------------------------------------------------------
TABLE_IX = {
    "gasket2d": {
        "bounding_box": dict(time_ms=65.78, total_blocks=88_736_400,
                             wasted=86_783_275, energy_j=6.73, logic="if_O1"),
        "paper": dict(time_ms=8.62, total_blocks=1_953_125, wasted=0,
                      energy_j=1.39, logic="bitwise"),
        "OSS:120b@20": dict(time_ms=8.62, energy_j=1.39, logic="bitwise"),
    },
    "sierpinski3d": {
        "bounding_box": dict(time_ms=15_949.00, total_blocks=8_000_000_000,
                             wasted=7_998_046_875, energy_j=1591.71,
                             logic="if_O1", projected=True),
        "paper": dict(time_ms=3.30, total_blocks=1_953_125, wasted=0,
                      energy_j=0.55, logic="bitwise"),
        "R1:70b@100": dict(time_ms=3.30, energy_j=0.56, logic="bitwise"),
    },
}

# Headline claims (abstract / Sec. V.C)
CLAIM_SPEEDUP = 4833.0          # 3D Sierpinski: 15949 ms / 3.30 ms
CLAIM_ENERGY_REDUCTION = 2890.0  # 1591.71 J / 0.55 J
