"""AdamW with fp32 moments, global-norm clipping and cosine schedule.

Hand-rolled on pytrees (no optax dependency).  Moments inherit the param
sharding (ZeRO: with `embed -> data` FSDP rules the optimizer state is
sharded 2D exactly like the weights).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = cfg.lr * jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_state(params) -> dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def state_specs(param_spec_tree):
    """Logical-axis specs for the optimizer state (same sharding as params)."""
    return {
        "step": (),
        "m": param_spec_tree,
        "v": param_spec_tree,
    }


def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, state["step"])

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_params, {"step": step, "m": new_m, "v": new_v}, {
        "grad_norm": gnorm, "lr": lr,
    }
