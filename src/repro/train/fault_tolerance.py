"""Fault tolerance: straggler watchdog, checkpoint-restart loop, elastic
re-mesh.

On a real multi-pod deployment the failure signals come from the JAX
distributed runtime (missing heartbeats / collective timeouts).  This module
implements the *policy* layer — fully unit-testable on one host:

  * ``StepWatchdog``    — per-step wall-time tracking; flags stragglers when a
    step exceeds ``threshold x`` the trailing median (the mitigation at scale
    is preemptive re-checkpoint + evict of the slow host).
  * ``ResilientLoop``   — run steps, checkpoint every N, on failure restore
    the latest complete checkpoint and continue (with an injectable failure
    hook used by the tests).
  * ``elastic_restore`` — rebuild params/opt state from a checkpoint onto a
    *different* mesh (survivor topology) via reshard-on-restore.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class StepWatchdog:
    threshold: float = 3.0          # x median => straggler
    window: int = 32
    _times: list = dataclasses.field(default_factory=list)
    stragglers: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; True if this step is a straggler."""
        history = self._times[-self.window:]
        is_straggler = False
        if len(history) >= 8:
            med = statistics.median(history)
            if seconds > self.threshold * med:
                is_straggler = True
                self.stragglers.append((step, seconds, med))
        self._times.append(seconds)
        return is_straggler

    @property
    def median(self) -> float:
        h = self._times[-self.window:]
        return statistics.median(h) if h else 0.0


class InjectedFailure(RuntimeError):
    """Stand-in for a collective timeout / lost host."""


@dataclasses.dataclass
class ResilientLoop:
    """Checkpoint-restart training loop driver."""

    step_fn: Callable[..., tuple]        # (params, opt, batch) -> (p, o, m)
    batch_fn: Callable[[int], Any]       # step -> batch
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restores: int = 8
    failure_hook: Callable[[int], None] | None = None  # tests inject faults
    watchdog: StepWatchdog = dataclasses.field(default_factory=StepWatchdog)

    def run(self, params, opt_state, start_step: int, num_steps: int,
            log_every: int = 0, log_fn=print):
        step = start_step
        restores = 0
        metrics = None
        while step < start_step + num_steps:
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)
                t0 = time.monotonic()
                batch = self.batch_fn(step)
                params, opt_state, metrics = self.step_fn(params, opt_state,
                                                          batch)
                if hasattr(metrics.get("loss", None), "block_until_ready"):
                    metrics["loss"].block_until_ready()
                self.watchdog.observe(step, time.monotonic() - t0)
                step += 1
                if step % self.ckpt_every == 0:
                    ckpt.save(self.ckpt_dir, step, params, opt_state)
                    ckpt.gc_old(self.ckpt_dir, self.keep)
                if log_every and step % log_every == 0:
                    log_fn(f"step {step}: " + ", ".join(
                        f"{k}={float(v):.4f}" for k, v in metrics.items()))
            except InjectedFailure:
                restores += 1
                if restores > self.max_restores:
                    raise
                last = ckpt.latest_step(self.ckpt_dir)
                if last is None:  # nothing saved yet — restart from given state
                    step = start_step
                    continue
                (restored, _) = ckpt.restore(
                    self.ckpt_dir, last, {"params": params,
                                          "opt_state": opt_state})
                params, opt_state = restored["params"], restored["opt_state"]
                step = last
        return params, opt_state, {"final_step": step, "restores": restores,
                                   "metrics": metrics}


def elastic_restore(ckpt_dir: str, step: int, template, target_shardings):
    """Restore a checkpoint onto a different (survivor) mesh topology."""
    return ckpt.restore(ckpt_dir, step, template, shardings=target_shardings)
