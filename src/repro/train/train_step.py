"""Loss + train_step builder.

Features: causal-LM cross entropy (fp32 logsumexp), z-loss, MoE aux loss,
microbatch gradient accumulation (scan), global-norm clipping, AdamW, donated
buffers, optional int8 gradient compression across the `pod` axis.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: opt.OptimizerConfig = dataclasses.field(
        default_factory=opt.OptimizerConfig)
    microbatches: int = 1
    z_loss_coef: float = 1e-4
    moe_aux_coef: float = 1e-2
    grad_compression: bool = False   # int8 cross-pod all-reduce (shard_map)


def lm_loss(params, cfg, batch, z_loss_coef=1e-4, moe_aux_coef=1e-2):
    """Next-token cross entropy; labels = tokens shifted by the data layer."""
    logits, aux = T.forward(params, cfg, batch["tokens"],
                            batch.get("extra"), with_aux=True)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = jnp.sum((lse - picked) * mask) / denom
    zl = z_loss_coef * jnp.sum(jnp.square(lse) * mask) / denom
    total = ce + zl + moe_aux_coef * aux
    return total, {"ce": ce, "z_loss": zl, "moe_aux": aux}


def make_train_step(cfg, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    The batch leading dim is split into `microbatches` accumulation slices.
    """

    def loss_fn(params, mb):
        return lm_loss(params, cfg, mb, tcfg.z_loss_coef, tcfg.moe_aux_coef)

    def grads_of(params, batch):
        if tcfg.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def mb_slice(i, t):
            mb = t.shape[0] // tcfg.microbatches
            return jax.lax.dynamic_slice_in_dim(t, i * mb, mb, axis=0)

        def acc_fn(carry, i):
            loss_a, metrics_a, grads_a = carry
            mb = jax.tree.map(functools.partial(mb_slice, i), batch)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            return (
                loss_a + loss, jax.tree.map(jnp.add, metrics_a, metrics),
                jax.tree.map(jnp.add, grads_a, grads),
            ), None

        zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zeros_m = {"ce": 0.0, "z_loss": 0.0, "moe_aux": 0.0}
        zeros_m = jax.tree.map(jnp.float32, zeros_m)
        (loss, metrics, grads), _ = jax.lax.scan(
            acc_fn, (jnp.float32(0.0), zeros_m, zeros_g),
            jnp.arange(tcfg.microbatches))
        inv = 1.0 / tcfg.microbatches
        return (loss * inv, jax.tree.map(lambda x: x * inv, metrics),
                jax.tree.map(lambda g: g * inv, grads))

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        new_params, new_state, om = opt.apply_updates(
            params, grads, opt_state, tcfg.optimizer)
        return new_params, new_state, {"loss": loss, **metrics, **om}

    return train_step


def make_eval_step(cfg, tcfg: TrainConfig):
    def eval_step(params, batch):
        loss, metrics = lm_loss(params, cfg, batch, tcfg.z_loss_coef,
                                tcfg.moe_aux_coef)
        return {"loss": loss, **metrics}
    return eval_step
