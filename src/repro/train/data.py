"""Synthetic data pipeline (deterministic, host-shardable, restart-safe).

Generates token streams with enough structure to make loss-drop visible
(zipfian unigrams + short-range copy patterns), keyed on (seed, step, host)
so every restart and every host produces identical data independent of
world size — the property elastic restarts rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_period: int = 64     # structure: token t = token t-period sometimes


class SyntheticLM:
    """Deterministic synthetic LM batches: batch[step] is pure f(seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int, host_index: int = 0, host_count: int = 1):
        cfg = self.cfg
        per_host = cfg.global_batch // host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_index]))
        # zipf-ish unigram over a 1024-token active set
        active = min(1024, cfg.vocab_size - 1)
        ranks = np.arange(1, active + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(active, size=(per_host, cfg.seq_len + 1), p=probs) + 1
        # overlay copy structure
        p = cfg.copy_period
        if cfg.seq_len + 1 > p:
            copy_mask = rng.random((per_host, cfg.seq_len + 1 - p)) < 0.5
            toks[:, p:] = np.where(copy_mask, toks[:, :-p], toks[:, p:])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def iter_batches(self, start_step: int = 0, host_index: int = 0,
                     host_count: int = 1) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step, host_index, host_count)
            step += 1
