"""Checkpointing: atomic, restart-safe, mesh-elastic.

Layout:
    <dir>/step_<n>.tmp/        — in-progress write
    <dir>/step_<n>/            — complete (atomic rename)
        arrays_<proc>.npz      — flattened leaf arrays (this process's data)
        manifest.json          — step, tree structure, shapes, dtypes

Restore reshards onto whatever mesh/sharding the *current* job uses
(`jax.device_put` against target shardings), so a checkpoint written on a
(2,16,16) multi-pod mesh restores onto (16,16) survivors — the elastic
scaling path.  Single-controller here (process 0 writes global arrays);
the per-process file naming and manifest carry the multi-host extension.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, params, opt_state=None, extra: dict | None = None):
    """Atomic checkpoint write. Returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    state = {"params": params}
    if opt_state is not None:
        state["opt_state"] = opt_state
    leaves, _ = _flatten_with_paths(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in leaves.items()}
    np.savez(os.path.join(tmp, f"arrays_{jax.process_index()}.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "process_count": jax.process_count(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic completion marker
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, template, shardings=None):
    """Restore into the structure of `template` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings` (same tree) reshards onto the live mesh —
    the elastic-restart path; None keeps arrays on the default device."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "arrays_0.npz"))
    leaves, treedef = _flatten_with_paths(template)
    shard_leaves = None
    if shardings is not None:
        shard_leaves, _ = _flatten_with_paths(shardings)
    out = {}
    for key, tmpl in leaves.items():
        arr = data[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs "
                             f"template {tmpl.shape}")
        if shard_leaves is not None:
            out[key] = jax.device_put(arr, shard_leaves[key])
        else:
            out[key] = jax.device_put(arr.astype(tmpl.dtype))
    restored = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template),
        [out[k] for k in leaves.keys()])
    return restored, manifest


def gc_old(ckpt_dir: str, keep: int = 3):
    """Delete all but the newest `keep` complete checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
