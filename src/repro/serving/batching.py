"""Admission queue + request batching for LLM backends.

The service's coalescing table already collapses concurrent requests for the
*same* cell into one backend call; this module handles the orthogonal case —
concurrent requests for *different* cells on the same model.  A
``BatchingBackend`` wraps any ``LLMBackend`` and funnels its ``generate``
calls through a bounded admission queue drained by one worker thread: the
worker takes the oldest pending request, waits up to ``max_wait`` seconds for
companions (up to ``max_batch``), and issues one batched backend call for the
group (``generate_batch`` when the inner backend has real batched inference,
e.g. ``EngineBackend``'s single padded prefill; a per-item loop otherwise).

Admission control is the back-pressure story for the HTTP frontend: when
``max_pending`` requests are already queued, new arrivals are rejected with
:class:`AdmissionError` — the server maps that to ``503`` so clients retry
with backoff instead of piling onto an overloaded process.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time

from repro.core.backends import LLMBackend, LLMBusyError, LLMResponse
from repro.obs import trace as obs_trace


class AdmissionError(LLMBusyError):
    """The admission queue is full — shed load instead of queueing unboundedly.

    Subclasses :class:`LLMBusyError` so every admission-control path in the
    stack (threaded batcher, continuous batcher, async frontend) speaks one
    retryable error type that the wire layer maps to 503."""


@dataclasses.dataclass
class BatchStats:
    """Counters for one model's batching queue."""

    requests: int = 0        # admitted generate() calls
    rejected: int = 0        # refused at admission (queue full)
    batches: int = 0         # backend calls issued
    batched_requests: int = 0  # requests that shared a call with >=1 other
    max_batch_seen: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Pending:
    __slots__ = ("prompt", "meta", "event", "response", "error")

    def __init__(self, prompt: str, meta: dict):
        self.prompt = prompt
        self.meta = meta
        self.event = threading.Event()
        self.response: LLMResponse | None = None
        self.error: BaseException | None = None


class BatchingBackend:
    """LLMBackend adapter: same ``generate`` surface, batched execution.

    Transparent to the cache layer — ``name`` and ``cache_fingerprint``
    proxy to the wrapped backend, so content addresses are identical with
    and without batching."""

    def __init__(self, inner: LLMBackend, max_batch: int = 8,
                 max_wait: float = 0.01, max_pending: int = 256):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.inner = inner
        self.max_batch = max_batch
        self.max_wait = max_wait
        self.stats = BatchStats()
        self._queue: "queue.Queue[_Pending]" = queue.Queue(maxsize=max_pending)
        self._mu = threading.Lock()
        self._worker: threading.Thread | None = None
        self._stop = threading.Event()

    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def cache_fingerprint(self):
        return getattr(self.inner, "cache_fingerprint", None)

    # -- client side -------------------------------------------------------
    def generate(self, prompt: str, *, meta: dict) -> LLMResponse:
        if self._stop.is_set():
            raise AdmissionError(f"batching queue for {self.name!r} is closed")
        item = _Pending(prompt, meta)
        try:
            self._queue.put_nowait(item)
        except queue.Full:
            with self._mu:
                self.stats.rejected += 1
            raise AdmissionError(
                f"admission queue full ({self._queue.maxsize} pending) for "
                f"model {self.name!r}") from None
        with self._mu:
            self.stats.requests += 1
            self._ensure_worker()
        # poll-wait so a close() racing this admission can never strand us:
        # close() drains the queue with errors, and anything it missed is
        # caught by the stop-flag check here
        with obs_trace.span("batch_wait", model=self.name):
            while not item.event.wait(0.1):
                if self._stop.is_set() and not item.event.is_set():
                    raise AdmissionError(
                        f"batching queue for {self.name!r} closed "
                        "while waiting")
        if item.error is not None:
            raise item.error
        return item.response  # type: ignore[return-value]

    # -- worker side -------------------------------------------------------
    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._drain, name=f"batcher-{self.name}", daemon=True)
            self._worker.start()

    def _collect(self) -> list[_Pending]:
        """Oldest pending request + companions arriving within max_wait.

        A full batch dispatches the instant the ``max_batch``-th request is in
        hand: already-queued companions are drained without blocking first, and
        the deadline loop is only entered for the remaining free slots — a
        burst of ``max_batch`` arrivals never sleeps out ``max_wait``."""
        try:
            first = self._queue.get(timeout=0.1)
        except queue.Empty:
            return []
        batch = [first]
        # eager pass: take whatever is already waiting, no timer involved
        while len(batch) < self.max_batch:
            try:
                batch.append(self._queue.get_nowait())
            except queue.Empty:
                break
        if len(batch) >= self.max_batch:
            return batch
        deadline = time.monotonic() + self.max_wait
        while len(batch) < self.max_batch:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                batch.append(self._queue.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _drain(self) -> None:
        while not self._stop.is_set():
            batch = self._collect()
            if not batch:
                continue
            with self._mu:
                self.stats.batches += 1
                self.stats.max_batch_seen = max(self.stats.max_batch_seen,
                                                len(batch))
                if len(batch) > 1:
                    self.stats.batched_requests += len(batch)
            try:
                t0 = time.monotonic()
                responses = self._run(batch)
                dt = time.monotonic() - t0
                for item, resp in zip(batch, responses):
                    item.response = resp
                    # the drain thread serves many requests, so attribution
                    # goes through each item's meta-carried trace snapshot
                    obs_trace.record_for_meta(
                        item.meta, "engine_generate", dt, batch=len(batch),
                        model=self.name)
            except BaseException as e:  # noqa: BLE001 — fan the error out
                for item in batch:
                    item.error = e
            finally:
                for item in batch:
                    item.event.set()

    def _run(self, batch: list[_Pending]) -> list[LLMResponse]:
        gen_batch = getattr(self.inner, "generate_batch", None)
        if gen_batch is not None and len(batch) > 1:
            return gen_batch([i.prompt for i in batch],
                             [i.meta for i in batch])
        return [self.inner.generate(i.prompt, meta=i.meta) for i in batch]

    def close(self) -> None:
        """Stop the worker and fail any still-pending request — callers must
        never be left blocking on an event nobody will set."""
        self._stop.set()
        if self._worker is not None:
            self._worker.join(timeout=1.0)
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            item.error = AdmissionError(
                f"batching queue for {self.name!r} closed")
            item.event.set()


def batching_factory(backend_factory, max_batch: int = 8,
                     max_wait: float = 0.01, max_pending: int = 256):
    """Wrap a per-model backend factory so every model gets one shared
    BatchingBackend (the 'group concurrent derives for the same model into
    one batched call' knob of the serving stack).  The returned factory
    exposes ``.batchers`` for stats inspection."""
    batchers: dict[str, BatchingBackend] = {}
    mu = threading.Lock()

    def factory(model: str) -> BatchingBackend:
        with mu:
            if model not in batchers:
                batchers[model] = BatchingBackend(
                    backend_factory(model), max_batch=max_batch,
                    max_wait=max_wait, max_pending=max_pending)
            return batchers[model]

    factory.batchers = batchers
    return factory
