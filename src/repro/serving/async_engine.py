"""Continuous batching for the in-repo engine: per-step admission scheduler.

``serving/batching.py`` gathers a batch, runs it to completion, and only then
collects the next one — a derive arriving one millisecond after dispatch waits
out the *entire* decode of the in-flight batch.  This module replaces that
gather-then-drain loop with a step-interleaved scheduler:

* Requests admitted at the same step boundary form a **cohort** sharing one
  batched prefill and one KV cache (the transformer cache keeps a single
  position scalar per layer, so rows of one cache must decode in lock-step —
  cohorts are exactly the granularity at which that invariant holds).
* The worker advances every active cohort by **one decode step per scheduler
  tick**, so multiple cohorts at different positions interleave on the same
  device instead of queueing behind each other.
* New arrivals are admitted at the **next step boundary** — bounded by one
  decode step of latency, not a whole batch drain — as long as total active
  requests stay within ``decode_slots``.

Admission control mirrors the threaded batcher: a bounded pending queue sheds
with :class:`LLMBusyError` (wire 503), and requests that wait longer than
``admission_timeout`` before reaching a slot fail with :class:`LLMTimeoutError`
(wire 504).  :class:`ContinuousBatchingBackend` is a drop-in sync
``LLMBackend`` facade over the scheduler; :class:`AsyncEngineBackend` is the
``AsyncLLMBackend`` face with the ``start/close/health_check/warm`` lifecycle.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Protocol

import numpy as np

from repro.core.backends import (
    EngineBackend,
    LLMBusyError,
    LLMResponse,
    LLMTimeoutError,
)
from repro.obs import trace as obs_trace


class CohortStepper(Protocol):
    """Model-side contract the scheduler drives.

    ``prefill`` turns a group of prompts into opaque cohort state; ``step``
    advances the whole cohort one decode step and reports completion;
    ``finalize`` converts finished state into per-request responses."""

    def prefill(self, prompts: list[str]) -> object: ...

    def step(self, state: object) -> bool: ...

    def finalize(self, state: object,
                 metas: list[dict]) -> list[LLMResponse]: ...


@dataclasses.dataclass
class _EngineCohortState:
    toks: np.ndarray          # (B, prompt_tokens) int32 prompt tokens
    tok: object               # (B, 1) current sampled token (device array)
    cache: object             # per-cohort KV cache (rows share one position)
    key: object               # PRNG key when sampling, else None
    generated: list           # appended (B, 1) token arrays
    steps_done: int = 0
    t0: float = 0.0


class EngineStepper:
    """Drive ``EngineBackend``'s transformer one decode step at a time.

    Reuses the backend's tokenizer, params/config, synthesis fallback and
    energy model, so responses (and therefore content addresses) are
    indistinguishable from the drained-batch path — only the scheduling
    changes."""

    def __init__(self, backend: EngineBackend):
        self.backend = backend
        self._fns = None
        self._mu = threading.Lock()

    def _ensure(self):
        with self._mu:
            if self._fns is None:
                import jax

                from repro.models import transformer as T

                params, cfg = self.backend._ensure_engine()
                prefill = jax.jit(lambda p, t: T.prefill(p, cfg, t))
                step = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))
                self._fns = (params, cfg, prefill, step)
        return self._fns

    def prefill(self, prompts: list[str]) -> _EngineCohortState:
        import jax
        import jax.numpy as jnp

        from repro.serving.engine import greedy

        params, cfg, prefill, _ = self._ensure()
        b = self.backend
        toks = np.stack([b._tokenize(p, cfg.vocab_size) for p in prompts])
        t0 = time.monotonic()
        logits, cache = prefill(params, jnp.asarray(toks))
        key = jax.random.PRNGKey(b.seed) if b.temperature else None
        tok = greedy(logits[:, -1:, : cfg.vocab_size], key, b.temperature)
        return _EngineCohortState(toks=toks, tok=tok.astype(jnp.int32),
                                  cache=cache, key=key, generated=[], t0=t0)

    def step(self, state: _EngineCohortState) -> bool:
        import jax
        import jax.numpy as jnp

        from repro.serving.engine import greedy

        params, cfg, _, step = self._ensure()
        b = self.backend
        state.generated.append(state.tok)
        sub = None
        if state.key is not None:
            state.key, sub = jax.random.split(state.key)
        logits, state.cache = step(params, state.tok, state.cache)
        tok = greedy(logits[:, :, : cfg.vocab_size], sub, b.temperature)
        state.tok = tok.astype(jnp.int32)
        state.steps_done += 1
        return state.steps_done >= b.max_new_tokens

    def finalize(self, state: _EngineCohortState,
                 metas: list[dict]) -> list[LLMResponse]:
        from repro.core import synthesis
        from repro.core.backends import canonical_code

        b = self.backend
        per_seconds = (time.monotonic() - state.t0) / len(metas)
        sampled = np.concatenate(
            [np.asarray(t) for t in state.generated], axis=1)
        out = []
        for meta, row in zip(metas, sampled):
            text = b._detokenize(row)
            try:
                synthesis.synthesize(text)
            except synthesis.SynthesisError:
                text = f"```python\n{canonical_code(meta['domain'])}```"
            out.append(LLMResponse(
                text=text, model=b.name,
                tokens_in=state.toks.shape[1], tokens_out=state.steps_done,
                seconds=per_seconds, joules=per_seconds * b.power_w,
            ))
        return out


@dataclasses.dataclass
class ContinuousStats:
    """Counters for one model's continuous-batching scheduler."""

    slots: int = 0             # configured decode_slots
    requests: int = 0          # admitted submit() calls
    rejected: int = 0          # shed at admission (pending queue full)
    timeouts: int = 0          # expired waiting for a free slot
    completed: int = 0         # responses delivered
    prefills: int = 0          # cohort prefills issued
    steps: int = 0             # decode steps across all cohorts
    cohorts: int = 0           # cohorts formed
    joined_inflight: int = 0   # requests admitted while >=1 cohort was decoding
    occupancy: int = 0         # active requests right now
    max_occupancy: int = 0     # high-water mark of concurrent active requests

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Req:
    __slots__ = ("prompt", "meta", "future", "enqueued")

    def __init__(self, prompt: str, meta: dict):
        self.prompt = prompt
        self.meta = meta
        self.future: concurrent.futures.Future = concurrent.futures.Future()
        self.enqueued = time.monotonic()


class _Cohort:
    __slots__ = ("reqs", "state", "t0")

    def __init__(self, reqs: list[_Req], state: object):
        self.reqs = reqs
        self.state = state
        self.t0 = time.monotonic()


class ContinuousBatcher:
    """Step-interleaved scheduler over a :class:`CohortStepper`.

    One worker thread owns the device: each tick it (1) expires requests that
    waited past ``admission_timeout``, (2) admits queued requests up to the
    free ``decode_slots`` as a fresh cohort (batched prefill), and (3)
    advances every active cohort exactly one decode step.  A request arriving
    mid-decode therefore starts at the next step boundary instead of waiting
    for the in-flight batch to drain."""

    IDLE_WAIT = 0.02

    def __init__(self, stepper: CohortStepper, decode_slots: int = 8,
                 max_pending: int = 256, admission_timeout: float = 30.0,
                 max_cohort: int | None = None):
        if decode_slots < 1:
            raise ValueError("decode_slots must be >= 1")
        self.stepper = stepper
        self.decode_slots = decode_slots
        self.max_pending = max_pending
        self.admission_timeout = admission_timeout
        self.max_cohort = max_cohort or decode_slots
        self.stats = ContinuousStats(slots=decode_slots)
        self._pending: collections.deque[_Req] = collections.deque()
        self._cohorts: list[_Cohort] = []
        self._mu = threading.Lock()
        self._work = threading.Event()
        self._stop = threading.Event()
        self._worker: threading.Thread | None = None

    # -- client side -------------------------------------------------------
    def submit(self, prompt: str, meta: dict) -> concurrent.futures.Future:
        """Enqueue one request; resolves to an LLMResponse.  Sheds with
        LLMBusyError when ``max_pending`` requests already wait."""
        if self._stop.is_set():
            raise LLMBusyError("continuous batcher is closed")
        req = _Req(prompt, meta)
        with self._mu:
            if len(self._pending) >= self.max_pending:
                self.stats.rejected += 1
                raise LLMBusyError(
                    f"admission queue full ({self.max_pending} pending) for "
                    f"continuous batcher")
            self._pending.append(req)
            self.stats.requests += 1
            self._ensure_worker()
        self._work.set()
        return req.future

    def start(self) -> None:
        with self._mu:
            self._ensure_worker()

    # -- worker side -------------------------------------------------------
    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._loop, name="continuous-batcher", daemon=True)
            self._worker.start()

    def _expire(self, now: float) -> None:
        expired = []
        with self._mu:
            kept = collections.deque()
            while self._pending:
                req = self._pending.popleft()
                if now - req.enqueued > self.admission_timeout:
                    expired.append(req)
                else:
                    kept.append(req)
            self._pending = kept
            self.stats.timeouts += len(expired)
        for req in expired:
            req.future.set_exception(LLMTimeoutError(
                f"request waited > {self.admission_timeout:.1f}s for a free "
                f"decode slot"))

    def _admit(self) -> None:
        with self._mu:
            occupancy = sum(len(c.reqs) for c in self._cohorts)
            free = min(self.decode_slots - occupancy, self.max_cohort)
            admitted: list[_Req] = []
            while free > 0 and self._pending:
                req = self._pending.popleft()
                if not req.future.set_running_or_notify_cancel():
                    continue
                admitted.append(req)
                free -= 1
            if not admitted:
                return
            if self._cohorts:
                self.stats.joined_inflight += len(admitted)
        t_admit = time.monotonic()
        try:
            state = self.stepper.prefill([r.prompt for r in admitted])
        except BaseException as e:  # noqa: BLE001 — fan the error out
            for req in admitted:
                req.future.set_exception(e)
            return
        dt_prefill = time.monotonic() - t_admit
        for req in admitted:
            # the scheduler thread serves every request, so attribution goes
            # through each request's meta-carried trace snapshot
            obs_trace.record_for_meta(
                req.meta, "cohort_join", t_admit - req.enqueued,
                cohort=len(admitted))
            obs_trace.record_for_meta(
                req.meta, "engine_prefill", dt_prefill,
                cohort=len(admitted))
        with self._mu:
            self._cohorts.append(_Cohort(admitted, state))
            self.stats.prefills += 1
            self.stats.cohorts += 1
            occupancy = sum(len(c.reqs) for c in self._cohorts)
            self.stats.occupancy = occupancy
            self.stats.max_occupancy = max(self.stats.max_occupancy,
                                           occupancy)

    def _loop(self) -> None:
        while not self._stop.is_set():
            self._expire(time.monotonic())
            self._admit()  # step boundary: new arrivals join here
            with self._mu:
                cohorts = list(self._cohorts)
            if not cohorts:
                if self._work.wait(self.IDLE_WAIT):
                    self._work.clear()
                continue
            finished: list[tuple[_Cohort, BaseException | None]] = []
            for cohort in cohorts:
                try:
                    done = self.stepper.step(cohort.state)
                    with self._mu:
                        self.stats.steps += 1
                    if done:
                        finished.append((cohort, None))
                except BaseException as e:  # noqa: BLE001
                    finished.append((cohort, e))
            for cohort, err in finished:
                with self._mu:
                    self._cohorts.remove(cohort)
                if err is None:
                    try:
                        responses = self.stepper.finalize(
                            cohort.state, [r.meta for r in cohort.reqs])
                    except BaseException as e:  # noqa: BLE001
                        err = e
                if err is not None:
                    for req in cohort.reqs:
                        req.future.set_exception(err)
                else:
                    decode_s = time.monotonic() - cohort.t0
                    steps = getattr(cohort.state, "steps_done", 0)
                    for req, resp in zip(cohort.reqs, responses):
                        obs_trace.record_for_meta(
                            req.meta, "engine_decode", decode_s, steps=steps)
                        req.future.set_result(resp)
                    with self._mu:
                        self.stats.completed += len(cohort.reqs)
            with self._mu:
                self.stats.occupancy = sum(
                    len(c.reqs) for c in self._cohorts)
        self._fail_all(LLMBusyError("continuous batcher closed"))

    def _fail_all(self, err: BaseException) -> None:
        with self._mu:
            pending = list(self._pending)
            self._pending.clear()
            cohorts = list(self._cohorts)
            self._cohorts.clear()
            self.stats.occupancy = 0
        for req in pending:
            if not req.future.done():
                req.future.set_exception(err)
        for cohort in cohorts:
            for req in cohort.reqs:
                if not req.future.done():
                    req.future.set_exception(err)

    def close(self) -> None:
        self._stop.set()
        self._work.set()
        worker = self._worker
        if worker is not None:
            worker.join(timeout=5.0)
        # worker may have exited before _fail_all ran (or never started)
        self._fail_all(LLMBusyError("continuous batcher closed"))


class ContinuousBatchingBackend:
    """Sync ``LLMBackend`` facade over :class:`ContinuousBatcher` — drop-in
    for ``MappingService``: same ``generate`` surface, same content addresses
    (``name``/``cache_fingerprint`` proxy the wrapped engine backend)."""

    def __init__(self, inner: EngineBackend, decode_slots: int = 8,
                 max_pending: int = 256, admission_timeout: float = 30.0):
        self.inner = inner
        self.batcher = ContinuousBatcher(
            EngineStepper(inner), decode_slots=decode_slots,
            max_pending=max_pending, admission_timeout=admission_timeout)

    @property
    def name(self) -> str:
        return self.inner.name

    @property
    def cache_fingerprint(self):
        return getattr(self.inner, "cache_fingerprint", None)

    @property
    def stats(self) -> ContinuousStats:
        return self.batcher.stats

    def generate(self, prompt: str, *, meta: dict) -> LLMResponse:
        fut = self.batcher.submit(prompt, meta)
        # poll-wait so close() racing this call can never strand us
        while True:
            try:
                return fut.result(timeout=0.1)
            except concurrent.futures.TimeoutError:
                if self.batcher._stop.is_set() and not fut.done():
                    raise LLMBusyError(
                        "continuous batcher closed while waiting") from None

    def close(self) -> None:
        self.batcher.close()


class AsyncEngineBackend:
    """``AsyncLLMBackend`` face of the continuous batcher: awaitable
    ``generate`` plus the ``start/close/health_check/warm`` lifecycle."""

    def __init__(self, inner: EngineBackend, decode_slots: int = 8,
                 max_pending: int = 256, admission_timeout: float = 30.0):
        self.inner = inner
        self.name = inner.name
        self.batcher = ContinuousBatcher(
            EngineStepper(inner), decode_slots=decode_slots,
            max_pending=max_pending, admission_timeout=admission_timeout)

    @property
    def cache_fingerprint(self):
        return getattr(self.inner, "cache_fingerprint", None)

    async def start(self) -> None:
        self.batcher.start()

    async def close(self) -> None:
        import asyncio

        loop = asyncio.get_running_loop()
        await loop.run_in_executor(None, self.batcher.close)

    async def health_check(self) -> bool:
        if self.batcher._stop.is_set():
            return False
        worker = self.batcher._worker
        return worker is None or worker.is_alive()

    async def warm(self, timeout_s: float = 120.0) -> None:
        """Run one throwaway generation so params init + jit tracing happen
        before the first real request."""
        import asyncio

        fut = self.batcher.submit(
            "warmup", {"domain": "tri2d", "stage": 0})
        await asyncio.wait_for(asyncio.wrap_future(fut), timeout=timeout_s)

    async def generate(self, prompt: str, *, meta: dict) -> LLMResponse:
        import asyncio

        fut = self.batcher.submit(prompt, meta)
        return await asyncio.wrap_future(fut)


def continuous_factory(backend_factory, decode_slots: int = 8,
                       max_pending: int = 256,
                       admission_timeout: float = 30.0):
    """Per-model factory mirroring ``batching_factory``: every model gets one
    shared :class:`ContinuousBatchingBackend`.  Exposes ``.batchers``."""
    batchers: dict[str, ContinuousBatchingBackend] = {}
    mu = threading.Lock()

    def factory(model: str) -> ContinuousBatchingBackend:
        with mu:
            if model not in batchers:
                batchers[model] = ContinuousBatchingBackend(
                    backend_factory(model), decode_slots=decode_slots,
                    max_pending=max_pending,
                    admission_timeout=admission_timeout)
            return batchers[model]

    factory.batchers = batchers
    return factory
