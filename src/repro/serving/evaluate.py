"""EvaluationService — the batched map-evaluation hot path.

Derivation (PRs 1–5) produces mapping *artifacts*; this service runs them.
It accepts batches of heterogeneous queries — each a ``(domain | artifact
key, tier, λ-range / box extent)`` — and executes them the way deployed
kernels want to be executed:

  * **executable grouping** — queries that resolve to the same compiled
    executable family (same spec identity, tier, block size, interpret
    mode) are merged: the group runs ONE kernel launch padded to the
    widest member, and every member slices its answer out of the shared
    device buffer.  A batch of 20 tri2d prefix queries costs one dispatch.
  * **async dispatch across groups** — all group executables are
    dispatched before any host transfer, so heterogeneous groups overlap
    on device; there are zero host round-trips between same-shape queries
    and exactly one device->host transfer per group.
  * **compiled-executable cache** — resolution goes through
    :mod:`repro.core.compile_cache`, so a warm query pays a dict hit + a
    dispatch, never a re-trace (see ``kernels/domain_map/ops.py``).
  * **multi-device sweeps** — with more than one visible device,
    ``sweep`` shards the λ-range of each grid cell across devices with
    ``shard_map`` over the registry's traceable ``jnp`` tier.

Query schema (one dict per query; the wire form of ``POST /v1/evaluate``):

    {"domain": "tri2d",            # or "key": "<64-hex content address>"
     "tier": "map",                # "map" (default) | "membership"
     "n_points": 4096,             # map tier: λ-range length
     "start": 0,                   # map tier: λ-range offset (default 0)
     "extent": [64, 64],           # membership tier: bounding-box extent
     "block_n": 1024,              # optional; kernel block size
     "interpret": null}            # optional; default: auto per backend

``key`` queries resolve a *derived* artifact by content address through the
artifact store (the paper's Phase-4 integration: only a deployable —
100%-ordered — artifact may drive the mapped kernel).  ``domain`` queries
run the registry's ground-truth geometry.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Callable, Iterable, Iterator, Sequence

import numpy as np

from repro.core import compile_cache as cc
from repro.core.domains import DOMAINS, Domain
from repro.core.registry import REGISTRY
from repro.core.store import valid_key
from repro.kernels.domain_map import ops

#: hard ceiling on one query's output size — a JSON-serialized answer past
#: this is a transport problem, not an evaluation problem (use sweeps).
MAX_POINTS = 1 << 21

TIERS = ("map", "membership")


def auto_interpret() -> bool:
    """Pallas lowers natively on TPU/GPU; anywhere else (CPU CI, tests)
    the kernels run in interpret mode."""
    import jax

    return jax.default_backend() not in ("tpu", "gpu")


@dataclasses.dataclass
class EvalStats:
    """Cumulative counters for the /metrics surface."""

    queries: int = 0          # individual queries admitted
    batches: int = 0          # evaluate_batch calls
    groups: int = 0           # executable groups dispatched
    shared: int = 0           # queries that rode another query's dispatch
    points: int = 0           # points asked for (pre-padding)
    padded_points: int = 0    # points computed (post-padding/merging)
    sweep_cells: int = 0      # cells streamed by sweep()
    sharded_dispatches: int = 0  # multi-device shard_map dispatches
    errors: int = 0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["padding_overhead"] = (
            (self.padded_points - self.points) / self.padded_points
            if self.padded_points else 0.0)
        return d


@dataclasses.dataclass
class _Plan:
    """One admitted query, fully resolved for grouping."""

    index: int
    spec: object              # str domain name | MappingArtifact
    domain: Domain
    tier: str
    n_points: int             # valid points requested (box total for BB)
    start: int
    extent: tuple[int, ...] | None
    block_n: int
    interpret: bool
    padded: int
    ndigits: int
    fingerprint: str

    @property
    def group_key(self) -> tuple:
        if self.tier == "membership":
            # a box kernel's unravel strides bake the extent into the
            # lowering — only identical boxes share an executable
            return (self.fingerprint, "membership", self.extent,
                    self.block_n, self.interpret)
        # map-tier prefix queries share freely: the widest member's output
        # contains every narrower member's answer
        return (self.fingerprint, "map", self.start, self.block_n,
                self.interpret)

    @property
    def wire_key(self) -> tuple:
        """The full answer identity (group identity + this member's exact
        λ-range/extent) — what a cached wire blob is keyed by.  Two queries
        with equal wire keys get byte-identical responses."""
        return (*self.group_key, self.n_points, self.start)


class EvaluationService:
    """Batched evaluation of thread maps over compiled executables.

    ``artifact_resolver`` (optional) maps a 64-hex content address to a
    :class:`~repro.core.artifact.MappingArtifact` (or None) — wire queries
    carrying ``key`` instead of ``domain`` resolve through it; typically
    ``MappingService.artifact_for_key``."""

    def __init__(self, artifact_resolver: Callable | None = None,
                 compile_cache=cc.USE_DEFAULT,
                 max_points: int = MAX_POINTS,
                 default_block_n: int = 1024):
        self.artifact_resolver = artifact_resolver
        self.cache = cc.resolve(compile_cache)
        self.max_points = max_points
        self.default_block_n = default_block_n
        self.stats = EvalStats()
        self._mu = threading.Lock()

    # -- query admission ---------------------------------------------------
    def _resolve_spec(self, q: dict):
        key = q.get("key")
        if key is not None:
            if not isinstance(key, str) or not valid_key(key):
                raise ValueError(
                    "'key' must be a 64-hex artifact content address")
            if self.artifact_resolver is None:
                raise ValueError(
                    "this evaluator cannot resolve artifact keys "
                    "(no store attached)")
            art = self.artifact_resolver(key)
            if art is None:
                raise KeyError(key)
            if not art.deployable:
                raise ValueError(
                    f"artifact {key[:12]}… is not deployable: "
                    f"ordered={art.report.ordered_pct:.2f}% "
                    f"(error={art.report.error!r})")
            return art, art.domainobj
        domain = q.get("domain")
        if not isinstance(domain, str):
            raise ValueError("query must carry string 'domain' or 'key'")
        if domain not in DOMAINS:
            raise KeyError(domain)
        return domain, DOMAINS[domain]

    def _plan(self, index: int, q: dict) -> _Plan:
        if not isinstance(q, dict):
            raise ValueError("each query must be a JSON object")
        spec, dom = self._resolve_spec(q)
        tier = q.get("tier", "map")
        if tier not in TIERS:
            raise ValueError(f"'tier' must be one of {TIERS}, got {tier!r}")
        block_n = q.get("block_n", self.default_block_n)
        if not isinstance(block_n, int) or isinstance(block_n, bool) \
                or block_n <= 0:
            raise ValueError("'block_n' must be a positive integer")
        interpret = q.get("interpret")
        if interpret is None:
            interpret = auto_interpret()
        if not isinstance(interpret, bool):
            raise ValueError("'interpret' must be a boolean")
        if tier == "membership":
            extent = q.get("extent")
            if (not isinstance(extent, (list, tuple)) or not extent
                    or not all(isinstance(e, int) and not isinstance(e, bool)
                               and e > 0 for e in extent)):
                raise ValueError("membership queries need 'extent': a "
                                 "non-empty list of positive integers")
            if len(extent) != dom.dim:
                raise ValueError(
                    f"extent has {len(extent)} axes; domain "
                    f"{dom.name!r} is {dom.dim}-dimensional")
            total = int(np.prod(extent))
            if total > self.max_points:
                raise ValueError(
                    f"extent covers {total} cells > max {self.max_points}")
            _, padded, ndigits = ops.membership_plan(
                spec, tuple(extent), block_n)
            return _Plan(index, spec, dom, tier, total, 0, tuple(extent),
                         block_n, interpret, padded, ndigits,
                         cc.spec_fingerprint(spec))
        n_points = q.get("n_points")
        if not isinstance(n_points, int) or isinstance(n_points, bool) \
                or n_points <= 0:
            raise ValueError("map queries need 'n_points': a positive "
                             "integer")
        if n_points > self.max_points:
            raise ValueError(
                f"n_points {n_points} > max {self.max_points}")
        start = q.get("start", 0)
        if not isinstance(start, int) or isinstance(start, bool) \
                or start < 0:
            raise ValueError("'start' must be a non-negative integer")
        _, padded, ndigits = ops.map_plan(spec, n_points, block_n, start)
        return _Plan(index, spec, dom, tier, n_points, start, None,
                     block_n, interpret, padded, ndigits,
                     cc.spec_fingerprint(spec))

    # -- execution ---------------------------------------------------------
    def _group_executable(self, plans: list[_Plan]):
        """One compiled executable covering every plan in the group (padded
        to the widest member, digits to the deepest member — both exact:
        extra λ range is sliced away, extra digit layers contribute zero)."""
        lead = plans[0]
        padded = max(p.padded for p in plans)
        ndigits = max(p.ndigits for p in plans)
        before = self.cache.stats.misses + self.cache.stats.disk_hits \
            if self.cache is not None else 0
        if lead.tier == "membership":
            call = ops.membership_executable(
                lead.spec, lead.extent, padded, lead.block_n, ndigits,
                lead.interpret, compile_cache=self.cache)
        else:
            call = ops.mapped_executable(
                lead.spec, padded, lead.block_n, ndigits, lead.interpret,
                start=lead.start, compile_cache=self.cache)
        compiled_fresh = self.cache is not None and (
            self.cache.stats.misses + self.cache.stats.disk_hits > before)
        return call, padded, ndigits, compiled_fresh

    def evaluate_batch(self, queries: Sequence[dict]
                       ) -> tuple[list[dict], dict]:
        """Evaluate a heterogeneous batch: ``(results, batch_meta)``.

        Results arrive in query order; each carries its coordinates/mask as
        a numpy array plus grouping/caching provenance.  A malformed query
        fails the whole batch (``ValueError``); an unknown domain or
        artifact key raises ``KeyError`` — both before any dispatch."""
        if not queries:
            raise ValueError("empty query batch")
        try:
            plans = [self._plan(i, q) for i, q in enumerate(queries)]
        except Exception:
            with self._mu:
                self.stats.errors += 1
            raise
        groups: dict[tuple, list[_Plan]] = {}
        for p in plans:
            groups.setdefault(p.group_key, []).append(p)

        # phase 1 — dispatch every group (device work overlaps; no host
        # transfer yet)
        launched = []
        for members in groups.values():
            call, padded, ndigits, fresh = self._group_executable(members)
            launched.append((members, call(), padded, ndigits, fresh))

        # phase 2 — one transfer per group, then pure-host slicing
        results: list[dict] = [None] * len(plans)  # type: ignore[list-item]
        for gid, (members, out_dev, padded, ndigits, fresh) in \
                enumerate(launched):
            out = np.asarray(out_dev)
            for p in members:
                if p.tier == "membership":
                    # the kernel's int32 0/1 column is logically boolean —
                    # publish it as bool_ (1 byte/cell on the wire) and let
                    # the dtype ride the payload so clients round-trip it
                    data = {"mask": out[0, :p.n_points].astype(np.bool_)}
                else:
                    data = {"coords": out[:p.domain.dim, :p.n_points].T}
                results[p.index] = {
                    "index": p.index,
                    "domain": p.domain.name,
                    "tier": p.tier,
                    "n_points": p.n_points,
                    "start": p.start,
                    "extent": list(p.extent) if p.extent else None,
                    "block_n": p.block_n,
                    "ndigits": ndigits,
                    "padded": padded,
                    "interpret": p.interpret,
                    "group": gid,
                    "group_size": len(members),
                    "executable": "miss" if fresh else "hit",
                    **data,
                }
        with self._mu:
            self.stats.queries += len(plans)
            self.stats.batches += 1
            self.stats.groups += len(groups)
            self.stats.shared += len(plans) - len(groups)
            self.stats.points += sum(p.n_points for p in plans)
            # per dispatched query: every member of a group is served from
            # the group's padded launch, so a group of k queries padded to
            # P accounts k*P — keeping padded_points >= points and the
            # derived padding_overhead in [0, 1) even when merging wins
            self.stats.padded_points += sum(
                lp * len(members) for (members, _, lp, _, _) in launched)
        meta = {
            "queries": len(plans),
            "groups": len(groups),
            "dispatches": len(groups),
        }
        return results, meta

    def evaluate(self, query: dict) -> dict:
        """Single-query form of :meth:`evaluate_batch`."""
        results, _ = self.evaluate_batch([query])
        return results[0]

    # -- wire-cache identity -------------------------------------------------
    def batch_cache_key(self, queries: Sequence[dict]
                        ) -> tuple[tuple, tuple[str, ...]] | None:
        """``(batch identity, artifact keys)`` for the frontends' encoded-
        response LRU: per member the resolved executable group plus the
        exact λ-range/extent, so equal keys guarantee byte-identical
        answers.  ``None`` when any query fails admission — the caller
        falls through to :meth:`evaluate_batch`, which raises the
        authoritative (400/404) error.  Planning is pure resolution (dict
        lookups + arithmetic, no dispatch), cheap enough for a hot path."""
        try:
            plans = [self._plan(i, q) for i, q in enumerate(queries)]
        except Exception:  # noqa: BLE001 — identity only, never authoritative
            return None
        arts = sorted({p.fingerprint.split(":", 1)[1] for p in plans
                       if p.fingerprint.startswith("artifact:")})
        return tuple(p.wire_key for p in plans), tuple(arts)

    def cache_generation(self) -> int:
        """Compile-cache eviction count — the generation stamp that expires
        frontend wire blobs when the executable LRU rotates (a cached
        response's ``executable: hit`` provenance is only honest while the
        executables it rode are still resident)."""
        return self.cache.stats.evictions if self.cache is not None else 0

    # -- sweeps ------------------------------------------------------------
    def sweep(self, domains: Iterable[str], sizes: Iterable[int],
              tier: str = "map", block_n: int | None = None,
              interpret: bool | None = None) -> Iterator[dict]:
        """Grid sweep over (domain × n_points), streaming one result per
        cell — the NDJSON surface of ``POST /v1/evaluate``.  With more than
        one visible device, each map-tier cell's λ-range is sharded across
        devices via ``shard_map`` over the registry's ``jnp`` tier."""
        import jax

        domains = list(domains)
        sizes = [int(s) for s in sizes]
        if not domains or not sizes:
            raise ValueError("sweep needs non-empty 'domains' and sizes")
        n_dev = len(jax.devices())
        for name in domains:
            for n in sizes:
                q = {"domain": name, "n_points": n, "tier": tier}
                if block_n is not None:
                    q["block_n"] = block_n
                if interpret is not None:
                    q["interpret"] = interpret
                if tier == "map" and n_dev > 1:
                    res = self._sharded_cell(name, n, n_dev)
                else:
                    res = self.evaluate(q)
                with self._mu:
                    self.stats.sweep_cells += 1
                yield res

    def _sharded_cell(self, name: str, n_points: int, n_dev: int) -> dict:
        """One sweep cell evaluated across every visible device: shard_map
        splits the λ-range, each device runs the registry's traceable jnp
        map on its shard.  The compiled program is cached like any other
        executable (tier ``map_sharded``)."""
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        if name not in DOMAINS:
            raise KeyError(name)
        dom = DOMAINS[name]
        if n_points > self.max_points:
            raise ValueError(
                f"n_points {n_points} > max {self.max_points}")
        padded = -(-n_points // n_dev) * n_dev
        ndigits = max(dom.level_for_points(padded), 1) \
            if dom.kind == "fractal" else 13
        fn = REGISTRY.tier(name, None, "jnp")
        devices = np.array(jax.devices())

        def build():
            mesh = Mesh(devices, ("d",))

            def run():
                lams = jnp.arange(padded, dtype=jnp.int32)
                return shard_map(
                    lambda l: fn(l, ndigits),
                    mesh=mesh, in_specs=P("d"), out_specs=P("d"))(lams)

            return run

        if self.cache is not None:
            key = cc.ExecKey(f"domain:{name}", "map_sharded",
                             (padded, n_dev), 0, ndigits)
            call = self.cache.get(key, build)
        else:
            call = build()
        coords = np.asarray(call())[:n_points]
        with self._mu:
            self.stats.queries += 1
            self.stats.sharded_dispatches += 1
            self.stats.points += n_points
            self.stats.padded_points += padded
        return {
            "index": 0, "domain": name, "tier": "map",
            "n_points": n_points, "start": 0, "extent": None,
            "block_n": 0, "ndigits": ndigits, "padded": padded,
            "interpret": False, "group": 0, "group_size": 1,
            "executable": "sharded", "devices": n_dev,
            "coords": coords,
        }

    # -- introspection -----------------------------------------------------
    def stats_dict(self) -> dict:
        with self._mu:
            out = self.stats.as_dict()
        if self.cache is not None:
            out["compile_cache"] = self.cache.stats_dict()
        return out


def wire_result(res: dict) -> dict:
    """JSON-safe form of one evaluation result: arrays become lists, and a
    ``dtype`` side-channel records each array's native dtype so the client
    rehydrates exactly what the server computed (the binary codec carries
    the same identity in its segment header)."""
    out = dict(res)
    dtypes = {}
    for field in ("coords", "mask"):
        if out.get(field) is not None:
            arr = np.asarray(out[field])
            dtypes[field] = arr.dtype.name
            out[field] = arr.tolist()
    if dtypes:
        out["dtype"] = dtypes
    return out


def encoded_batch_response(evaluator: EvaluationService, cache,
                           queries: Sequence[dict], *, single: bool,
                           binary: bool) -> bytes:
    """Evaluate a (single|batch) request straight to encoded response
    bytes, through an optional :class:`~repro.serving.wire.WireCache` —
    the one evaluate hot path both frontends share, so the threaded and
    asyncio servers can never disagree on bytes.

    Cache policy mirrors the async frontend's derive blob cache: only
    responses whose every member rode an already-compiled executable
    (``executable: hit``) are cached — a first-launch response truthfully
    says ``miss`` exactly once, and repeats cache the honest rehydrated
    bytes.  Entries are keyed by resolved executable group + λ-range and
    generation-stamped against compile-cache eviction."""
    from repro.serving import wire

    cell = None
    identity = evaluator.batch_cache_key(queries) if cache is not None \
        else None
    if identity is not None:
        cell = ("bin" if binary else "json",
                "single" if single else "batch", identity[0])
        blob = cache.get(cell, evaluator.cache_generation())
        if blob is not None:
            return blob
    results, meta = evaluator.evaluate_batch(list(queries))
    if binary:
        payload = results[0] if single \
            else {"results": results, "batch": meta}
        blob = wire.encode_frame(payload)
    else:
        payload = wire_result(results[0]) if single \
            else {"results": [wire_result(r) for r in results],
                  "batch": meta}
        blob = json.dumps(payload, default=str).encode()
    if cell is not None and all(r.get("executable") == "hit"
                                for r in results):
        cache.put(cell, blob, evaluator.cache_generation(),
                  artifact_keys=identity[1])
    return blob


def hydrate_result(payload: dict) -> dict:
    """Client-side inverse of :func:`wire_result`.  Dtypes come from the
    payload's ``dtype`` field; against an older server that doesn't send
    one, int32 (those servers also computed int32) keeps the round-trip
    faithful rather than guessed."""
    out = dict(payload)
    dtypes = out.pop("dtype", None) or {}
    for field, fallback in (("coords", np.int32), ("mask", np.int32)):
        val = out.get(field)
        if val is not None and not isinstance(val, np.ndarray):
            out[field] = np.asarray(
                val, dtype=np.dtype(dtypes.get(field, fallback)))
    return out
