"""Asyncio event-loop frontend for the MappingService — stdlib only.

The threaded frontend (``serving/http.py``) spends one OS thread per open
connection; at C10K-scale concurrency those threads are mostly parked on
socket reads, burning memory and scheduler time (the serving-tier analogue of
the paper's wasted GPU blocks).  :class:`AsyncMappingHTTPServer` serves the
same wire surface from a single event loop:

  * **hot path inline** — a derive whose cell is already resident resolves on
    the event loop itself via :meth:`MappingService.try_cached` (two dict
    lookups once warm) plus a wire-bytes LRU that skips re-serialization, so
    the common request costs no thread handoff at all;
  * **cold path offloaded** — pipeline runs, evaluation launches and
    forwarding hops execute on a bounded worker pool behind frontend
    admission control: past ``max_pending`` in-flight offloads the server
    sheds with 503 exactly like the threaded path's batching queue;
  * **backpressure-aware streaming** — /v1/grid and /v1/evaluate sweeps are
    *pull*-driven: the producer advances one cell per ``await drain()``, so a
    stalled reader pauses production at the write-buffer high-water mark
    (``stream_buffer_bytes``) instead of buffering the rest of the sweep, and
    never blocks other connections.

Route surface, status codes (via :func:`~repro.serving.http.map_error`) and
the /metrics payload shape are identical to the threaded server, so the
pooled keep-alive client (``serving/client.py``) and the cluster fabric work
against either frontend unchanged.  Typed backend errors map to wire codes:
``LLMBusyError`` → 503 retryable, ``LLMTimeoutError`` → 504 retryable.
"""
from __future__ import annotations

import asyncio
import collections
import concurrent.futures
import contextvars
import json
import socket
import threading
import time
import urllib.error
import urllib.request

from repro.core import pipeline
from repro.core import store as store_mod
from repro.core.backends import LLMBusyError
from repro.core.domains import DOMAINS
from repro.obs import Observability
from repro.obs import trace as obs_trace
from repro.serving import wire
from repro.serving.http import (
    FORWARDED_HEADER,
    MAX_BODY_BYTES,
    collect_metrics,
    map_error,
)
from repro.serving.map_service import MappingService

_REASONS = {
    200: "OK", 400: "Bad Request", 404: "Not Found",
    500: "Internal Server Error", 503: "Service Unavailable",
    504: "Gateway Timeout",
}

_SENTINEL = object()


def _head(status: int, content_type: str, length: int | None,
          close: bool, extra: dict | None = None) -> bytes:
    lines = [f"HTTP/1.1 {status} {_REASONS.get(status, 'OK')}",
             f"Content-Type: {content_type}"]
    if length is not None:
        lines.append(f"Content-Length: {length}")
    if extra:
        lines.extend(f"{name}: {value}" for name, value in extra.items())
    if close:
        lines.append("Connection: close")
    return ("\r\n".join(lines) + "\r\n\r\n").encode()


class _Conn:
    """One keep-alive connection's parsed-request context + reply helpers."""

    __slots__ = ("reader", "writer", "method", "path", "headers", "raw",
                 "keep_alive", "responded")

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter):
        self.reader = reader
        self.writer = writer
        self.method = ""
        self.path = ""
        self.headers: dict[str, str] = {}
        self.raw = b""
        self.keep_alive = True
        self.responded = False

    def body(self) -> dict:
        if not self.raw:
            return {}
        body = json.loads(self.raw)
        if not isinstance(body, dict):
            raise ValueError("request body must be a JSON object")
        return body

    async def send_bytes(self, status: int, body: bytes,
                         content_type: str = "application/json",
                         close: bool = False) -> None:
        if close:
            self.keep_alive = False
        self.responded = True
        # echo the active trace ID (set by _dispatch) back to the caller
        extra = obs_trace.wire_headers() or None
        self.writer.write(
            _head(status, content_type, len(body), close, extra) + body)
        await self.writer.drain()

    async def send_json(self, status: int, payload: dict,
                        close: bool = False) -> None:
        # default=str matches the store's serialization (see serving/http.py)
        body = json.dumps(payload, default=str).encode()
        if status >= 400 and self.raw:
            # error responses on keep-alive connections whose body might not
            # have been consumed close-delimit, mirroring the threaded server
            close = True
        await self.send_bytes(status, body, close=close)


class AsyncMappingHTTPServer:
    """Event-loop face of one MappingService.

    ``port=0`` binds an ephemeral port in ``__init__`` (read ``.port`` /
    ``.url`` immediately).  ``start()`` spins the loop in a daemon thread
    (the test/embedding shape); ``serve_forever()`` blocks the caller (the
    CLI shape).  Usable as a context manager.  ``async_backends`` is an
    optional list of ``AsyncLLMBackend`` instances whose lifecycle
    (``start``/``warm``/``health_check``/``close``) the server drives."""

    def __init__(self, service: MappingService, host: str = "127.0.0.1",
                 port: int = 0, max_workers: int = 16,
                 max_pending: int = 256, idle_timeout: float = 60.0,
                 stream_buffer_bytes: int = 256 * 1024,
                 stall_threshold: float = 0.25,
                 wire_cache_entries: int = 1024,
                 async_backends: list | None = None,
                 observability: bool = True,
                 router=None, serve_delay: float = 0.0):
        from repro.serving.router import RequestRouter

        self.service = service
        self.cluster = None
        self.forwarded = 0
        self.forward_errors = 0
        self.forward_timeout = 30.0
        #: per-node scheduler + load-aware replica selector (see the
        #: threaded frontend — forwards go to the *best* owner)
        self.router = router if router is not None else RequestRouter()
        #: chaos/benchmark knob: delay every derive this long (an
        #: artificially slowed replica the selector must route around);
        #: awaited on the loop, so other connections keep being served
        self.serve_delay = max(0.0, float(serve_delay))
        self.obs = Observability(mode="async", enabled=observability)
        self.max_pending = max_pending
        self.idle_timeout = idle_timeout
        self.stream_buffer_bytes = stream_buffer_bytes
        self.stall_threshold = stall_threshold
        self.async_backends = list(async_backends or [])
        # frontend counters (the "aio" section of /metrics)
        self.fast_hits = 0        # derives served inline off try_cached
        self.wire_hits = 0        # ... without even re-serializing
        self.offloaded = 0        # requests that took the worker pool
        self.shed = 0             # 503s from frontend admission control
        self.stream_stalls = 0    # drains that exceeded stall_threshold
        self.connections = 0      # open connections right now
        self._pending = 0         # in-flight offloads (loop-thread only)
        self._wire_cache: "collections.OrderedDict[tuple, tuple[str, bytes]]" \
            = collections.OrderedDict()
        self._wire_cache_entries = wire_cache_entries
        #: encoded evaluate responses (binary or JSON), keyed by resolved
        #: executable group + λ-range — warm hits serve inline on the loop
        self.eval_wire = wire.WireCache(entries=wire_cache_entries)
        self.eval_wire_hits = 0   # evaluates served inline off eval_wire
        self._eval_served = False  # first evaluate (jax import) completed
        self._evaluator = None
        self._evaluator_mu = threading.Lock()
        self._executor = concurrent.futures.ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="aio-worker")
        # fleet-sized accept backlog, matching the threaded frontend's
        # _FleetHTTPServer: connection bursts must queue, not reset
        self._sock = socket.create_server((host, port), backlog=128,
                                          reuse_port=False)
        self.host = host
        self.port = self._sock.getsockname()[1]
        self.obs.node = self.url
        self._loop: asyncio.AbstractEventLoop | None = None
        self._thread: threading.Thread | None = None
        self._started = threading.Event()
        self._stopping = False
        self._shutdown: asyncio.Event | None = None
        self._server: asyncio.AbstractServer | None = None
        self._writers: set[asyncio.StreamWriter] = set()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    @property
    def evaluator(self):
        with self._evaluator_mu:
            if self._evaluator is None:
                from repro.serving.evaluate import EvaluationService

                self._evaluator = EvaluationService(
                    artifact_resolver=self.service.artifact_for_key)
            return self._evaluator

    def attach_cluster(self, cluster):
        """Join a sharded fleet — same wiring as the threaded server (ring
        into the peer tier, store to anti-entropy, heartbeats on)."""
        from repro.core.store import PeerStore

        self.cluster = cluster
        store = self.service.store
        if store is not None:
            if store.peer is None:
                store.peer = PeerStore(router=cluster.replica_peers)
            else:
                store.peer.router = cluster.replica_peers
            cluster.store = store
        # load piggyback + selector feedback, same as the threaded server
        if cluster.load_provider is None:
            cluster.load_provider = self.router.load
        if cluster.on_load is None:
            cluster.on_load = self.router.advertise
        cluster.start()
        return cluster

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "AsyncMappingHTTPServer":
        self._thread = threading.Thread(
            target=self._run_loop, name="mapping-aio", daemon=True)
        self._thread.start()
        if not self._started.wait(timeout=30.0):
            raise RuntimeError("async server failed to start")
        return self

    def serve_forever(self) -> None:
        if self._thread is None:
            self.start()
        try:
            while self._thread is not None and self._thread.is_alive():
                self._thread.join(timeout=0.5)
        except KeyboardInterrupt:
            pass
        finally:
            self.close()

    def _run_loop(self) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop
        try:
            loop.run_until_complete(self._main())
        finally:
            loop.close()

    async def _main(self) -> None:
        self._shutdown = asyncio.Event()
        for backend in self.async_backends:
            await backend.start()
        self._server = await asyncio.start_server(
            self._handle, sock=self._sock)
        self._started.set()
        await self._shutdown.wait()
        self._server.close()
        await self._server.wait_closed()
        for writer in list(self._writers):
            writer.close()
        for backend in self.async_backends:
            try:
                await backend.close()
            except Exception:  # noqa: BLE001 — shutdown is best-effort
                pass
        # reap in-flight connection tasks so loop.close() is clean
        tasks = [t for t in asyncio.all_tasks()
                 if t is not asyncio.current_task()]
        for task in tasks:
            task.cancel()
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)

    async def warm(self, timeout_s: float = 120.0) -> None:
        for backend in self.async_backends:
            await backend.warm(timeout_s=timeout_s)

    def close(self) -> None:
        if self._stopping:
            return
        self._stopping = True
        if self.cluster is not None:
            self.cluster.close()
        loop, shutdown = self._loop, self._shutdown
        if loop is not None and shutdown is not None and loop.is_running():
            loop.call_soon_threadsafe(shutdown.set)
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._executor.shutdown(wait=False, cancel_futures=True)
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "AsyncMappingHTTPServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- metrics -----------------------------------------------------------
    def observe(self, endpoint: str, seconds: float, ok: bool) -> None:
        self.obs.observe(endpoint, seconds, ok)

    def metrics(self) -> dict:
        with self._evaluator_mu:
            evaluator = self._evaluator
        out = collect_metrics(
            self.service, self.obs.http_dict(), cluster=self.cluster,
            forwarded=self.forwarded, forward_errors=self.forward_errors,
            evaluator=evaluator, frontend=self.obs.frontend_dict(),
            router=self.router, eval_wire=self.eval_wire)
        # event-loop frontend counters ride inside the shared "frontend"
        # section (parity with the threaded server's key set) and stay
        # aliased at the legacy top-level "aio" key for existing consumers
        out["frontend"]["aio"] = out["aio"] = {
            "fast_hits": self.fast_hits,
            "wire_hits": self.wire_hits,
            "eval_wire_hits": self.eval_wire_hits,
            "offloaded": self.offloaded,
            "shed": self.shed,
            "stream_stalls": self.stream_stalls,
            "connections": self.connections,
            "pending": self._pending,
            "max_pending": self.max_pending,
        }
        return out

    def metrics_prometheus(self) -> str:
        """Prometheus text exposition of the same numbers (see the threaded
        server's ``metrics_prometheus``)."""
        return self.obs.prometheus(self.metrics())

    # -- offload with admission control -------------------------------------
    async def _offload(self, fn, *args, admitted: bool = True):
        """Run blocking work on the worker pool.  ``admitted=True`` paths
        count against ``max_pending`` and shed with LLMBusyError → 503 when
        the frontend is saturated (mirror of the batching queue's story).
        The call runs under a copy of the event loop's context so the
        active trace (a contextvar) survives the thread handoff —
        ``run_in_executor`` alone would drop it."""
        if admitted:
            if self._pending >= self.max_pending:
                self.shed += 1
                raise LLMBusyError(
                    f"async frontend at capacity ({self.max_pending} "
                    f"requests in flight)")
            self._pending += 1
            self.offloaded += 1
        ctx = contextvars.copy_context()
        try:
            return await asyncio.get_running_loop().run_in_executor(
                self._executor, ctx.run, fn, *args)
        finally:
            if admitted:
                self._pending -= 1

    # -- wire-bytes hot cache ------------------------------------------------
    def _wire_get(self, cell: tuple) -> bytes | None:
        hit = self._wire_cache.get(cell)
        if hit is None:
            return None
        self._wire_cache.move_to_end(cell)
        return hit[1]

    def _wire_put(self, cell: tuple, key: str, blob: bytes) -> None:
        self._wire_cache[cell] = (key, blob)
        self._wire_cache.move_to_end(cell)
        while len(self._wire_cache) > self._wire_cache_entries:
            self._wire_cache.popitem(last=False)

    def _wire_invalidate(self, key: str) -> None:
        stale = [cell for cell, (k, _) in self._wire_cache.items()
                 if k == key]
        for cell in stale:
            self._wire_cache.pop(cell, None)

    # -- connection handling -------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        transport = writer.transport
        if transport is not None:
            # the backpressure knob: drain() blocks once this much response
            # is unsent, pausing the producer for that one connection
            transport.set_write_buffer_limits(high=self.stream_buffer_bytes)
        self._writers.add(writer)
        self.connections += 1
        try:
            while not self._stopping:
                conn = _Conn(reader, writer)
                try:
                    blob = await asyncio.wait_for(
                        reader.readuntil(b"\r\n\r\n"), self.idle_timeout)
                except (asyncio.IncompleteReadError, ConnectionResetError,
                        asyncio.TimeoutError, TimeoutError):
                    break  # client closed or went idle past the reaper
                except asyncio.LimitOverrunError:
                    await conn.send_json(
                        400, {"error": "request header block too large"},
                        close=True)
                    break
                if not self._parse(conn, blob):
                    await conn.send_json(
                        400, {"error": "malformed request line"}, close=True)
                    break
                try:
                    length = int(conn.headers.get("content-length") or 0)
                except ValueError:
                    length = 0
                if length > MAX_BODY_BYTES:
                    await conn.send_json(400, {
                        "error": f"request body too large ({length} bytes)",
                    }, close=True)
                    break
                if length:
                    try:
                        conn.raw = await asyncio.wait_for(
                            reader.readexactly(length), self.idle_timeout)
                    except (asyncio.IncompleteReadError,
                            ConnectionResetError, asyncio.TimeoutError,
                            TimeoutError):
                        break
                await self._dispatch(conn)
                if not conn.keep_alive:
                    break
        except (ConnectionResetError, BrokenPipeError):
            pass
        finally:
            self._writers.discard(writer)
            self.connections -= 1
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass

    @staticmethod
    def _parse(conn: _Conn, blob: bytes) -> bool:
        try:
            head = blob.decode("latin-1")
        except UnicodeDecodeError:
            return False
        lines = head.split("\r\n")
        parts = lines[0].split()
        if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
            return False
        conn.method, conn.path, version = parts
        for line in lines[1:]:
            if not line:
                continue
            name, sep, value = line.partition(":")
            if sep:
                conn.headers[name.strip().lower()] = value.strip()
        wants_close = conn.headers.get("connection", "").lower() == "close"
        conn.keep_alive = version == "HTTP/1.1" and not wants_close
        return True

    async def _dispatch(self, conn: _Conn) -> None:
        endpoint, handler = self._route(conn)
        # activate the request trace on this task's context: handlers (and
        # context-copied offloads) record spans under it, send_bytes echoes
        # the ID, end_request records the request-level span + deactivates
        token = self.obs.begin_request(
            conn.headers.get(obs_trace.TRACE_HEADER.lower()))
        t0 = time.monotonic()
        ok = True
        try:
            await handler(conn)
        except (BrokenPipeError, ConnectionResetError):
            ok = False
            conn.keep_alive = False
        except Exception as e:  # noqa: BLE001 — surface, don't kill the loop
            ok = False
            status, payload = map_error(e)
            if not conn.responded:
                try:
                    await conn.send_json(status, payload)
                except (BrokenPipeError, ConnectionResetError):
                    conn.keep_alive = False
            else:
                conn.keep_alive = False
        finally:
            seconds = time.monotonic() - t0
            self.observe(endpoint, seconds, ok)
            self.obs.end_request(token, endpoint, seconds, ok)

    def _route(self, conn: _Conn):
        method, path = conn.method, conn.path
        if method == "GET":
            if path == "/healthz":
                return "healthz", self._healthz
            if path == "/metrics" or path.startswith("/metrics?"):
                return "metrics", self._metrics_route
            if path == "/v1/traces":
                return "traces", self._traces_route
            if path.startswith("/v1/trace/"):
                return "trace", self._trace_route
            if path == "/v1/store/stats":
                return "store_stats", self._store_stats
            if path == "/v1/cluster" or path.startswith("/v1/cluster?"):
                return "cluster", self._cluster_view
            if path == "/v1/replicate/manifest":
                return "manifest", self._manifest
            if path.startswith("/v1/artifact/"):
                return "artifact", self._artifact
            if path.startswith("/v1/replicate/"):
                return "replicate_pull", self._replicate_pull
        elif method == "POST":
            if path == "/v1/derive":
                return "derive", self._derive
            if path == "/v1/evaluate" or path.startswith("/v1/evaluate?"):
                return "evaluate", self._evaluate
            if path == "/v1/grid":
                return "grid", self._grid
            if path.startswith("/v1/replicate/"):
                return "replicate_push", self._replicate_push
        elif method == "DELETE":
            if path.startswith("/v1/artifact/"):
                return "artifact_delete", self._artifact_delete
        return "unknown", self._not_found

    async def _not_found(self, conn: _Conn) -> None:
        await conn.send_json(404, {"error": f"no route {conn.path!r}"})

    # -- endpoints -----------------------------------------------------------
    async def _healthz(self, conn: _Conn) -> None:
        store = self.service.store
        peers = getattr(getattr(store, "peer", None), "peers", [])
        payload = {
            "status": "ok",
            "store": store is not None,
            "peers": len(peers),
            "domains": len(DOMAINS),
            "loop": "asyncio",
            "mode": self.obs.mode,
            "uptime_seconds": self.obs.uptime_seconds(),
            "started_unix": self.obs.started_unix,
            "backend_names": sorted(self.service.backends()),
            # advertised load, same numbers the cluster view piggybacks
            "load": self.router.load(),
        }
        if self.cluster is not None:
            payload["cluster_nodes_up"] = len(self.cluster.live_peers()) + 1
        if self.async_backends:
            checks = await asyncio.gather(
                *(b.health_check() for b in self.async_backends),
                return_exceptions=True)
            payload["backends"] = {
                b.name: c is True
                for b, c in zip(self.async_backends, checks)}
        await conn.send_json(200, payload)

    async def _metrics_route(self, conn: _Conn) -> None:
        from urllib.parse import parse_qs, urlsplit

        fmt = parse_qs(urlsplit(conn.path).query).get("format", [""])[0]
        if fmt == "prometheus":
            text = await self._offload(self.metrics_prometheus,
                                       admitted=False)
            await conn.send_bytes(
                200, text.encode(),
                content_type="text/plain; version=0.0.4")
            return
        await conn.send_json(200, self.metrics())

    async def _traces_route(self, conn: _Conn) -> None:
        await conn.send_json(200, self.obs.traces_payload())

    async def _trace_route(self, conn: _Conn) -> None:
        trace_id = conn.path[len("/v1/trace/"):]
        payload = self.obs.trace_payload(trace_id)
        if payload is None:
            await conn.send_json(404, {
                "error": f"no trace {trace_id!r} on this node",
                "trace_id": trace_id})
            return
        await conn.send_json(200, payload)

    async def _store_stats(self, conn: _Conn) -> None:
        def build() -> dict:
            store = self.service.store
            if store is None:
                payload = {"store": None}
            else:
                payload = {"store": store.stats(), "usage": store.usage()}
            if self.cluster is not None:
                payload["cluster"] = {**self.cluster.stats(),
                                      "forwarded": self.forwarded,
                                      "forward_errors": self.forward_errors}
            with self._evaluator_mu:
                evaluator = self._evaluator
            if evaluator is not None and evaluator.cache is not None:
                payload["compile_cache"] = evaluator.cache.stats_dict()
            return payload

        await conn.send_json(200, await self._offload(build, admitted=False))

    async def _cluster_view(self, conn: _Conn) -> None:
        from urllib.parse import parse_qs, urlsplit

        if self.cluster is None:
            await conn.send_json(404, {"error": "node runs standalone "
                                                "(no --cluster-seed)"})
            return
        query = urlsplit(conn.path).query
        announced = parse_qs(query).get("from", [""])[0]
        if announced:
            self.cluster.observe(announced)
        await conn.send_json(200, self.cluster.view())

    async def _manifest(self, conn: _Conn) -> None:
        store = self.service.store
        keys = await self._offload(store.keys, admitted=False) \
            if store is not None else []
        await conn.send_json(200, {"keys": keys, "count": len(keys)})

    def _key_from_path(self, conn: _Conn, prefix: str) -> str | None:
        key = conn.path[len(prefix):]
        if not store_mod.valid_key(key):
            return None
        return key

    async def _bad_key(self, conn: _Conn, key: str) -> None:
        await conn.send_json(400, {
            "error": "invalid key: content addresses are 64 lowercase hex "
                     "characters",
            "key": key})

    async def _artifact(self, conn: _Conn) -> None:
        key = self._key_from_path(conn, "/v1/artifact/")
        if key is None:
            await self._bad_key(conn, conn.path[len("/v1/artifact/"):])
            return
        store = self.service.store
        if store is None:
            await conn.send_json(404, {
                "error": "server runs without a store "
                         "(REPRO_ARTIFACT_CACHE=off)", "key": key})
            return
        rec = await self._offload(
            lambda: store.load(key, local_only=True), admitted=False)
        if rec is None:
            await conn.send_json(404, {
                "error": f"no record for key {key!r}", "key": key})
            return
        res = pipeline.result_from_record(rec, DOMAINS[rec["domain"]], key)
        art = res.artifact
        await conn.send_json(200, {
            "key": key,
            "record": rec,
            "artifact": art.to_record() if art is not None else None,
        })

    async def _artifact_delete(self, conn: _Conn) -> None:
        key = self._key_from_path(conn, "/v1/artifact/")
        if key is None:
            await self._bad_key(conn, conn.path[len("/v1/artifact/"):])
            return
        store = self.service.store
        if store is None:
            await conn.send_json(404, {
                "error": "server runs without a store "
                         "(REPRO_ARTIFACT_CACHE=off)", "key": key})
            return
        self._wire_invalidate(key)
        # cached evaluate responses embedding this artifact die with it
        self.eval_wire.invalidate_artifact(key)
        if await self._offload(store.delete, key, admitted=False):
            await conn.send_json(200, {"key": key, "deleted": True})
        else:
            await conn.send_json(404, {
                "error": f"no record for key {key!r}", "key": key})

    async def _replicate_pull(self, conn: _Conn) -> None:
        key = self._key_from_path(conn, "/v1/replicate/")
        if key is None:
            await self._bad_key(conn, conn.path[len("/v1/replicate/"):])
            return
        store = self.service.store
        rec = await self._offload(store.load_local, key, admitted=False) \
            if store is not None else None
        if rec is None:
            await conn.send_json(404, {
                "error": f"no record for key {key!r}", "key": key})
            return
        await conn.send_json(200, rec)

    async def _replicate_push(self, conn: _Conn) -> None:
        key = self._key_from_path(conn, "/v1/replicate/")
        if key is None:
            await self._bad_key(conn, conn.path[len("/v1/replicate/"):])
            return
        store = self.service.store
        if store is None:
            await conn.send_json(404, {
                "error": "server runs without a store "
                         "(REPRO_ARTIFACT_CACHE=off)", "key": key})
            return
        rec = conn.body()
        if not rec or "domain" not in rec:
            raise ValueError("replication push body must be a derivation "
                             "record (JSON object with 'domain')")
        if not store_mod.verify_envelope(key, rec):
            raise ValueError(
                "replication push rejected: record envelope must carry "
                f"schema {store_mod.SCHEMA_VERSION}, the URL key, and a "
                "matching payload checksum")
        await self._offload(store.store_local, key, rec, admitted=False)
        await conn.send_json(200, {"key": key, "stored": True})

    # -- derive --------------------------------------------------------------
    @staticmethod
    def _derive_cell(body: dict) -> tuple[str, str, int]:
        domain = body.get("domain")
        model = body.get("model")
        if not isinstance(domain, str) or not isinstance(model, str):
            raise ValueError("body must carry string 'domain' and 'model'")
        stage = body.get("stage", 100)
        if not isinstance(stage, int) or isinstance(stage, bool):
            raise ValueError("'stage' must be an integer")
        return domain, model, stage

    async def _derive(self, conn: _Conn) -> None:
        body = conn.body()
        domain, model, stage = self._derive_cell(body)
        cell = (domain, model, stage)
        if self.serve_delay > 0:  # chaos knob: a slowed replica — awaited,
            await asyncio.sleep(self.serve_delay)  # other conns unaffected
        # hot path, entirely on the event loop: memoized content address +
        # memory-tier result + cached wire bytes — no thread handoff
        res = self.service.try_cached(domain, model, stage)
        if res is not None:
            self.fast_hits += 1
            blob = self._wire_get(cell)
            if blob is not None:
                self.wire_hits += 1
            else:
                blob = json.dumps(
                    pipeline.wire_from_result(res), default=str).encode()
                self._wire_put(cell, res.cache_key or "", blob)
            await conn.send_bytes(200, blob)
            return
        if await self._maybe_forward(conn, body, domain, model, stage):
            return
        # cold path: pipeline run on the worker pool behind admission
        # control.  The fresh response is NOT wire-cached: its payload says
        # cache_hit=false, which is only true once — repeats take the
        # try_cached path above and cache the truthful rehydrated bytes.
        def run() -> bytes:
            with self.router.track():
                r = self.service.derive(domain, model, stage)
            return json.dumps(
                pipeline.wire_from_result(r), default=str).encode()

        blob = await self._offload(run)
        await conn.send_bytes(200, blob)

    async def _maybe_forward(self, conn: _Conn, body: dict, domain: str,
                             model: str, stage: int) -> bool:
        """One-hop ownership forwarding, same policy as the threaded server
        (serve locally when resident or owned; degrade to local derivation
        when every replica is unreachable).  Owner order comes from the
        router's replica selector; the blocking hop runs on the worker pool
        under admission control — a slow owner consumes one offload slot,
        never the event loop."""
        cluster = self.cluster
        if cluster is None or conn.headers.get(FORWARDED_HEADER.lower()):
            return False
        key = await self._offload(
            self.service.request_key, domain, model, stage, admitted=False)
        if cluster.owns(key):
            return False
        store = self.service.store
        if store is not None and key in store:
            return False
        candidates = cluster.replica_peers(key)

        def attempt(owner: str) -> tuple[int, bytes]:
            req = urllib.request.Request(
                f"{owner}/v1/derive", data=json.dumps(body).encode(),
                method="POST",
                headers={"Content-Type": "application/json",
                         FORWARDED_HEADER: "1",
                         **obs_trace.wire_headers()})
            try:
                with obs_trace.span("forward", owner=owner), \
                        urllib.request.urlopen(  # noqa: S310 — fleet URL
                            req, timeout=self.forward_timeout) as resp:
                    return resp.status, resp.read()
            except urllib.error.HTTPError as e:
                return e.code, e.read()  # the owner answered: relay verdict

        def on_error(owner: str, exc: Exception) -> None:
            self.forward_errors += 1

        def hop() -> tuple[int, bytes] | None:
            with obs_trace.span("route_decision", key=key[:16],
                                candidates=len(candidates),
                                policy=self.router.policy) as span:
                answer = self.router.dispatch(key, candidates, attempt,
                                              on_error=on_error)
                span["forwarded"] = answer is not None
            return answer

        relayed = await self._offload(hop)
        if relayed is None:
            return False
        self.forwarded += 1
        status, payload = relayed
        await conn.send_bytes(status, payload)
        return True

    # -- evaluate ------------------------------------------------------------
    async def _evaluate(self, conn: _Conn) -> None:
        from repro.serving import evaluate as ev

        ctype = conn.headers.get("content-type")
        if wire.is_binary(ctype):
            # binary-framed request body: WireFormatError (a ValueError)
            # surfaces as a structured 400 through _dispatch's map_error
            body = wire.decode_request(conn.raw)
        else:
            body = conn.body()
        binary = wire.wants_binary(conn.headers.get("accept"),
                                   conn.path, ctype)
        evaluator = self.evaluator
        sweep = body.get("sweep")
        if sweep is not None:
            if not isinstance(sweep, dict):
                raise ValueError("'sweep' must be a JSON object")
            await self._evaluate_sweep(conn, evaluator, sweep, binary)
            return
        queries = body.get("queries")
        if queries is not None and not isinstance(queries, list):
            raise ValueError("'queries' must be a list")
        single = queries is None
        batch = [body] if single else queries
        response_type = wire.CONTENT_TYPE if binary else "application/json"
        # hot path, entirely on the event loop: once the batch's executable
        # identity is resolvable (dict lookups + arithmetic when the
        # artifact store is warm), a cached encoded response sends with no
        # thread handoff and no re-serialization — the evaluate analogue of
        # the derive fast path above.  Gated on one completed evaluate:
        # planning imports jax/kernels, and that first multi-second import
        # belongs on the worker pool, not the loop.
        if self._eval_served:
            identity = evaluator.batch_cache_key(batch)
            if identity is not None:
                cell = ("bin" if binary else "json",
                        "single" if single else "batch", identity[0])
                blob = self.eval_wire.get(cell,
                                          evaluator.cache_generation())
                if blob is not None:
                    self.eval_wire_hits += 1
                    await conn.send_bytes(200, blob,
                                          content_type=response_type)
                    return
        if await self._maybe_forward_evaluate(conn, body, batch, binary):
            return
        blob = await self._offload(
            lambda: ev.encoded_batch_response(
                evaluator, self.eval_wire, batch,
                single=single, binary=binary))
        self._eval_served = True
        await conn.send_bytes(200, blob, content_type=response_type)

    async def _maybe_forward_evaluate(self, conn: _Conn, body: dict,
                                      queries: list, binary: bool) -> bool:
        """One-hop forward for artifact-key evaluates this node neither
        owns nor holds (the owner has the artifact and its executables
        warm).  The owner's bytes and Content-Type relay verbatim — binary
        passthrough, never re-encoded.  Same policy as the threaded
        frontend; the blocking hop rides the worker pool."""
        cluster = self.cluster
        if cluster is None or conn.headers.get(FORWARDED_HEADER.lower()):
            return False
        keys = {q.get("key") for q in queries if isinstance(q, dict)}
        keys.discard(None)
        if len(keys) != 1:
            return False
        key = keys.pop()
        if not isinstance(key, str) or not store_mod.valid_key(key):
            return False  # the evaluator raises the structured 400
        if cluster.owns(key):
            return False
        store = self.service.store
        if store is not None and key in store:
            return False
        candidates = cluster.replica_peers(key)
        accept = wire.CONTENT_TYPE if binary else "application/json"

        def attempt(owner: str) -> tuple[int, bytes, str]:
            req = urllib.request.Request(
                f"{owner}/v1/evaluate", data=json.dumps(body).encode(),
                method="POST",
                headers={"Content-Type": "application/json",
                         "Accept": accept,
                         FORWARDED_HEADER: "1",
                         **obs_trace.wire_headers()})
            try:
                with obs_trace.span("forward_evaluate", owner=owner), \
                        urllib.request.urlopen(  # noqa: S310 — fleet URL
                            req, timeout=self.forward_timeout) as resp:
                    return (resp.status, resp.read(),
                            resp.headers.get("Content-Type")
                            or "application/json")
            except urllib.error.HTTPError as e:
                return (e.code, e.read(),
                        e.headers.get("Content-Type") or "application/json")

        def on_error(owner: str, exc: Exception) -> None:
            self.forward_errors += 1

        def hop() -> tuple[int, bytes, str] | None:
            return self.router.dispatch(key, candidates, attempt,
                                        on_error=on_error)

        relayed = await self._offload(hop)
        if relayed is None:
            return False  # every owner failed: serve (404) locally
        self.forwarded += 1
        status, payload, ctype = relayed
        await conn.send_bytes(status, payload, content_type=ctype)
        return True

    async def _evaluate_sweep(self, conn: _Conn, evaluator,
                              sweep: dict, binary: bool = False) -> None:
        from repro.serving import evaluate as ev

        domains = sweep.get("domains")
        sizes = sweep.get("sizes")
        if not isinstance(domains, list) or not domains:
            raise ValueError("'sweep.domains' must be a non-empty list")
        if not isinstance(sizes, list) or not sizes:
            raise ValueError("'sweep.sizes' must be a non-empty list")
        cells = evaluator.sweep(
            domains, sizes, tier=sweep.get("tier", "map"),
            block_n=sweep.get("block_n"),
            interpret=sweep.get("interpret"))
        if binary:
            await self._stream(
                conn, cells,
                lambda res: wire.stream_chunk(wire.encode_frame(res)),
                wire.STREAM_CONTENT_TYPE)
            return
        await self._stream_ndjson(conn, cells, ev.wire_result)

    # -- streaming -----------------------------------------------------------
    async def _grid(self, conn: _Conn) -> None:
        body = conn.body()

        def names(field):
            val = body.get(field)
            if val is None:
                return None
            if not isinstance(val, list):
                raise ValueError(f"{field!r} must be a list")
            return val

        domains, models, stages = (names("domains"), names("models"),
                                   names("stages"))
        cells = self.service.run_grid(domains, models, stages)
        await self._stream_ndjson(conn, cells, pipeline.wire_from_result)

    async def _stream_ndjson(self, conn: _Conn, cells, wire_fn) -> None:
        await self._stream(
            conn, cells,
            lambda res: (json.dumps(wire_fn(res)) + "\n").encode(),
            "application/x-ndjson")

    async def _stream(self, conn: _Conn, cells, encode,
                      content_type: str) -> None:
        """Pull-driven close-delimited stream with real backpressure:
        the producer (a blocking generator) is advanced one cell per loop
        turn on the worker pool, and each cell's bytes (an NDJSON line or
        a length-prefixed binary frame — ``encode`` decides) are followed
        by ``await drain()`` — once a slow reader's write buffer passes
        the high-water mark, production for *that* connection pauses until
        the client reads.  Other connections keep being served; nothing is
        buffered beyond the transport's ``stream_buffer_bytes``."""
        conn.responded = True
        conn.keep_alive = False  # length unknowable: close-delimited
        conn.writer.write(_head(200, content_type, None, True))
        loop = asyncio.get_running_loop()
        stalled = False
        # one context snapshot for the whole stream: every generator step
        # runs under the request's trace regardless of which pool thread
        # picks it up
        ctx = contextvars.copy_context()
        try:
            while True:
                res = await loop.run_in_executor(
                    self._executor, ctx.run, next, cells, _SENTINEL)
                if res is _SENTINEL:
                    break
                conn.writer.write(encode(res))
                t0 = time.monotonic()
                await conn.writer.drain()  # the backpressure point
                if not stalled and \
                        time.monotonic() - t0 > self.stall_threshold:
                    stalled = True
                    self.stream_stalls += 1
        except (BrokenPipeError, ConnectionResetError):
            pass  # client went away mid-stream: stop producing
        except Exception as e:  # noqa: BLE001 — headers are gone
            try:
                conn.writer.write(
                    encode({"error": f"{type(e).__name__}: {e}"}))
                await conn.writer.drain()
            except (BrokenPipeError, ConnectionResetError):
                pass


def serve(service: MappingService | None = None, host: str = "127.0.0.1",
          port: int = 8000, **kw) -> AsyncMappingHTTPServer:
    """Boot an async server and block the calling thread (the CLI path)."""
    server = AsyncMappingHTTPServer(service or MappingService(), host, port,
                                    **kw)
    server.serve_forever()
    return server
