"""HTTP frontend for the MappingService — stdlib only, no new deps.

One derivation server, many cheap clients: the paper's one-time LLM
derivation cost only amortizes when every later GPU launch shares it, and
sharing across machines means a network surface.  This module wraps a
:class:`~repro.serving.map_service.MappingService` in a
``ThreadingHTTPServer`` speaking JSON:

    POST   /v1/derive           {domain, model, stage}  -> wire payload
    POST   /v1/evaluate         batched map evaluation: {domain|key, tier,
                                n_points|extent, ...} single query, or
                                {queries: [...]} heterogeneous batch, or
                                {sweep: {domains, sizes}} NDJSON stream —
                                mapped coordinates, not mapping code
    GET    /v1/artifact/<key>   cached derivation record by content address
                                (local tiers only — no peer probe)
    DELETE /v1/artifact/<key>   drop one record from this node's tiers
    POST   /v1/grid             {domains, models, stages} -> NDJSON stream,
                                one wire payload per resolved cell
    GET    /v1/store/stats      per-tier store counters + disk usage
                                (+ cluster/ring state when clustered)
    GET    /v1/cluster          membership view exchange: this node's view
                                of the fleet + ring parameters (404 when
                                the node runs standalone)
    GET    /v1/replicate/manifest  this node's key manifest (local tiers) —
                                the anti-entropy repair surface
    GET    /v1/replicate/<key>  replication pull: the raw local record
                                (memory/disk only — a peer's question never
                                triggers our own peer fetch)
    POST   /v1/replicate/<key>  replication push: store a record published
                                by a sibling server into the local tiers
    GET    /healthz             liveness probe (+ uptime, serving mode)
    GET    /metrics             ServiceStats + per-endpoint latency
                                percentiles + batching/admission counters +
                                per-tier store counters + cluster state
                                (?format=prometheus -> text exposition)
    GET    /v1/trace/<id>       this node's span shard of one request trace
    GET    /v1/traces           recent trace IDs + ring-buffer stats

Every thread the server spawns funnels into the *same* service instance, so
the coalescing table and artifact-store file lock built in PR 2 are exactly
the concurrency story here too: N concurrent POSTs for one cell still run
one pipeline.  Payload schemas live in ``core/pipeline.py``
(``wire_from_result``/``result_from_wire``) so the client rehydrates the
same record shape the store holds.  ``AdmissionError`` from the batching
queue maps to 503 — the server sheds load instead of queueing unboundedly.
The two /v1/replicate endpoints are the wire surface of
:class:`~repro.core.store.PeerStore` — point two servers at each other with
``--peers`` and a derivation on either is a hit on both.

Responses speak HTTP/1.1 with explicit Content-Length, so a client holding
a pooled connection (``serving/client.py``) reuses it across requests
instead of paying a TCP handshake per derive; the /v1/grid NDJSON stream is
the one close-delimited response (its length is unknowable up front).

With a :class:`~repro.serving.cluster.ClusterMembership` attached
(``--cluster-seed``), the node participates in a consistent-hash sharded
fleet: a POST /v1/derive whose content address this node does not own is
forwarded to the ring owner (one hop at most — forwarded requests carry
``X-Repro-Forwarded`` and are always served where they land), replication
pushes are scoped to the key's K replicas, and the anti-entropy loop
repairs owned-but-missing records through the manifest endpoint.
"""
from __future__ import annotations

import json
import socket
import threading
import time
import urllib.error
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlsplit

from repro.core import pipeline
from repro.core import store as store_mod
from repro.core.backends import (
    LLMBusyError,
    LLMTimeoutError,
    LLMUnavailableError,
)
from repro.core.domains import DOMAINS
from repro.obs import Observability
from repro.obs import trace as obs_trace
from repro.serving import wire
from repro.serving.map_service import MappingService

MAX_BODY_BYTES = 1 << 20  # a derive/grid request is tiny; refuse anything big

#: marks a derive that already took its one forwarding hop — the receiving
#: node serves it locally even if its ring view disagrees, so two nodes with
#: momentarily different views can never bounce a request between them
FORWARDED_HEADER = "X-Repro-Forwarded"


class _FleetHTTPServer(ThreadingHTTPServer):
    """ThreadingHTTPServer with a fleet-sized accept backlog.  The stdlib
    default (``request_queue_size = 5``) drops connections under the
    bursts a sharded fleet actually produces — 64 clients opening at
    once, or a router fanning forwarded hops into one hot owner — which
    surfaces as resets, spurious failure penalties in the replica
    selector, and needless local-degradation derives."""

    request_queue_size = 128


def map_error(e: BaseException) -> tuple[int, dict]:
    """Typed exception -> (status, JSON body), shared by the threaded and
    asyncio frontends so the two paths can never disagree on a wire code:

        LLMTimeoutError                    -> 504 retryable (deadline blown;
                                              derivations are idempotent)
        LLMBusyError (incl AdmissionError) -> 503 retryable (shed, back off)
        LLMUnavailableError                -> 503 retryable (backend down)
        KeyError                           -> 404 (unknown domain/model/key)
        ValueError / bad JSON              -> 400
        anything else                      -> 500
    """
    if isinstance(e, LLMTimeoutError):
        return 504, {"error": str(e), "retryable": True}
    if isinstance(e, (LLMBusyError, LLMUnavailableError)):
        return 503, {"error": str(e), "retryable": True}
    if isinstance(e, KeyError):
        return 404, {"error": f"unknown name: {e}"}
    if isinstance(e, (ValueError, json.JSONDecodeError)):
        return 400, {"error": str(e)}
    return 500, {"error": f"{type(e).__name__}: {e}"}


def collect_metrics(service: MappingService, http: dict, cluster=None,
                    forwarded: int = 0, forward_errors: int = 0,
                    evaluator=None, frontend: dict | None = None,
                    router=None, eval_wire=None) -> dict:
    """The shared /metrics payload shape — one builder for the threaded and
    asyncio frontends so scrapers see identical keys from either.  The
    per-endpoint ``http`` section comes from the observability plane's
    bounded histograms (``repro.obs``); ``frontend`` is the mode/uptime/
    trace-buffer section both frontends emit with one key set (the metrics
    parity contract)."""
    out = {
        "service": service.stats_snapshot().as_dict(),
        "inflight": service.inflight_count(),
        "http": http,
        "batching": {},
    }
    if frontend is not None:
        out["frontend"] = frontend
    for model, backend in service.backends().items():
        # duck-typed: BatchingBackend.BatchStats and the continuous
        # batcher's ContinuousStats both publish as_dict()
        stats = getattr(backend, "stats", None)
        if hasattr(stats, "as_dict"):
            out["batching"][model] = stats.as_dict()
    if service.store is not None:
        # counters only — sizing the store (a directory glob) is the
        # explicit /v1/store/stats endpoint, not the scrape path
        out["store"] = {"hits": service.store.hits,
                        "misses": service.store.misses,
                        "tiers": service.store.stats()}
    if cluster is not None:
        out["cluster"] = {**cluster.stats(),
                          "forwarded": forwarded,
                          "forward_errors": forward_errors}
    if router is not None:
        # queue depth/expiry/retry gauges + per-replica selection counters
        # (the numbers the routing chaos CI leg asserts traffic shifts on)
        out["router"] = router.stats_dict()
    if evaluator is not None:
        # stats_dict embeds the compile-cache counters; surface them at
        # the top level too so scrapers find one well-known key
        ev = evaluator.stats_dict()
        out["compile_cache"] = ev.pop("compile_cache", None)
        out["evaluate"] = ev
    if eval_wire is not None:
        # the evaluate-plane response-bytes LRU (serving/wire.py)
        out["evaluate_wire"] = eval_wire.stats_dict()
    return out


class MappingHTTPServer:
    """The networked face of one MappingService.

    ``port=0`` binds an ephemeral port (read it back from ``.port`` /
    ``.url``).  ``start()`` serves from a daemon thread; ``close()`` shuts
    the listener down and joins it.  Usable as a context manager."""

    def __init__(self, service: MappingService, host: str = "127.0.0.1",
                 port: int = 0, observability: bool = True,
                 router=None, serve_delay: float = 0.0,
                 wire_cache_entries: int = 256):
        from repro.serving.router import RequestRouter

        self.service = service
        self.cluster = None  # ClusterMembership once attach_cluster() ran
        self.forwarded = 0          # derives proxied to their ring owner
        self.forward_errors = 0     # owner unreachable -> served locally
        # below the client's default 60s timeout: a stalled owner must not
        # pin forwarding threads past the point the caller has given up —
        # the forward degrades to local derivation instead
        self.forward_timeout = 30.0
        #: per-node scheduler + load-aware replica selector (forwards go to
        #: the *best* owner, not the first; queue depth is advertised to
        #: peers via the cluster view and /healthz)
        self.router = router if router is not None else RequestRouter()
        #: chaos/benchmark knob: sleep this long before serving each derive
        #: (an artificially slowed replica the selector must route around)
        self.serve_delay = max(0.0, float(serve_delay))
        self.obs = Observability(mode="threaded", enabled=observability)
        #: encoded evaluate responses keyed by resolved executable group +
        #: λ-range (binary and JSON cached separately; see serving/wire.py)
        self.eval_wire = wire.WireCache(entries=wire_cache_entries)
        self._evaluator = None       # EvaluationService, built on first use
        self._evaluator_mu = threading.Lock()
        self._conn_sockets: set = set()  # live keep-alive connections
        self._conn_mu = threading.Lock()
        handler = _make_handler(self)
        self.httpd = _FleetHTTPServer((host, port), handler)
        self.httpd.daemon_threads = True
        self.host = host
        self.port = self.httpd.server_address[1]
        self.obs.node = self.url
        self._thread: threading.Thread | None = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    @property
    def evaluator(self):
        """The node's EvaluationService, constructed on first evaluate
        request — a derive-only node never imports jax/kernels for it."""
        with self._evaluator_mu:
            if self._evaluator is None:
                from repro.serving.evaluate import EvaluationService

                self._evaluator = EvaluationService(
                    artifact_resolver=self.service.artifact_for_key)
            return self._evaluator

    def attach_cluster(self, cluster) -> "ClusterMembership":  # noqa: F821
        """Join this node to a sharded fleet: wire the membership's ring
        into the store's peer tier (owner-scoped pulls and pushes instead of
        the static broadcast mesh), hand the store to the anti-entropy loop,
        and start the heartbeat/sync threads.  Call after construction —
        membership identity is this server's URL, which an ephemeral-port
        bind only knows post-bind."""
        from repro.core.store import PeerStore

        self.cluster = cluster
        store = self.service.store
        if store is not None:
            if store.peer is None:
                store.peer = PeerStore(router=cluster.replica_peers)
            else:
                store.peer.router = cluster.replica_peers
            cluster.store = store
        # load piggyback: our queue depth rides every view we serve, and
        # every successful probe feeds the peer's advertised depth into the
        # replica selector
        if cluster.load_provider is None:
            cluster.load_provider = self.router.load
        if cluster.on_load is None:
            cluster.on_load = self.router.advertise
        cluster.start()
        return cluster

    def start(self) -> "MappingHTTPServer":
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, name="mapping-http", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        self.httpd.serve_forever()

    def track_connection(self, sock, alive: bool) -> None:
        with self._conn_mu:
            (self._conn_sockets.add if alive
             else self._conn_sockets.discard)(sock)

    def close(self) -> None:
        if self.cluster is not None:
            self.cluster.close()
        self.httpd.shutdown()
        self.httpd.server_close()
        # sever established keep-alive connections too — without this a
        # "killed" node keeps answering pooled clients through lingering
        # handler threads, which is not what killed means
        with self._conn_mu:
            sockets = list(self._conn_sockets)
            self._conn_sockets.clear()
        for sock in sockets:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
        if self._thread is not None:
            self._thread.join(timeout=2.0)

    def __enter__(self) -> "MappingHTTPServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- metrics -----------------------------------------------------------
    def observe(self, endpoint: str, seconds: float, ok: bool) -> None:
        self.obs.observe(endpoint, seconds, ok)

    def metrics(self) -> dict:
        """The /metrics payload: one shared ServiceStats view + HTTP-layer
        latency percentiles + batching queues + per-tier store counters."""
        with self._evaluator_mu:
            evaluator = self._evaluator
        return collect_metrics(
            self.service, self.obs.http_dict(), cluster=self.cluster,
            forwarded=self.forwarded, forward_errors=self.forward_errors,
            evaluator=evaluator, frontend=self.obs.frontend_dict(),
            router=self.router, eval_wire=self.eval_wire)

    def metrics_prometheus(self) -> str:
        """The same numbers as Prometheus text exposition: registered
        instruments (latency histograms) + every numeric leaf of the JSON
        payload flattened to ``repro_*`` gauges."""
        return self.obs.prometheus(self.metrics())


def _make_handler(server: MappingHTTPServer):
    class Handler(BaseHTTPRequestHandler):
        # HTTP/1.1: every JSON response carries Content-Length, so pooled
        # client connections stay open across requests (keep-alive).  The
        # one exception is /v1/grid, whose NDJSON stream has no knowable
        # length — it answers `Connection: close` and stays close-delimited.
        protocol_version = "HTTP/1.1"
        # reap idle keep-alive connections so abandoned clients don't pin
        # a handler thread forever (socket timeout -> close_connection)
        timeout = 60.0
        # TCP_NODELAY: headers and body go out as separate small writes,
        # and on a keep-alive connection Nagle holds the second one until
        # the peer's delayed ACK (~40ms per response on loopback); fresh
        # connections never showed it because close() flushes
        disable_nagle_algorithm = True

        def setup(self) -> None:
            super().setup()
            server.track_connection(self.connection, alive=True)

        def finish(self) -> None:
            server.track_connection(self.connection, alive=False)
            super().finish()

        def log_message(self, fmt, *args):  # quiet by default
            pass

        # -- plumbing ------------------------------------------------------
        def _request_body_len(self) -> int:
            try:
                return int(self.headers.get("Content-Length") or 0)
            except (TypeError, ValueError):
                return 0

        def _send_json(self, status: int, payload: dict) -> None:
            # default=str matches the store's checksum/publish serialization
            # (core/store.py), so a memory-tier record holding a value the
            # disk tier would stringify (e.g. a Path) serves identically
            # from either tier instead of 500ing from the hot one
            body = json.dumps(payload, default=str).encode()
            self._send_body(status, body, "application/json")

        def _send_body(self, status: int, body: bytes,
                       content_type: str) -> None:
            self.send_response(status)
            self.send_header("Content-Type", content_type)
            self.send_header("Content-Length", str(len(body)))
            trace_id = obs_trace.current_trace_id()
            if trace_id is not None:
                # echo the request's trace ID so callers learn the ID the
                # ingress node minted for them
                self.send_header(obs_trace.TRACE_HEADER, trace_id)
            if status >= 400 and self._request_body_len() > 0:
                # an error may have fired before the request body was read
                # (oversized body, unknown route): close-delimit so the
                # unread bytes can't be parsed as the next request on a
                # kept-alive connection (send_header flips close_connection)
                self.send_header("Connection", "close")
            self.end_headers()
            self.wfile.write(body)

        def _read_body(self) -> dict:
            length = int(self.headers.get("Content-Length") or 0)
            if length > MAX_BODY_BYTES:
                raise ValueError(f"request body too large ({length} bytes)")
            raw = self.rfile.read(length) if length else b""
            if not raw:
                return {}
            if wire.is_binary(self.headers.get("Content-Type")):
                # a binary-framed request: malformed/truncated frames and
                # unknown wire versions raise WireFormatError (a
                # ValueError) -> structured 400 via _timed's map_error
                return wire.decode_request(raw)
            body = json.loads(raw)
            if not isinstance(body, dict):
                raise ValueError("request body must be a JSON object")
            return body

        def _key_from_path(self, prefix: str) -> str | None:
            """The content address from a /v1/.../<key> URL, or None after
            answering 400.  Keys are always sha256 hex digests (see
            ``store.cache_key``), so rejecting anything else is lossless —
            and it is the security boundary that keeps a wire-supplied
            ``../``-style key from ever reaching a filesystem path."""
            key = self.path[len(prefix):]
            if not store_mod.valid_key(key):
                self._send_json(400, {
                    "error": "invalid key: content addresses are 64 "
                             "lowercase hex characters",
                    "key": key})
                return None
            return key

        def _timed(self, endpoint: str, fn) -> None:
            t0 = time.monotonic()
            ok = True
            token = server.obs.begin_request(
                self.headers.get(obs_trace.TRACE_HEADER))
            try:
                fn()
            except (BrokenPipeError, ConnectionResetError):
                ok = False  # client went away mid-response: nothing to send
            except Exception as e:  # noqa: BLE001 — surface, don't kill thread
                ok = False
                status, payload = map_error(e)
                self._send_json(status, payload)
            finally:
                seconds = time.monotonic() - t0
                server.observe(endpoint, seconds, ok)
                server.obs.end_request(token, endpoint, seconds, ok)

        # -- endpoints -----------------------------------------------------
        def do_GET(self) -> None:  # noqa: N802 (http.server API)
            if self.path == "/healthz":
                self._timed("healthz", self._healthz)
            elif self.path == "/metrics" \
                    or self.path.startswith("/metrics?"):
                self._timed("metrics", self._metrics)
            elif self.path == "/v1/traces":
                self._timed("traces", self._traces)
            elif self.path.startswith("/v1/trace/"):
                self._timed("trace", self._trace)
            elif self.path == "/v1/store/stats":
                self._timed("store_stats", self._store_stats)
            elif self.path == "/v1/cluster" \
                    or self.path.startswith("/v1/cluster?"):
                self._timed("cluster", self._cluster_view)
            elif self.path == "/v1/replicate/manifest":
                self._timed("manifest", self._manifest)
            elif self.path.startswith("/v1/artifact/"):
                self._timed("artifact", self._artifact)
            elif self.path.startswith("/v1/replicate/"):
                self._timed("replicate_pull", self._replicate_pull)
            else:
                self._send_json(404, {"error": f"no route {self.path!r}"})

        def do_POST(self) -> None:  # noqa: N802
            if self.path == "/v1/derive":
                self._timed("derive", self._derive)
            elif self.path == "/v1/evaluate" \
                    or self.path.startswith("/v1/evaluate?"):
                self._timed("evaluate", self._evaluate)
            elif self.path == "/v1/grid":
                self._timed("grid", self._grid)
            elif self.path.startswith("/v1/replicate/"):
                self._timed("replicate_push", self._replicate_push)
            else:
                self._send_json(404, {"error": f"no route {self.path!r}"})

        def do_DELETE(self) -> None:  # noqa: N802
            if self.path.startswith("/v1/artifact/"):
                self._timed("artifact_delete", self._artifact_delete)
            else:
                self._send_json(404, {"error": f"no route {self.path!r}"})

        def _healthz(self) -> None:
            store = server.service.store
            peers = getattr(getattr(store, "peer", None), "peers", [])
            payload = {
                "status": "ok",
                "store": store is not None,
                "peers": len(peers),
                "domains": len(DOMAINS),
                "mode": server.obs.mode,
                "uptime_seconds": server.obs.uptime_seconds(),
                "started_unix": server.obs.started_unix,
                "backend_names": sorted(server.service.backends()),
                # the advertised load (same numbers the cluster view
                # piggybacks) — lets external LBs and siblings read queue
                # depth off the liveness probe
                "load": server.router.load(),
            }
            if server.cluster is not None:
                payload["cluster_nodes_up"] = \
                    len(server.cluster.live_peers()) + 1
            self._send_json(200, payload)

        def _metrics(self) -> None:
            query = parse_qs(urlsplit(self.path).query)
            if query.get("format", [""])[0] == "prometheus":
                self._send_body(200, server.metrics_prometheus().encode(),
                                "text/plain; version=0.0.4")
                return
            self._send_json(200, server.metrics())

        def _trace(self) -> None:
            trace_id = self.path[len("/v1/trace/"):]
            payload = server.obs.trace_payload(trace_id)
            if payload is None:
                self._send_json(404, {"error": f"no trace {trace_id!r} on "
                                               "this node",
                                      "trace_id": trace_id})
                return
            self._send_json(200, payload)

        def _traces(self) -> None:
            self._send_json(200, server.obs.traces_payload())

        def _store_stats(self) -> None:
            store = server.service.store
            if store is None:
                payload = {"store": None}
            else:
                payload = {"store": store.stats(), "usage": store.usage()}
            if server.cluster is not None:
                payload["cluster"] = {**server.cluster.stats(),
                                      "forwarded": server.forwarded,
                                      "forward_errors": server.forward_errors}
            with server._evaluator_mu:
                evaluator = server._evaluator
            if evaluator is not None and evaluator.cache is not None:
                payload["compile_cache"] = evaluator.cache.stats_dict()
            self._send_json(200, payload)

        def _cluster_view(self) -> None:
            """Membership view exchange: how peers (and ring-aware clients)
            discover the fleet.  A probing peer announces itself via
            ``?from=`` and is folded into our view (symmetric discovery —
            a seed learns its joiners the moment they first probe it).  A
            standalone node answers 404 — it has no view, and PR-4-era
            callers never ask."""
            if server.cluster is None:
                self._send_json(404, {"error": "node runs standalone "
                                               "(no --cluster-seed)"})
                return
            query = urlsplit(self.path).query
            announced = parse_qs(query).get("from", [""])[0]
            if announced:
                server.cluster.observe(announced)
            self._send_json(200, server.cluster.view())

        def _manifest(self) -> None:
            """This node's key manifest (local tiers only): what the
            anti-entropy loop on a peer diffs against its own holdings."""
            store = server.service.store
            keys = store.keys() if store is not None else []
            self._send_json(200, {"keys": keys, "count": len(keys)})

        def _derive(self) -> None:
            body = self._read_body()
            domain = body.get("domain")
            model = body.get("model")
            if not isinstance(domain, str) or not isinstance(model, str):
                raise ValueError("body must carry string 'domain' and 'model'")
            stage = body.get("stage", 100)
            if not isinstance(stage, int) or isinstance(stage, bool):
                raise ValueError("'stage' must be an integer")
            if self._maybe_forward(body, domain, model, stage):
                return
            if server.serve_delay > 0:  # chaos knob: an artificially slow
                time.sleep(server.serve_delay)  # replica to route around
            with server.router.track():
                res = server.service.derive(domain, model, stage)
            self._send_json(200, pipeline.wire_from_result(res))

        def _maybe_forward(self, body: dict, domain: str, model: str,
                           stage: int) -> bool:
            """Forward a derive this node does not own to the *best* ring
            owner (True = response already relayed).  At most one hop:
            forwarded requests are marked and always served where they
            land.  A node that already holds the record serves it
            regardless of ownership — a local hit beats a network hop.
            Owner order comes from the router's replica selector (EWMA
            latency + advertised queue depth, epsilon-greedy), and the hop
            runs through its bounded scheduler: a failed owner books a
            retry, a full queue or blown TTL degrades to local derivation
            (the fleet may briefly hold an extra copy; correctness never
            depends on placement)."""
            cluster = server.cluster
            if cluster is None or self.headers.get(FORWARDED_HEADER):
                return False
            key = server.service.request_key(domain, model, stage)
            if cluster.owns(key):
                return False
            store = server.service.store
            if store is not None and key in store:
                return False  # resident locally: serve, don't hop
            candidates = cluster.replica_peers(key)

            def hop(owner: str) -> tuple[int, bytes]:
                req = urllib.request.Request(
                    f"{owner}/v1/derive", data=json.dumps(body).encode(),
                    method="POST",
                    headers={"Content-Type": "application/json",
                             FORWARDED_HEADER: "1",
                             # the hop carries the trace ID, so the owner
                             # records its spans under the same trace
                             **obs_trace.wire_headers()})
                try:
                    with obs_trace.span("forward", owner=owner), \
                            urllib.request.urlopen(  # noqa: S310 — fleet URL
                                req, timeout=server.forward_timeout) as resp:
                        return resp.status, resp.read()
                except urllib.error.HTTPError as e:
                    # the owner answered: relay its verdict (400/404/503…)
                    return e.code, e.read()

            def on_error(owner: str, exc: Exception) -> None:
                server.forward_errors += 1

            with obs_trace.span("route_decision", key=key[:16],
                                candidates=len(candidates),
                                policy=server.router.policy) as span:
                answer = server.router.dispatch(key, candidates, hop,
                                                on_error=on_error)
                span["forwarded"] = answer is not None
            if answer is None:
                return False  # every owner failed/shed: local degradation
            status, payload = answer
            server.forwarded += 1
            self._send_body(status, payload, "application/json")
            return True

        def _evaluate(self) -> None:
            """Batched map evaluation: mapped coordinates (or a BB
            membership mask), not mapping code.  Three body shapes:

              {domain|key, tier?, n_points|extent, ...}   one query
              {"queries": [...]}                           heterogeneous batch
              {"sweep": {"domains": [...], "sizes": [...],
                         "tier"?, "block_n"?, "interpret"?}}  NDJSON or
                                              binary frame stream

            ``Accept: application/x-repro-binary`` (or ``?format=binary``,
            or a binary-framed request body) answers binary frames instead
            of JSON; responses come through the encoded-bytes LRU.  Unknown
            domains / artifact keys answer 404, malformed bodies (JSON or
            binary frame) 400 — both via ``_timed``'s exception mapping."""
            from repro.serving import evaluate as ev

            binary = wire.wants_binary(self.headers.get("Accept"),
                                       self.path,
                                       self.headers.get("Content-Type"))
            body = self._read_body()
            evaluator = server.evaluator
            sweep = body.get("sweep")
            if sweep is not None:
                if not isinstance(sweep, dict):
                    raise ValueError("'sweep' must be a JSON object")
                self._evaluate_sweep(evaluator, sweep, binary)
                return
            queries = body.get("queries")
            if queries is not None and not isinstance(queries, list):
                raise ValueError("'queries' must be a list")
            if self._maybe_forward_evaluate(
                    body, [body] if queries is None else queries, binary):
                return
            blob = ev.encoded_batch_response(
                evaluator, server.eval_wire,
                [body] if queries is None else queries,
                single=queries is None, binary=binary)
            self._send_body(200, blob, wire.CONTENT_TYPE if binary
                            else "application/json")

        def _maybe_forward_evaluate(self, body: dict, queries: list,
                                    binary: bool) -> bool:
            """One-hop forward for artifact-key evaluates this node neither
            owns nor holds: the ring owner has the artifact resident (and
            its compiled executables warm), so the hop beats a local 404.
            The owner's bytes and Content-Type are relayed *verbatim* —
            binary passthrough, a forwarded evaluate is never re-encoded.
            Domain-only queries (any node computes ground truth) and
            mixed-key batches serve locally."""
            cluster = server.cluster
            if cluster is None or self.headers.get(FORWARDED_HEADER):
                return False
            keys = {q.get("key") for q in queries if isinstance(q, dict)}
            keys.discard(None)
            if len(keys) != 1:
                return False
            key = keys.pop()
            if not isinstance(key, str) or not store_mod.valid_key(key):
                return False  # the evaluator raises the structured 400
            if cluster.owns(key):
                return False
            store = server.service.store
            if store is not None and key in store:
                return False  # resident locally: serve, don't hop
            candidates = cluster.replica_peers(key)
            accept = wire.CONTENT_TYPE if binary else "application/json"

            def hop(owner: str) -> tuple[int, bytes, str]:
                req = urllib.request.Request(
                    f"{owner}/v1/evaluate", data=json.dumps(body).encode(),
                    method="POST",
                    headers={"Content-Type": "application/json",
                             "Accept": accept,
                             FORWARDED_HEADER: "1",
                             **obs_trace.wire_headers()})
                try:
                    with obs_trace.span("forward_evaluate", owner=owner), \
                            urllib.request.urlopen(  # noqa: S310 — fleet URL
                                req, timeout=server.forward_timeout) as resp:
                        return (resp.status, resp.read(),
                                resp.headers.get("Content-Type")
                                or "application/json")
                except urllib.error.HTTPError as e:
                    return (e.code, e.read(),
                            e.headers.get("Content-Type")
                            or "application/json")

            def on_error(owner: str, exc: Exception) -> None:
                server.forward_errors += 1

            answer = server.router.dispatch(key, candidates, hop,
                                            on_error=on_error)
            if answer is None:
                return False  # every owner failed: serve (404) locally
            status, payload, ctype = answer
            server.forwarded += 1
            self._send_body(status, payload, ctype)
            return True

        def _evaluate_sweep(self, evaluator, sweep: dict,
                            binary: bool = False) -> None:
            """Streamed grid sweep: one result per (domain, n_points) cell
            as it resolves — NDJSON lines, or length-prefixed binary frames
            when negotiated.  Both framings are close-delimited (length
            unknowable up front)."""
            from repro.serving import evaluate as ev

            domains = sweep.get("domains")
            sizes = sweep.get("sizes")
            if not isinstance(domains, list) or not domains:
                raise ValueError("'sweep.domains' must be a non-empty list")
            if not isinstance(sizes, list) or not sizes:
                raise ValueError("'sweep.sizes' must be a non-empty list")
            cells = evaluator.sweep(
                domains, sizes, tier=sweep.get("tier", "map"),
                block_n=sweep.get("block_n"),
                interpret=sweep.get("interpret"))
            if binary:
                ctype = wire.STREAM_CONTENT_TYPE

                def encode(res: dict) -> bytes:
                    return wire.stream_chunk(wire.encode_frame(res))
            else:
                ctype = "application/x-ndjson"

                def encode(res: dict) -> bytes:
                    return (json.dumps(ev.wire_result(res)) + "\n").encode()
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            # stream length unknowable up front: close-delimit (matches
            # /v1/grid; send_header flips close_connection)
            self.send_header("Connection", "close")
            self.end_headers()
            try:
                for res in cells:
                    self.wfile.write(encode(res))
                    self.wfile.flush()
            except (BrokenPipeError, ConnectionResetError):
                raise
            except Exception as e:  # noqa: BLE001 — headers are gone
                self.wfile.write(
                    encode({"error": f"{type(e).__name__}: {e}"}))

        def _artifact(self) -> None:
            key = self._key_from_path("/v1/artifact/")
            if key is None:
                return
            store = server.service.store
            if store is None:
                self._send_json(404, {"error": "server runs without a store "
                                               "(REPRO_ARTIFACT_CACHE=off)",
                                      "key": key})
                return
            # local tiers only: this is a cache-inspection endpoint, and a
            # miss must not cost an uncoalesced peer sweep per request —
            # peer read-through belongs to the coalesced derive path (and
            # the explicit /v1/replicate surface)
            rec = store.load(key, local_only=True)
            if rec is None:
                self._send_json(404, {"error": f"no record for key {key!r}",
                                      "key": key})
                return
            res = pipeline.result_from_record(rec, DOMAINS[rec["domain"]], key)
            art = res.artifact
            self._send_json(200, {
                "key": key,
                "record": rec,
                "artifact": art.to_record() if art is not None else None,
            })

        def _artifact_delete(self) -> None:
            key = self._key_from_path("/v1/artifact/")
            if key is None:
                return
            store = server.service.store
            if store is None:
                self._send_json(404, {"error": "server runs without a store "
                                               "(REPRO_ARTIFACT_CACHE=off)",
                                      "key": key})
                return
            # cached evaluate responses embedding this artifact's
            # coordinates must die with it
            server.eval_wire.invalidate_artifact(key)
            if store.delete(key):
                self._send_json(200, {"key": key, "deleted": True})
            else:
                self._send_json(404, {"error": f"no record for key {key!r}",
                                      "key": key})

        def _replicate_pull(self) -> None:
            """The raw local record for a sibling server's PeerStore.
            Local tiers only — peers asking each other can never recurse."""
            key = self._key_from_path("/v1/replicate/")
            if key is None:
                return
            store = server.service.store
            rec = store.load_local(key) if store is not None else None
            if rec is None:
                self._send_json(404, {"error": f"no record for key {key!r}",
                                      "key": key})
                return
            self._send_json(200, rec)

        def _replicate_push(self) -> None:
            """Accept a record a sibling just published (its write-back).
            Stored into the local tiers only — no push echo back out.  The
            envelope is verified before anything lands: a mismatched or
            missing checksum is a 400, same bytes DiskStore would
            quarantine on read — corruption must not enter via the wire."""
            key = self._key_from_path("/v1/replicate/")
            if key is None:
                return
            store = server.service.store
            if store is None:
                self._send_json(404, {"error": "server runs without a store "
                                               "(REPRO_ARTIFACT_CACHE=off)",
                                      "key": key})
                return
            rec = self._read_body()
            if not rec or "domain" not in rec:
                raise ValueError("replication push body must be a derivation "
                                 "record (JSON object with 'domain')")
            if not store_mod.verify_envelope(key, rec):
                raise ValueError(
                    "replication push rejected: record envelope must carry "
                    f"schema {store_mod.SCHEMA_VERSION}, the URL key, and a "
                    "matching payload checksum")
            store.store_local(key, rec)
            self._send_json(200, {"key": key, "stored": True})

        def _grid(self) -> None:
            body = self._read_body()

            def names(field):
                val = body.get(field)
                if val is None:
                    return None
                if not isinstance(val, list):
                    raise ValueError(f"{field!r} must be a list")
                return val

            domains, models, stages = (names("domains"), names("models"),
                                       names("stages"))
            self.send_response(200)
            self.send_header("Content-Type", "application/x-ndjson")
            # the stream's length is unknowable up front: close-delimit this
            # one response (send_header flips close_connection for us)
            self.send_header("Connection", "close")
            self.end_headers()
            # stream one line per resolved cell; a mid-stream failure becomes
            # a terminal error line (headers are already gone)
            try:
                for res in server.service.run_grid(domains, models, stages):
                    line = json.dumps(pipeline.wire_from_result(res)) + "\n"
                    self.wfile.write(line.encode())
                    self.wfile.flush()
            except (BrokenPipeError, ConnectionResetError):
                raise
            except Exception as e:  # noqa: BLE001
                self.wfile.write(
                    (json.dumps({"error": f"{type(e).__name__}: {e}"}) +
                     "\n").encode())

    return Handler


def serve(service: MappingService | None = None, host: str = "127.0.0.1",
          port: int = 8000) -> MappingHTTPServer:
    """Boot a server in the calling thread (the CLI path)."""
    server = MappingHTTPServer(service or MappingService(), host, port)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.httpd.server_close()
    return server
