"""Load-aware request routing: a bounded per-node scheduler + a latency/
queue-depth replica selector.

The ring (:mod:`repro.serving.cluster`) decides *who may* serve a key — a
static, placement-only answer.  This module decides *who should serve it
right now*: among the K owners, a slow or queue-saturated replica must
re-earn traffic instead of receiving its static hash share while idle
siblings starve.  Three pieces:

  * :class:`RequestQueue`    — a bounded FIFO with TTL expiry and a retry
    lane.  ``offer`` refuses when full (the caller sheds — for forwards
    that means graceful local degradation, never an error), ``take``
    drains the retry lane first and silently drops entries whose deadline
    passed, and ``requeue`` moves a failed entry into the retry lane so a
    transient peer error gets one more shot ahead of fresh arrivals.
  * :class:`ReplicaSelector` — per-replica EWMA of *observed* latency
    blended with the *advertised* queue depth each peer piggybacks on
    heartbeats and ``/healthz``.  Ranking is epsilon-greedy: with
    probability ``epsilon`` a non-best candidate is promoted, so a
    recovered replica (whose stale EWMA still remembers the bad times)
    re-earns traffic instead of being starved forever.  Unknown replicas
    score optimistically (cost 0) — a fresh joiner is tried immediately.
  * :class:`RequestRouter`   — composes the two per node and exposes the
    frontends' integration surface: ``dispatch`` runs one request's
    forward attempts through the scheduler (admission -> ranked candidates
    -> per-attempt latency observation -> retry lane on failure -> TTL
    give-up), ``track`` counts local in-flight work, and ``load`` is what
    the cluster advertises to peers as this node's queue depth.

Everything is stdlib, thread-safe, and deterministic under a seeded RNG so
tests can pin the exploration schedule.  ``policy="static"`` preserves the
pre-adaptive ring-order behavior — it is both the benchmark baseline and
the escape hatch.
"""
from __future__ import annotations

import contextlib
import random
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Iterable

__all__ = ["RequestQueue", "ReplicaSelector", "RequestRouter", "RouterStats"]

#: router policies selectable via ``--route-policy``
POLICIES = ("loaded", "static")


@dataclass
class RouterStats:
    """Scheduler counters (the gauges ``/metrics`` exposes)."""

    enqueued: int = 0   # offers accepted
    dequeued: int = 0   # entries handed to a consumer
    expired: int = 0    # entries dropped past their deadline
    retried: int = 0    # entries moved to the retry lane
    shed: int = 0       # offers refused because the queue was full

    def as_dict(self) -> dict[str, int]:
        return {"enqueued": self.enqueued, "dequeued": self.dequeued,
                "expired": self.expired, "retried": self.retried,
                "shed": self.shed}


class _Entry:
    __slots__ = ("item", "deadline")

    def __init__(self, item: Any, deadline: float):
        self.item = item
        self.deadline = deadline


class RequestQueue:
    """Bounded FIFO with TTL expiry and a retry lane.

    Capacity covers both lanes together — a retry burst cannot grow the
    queue past what admission agreed to.  Expiry is lazy (checked on
    ``take``/``depth``): an entry that waited out its TTL is dropped and
    counted, never handed to a consumer, so a consumer can trust that
    whatever it takes still has budget left."""

    def __init__(self, capacity: int = 256, ttl: float = 30.0):
        self.capacity = max(1, int(capacity))
        self.ttl = float(ttl)
        self.stats = RouterStats()
        self._main: list[_Entry] = []
        self._retry: list[_Entry] = []
        self._mu = threading.Lock()

    def _drop_expired(self, now: float) -> None:
        """Callers hold ``_mu``."""
        for lane in (self._retry, self._main):
            kept = [e for e in lane if e.deadline > now]
            self.stats.expired += len(lane) - len(kept)
            lane[:] = kept

    def offer(self, item: Any, ttl: float | None = None) -> bool:
        """Admit ``item`` (False = full, caller sheds)."""
        now = time.monotonic()
        with self._mu:
            self._drop_expired(now)
            if len(self._main) + len(self._retry) >= self.capacity:
                self.stats.shed += 1
                return False
            self._main.append(_Entry(item, now + (self.ttl if ttl is None
                                                  else ttl)))
            self.stats.enqueued += 1
            return True

    def requeue(self, item: Any) -> bool:
        """Move ``item`` into the retry lane (ahead of fresh arrivals),
        keeping its original deadline when it is already queued — a retry
        must not extend the request's budget.  False when the item is
        unknown and the queue is full."""
        now = time.monotonic()
        with self._mu:
            self._drop_expired(now)
            entry = None
            for lane in (self._main, self._retry):
                for e in lane:
                    if e.item is item:
                        lane.remove(e)
                        entry = e
                        break
                if entry is not None:
                    break
            if entry is None:
                if len(self._main) + len(self._retry) >= self.capacity:
                    self.stats.shed += 1
                    return False
                entry = _Entry(item, now + self.ttl)
            self._retry.append(entry)
            self.stats.retried += 1
            return True

    def take(self) -> Any:
        """The oldest live entry, retry lane first (None when empty)."""
        now = time.monotonic()
        with self._mu:
            self._drop_expired(now)
            for lane in (self._retry, self._main):
                if lane:
                    self.stats.dequeued += 1
                    return lane.pop(0).item
            return None

    def remove(self, item: Any) -> bool:
        """Withdraw a specific item (admission release), expired or not."""
        with self._mu:
            for lane in (self._main, self._retry):
                for e in lane:
                    if e.item is item:
                        lane.remove(e)
                        return True
            return False

    def depth(self) -> int:
        now = time.monotonic()
        with self._mu:
            self._drop_expired(now)
            return len(self._main) + len(self._retry)


class _Replica:
    __slots__ = ("ewma_ms", "last_ms", "samples", "queue_depth",
                 "selections", "failures")

    def __init__(self):
        self.ewma_ms = 0.0
        self.last_ms = 0.0
        self.samples = 0
        self.queue_depth = 0
        self.selections = 0
        self.failures = 0


class ReplicaSelector:
    """EWMA-latency + advertised-queue-depth ranking with epsilon-greedy
    exploration.

    ``cost(url) = ewma_ms + depth_penalty_ms * advertised_queue_depth``;
    never-observed replicas cost 0 (optimism: fresh joiners and recovered
    nodes get tried immediately).  A failed attempt books at least
    ``failure_penalty_ms`` into the EWMA so a dead replica decays out of
    the rotation fast — and re-earns its way back via exploration plus the
    optimistic reset when membership forgets and re-adds it."""

    def __init__(self, alpha: float = 0.3, epsilon: float = 0.05,
                 depth_penalty_ms: float = 5.0,
                 failure_penalty_ms: float = 250.0,
                 seed: int | None = None):
        self.alpha = min(max(float(alpha), 0.01), 1.0)
        self.epsilon = min(max(float(epsilon), 0.0), 1.0)
        self.depth_penalty_ms = float(depth_penalty_ms)
        self.failure_penalty_ms = float(failure_penalty_ms)
        self.explorations = 0
        self._rng = random.Random(seed)
        self._replicas: dict[str, _Replica] = {}
        self._mu = threading.Lock()

    def _get(self, url: str) -> _Replica:
        """Callers hold ``_mu``."""
        replica = self._replicas.get(url)
        if replica is None:
            replica = self._replicas[url] = _Replica()
        return replica

    def observe(self, url: str, seconds: float, ok: bool = True) -> None:
        """Fold one attempt's measured latency into the replica's EWMA."""
        ms = max(0.0, seconds * 1e3)
        with self._mu:
            replica = self._get(url)
            if not ok:
                replica.failures += 1
                ms = max(ms, self.failure_penalty_ms)
            if replica.samples == 0:
                replica.ewma_ms = ms
            else:
                replica.ewma_ms += self.alpha * (ms - replica.ewma_ms)
            replica.last_ms = ms
            replica.samples += 1

    def advertise(self, url: str, load: dict | None) -> None:
        """Fold in a queue-depth advertisement (heartbeat piggyback or a
        ``/healthz`` answer)."""
        if not isinstance(load, dict):
            return
        try:
            depth = max(0, int(load.get("queue_depth", 0)))
        except (TypeError, ValueError):
            return
        with self._mu:
            self._get(url).queue_depth = depth

    def record_selection(self, url: str) -> None:
        with self._mu:
            self._get(url).selections += 1

    def forget(self, url: str) -> None:
        """Drop learned state (e.g. the fleet forgot the node) — if it
        comes back it restarts optimistic."""
        with self._mu:
            self._replicas.pop(url, None)

    def cost(self, url: str) -> float:
        with self._mu:
            replica = self._replicas.get(url)
            if replica is None or replica.samples == 0:
                depth = 0 if replica is None else replica.queue_depth
                return self.depth_penalty_ms * depth
            return replica.ewma_ms + self.depth_penalty_ms * \
                replica.queue_depth

    def rank(self, urls: Iterable[str]) -> list[str]:
        """Candidates in serving preference order: cost-ascending with the
        caller's (ring) order as the tiebreak; with probability ``epsilon``
        one non-best candidate is promoted to the front instead."""
        urls = list(urls)
        if len(urls) <= 1:
            return urls
        costs = {u: self.cost(u) for u in urls}
        ranked = [u for _, _, u in
                  sorted((costs[u], i, u) for i, u in enumerate(urls))]
        if self.epsilon > 0 and self._rng.random() < self.epsilon:
            with self._mu:
                self.explorations += 1
                j = self._rng.randrange(1, len(ranked))
            ranked.insert(0, ranked.pop(j))
        return ranked

    def snapshot(self) -> dict[str, dict]:
        """Per-replica state for ``/metrics`` (the selection counters the
        chaos CI asserts traffic shifts on)."""
        with self._mu:
            return {url: {"ewma_ms": round(r.ewma_ms, 3),
                          "last_ms": round(r.last_ms, 3),
                          "samples": r.samples,
                          "queue_depth": r.queue_depth,
                          "selections": r.selections,
                          "failures": r.failures}
                    for url, r in self._replicas.items()}


class RequestRouter:
    """Per-node composition of scheduler + selector, plus the in-flight
    gauge the cluster advertises as this node's queue depth.

    ``policy="loaded"`` ranks owners by the selector; ``policy="static"``
    keeps ring order (the pre-adaptive behavior, also the benchmark
    baseline).  Either way every forward attempt is measured and fed back,
    so flipping a static fleet to loaded starts from a warm model."""

    def __init__(self, policy: str = "loaded", max_pending: int = 256,
                 ttl: float = 30.0, epsilon: float = 0.05,
                 alpha: float = 0.3, depth_penalty_ms: float = 5.0,
                 failure_penalty_ms: float = 250.0,
                 seed: int | None = None):
        policy = (policy or "loaded").strip().lower()
        if policy not in POLICIES:
            raise ValueError(f"unknown route policy {policy!r} (expected "
                             f"one of {', '.join(POLICIES)})")
        self.policy = policy
        self.queue = RequestQueue(capacity=max_pending, ttl=ttl)
        self.selector = ReplicaSelector(
            alpha=alpha, epsilon=epsilon, depth_penalty_ms=depth_penalty_ms,
            failure_penalty_ms=failure_penalty_ms, seed=seed)
        self._inflight = 0
        self._mu = threading.Lock()

    # -- local load accounting (what peers see) ----------------------------
    @contextlib.contextmanager
    def track(self):
        """Count one unit of local in-flight work (a derive being served)
        toward the advertised queue depth."""
        with self._mu:
            self._inflight += 1
        try:
            yield
        finally:
            with self._mu:
                self._inflight -= 1

    def inflight(self) -> int:
        with self._mu:
            return self._inflight

    def load(self) -> dict:
        """This node's advertisement: piggybacked on every ``/v1/cluster``
        view and served on ``/healthz``."""
        return {"queue_depth": self.inflight() + self.queue.depth(),
                "inflight": self.inflight()}

    # -- selection ---------------------------------------------------------
    def rank_owners(self, owners: Iterable[str]) -> list[str]:
        owners = list(owners)
        if self.policy == "static":
            return owners
        return self.selector.rank(owners)

    def observe(self, url: str, seconds: float, ok: bool = True) -> None:
        self.selector.observe(url, seconds, ok=ok)

    def advertise(self, url: str, load: dict | None) -> None:
        self.selector.advertise(url, load)

    # -- the forward-hop scheduler -----------------------------------------
    def dispatch(self, key: str, candidates: Iterable[str],
                 attempt: Callable[[str], Any],
                 on_error: Callable[[str, Exception], Any] | None = None):
        """Run one request's forward attempts through the scheduler.

        Admission first: a full queue sheds the *hop* (returns None — the
        caller degrades to serving locally, which is always correct).
        Candidates are then tried best-first; a failed attempt books the
        failure into the selector, moves the request to the retry lane,
        and tries the next candidate — until the TTL budget expires.
        Returns the first successful attempt's result, else None."""
        candidates = list(candidates)
        if not candidates:
            return None
        token = object()
        if not self.queue.offer(token):
            return None
        deadline = time.monotonic() + self.queue.ttl
        try:
            for url in self.rank_owners(candidates):
                if time.monotonic() >= deadline:
                    self.queue.stats.expired += 1
                    break
                self.selector.record_selection(url)
                t0 = time.monotonic()
                try:
                    result = attempt(url)
                except Exception as exc:  # noqa: BLE001 — any hop failure
                    self.observe(url, time.monotonic() - t0, ok=False)
                    if on_error is not None:
                        on_error(url, exc)
                    self.queue.requeue(token)  # retry lane: next candidate
                    continue
                self.observe(url, time.monotonic() - t0, ok=True)
                return result
            return None
        finally:
            self.queue.remove(token)

    # -- metrics -----------------------------------------------------------
    def stats_dict(self) -> dict:
        return {"policy": self.policy,
                "epsilon": self.selector.epsilon,
                "inflight": self.inflight(),
                "queue_depth": self.load()["queue_depth"],
                "queue": {"capacity": self.queue.capacity,
                          "ttl_seconds": self.queue.ttl,
                          "depth": self.queue.depth(),
                          **self.queue.stats.as_dict()},
                "explorations": self.selector.explorations,
                "replicas": self.selector.snapshot()}
