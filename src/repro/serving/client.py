"""RemoteMappingService — the client half of the networked serving stack.

Same ``derive`` / ``run_grid`` / ``artifact`` / ``grid`` surface as the
in-process :class:`~repro.serving.map_service.MappingService`, resolved over
HTTP against a :mod:`repro.serving.http` server instead of a local pipeline.
Callers can therefore swap `MappingService()` for
`RemoteMappingService(url)` without touching anything downstream — results
rehydrate through the same wire schema the cache stores
(``pipeline.result_from_wire``), so a remote ``DerivationResult`` carries
the same artifact, report, and content address a local one would.

Failure policy, in order:

  * transport errors (connection refused / reset / timeout) retry with
    exponential backoff up to ``retries`` times;
  * ``503`` (admission shed) is retryable the same way — the server asked
    us to back off;
  * other HTTP errors (400/404/500) raise :class:`RemoteServiceError`
    immediately — retrying a malformed or failing request won't help;
  * when every attempt fails *and* a ``fallback`` service was provided, the
    request is served locally (graceful degradation: the client machine
    re-derives rather than erroring out, at local inference cost).
"""
from __future__ import annotations

import dataclasses
import json
import time
import urllib.error
import urllib.request
from typing import Callable, Iterable, Iterator, Sequence

from repro.core import pipeline
from repro.core.artifact import MappingArtifact
from repro.core.domains import Domain
from repro.serving.map_service import MappingService

_RETRYABLE_STATUS = (503,)


class RemoteServiceError(RuntimeError):
    """Terminal client-side failure (bad request, server fault, or transport
    failure with no fallback configured)."""

    def __init__(self, message: str, status: int | None = None):
        super().__init__(message)
        self.status = status


def _falls_back(e: RemoteServiceError) -> bool:
    """Only server-absent / server-overloaded failures degrade to the local
    fallback; a definite HTTP answer (400/404/500) is the server speaking
    and must surface to the caller."""
    return e.status is None or e.status in _RETRYABLE_STATUS


@dataclasses.dataclass
class ClientStats:
    """Client-side counters (the remote complement of ServiceStats)."""

    remote_requests: int = 0   # HTTP calls that returned a result
    retries: int = 0           # extra attempts after a retryable failure
    fallbacks: int = 0         # requests served by the local fallback
    server_cache_hits: int = 0  # results the server marked cache_hit

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class RemoteMappingService:
    """MappingService surface over a remote derivation server."""

    def __init__(
        self,
        url: str,
        timeout: float = 60.0,
        retries: int = 3,
        backoff: float = 0.1,
        fallback: MappingService | Callable[[], MappingService] | None = None,
    ):
        self.url = url.rstrip("/")
        self.timeout = timeout
        self.retries = retries
        self.backoff = backoff
        self.stats = ClientStats()
        self._fallback = fallback
        self._fallback_service: MappingService | None = None

    # -- transport ---------------------------------------------------------
    def _open(self, path: str, body: dict | None = None,
              method: str | None = None):
        data = json.dumps(body).encode() if body is not None else None
        req = urllib.request.Request(
            f"{self.url}{path}", data=data, method=method,
            headers={"Content-Type": "application/json"} if data else {},
        )
        return urllib.request.urlopen(req, timeout=self.timeout)  # noqa: S310

    def _attempts(self, path: str, body: dict | None,
                  method: str | None = None):
        """Yield open responses, retrying transport/503 failures with
        backoff; raises the terminal error when attempts are exhausted."""
        last: Exception | None = None
        for attempt in range(self.retries + 1):
            if attempt:
                time.sleep(self.backoff * (2 ** (attempt - 1)))
                self.stats.retries += 1
            try:
                return self._open(path, body, method)
            except urllib.error.HTTPError as e:
                if e.code in _RETRYABLE_STATUS:
                    last = e
                    continue
                detail = ""
                try:
                    detail = json.loads(e.read()).get("error", "")
                except Exception:  # noqa: BLE001 — detail is best-effort
                    pass
                raise RemoteServiceError(
                    f"{path} -> HTTP {e.code}: {detail or e.reason}",
                    status=e.code) from e
            except (urllib.error.URLError, ConnectionError, TimeoutError,
                    OSError) as e:
                last = e
                continue
        status = last.code if isinstance(last, urllib.error.HTTPError) else None
        raise RemoteServiceError(
            f"{path} unreachable after {self.retries + 1} attempts: {last}",
            status=status) from last

    def _call_json(self, path: str, body: dict | None = None,
                   method: str | None = None) -> dict:
        with self._attempts(path, body, method) as resp:
            payload = json.loads(resp.read())
        self.stats.remote_requests += 1
        return payload

    # -- fallback ----------------------------------------------------------
    def _local(self) -> MappingService | None:
        if self._fallback is None:
            return None
        if self._fallback_service is None:
            fb = self._fallback
            self._fallback_service = fb() if callable(fb) and not isinstance(
                fb, MappingService) else fb  # type: ignore[assignment]
        return self._fallback_service

    # -- MappingService surface --------------------------------------------
    def derive(self, domain: str | Domain, model: str,
               stage: int = 100) -> pipeline.DerivationResult:
        name = domain.name if isinstance(domain, Domain) else domain
        try:
            payload = self._call_json(
                "/v1/derive", {"domain": name, "model": model, "stage": stage})
        except RemoteServiceError as e:
            local = self._local()
            if local is None or not _falls_back(e):
                raise
            self.stats.fallbacks += 1
            return local.derive(domain, model, stage)
        res = pipeline.result_from_wire(payload)
        if res.cache_hit:
            self.stats.server_cache_hits += 1
        return res

    def artifact(self, domain: str | Domain, model: str,
                 stage: int = 100) -> MappingArtifact | None:
        return self.derive(domain, model, stage).artifact

    def fetch_artifact(self, key: str) -> dict:
        """GET /v1/artifact/<key>: the raw {record, artifact} payload for a
        content address (no derivation is triggered)."""
        return self._call_json(f"/v1/artifact/{key}")

    def delete_artifact(self, key: str) -> dict:
        """DELETE /v1/artifact/<key>: drop one record from the server's
        local tiers (per-node ops action; peers keep their copies)."""
        return self._call_json(f"/v1/artifact/{key}", method="DELETE")

    def pull_record(self, key: str) -> dict:
        """GET /v1/replicate/<key>: the raw local record (the same surface
        PeerStore reads — memory/disk only, no peer recursion server-side)."""
        return self._call_json(f"/v1/replicate/{key}")

    def store_stats(self) -> dict:
        """GET /v1/store/stats: per-tier counters + disk usage."""
        return self._call_json("/v1/store/stats")

    def run_grid(
        self,
        domains: Iterable[str | Domain] | None = None,
        models: Iterable[str] | None = None,
        stages: Sequence[int] | None = None,
    ) -> Iterator[pipeline.DerivationResult]:
        """Streamed sweep: one rehydrated result per NDJSON line, as the
        server resolves cells."""
        body = {}
        if domains is not None:
            body["domains"] = [d.name if isinstance(d, Domain) else d
                               for d in domains]
        if models is not None:
            body["models"] = list(models)
        if stages is not None:
            body["stages"] = list(stages)
        try:
            resp = self._attempts("/v1/grid", body)
        except RemoteServiceError as e:
            local = self._local()
            if local is None or not _falls_back(e):
                raise
            self.stats.fallbacks += 1
            yield from local.run_grid(domains, models, stages)
            return
        with resp:
            self.stats.remote_requests += 1
            while True:
                # wrap per-line reads so a server dying mid-stream surfaces
                # as the documented error type, not a raw socket exception
                try:
                    raw = resp.readline()
                except (ConnectionError, TimeoutError, OSError) as e:
                    raise RemoteServiceError(
                        f"/v1/grid stream broke mid-sweep: {e}") from e
                if not raw:
                    break
                line = raw.strip()
                if not line:
                    continue
                payload = json.loads(line)
                if "error" in payload and "record" not in payload:
                    raise RemoteServiceError(
                        f"/v1/grid failed mid-stream: {payload['error']}")
                res = pipeline.result_from_wire(payload)
                if res.cache_hit:
                    self.stats.server_cache_hits += 1
                yield res

    def grid(self, domains=None, models=None, stages=None,
             ) -> dict[tuple[str, str, int], pipeline.DerivationResult]:
        return {(r.domain, r.model, r.stage): r
                for r in self.run_grid(domains, models, stages)}

    # -- server introspection ----------------------------------------------
    def healthy(self) -> bool:
        try:
            return self._call_json("/healthz").get("status") == "ok"
        except RemoteServiceError:
            return False

    def metrics(self) -> dict:
        """The server's /metrics payload (ServiceStats + latency + batching)."""
        return self._call_json("/metrics")
