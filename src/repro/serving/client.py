"""RemoteMappingService — the client half of the networked serving stack.

Same ``derive`` / ``run_grid`` / ``artifact`` / ``grid`` surface as the
in-process :class:`~repro.serving.map_service.MappingService`, resolved over
HTTP against a :mod:`repro.serving.http` server instead of a local pipeline.
Callers can therefore swap `MappingService()` for
`RemoteMappingService(url)` without touching anything downstream — results
rehydrate through the same wire schema the cache stores
(``pipeline.result_from_wire``), so a remote ``DerivationResult`` carries
the same artifact, report, and content address a local one would.

Transport: one pooled keep-alive ``http.client`` connection per host (and
per thread), so a hot derive costs a request/response on a warm socket
instead of a TCP handshake + connect per call.  A pooled socket that died
while idle (server restart, keep-alive reaped) reconnects once silently
before the normal retry/backoff machinery sees anything.  Constructing with
``keep_alive=False`` sends ``Connection: close`` per request — the
pre-PR-5 behavior, kept as the benchmark baseline.

Cluster awareness: against a sharded fleet (``--cluster-seed``), the client
fetches the ``GET /v1/cluster`` view once, builds the same weighted
placement the servers use (ring or rendezvous — the view says which), and —
as soon as a cell's content address is known from its first response —
hashes locally and sends repeat derives straight to the key's owners,
skipping the server-side forwarding hop.  Among the K owners it ranks by
its *own* observed per-host latency (EWMA, seeded by the view's advertised
queue depths) before the ring-order fallback, so a slow replica loses this
client's traffic without any server-side help.  Against a standalone
server (404 on /v1/cluster) all of this degrades to plain single-host
behavior.

Failure policy, in order:

  * transport errors (connection refused / reset / timeout) retry with
    exponential backoff up to ``retries`` times;
  * ``503`` (admission shed) and ``504`` (backend deadline blown) are
    retryable the same way — derivations are idempotent by content address,
    so a resend is always safe; when every retry ends on one of these the
    terminal error is *typed* (:class:`RemoteBusyError` /
    :class:`RemoteTimeoutError`, which are also ``LLMBusyError`` /
    ``LLMTimeoutError``) so callers branch without parsing messages;
  * other HTTP errors (400/404/500) raise :class:`RemoteServiceError`
    immediately — retrying a malformed or failing request won't help;
  * an owner-routed request whose owner is unreachable falls back to the
    configured home URL (and refreshes the cluster view);
  * when every attempt fails *and* a ``fallback`` service was provided, the
    request is served locally (graceful degradation: the client machine
    re-derives rather than erroring out, at local inference cost).
"""
from __future__ import annotations

import dataclasses
import http.client
import json
import threading
import time
from typing import Callable, Iterable, Iterator, Sequence
from urllib.parse import urlsplit

from repro.core import pipeline
from repro.core.artifact import MappingArtifact
from repro.core.backends import LLMBusyError, LLMTimeoutError
from repro.core.domains import Domain
from repro.core.store import valid_key
from repro.obs import trace as obs_trace
from repro.serving.map_service import MappingService

#: 503 = admission shed (server asked us to back off); 504 = generation
#: deadline blown server-side — both are safe to resend because derivations
#: are idempotent by content address
_RETRYABLE_STATUS = (503, 504)
_TRANSPORT_ERRORS = (http.client.HTTPException, ConnectionError,
                     TimeoutError, OSError)


class RemoteServiceError(RuntimeError):
    """Terminal client-side failure (bad request, server fault, or transport
    failure with no fallback configured)."""

    def __init__(self, message: str, status: int | None = None):
        super().__init__(message)
        self.status = status


class RemoteBusyError(RemoteServiceError, LLMBusyError):
    """Every retry was answered 503: the server is persistently shedding.
    Doubly typed so ``except LLMBusyError`` works across the process
    boundary — the remote stack raises what the local stack would."""


class RemoteTimeoutError(RemoteServiceError, LLMTimeoutError):
    """Every retry was answered 504: the backend kept blowing its deadline.
    ``except LLMTimeoutError`` catches it, local or remote."""


def _exhausted_error(path: str, attempts: int, status: int | None,
                     last: Exception) -> RemoteServiceError:
    """The terminal error after retries run dry — typed by the last status
    so callers can branch on busy/timeout without parsing messages."""
    cls = {503: RemoteBusyError, 504: RemoteTimeoutError}.get(
        status or 0, RemoteServiceError)
    return cls(f"{path} unreachable after {attempts} attempts: {last}",
               status=status)


class _StatusError(Exception):
    """Internal: the server answered with a definite HTTP error status."""

    def __init__(self, status: int, reason: str, detail: str):
        super().__init__(f"HTTP {status}: {detail or reason}")
        self.status = status
        self.reason = reason
        self.detail = detail


def _falls_back(e: RemoteServiceError) -> bool:
    """Only server-absent / server-overloaded failures degrade to the local
    fallback; a definite HTTP answer (400/404/500) is the server speaking
    and must surface to the caller."""
    return e.status is None or e.status in _RETRYABLE_STATUS


@dataclasses.dataclass
class ClientStats:
    """Client-side counters (the remote complement of ServiceStats)."""

    remote_requests: int = 0   # HTTP calls that returned a result
    retries: int = 0           # extra attempts after a retryable failure
    fallbacks: int = 0         # requests served by the local fallback
    server_cache_hits: int = 0  # results the server marked cache_hit
    reconnects: int = 0        # pooled sockets found dead + reopened
    routed: int = 0            # requests sent straight to the ring owner
    reroutes: int = 0          # owner unreachable -> retried via home URL

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Response:
    """Keep-alive-aware response wrapper.  The connection is *checked out*
    of the pool for the response's whole lifetime (so a nested call made
    while a grid stream is suspended gets its own connection instead of
    clobbering the in-flight one); ``close`` checks a fully-drained
    response's connection back in, and drops an abandoned (mid-stream) or
    close-marked one — a half-read socket can never be reused."""

    def __init__(self, owner: "RemoteMappingService", netloc: str,
                 conn, resp):
        self._owner = owner
        self._netloc = netloc
        self._conn = conn
        self._resp = resp
        self._closed = False
        self.status = resp.status
        #: what the server actually sent — the client's binary-vs-JSON
        #: dispatch point (an old server ignores Accept and answers JSON)
        self.content_type = resp.getheader("Content-Type", "") or ""

    def read(self, amt: int | None = None) -> bytes:
        return self._resp.read() if amt is None else self._resp.read(amt)

    def readline(self) -> bytes:
        return self._resp.readline()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        resp = self._resp
        reusable = resp.isclosed() and not resp.will_close
        resp.close()
        if reusable:
            self._owner._checkin(self._netloc, self._conn)
        else:
            try:
                self._conn.close()
            except Exception:  # noqa: BLE001 — teardown is best-effort
                pass

    def __enter__(self) -> "_Response":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class RemoteMappingService:
    """MappingService surface over a remote derivation server."""

    def __init__(
        self,
        url: str,
        timeout: float = 60.0,
        retries: int = 3,
        backoff: float = 0.1,
        fallback: MappingService | Callable[[], MappingService] | None = None,
        keep_alive: bool = True,
        binary: bool = True,
    ):
        self.url = url.rstrip("/")
        self.timeout = timeout
        self.retries = retries
        self.backoff = backoff
        self.keep_alive = keep_alive
        #: negotiate the binary evaluate wire (Accept:
        #: application/x-repro-binary); an older server answers JSON and
        #: the client parses whatever Content-Type actually came back, so
        #: this is a preference, never a compatibility break
        self.binary = binary
        self.stats = ClientStats()
        self._fallback = fallback
        self._fallback_service: MappingService | None = None
        self._tls = threading.local()  # per-thread connection pool
        self._ring = None              # Placement once the view is fetched
        self._ring_checked = False     # 404 = standalone server: stay plain
        self._cell_keys: dict[tuple[str, str, int], str] = {}
        self._local_evaluator = None   # lazy EvaluationService fallback
        # client-side replica ranking: EWMA of *this client's* observed
        # per-host latency (no exploration — ring order is the tiebreak and
        # the fallback, so an unknown owner is simply tried in ring order)
        from repro.serving.router import ReplicaSelector

        self._selector = ReplicaSelector(epsilon=0.0, seed=0)

    # -- connection pool ---------------------------------------------------
    def _conns(self) -> dict:
        conns = getattr(self._tls, "conns", None)
        if conns is None:
            conns = self._tls.conns = {}
        return conns

    def _checkout(self, netloc: str, scheme: str):
        """Take the pooled connection for ``netloc`` (or build a fresh one).
        Checked-out connections are owned by exactly one in-flight response
        — a concurrent/nested call finds the pool slot empty and gets its
        own connection instead of corrupting the stream in progress."""
        conn = self._conns().pop(netloc, None)
        if conn is None:
            cls = (http.client.HTTPSConnection if scheme == "https"
                   else http.client.HTTPConnection)
            conn = cls(netloc, timeout=self.timeout)
        return conn

    def _checkin(self, netloc: str, conn) -> None:
        conns = self._conns()
        if netloc in conns:  # a nested call repopulated the slot meanwhile
            try:
                conn.close()
            except Exception:  # noqa: BLE001 — teardown is best-effort
                pass
        else:
            conns[netloc] = conn

    def close(self) -> None:
        """Close this thread's pooled connections (other threads' pools are
        reaped when the client is collected)."""
        conns = self._conns()
        for netloc in list(conns):
            conn = conns.pop(netloc, None)
            try:
                conn.close()
            except Exception:  # noqa: BLE001 — teardown is best-effort
                pass

    # -- transport ---------------------------------------------------------
    def _request_once(self, base: str, method: str, path: str,
                      data: bytes | None, headers: dict) -> _Response:
        parts = urlsplit(base)
        netloc = parts.netloc
        conn = self._checkout(netloc, parts.scheme)
        pooled = conn.sock is not None
        try:
            conn.request(method, path, body=data, headers=headers)
            resp = conn.getresponse()
        except _TRANSPORT_ERRORS:
            try:
                conn.close()
            except Exception:  # noqa: BLE001
                pass
            if not pooled:
                raise  # a fresh connect failed: genuine transport failure
            # the pooled socket died while idle (keep-alive reaped, server
            # restarted): reconnect once before the retry/backoff machinery
            # hears about it — derives are idempotent, so a resend is safe
            self.stats.reconnects += 1
            conn = self._checkout(netloc, parts.scheme)
            try:
                conn.request(method, path, body=data, headers=headers)
                resp = conn.getresponse()
            except _TRANSPORT_ERRORS:
                try:
                    conn.close()
                except Exception:  # noqa: BLE001
                    pass
                raise
        return _Response(self, netloc, conn, resp)

    def _open(self, path: str, body: dict | None = None,
              method: str | None = None, base: str | None = None,
              headers: dict | None = None) -> _Response:
        data = json.dumps(body).encode() if body is not None else None
        all_headers = {"Content-Type": "application/json"} if data else {}
        # propagate the caller's active trace (no-op outside one); an
        # explicit per-call header (derive's trace_id) wins over it
        all_headers.update(obs_trace.wire_headers())
        if headers:
            all_headers.update(headers)
        if not self.keep_alive:
            all_headers["Connection"] = "close"
        method = method or ("POST" if data is not None else "GET")
        resp = self._request_once(base or self.url, method, path, data,
                                  all_headers)
        if resp.status >= 400:
            raw = resp.read()
            resp.close()
            detail = ""
            try:
                detail = json.loads(raw).get("error", "")
            except Exception:  # noqa: BLE001 — detail is best-effort
                pass
            raise _StatusError(resp.status, "", detail)
        return resp

    def _attempts(self, path: str, body: dict | None,
                  method: str | None = None,
                  base: str | None = None,
                  headers: dict | None = None) -> _Response:
        """Open a response, retrying transport/503 failures with backoff;
        raises the terminal error when attempts are exhausted."""
        last: Exception | None = None
        for attempt in range(self.retries + 1):
            if attempt:
                time.sleep(self.backoff * (2 ** (attempt - 1)))
                self.stats.retries += 1
            try:
                return self._open(path, body, method, base=base,
                                  headers=headers)
            except _StatusError as e:
                if e.status in _RETRYABLE_STATUS:
                    last = e
                    continue
                raise RemoteServiceError(
                    f"{path} -> {e}", status=e.status) from e
            except _TRANSPORT_ERRORS as e:
                last = e
                continue
        status = last.status if isinstance(last, _StatusError) else None
        raise _exhausted_error(path, self.retries + 1, status, last) from last

    def _call_json(self, path: str, body: dict | None = None,
                   method: str | None = None, base: str | None = None,
                   headers: dict | None = None) -> dict:
        with self._attempts(path, body, method, base=base,
                            headers=headers) as resp:
            payload = json.loads(resp.read())
        self.stats.remote_requests += 1
        return payload

    # -- cluster routing ---------------------------------------------------
    def _cluster_ring(self):
        """The fleet's hash ring, fetched lazily from ``GET /v1/cluster``
        (None against a standalone server).  Cached until an owner-routed
        request fails, which invalidates and refetches.  A definite 404 is
        remembered (the server *is* standalone); a transport failure is
        not — one restart blip must not disable owner routing for the
        client's whole lifetime."""
        if self._ring_checked:
            return self._ring
        self._ring_checked = True
        try:
            with self._open("/v1/cluster") as resp:
                view = json.loads(resp.read())
        except _StatusError:
            self._ring = None  # standalone node: stay plain, don't re-ask
            return None
        except (*_TRANSPORT_ERRORS, ValueError):
            self._ring = None
            self._ring_checked = False  # transient: retry on the next call
            return None
        from repro.serving.cluster import (
            DEFAULT_REPLICAS, DEFAULT_VNODES, make_placement,
        )
        nodes = []
        for n in view.get("nodes", []):
            if not (isinstance(n, dict) and n.get("status") == "up"
                    and n.get("url")):
                continue
            nodes.append((n["url"], n.get("weight", 1.0)))
            # seed the latency ranking with the fleet's advertised queue
            # depths — useful before this client has observed anything
            self._selector.advertise(n["url"], n.get("load"))
        try:
            self._ring = make_placement(
                str(view.get("placement", "ring")), nodes,
                vnodes=int(view.get("vnodes", DEFAULT_VNODES)),
                replicas=int(view.get("replicas", DEFAULT_REPLICAS)))
        except ValueError:
            self._ring = None  # a placement this client doesn't speak:
            return None        # plain single-host behavior, still correct
        return self._ring

    def _invalidate_ring(self) -> None:
        self._ring = None
        self._ring_checked = False

    def _owner_urls(self, key: str | None) -> list[str]:
        """Where a request for ``key`` should land, best first: the key's
        K owners ranked by this client's own observed latency (ring order
        breaks ties and covers never-observed owners).  Empty when unknown
        / unclustered; a leading home URL means "don't route"."""
        if key is None:
            return []
        ring = self._cluster_ring()
        if ring is None:
            return []
        return self._selector.rank(ring.owners(key))

    def _call_routed(self, path: str, body: dict | None, key: str | None,
                     method: str | None = None,
                     headers: dict | None = None) -> dict:
        """``_call_json`` addressed to ``key``'s best ring owner when one
        is known, walking down the latency ranking and finally degrading to
        the home URL when every owner is unreachable — a definite answer
        from an owner (400/404/500) stands.  Every attempt's latency feeds
        the ranking, so a slowing replica loses this client's preference
        without any server-side help."""
        owners = self._owner_urls(key)
        if not owners or owners[0] == self.url:
            return self._call_json(path, body, method, headers=headers)
        for owner in owners:
            if owner == self.url:
                break  # the home URL is next-best: take the plain path
            t0 = time.monotonic()
            try:
                payload = self._call_json(path, body, method, base=owner,
                                          headers=headers)
            except RemoteServiceError as e:
                self._selector.observe(owner, time.monotonic() - t0,
                                       ok=False)
                if not _falls_back(e):
                    raise
                self.stats.reroutes += 1
                self._invalidate_ring()  # the view that routed us is stale
                continue                 # next-best owner, then home
            self._selector.observe(owner, time.monotonic() - t0)
            self.stats.routed += 1
            return payload
        return self._call_json(path, body, method, headers=headers)

    # -- fallback ----------------------------------------------------------
    def _local(self) -> MappingService | None:
        if self._fallback is None:
            return None
        if self._fallback_service is None:
            fb = self._fallback
            self._fallback_service = fb() if callable(fb) and not isinstance(
                fb, MappingService) else fb  # type: ignore[assignment]
        return self._fallback_service

    def _local_eval(self):
        """Local EvaluationService for evaluate fallback — enabled exactly
        when a derive ``fallback`` was configured (same degradation policy:
        the client machine computes rather than erroring out).  Artifact-key
        queries resolve against the fallback service's store."""
        if self._fallback is None:
            return None
        if self._local_evaluator is None:
            from repro.serving.evaluate import EvaluationService

            local = self._local()
            self._local_evaluator = EvaluationService(
                artifact_resolver=local.artifact_for_key
                if local is not None else None)
        return self._local_evaluator

    # -- key validation ----------------------------------------------------
    def _require_key(self, key: str) -> None:
        """Fail fast on a malformed content address — the server would
        answer 400 anyway, so don't pay the round-trip to hear it."""
        if not valid_key(key):
            raise RemoteServiceError(
                f"invalid key {key!r}: content addresses are 64 lowercase "
                "hex characters", status=400)

    # -- MappingService surface --------------------------------------------
    def derive(self, domain: str | Domain, model: str, stage: int = 100,
               trace_id: str | None = None) -> pipeline.DerivationResult:
        name = domain.name if isinstance(domain, Domain) else domain
        cell = (name, model, stage)
        headers = {obs_trace.TRACE_HEADER: trace_id} if trace_id else None
        try:
            payload = self._call_routed(
                "/v1/derive", {"domain": name, "model": model,
                               "stage": stage},
                key=self._cell_keys.get(cell), headers=headers)
        except RemoteServiceError as e:
            local = self._local()
            if local is None or not _falls_back(e):
                raise
            self.stats.fallbacks += 1
            return local.derive(domain, model, stage)
        key = payload.get("key")
        if isinstance(key, str) and valid_key(key):
            # remember the cell's content address: repeats hash locally and
            # go straight to the owner, skipping the forwarding hop
            self._cell_keys[cell] = key
        res = pipeline.result_from_wire(payload)
        if res.cache_hit:
            self.stats.server_cache_hits += 1
        return res

    def artifact(self, domain: str | Domain, model: str,
                 stage: int = 100) -> MappingArtifact | None:
        return self.derive(domain, model, stage).artifact

    # -- evaluation (mapped coordinates over the wire) ---------------------
    def evaluate(self, domain: str | Domain | None = None, *,
                 key: str | None = None, tier: str = "map",
                 n_points: int | None = None, start: int = 0,
                 extent: Sequence[int] | None = None,
                 block_n: int | None = None,
                 interpret: bool | None = None) -> dict:
        """POST /v1/evaluate (single query): mapped coordinates for a
        λ-range (map tier) or a membership mask over a box (membership
        tier), computed by the server's compiled-executable hot path.
        Returns the result dict with ``coords``/``mask`` as numpy arrays.

        Transport policy is identical to :meth:`derive`: transport errors
        and 503 retry with backoff; with a ``fallback`` configured, a dead
        server degrades to local evaluation (same kernels, same bytes)."""
        query: dict = {"tier": tier}
        if key is not None:
            self._require_key(key)
            query["key"] = key
        elif domain is not None:
            query["domain"] = domain.name if isinstance(domain, Domain) \
                else domain
        else:
            raise ValueError("evaluate() needs 'domain' or 'key'")
        if n_points is not None:
            query["n_points"] = n_points
        if start:
            query["start"] = start
        if extent is not None:
            query["extent"] = list(extent)
        if block_n is not None:
            query["block_n"] = block_n
        if interpret is not None:
            query["interpret"] = interpret
        return self.evaluate_batch([query])[0]

    def evaluate_batch(self, queries: Sequence[dict]) -> list[dict]:
        """POST /v1/evaluate with a heterogeneous query batch: one HTTP
        round-trip, server-side executable grouping, results in query
        order (``coords``/``mask`` hydrated to numpy arrays).

        With ``binary=True`` (the default) the request carries ``Accept:
        application/x-repro-binary`` and a binary-speaking server answers
        raw little-endian frames, hydrated zero-copy via ``np.frombuffer``
        with the exact dtype/shape the server computed.  An older server
        ignores the header and answers JSON — detected from the response
        Content-Type, parsed through the unchanged JSON path."""
        from repro.serving import evaluate as ev
        from repro.serving import wire

        headers = {"Accept": wire.CONTENT_TYPE} if self.binary else None
        try:
            with self._attempts("/v1/evaluate", {"queries": list(queries)},
                                headers=headers) as resp:
                ctype = resp.content_type
                raw = resp.read()
        except RemoteServiceError as e:
            local = self._local_eval()
            if local is None or not _falls_back(e):
                raise
            self.stats.fallbacks += 1
            results, _ = local.evaluate_batch(list(queries))
            return results
        self.stats.remote_requests += 1
        if wire.is_binary(ctype):
            payload = wire.decode_frame(raw)
            if not isinstance(payload, dict):
                raise RemoteServiceError(
                    "/v1/evaluate answered a non-object binary frame")
            return list(payload.get("results", []))
        payload = json.loads(raw)
        return [ev.hydrate_result(r) for r in payload.get("results", [])]

    def evaluate_sweep(self, domains: Sequence[str], sizes: Sequence[int],
                       tier: str = "map", block_n: int | None = None,
                       interpret: bool | None = None) -> Iterator[dict]:
        """Streamed evaluation sweep over (domain × n_points): one hydrated
        result per stream cell, as the server resolves them (the /v1/grid
        close-delimited framing, applied to the evaluation plane).

        With ``binary=True`` the request asks for the length-prefixed
        binary frame stream and each cell hydrates via ``np.frombuffer``;
        an older server streams NDJSON instead, which the Content-Type
        check routes through the unchanged line parser."""
        from repro.serving import evaluate as ev
        from repro.serving import wire

        sweep: dict = {"domains": list(domains), "sizes": list(sizes),
                       "tier": tier}
        if block_n is not None:
            sweep["block_n"] = block_n
        if interpret is not None:
            sweep["interpret"] = interpret
        headers = {"Accept": wire.STREAM_CONTENT_TYPE} if self.binary \
            else None
        try:
            resp = self._attempts("/v1/evaluate", {"sweep": sweep},
                                  headers=headers)
        except RemoteServiceError as e:
            local = self._local_eval()
            if local is None or not _falls_back(e):
                raise
            self.stats.fallbacks += 1
            yield from local.sweep(domains, sizes, tier=tier,
                                   block_n=block_n, interpret=interpret)
            return
        with resp:
            self.stats.remote_requests += 1
            if wire.is_binary(resp.content_type):
                try:
                    for payload in wire.iter_stream(resp.read):
                        if isinstance(payload, dict) and "error" in payload \
                                and "tier" not in payload:
                            raise RemoteServiceError(
                                "/v1/evaluate failed mid-stream: "
                                f"{payload['error']}")
                        yield payload
                except _TRANSPORT_ERRORS as e:
                    raise RemoteServiceError(
                        f"/v1/evaluate stream broke mid-sweep: {e}") from e
                return
            while True:
                try:
                    raw = resp.readline()
                except _TRANSPORT_ERRORS as e:
                    raise RemoteServiceError(
                        f"/v1/evaluate stream broke mid-sweep: {e}") from e
                if not raw:
                    break
                line = raw.strip()
                if not line:
                    continue
                payload = json.loads(line)
                if "error" in payload and "tier" not in payload:
                    raise RemoteServiceError(
                        f"/v1/evaluate failed mid-stream: {payload['error']}")
                yield ev.hydrate_result(payload)

    def fetch_artifact(self, key: str) -> dict:
        """GET /v1/artifact/<key>: the raw {record, artifact} payload for a
        content address (no derivation is triggered)."""
        self._require_key(key)
        return self._call_json(f"/v1/artifact/{key}")

    def delete_artifact(self, key: str) -> dict:
        """DELETE /v1/artifact/<key>: drop one record from the server's
        local tiers (per-node ops action; peers keep their copies)."""
        self._require_key(key)
        return self._call_json(f"/v1/artifact/{key}", method="DELETE")

    def pull_record(self, key: str) -> dict:
        """GET /v1/replicate/<key>: the raw local record (the same surface
        PeerStore reads — memory/disk only, no peer recursion server-side)."""
        self._require_key(key)
        return self._call_json(f"/v1/replicate/{key}")

    def manifest(self) -> dict:
        """GET /v1/replicate/manifest: the server's local key manifest."""
        return self._call_json("/v1/replicate/manifest")

    def cluster_view(self) -> dict:
        """GET /v1/cluster: the server's membership view (404 -> error on a
        standalone node)."""
        return self._call_json("/v1/cluster")

    def store_stats(self) -> dict:
        """GET /v1/store/stats: per-tier counters + disk usage."""
        return self._call_json("/v1/store/stats")

    def run_grid(
        self,
        domains: Iterable[str | Domain] | None = None,
        models: Iterable[str] | None = None,
        stages: Sequence[int] | None = None,
    ) -> Iterator[pipeline.DerivationResult]:
        """Streamed sweep: one rehydrated result per NDJSON line, as the
        server resolves cells."""
        body = {}
        if domains is not None:
            body["domains"] = [d.name if isinstance(d, Domain) else d
                               for d in domains]
        if models is not None:
            body["models"] = list(models)
        if stages is not None:
            body["stages"] = list(stages)
        try:
            resp = self._attempts("/v1/grid", body)
        except RemoteServiceError as e:
            local = self._local()
            if local is None or not _falls_back(e):
                raise
            self.stats.fallbacks += 1
            yield from local.run_grid(domains, models, stages)
            return
        with resp:
            self.stats.remote_requests += 1
            while True:
                # wrap per-line reads so a server dying mid-stream surfaces
                # as the documented error type, not a raw socket exception
                try:
                    raw = resp.readline()
                except _TRANSPORT_ERRORS as e:
                    raise RemoteServiceError(
                        f"/v1/grid stream broke mid-sweep: {e}") from e
                if not raw:
                    break
                line = raw.strip()
                if not line:
                    continue
                payload = json.loads(line)
                if "error" in payload and "record" not in payload:
                    raise RemoteServiceError(
                        f"/v1/grid failed mid-stream: {payload['error']}")
                res = pipeline.result_from_wire(payload)
                if res.cache_hit:
                    self.stats.server_cache_hits += 1
                yield res

    def grid(self, domains=None, models=None, stages=None,
             ) -> dict[tuple[str, str, int], pipeline.DerivationResult]:
        return {(r.domain, r.model, r.stage): r
                for r in self.run_grid(domains, models, stages)}

    # -- server introspection ----------------------------------------------
    def healthy(self) -> bool:
        try:
            return self._call_json("/healthz").get("status") == "ok"
        except RemoteServiceError:
            return False

    def metrics(self) -> dict:
        """The server's /metrics payload (ServiceStats + latency + batching)."""
        return self._call_json("/metrics")

    def metrics_prometheus(self) -> str:
        """GET /metrics?format=prometheus: the text exposition."""
        with self._attempts("/metrics?format=prometheus", None) as resp:
            text = resp.read().decode()
        self.stats.remote_requests += 1
        return text

    def trace(self, trace_id: str, base: str | None = None) -> dict:
        """GET /v1/trace/<id>: one node's span shard of a request trace.
        ``base`` asks a specific fleet node (each node holds only the spans
        it executed); default is the home URL."""
        return self._call_json(f"/v1/trace/{trace_id}", base=base)

    def traces(self, base: str | None = None) -> dict:
        """GET /v1/traces: recent trace IDs + ring-buffer stats."""
        return self._call_json("/v1/traces", base=base)
