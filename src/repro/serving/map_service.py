"""MappingService — the many-clients front end of the derivation pipeline.

The paper's economics hinge on one-time derivation amortized across many GPU
workloads; this service makes the "many clients share one artifact store"
scenario safe and cheap:

  * process-safety — writers serialize per content address through the
    store's ``FileLock`` (atomic-rename publish keeps readers lock-free, and
    stale locks from crashed holders are broken after a threshold), so two
    *processes* deriving the same cell yield one derivation + one record;
  * request coalescing — an in-flight table keyed by the cell's content
    address means N concurrent *threads* asking for the same (domain, model,
    stage) trigger exactly one pipeline run and all receive the shared
    ``DerivationResult``;
  * streaming sweeps — ``run_grid`` yields each cell's result as soon as it
    resolves (cache hit or fresh derivation) instead of buffering the whole
    (domain x model x stage) grid.

The service composes the pipeline's stage functions (``prepare_request`` /
``run_stages``) rather than reimplementing them, so the served path and the
local ``derive_mapping`` path share one content-address scheme by
construction.  ``REPRO_ARTIFACT_CACHE=off`` degrades the service to
coalescing-only: concurrent requests for one cell still share a single
derivation, but nothing persists, so sequential repeats re-derive.

Storage is the tiered :class:`~repro.core.store.TieredStore` (memory LRU ->
checksummed disk with TTL/size eviction -> peer replication): a hot hit
resolves from the memory tier with no disk read and no JSON parse, and once
a result has been rehydrated it is remembered on the entry so repeats skip
dataclass reconstruction too.  A bare disk-level store passed as ``store=``
(or the legacy ``cache=``) gains a memory tier automatically.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Iterable, Iterator, Sequence

import numpy as np

from repro.core import pipeline
from repro.core.artifact import MappingArtifact
from repro.core.backends import LLMBackend, MockLLMBackend
from repro.core.domains import DOMAINS, Domain
from repro.core.store import (
    ArtifactStore, as_tiered, default_store, finalize_record,
)
from repro.obs import trace as obs_trace

_USE_DEFAULT_CACHE = object()


@dataclasses.dataclass
class ServiceStats:
    """Operational counters: where each served request was resolved.

    This is the one stats surface shared by the in-process path and the HTTP
    path — ``MappingService.stats`` mutates it under the service lock, and
    ``GET /metrics`` publishes a :meth:`snapshot` of the same object, so the
    two views can never drift."""

    requests: int = 0        # derive() calls admitted (any resolution)
    derivations: int = 0     # pipeline actually ran (this process was leader)
    cache_hits: int = 0      # resolved from the shared artifact store
    coalesced: int = 0       # piggybacked on another thread's in-flight run
    errors: int = 0          # derive() raised (pipeline/backend/lock failure)
    stale_locks_broken: int = 0

    @property
    def cache_hit_ratio(self) -> float:
        """Fraction of admitted requests served without running the pipeline
        in this thread (store hits + coalesced waits)."""
        if self.requests == 0:
            return 0.0
        return (self.cache_hits + self.coalesced) / self.requests

    def snapshot(self) -> "ServiceStats":
        return dataclasses.replace(self)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["cache_hit_ratio"] = self.cache_hit_ratio
        return d


class _InFlight:
    """One in-progress derivation: followers wait on the event and share the
    leader's result (or its exception)."""

    __slots__ = ("event", "result", "error")

    def __init__(self):
        self.event = threading.Event()
        self.result: pipeline.DerivationResult | None = None
        self.error: BaseException | None = None


class MappingService:
    """Concurrency-safe artifact serving for (domain, model, stage) cells.

    One instance per process is the intended shape — its in-flight table
    coalesces threads, while the file lock in the artifact store coordinates
    across processes sharing the same cache root."""

    def __init__(
        self,
        store: ArtifactStore | None = _USE_DEFAULT_CACHE,  # type: ignore[assignment]
        backend_factory: Callable[[str], LLMBackend] = MockLLMBackend,
        n_validate: int = 100_000,
        sample_every: int = 50,
        lock_timeout: float = 300.0,
        stale_lock_seconds: float = 60.0,
        memory_entries: int = 256,
        cache: ArtifactStore | None = _USE_DEFAULT_CACHE,  # type: ignore[assignment]
    ):
        # lock_timeout bounds how long a follower process waits on a *live*
        # leader (whose heartbeat keeps the lock fresh) — it must comfortably
        # exceed a worst-case derivation, not a worst-case crash
        # (stale_lock_seconds covers crashes).
        if store is _USE_DEFAULT_CACHE:
            store = cache  # legacy keyword (PR 1..3 call sites)
        if store is _USE_DEFAULT_CACHE:
            store = default_store()
        # normalize to the tiered shape: a bare disk store gains a memory
        # hot tier, an existing TieredStore is used as-is, None stays None
        # (coalescing-only degradation)
        self.store = as_tiered(store, memory_entries)
        self.backend_factory = backend_factory
        self.n_validate = n_validate
        self.sample_every = sample_every
        self.lock_timeout = lock_timeout
        self.stale_lock_seconds = stale_lock_seconds
        self.stats = ServiceStats()
        self._backends: dict[str, LLMBackend] = {}
        self._inflight: dict[str, _InFlight] = {}
        self._request_keys: dict[tuple[str, str, int], str] = {}
        self._mu = threading.Lock()

    @property
    def cache(self):
        """Legacy name for :attr:`store` (kept for PR 1..3 call sites)."""
        return self.store

    # -- request identity --------------------------------------------------
    def _backend(self, model: str) -> LLMBackend:
        backend = self._backends.get(model)
        if backend is None:
            # construct outside the service lock (a real backend may load
            # weights / open sessions); first insert wins
            built = self.backend_factory(model)
            with self._mu:
                backend = self._backends.setdefault(model, built)
        return backend

    def _domain(self, domain: str | Domain) -> Domain:
        if isinstance(domain, Domain):
            return domain
        return DOMAINS[domain]

    def request(self, domain: str | Domain, model: str,
                stage: int = 100) -> pipeline.DerivationRequest:
        """The fully-addressed request for one cell — its ``key`` is both the
        cache address and the coalescing identity."""
        return pipeline.prepare_request(
            self._domain(domain), self._backend(model), stage,
            n_validate=self.n_validate, sample_every=self.sample_every)

    def request_key(self, domain: str | Domain, model: str,
                    stage: int = 100) -> str:
        """The content address one cell would derive under — what the HTTP
        layer hashes onto the cluster ring to decide whether this node owns
        an incoming derive or should forward it to the owner.

        The first call for a model constructs (and registers) its backend,
        because the address includes ``backend.name`` and
        ``cache_fingerprint`` — attributes only a live backend carries.
        That is the same work a local serve would do, and it happens once:
        repeats hit the memo below without touching the backend."""
        name = domain.name if isinstance(domain, Domain) else domain
        key = self._request_keys.get((name, model, stage))
        if key is None:
            key = self.request(domain, model, stage).key
            with self._mu:
                self._request_keys[(name, model, stage)] = key
        return key

    # -- serving -----------------------------------------------------------
    def try_cached(self, domain: str | Domain, model: str,
                   stage: int = 100) -> pipeline.DerivationResult | None:
        """Non-blocking hot path: the cell's result if it is already resident
        in the local tiers, else ``None`` — never coalesces, never locks,
        never probes peers, never runs the pipeline.

        This is the path an event-loop frontend can serve *inline*: after the
        first request for a (domain, model, stage) the memoized content
        address plus the memory tier make this a pair of dict lookups, so a
        hot cell costs no thread hop.  A miss means the caller should fall
        through to :meth:`derive` (off the event loop)."""
        if self.store is None:
            return None
        name = domain.name if isinstance(domain, Domain) else domain
        key = self._request_keys.get((name, model, stage))
        if key is None:
            return None  # cold cell: derive() will build + memoize the key
        res = self.store.load_result(key)
        if res is None:
            rec = self.store.load(key, local_only=True)
            if rec is None:
                return None
            res = pipeline.result_from_record(
                rec, self._domain(domain), key)
            self.store.remember_result(key, res)
        with self._mu:
            self.stats.requests += 1
            self.stats.cache_hits += 1
        return res

    def derive(
        self,
        domain: str | Domain,
        model: str,
        stage: int = 100,
        gt: np.ndarray | Callable[[], np.ndarray] | None = None,
    ) -> pipeline.DerivationResult:
        """Serve one cell: cache -> coalesce -> (locked) pipeline run."""
        try:
            req = self.request(domain, model, stage)
        except BaseException:
            with self._mu:
                self.stats.requests += 1
                self.stats.errors += 1
            raise
        name = domain.name if isinstance(domain, Domain) else domain
        with self._mu:
            self.stats.requests += 1
            # memoize the cell's content address so later try_cached()
            # calls (the event-loop fast path) resolve without rebuilding
            # the request
            self._request_keys.setdefault((name, model, stage), req.key)
        try:
            return self._derive_admitted(req, gt)
        except BaseException:
            with self._mu:
                self.stats.errors += 1
            raise

    def _derive_admitted(self, req: pipeline.DerivationRequest, gt):
        # lock-free fast path: a locally-published record needs no
        # coordination.  Local tiers only — N concurrent cold requests
        # must not each pay the peer probe (timeout x peers); the
        # coalescing leader probes peers exactly once under the lock.
        res = self._from_cache(req, local_only=True)
        if res is not None:
            return res

        with self._mu:
            fl = self._inflight.get(req.key)
            leader = fl is None
            if leader:
                fl = self._inflight[req.key] = _InFlight()
        if not leader:
            with obs_trace.span("coalesced_wait"):
                fl.event.wait()
            with self._mu:
                self.stats.coalesced += 1
            if fl.error is not None:
                raise fl.error
            return fl.result  # type: ignore[return-value]

        push = None
        try:
            fl.result, push = self._derive_locked(req, gt)
            return fl.result
        except BaseException as e:
            fl.error = e
            raise
        finally:
            with self._mu:
                self._inflight.pop(req.key, None)
            fl.event.set()
            if push is not None:
                # peer write-back last: after the file lock (cross-process
                # waiters) AND after the event (coalesced threads) are both
                # released — a slow or dead peer (timeout x N peers) delays
                # only the leader's own response, never the followers.
                # PeerStore.store never raises (push failures are counted).
                push()

    def _from_cache(self, req: pipeline.DerivationRequest,
                    local_only: bool = False):
        if self.store is None:
            return None
        # hottest path: a previously-rehydrated result resident in the
        # memory tier — no disk read, no JSON parse, no reconstruction
        res = self.store.load_result(req.key)
        if res is None:
            rec = self.store.load(req.key, local_only=local_only)
            if rec is None:
                return None
            res = pipeline.result_from_record(rec, req.domain, req.key)
            # rehydrated results carry cache_hit=True, so remembering one
            # (never a fresh derivation) keeps repeat serves truthful
            self.store.remember_result(req.key, res)
        with self._mu:
            self.stats.cache_hits += 1
        return res

    def _derive_locked(self, req: pipeline.DerivationRequest, gt):
        """Leader path: under the store's per-key file lock, re-check the
        store (another process may have published while we waited), then run
        the pipeline stages and publish atomically.  Returns ``(result,
        push)`` where ``push`` is the deferred peer write-back (or None) —
        best-effort replication must run only after both the file lock and
        the coalescing event are released, so the caller sequences it."""
        if self.store is None:
            with self._mu:
                self.stats.derivations += 1
            return pipeline.run_stages(req, gt), None
        lock = self.store.lock(req.key, timeout=self.lock_timeout,
                               stale_seconds=self.stale_lock_seconds)
        with lock:
            if lock.broke_stale:
                with self._mu:
                    self.stats.stale_locks_broken += 1
            res = self._from_cache(req)
            if res is not None:
                return res, None
            res = pipeline.run_stages(req, gt)
            record = finalize_record(req.key,
                                     pipeline.record_from_result(res))
            self.store.store_local(req.key, record)
            with self._mu:
                self.stats.derivations += 1
        peer = self.store.peer
        push = (lambda: peer.store(req.key, record)) \
            if peer is not None else None
        return res, push

    def backends(self) -> dict[str, LLMBackend]:
        """The per-model backends built so far (read-only view — the HTTP
        metrics endpoint reports batching-queue counters from these)."""
        with self._mu:
            return dict(self._backends)

    def stats_snapshot(self) -> ServiceStats:
        """A consistent copy of the counters (safe to serialize while other
        threads keep serving)."""
        with self._mu:
            return self.stats.snapshot()

    def inflight_count(self) -> int:
        """Cells currently being derived (coalescing table size) — the
        instantaneous companion to the cumulative ``stats.coalesced``."""
        with self._mu:
            return len(self._inflight)

    def store_stats(self) -> dict | None:
        """Per-tier store counters (memory/disk/peer hits, evictions,
        migrations, quarantines) — None when running store-less."""
        return self.store.stats() if self.store is not None else None

    def artifact(self, domain: str | Domain, model: str,
                 stage: int = 100) -> MappingArtifact | None:
        """The persistent product of a served cell (None if it failed)."""
        return self.derive(domain, model, stage).artifact

    def result_for_key(self, key: str) -> pipeline.DerivationResult | None:
        """Rehydrate a stored derivation by content address (local tiers
        only — no derivation is triggered, no peer sweep is paid).  This is
        how the evaluation plane resolves ``key`` queries: a client that
        learned a cell's content address from a derive can ask for mapped
        coordinates without respelling (domain, model, stage)."""
        if self.store is None:
            return None
        res = self.store.load_result(key)
        if res is not None:
            return res
        rec = self.store.load(key, local_only=True)
        if rec is None:
            return None
        res = pipeline.result_from_record(rec, DOMAINS[rec["domain"]], key)
        self.store.remember_result(key, res)
        return res

    def artifact_for_key(self, key: str) -> MappingArtifact | None:
        """The stored artifact for a content address (None when the record
        is absent or the derivation failed) — the ``artifact_resolver``
        the HTTP layer hands to its EvaluationService."""
        res = self.result_for_key(key)
        return res.artifact if res is not None else None

    # -- streaming sweeps --------------------------------------------------
    def run_grid(
        self,
        domains: Iterable[str | Domain] | None = None,
        models: Iterable[str] | None = None,
        stages: Sequence[int] | None = None,
    ) -> Iterator[pipeline.DerivationResult]:
        """Served grid sweep, streaming per-cell results as they resolve.

        Ground truth is enumerated lazily once per domain and shared across
        that domain's cells; fully-cached sweeps never enumerate at all.
        Defaults match ``pipeline.run_grid`` (the paper's measured grid)."""
        from repro.core import paper_tables as pt

        domains = list(domains) if domains is not None else sorted(pt.ACCURACY)
        models = list(models) if models is not None else list(pt.MODELS)
        stages = list(stages) if stages is not None else list(pt.STAGES)
        for dom_name in domains:
            dom = self._domain(dom_name)
            gt_memo: dict[str, np.ndarray] = {}

            def lazy_gt(d=dom, memo=gt_memo):
                if "gt" not in memo:
                    memo["gt"] = d.enumerate_points(self.n_validate)
                return memo["gt"]

            for model in models:
                for stage in stages:
                    yield self.derive(dom, model, stage, gt=lazy_gt)

    def grid(self, domains=None, models=None, stages=None,
             ) -> dict[tuple[str, str, int], pipeline.DerivationResult]:
        """Collected (non-streaming) form of :meth:`run_grid`."""
        return {(r.domain, r.model, r.stage): r
                for r in self.run_grid(domains, models, stages)}
