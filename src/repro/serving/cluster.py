"""Consistent-hash sharded fleet: ring placement, membership, anti-entropy.

PR 4's replication mesh was static — every node pushed every record to every
``--peers`` sibling, so a fleet of N servers held N copies of the whole
store and an operator re-wired flags to grow it.  This module makes the
fleet self-organizing and sharded:

  * :class:`Placement`         — the strategy interface: a deterministic
    ``key -> [owners]`` function over a *weighted* node set (per-node
    ``--weight`` for heterogeneous disk/compute budgets).
    :class:`HashRing` (consistent hashing, weight scales vnode count) is
    the default; :class:`RendezvousHash` (highest-random-weight) is the
    alternative with tighter balance at small N — selected fleet-wide via
    ``--placement`` and carried on the view so clients route identically.
  * :class:`ClusterMembership` — seed-based discovery: a new node is told
    one live node (``--cluster-seed``) and learns the rest through the
    ``GET /v1/cluster`` view-exchange endpoint.  A periodic heartbeat
    probes a deterministic-random O(log N) subset per round (the gossip
    fanout cap — membership traffic grows O(N log N), not O(N²)); a node
    that stops answering past ``down_after`` is marked down and drops out
    of the ring, and a rejoining node (same URL) is folded back in on its
    first successful probe.  Views piggyback each node's weight and live
    load (queue depth), which is what feeds the load-aware replica
    selector in :mod:`repro.serving.router`.
  * anti-entropy repair        — every ``sync_interval`` the node exchanges
    key manifests (``GET /v1/replicate/manifest``) with its live peers and
    pulls any record it *owns* but lacks.  That is how a node recovers
    publishes it missed while down, and how the fleet restores the
    replication factor after an owner dies (the ring reassigns the key; the
    new owner repairs itself from the surviving replica) — all with zero
    additional LLM inferences.

Ownership is advisory, not authoritative: every node can still serve any
record it holds, and a node that cannot reach an owner derives locally.
The ring only decides *placement* (who stores what) and *routing* (where
to look first) — correctness never depends on two nodes agreeing on the
view, because records are immutable per content address.
"""
from __future__ import annotations

import bisect
import hashlib
import json
import math
import random
import threading
import time
import urllib.error
import urllib.request
from typing import Any, Callable, Iterable
from urllib.parse import quote

from repro.core.store import valid_key, verify_envelope

DEFAULT_VNODES = 64
DEFAULT_REPLICAS = 2

#: placement strategies selectable via ``--placement`` / the view payload
PLACEMENTS = ("ring", "rendezvous")


def _hash(data: str) -> int:
    """Ring position of a node vnode or a record key: the first 8 bytes of
    sha256, so every node (and the client) computes identical placements
    from the same view."""
    return int.from_bytes(hashlib.sha256(data.encode()).digest()[:8], "big")


class Placement:
    """What the fleet needs from a placement strategy: a deterministic
    ``key -> [owner URLs]`` function over a weighted node set.  Both
    implementations are pure functions of ``(node, weight)`` pairs plus
    their own parameters — insertion order is irrelevant — so any two
    parties holding the same view route identically.  Weights let
    heterogeneous nodes (bigger disk, faster accelerator) claim a
    proportionally larger share of the key space."""

    kind = "placement"

    def __init__(self, nodes: Iterable = (),
                 replicas: int = DEFAULT_REPLICAS):
        self.replicas = max(1, int(replicas))
        self._weights: dict[str, float] = {}
        for node in nodes:
            if isinstance(node, str):
                self.add(node)
            else:  # (url, weight) pair
                self.add(node[0], node[1])

    # -- membership --------------------------------------------------------
    def add(self, node: str, weight: float = 1.0) -> None:
        raise NotImplementedError

    def remove(self, node: str) -> None:
        raise NotImplementedError

    @staticmethod
    def _clamp_weight(weight: float) -> float:
        try:
            weight = float(weight)
        except (TypeError, ValueError):
            weight = 1.0
        if not math.isfinite(weight) or weight <= 0:
            weight = 1.0
        return min(weight, 64.0)  # one node can never dwarf the fleet

    def weight(self, node: str) -> float:
        return self._weights.get(node, 1.0)

    @property
    def weights(self) -> dict[str, float]:
        return dict(self._weights)

    @property
    def nodes(self) -> list[str]:
        return sorted(self._weights)

    def __contains__(self, node: str) -> bool:
        return node in self._weights

    def __len__(self) -> int:
        return len(self._weights)

    # -- placement ---------------------------------------------------------
    def owners(self, key: str, n: int | None = None) -> list[str]:
        """The ``n`` (default ``replicas``) distinct nodes that own ``key``,
        in preference order (primary first).  Empty set -> empty list."""
        raise NotImplementedError

    def primary(self, key: str) -> str | None:
        owners = self.owners(key, 1)
        return owners[0] if owners else None


class HashRing(Placement):
    """Consistent-hash ring with virtual nodes and K-successor placement.

    ``owners(key)`` returns the first ``replicas`` *distinct* nodes
    clockwise from the key's point — fewer when the ring is smaller than K.
    A node's weight scales its vnode count (``round(vnodes * weight)``), so
    a weight-2 node claims ~2x the key space of a weight-1 sibling while a
    join/leave still only remaps keys adjacent to the changed node."""

    kind = "ring"

    def __init__(self, nodes: Iterable = (), vnodes: int = DEFAULT_VNODES,
                 replicas: int = DEFAULT_REPLICAS):
        self.vnodes = max(1, int(vnodes))
        self._points: list[tuple[int, str]] = []  # sorted (position, node)
        super().__init__(nodes, replicas=replicas)

    # -- membership --------------------------------------------------------
    def add(self, node: str, weight: float = 1.0) -> None:
        if node in self:
            return
        weight = self._clamp_weight(weight)
        self._weights[node] = weight
        for i in range(max(1, round(self.vnodes * weight))):
            bisect.insort(self._points, (_hash(f"{node}#{i}"), node))

    def remove(self, node: str) -> None:
        self._weights.pop(node, None)
        self._points = [(p, n) for p, n in self._points if n != node]

    # -- placement ---------------------------------------------------------
    def owners(self, key: str, n: int | None = None) -> list[str]:
        if not self._points:
            return []
        want = self.replicas if n is None else max(1, int(n))
        idx = bisect.bisect_left(self._points, (_hash(key), ""))
        out: list[str] = []
        for step in range(len(self._points)):
            node = self._points[(idx + step) % len(self._points)][1]
            if node not in out:
                out.append(node)
                if len(out) >= want:
                    break
        return out


class RendezvousHash(Placement):
    """Rendezvous (highest-random-weight) placement.

    Every node scores every key independently — ``owners(key)`` is the K
    highest scorers — so there is no ring geometry at all: a join/leave
    remaps exactly the keys the changed node wins/loses, and balance is
    tighter than a vnode ring's at small fleet sizes (no vnode clumping).
    The cost is O(N) hashing per lookup instead of O(log vnodes·N), which
    is why it's the comparison alternative rather than the default: below
    ~100 nodes the difference is noise, and the benchmark row keeps both
    honest.  Weights use the standard ``-w / ln(h)`` transform, giving a
    weight-2 node exactly 2x the win probability per key."""

    kind = "rendezvous"

    def __init__(self, nodes: Iterable = (), vnodes: int = DEFAULT_VNODES,
                 replicas: int = DEFAULT_REPLICAS):
        self.vnodes = max(1, int(vnodes))  # unused; kept for view parity
        super().__init__(nodes, replicas=replicas)

    # -- membership --------------------------------------------------------
    def add(self, node: str, weight: float = 1.0) -> None:
        if node not in self:
            self._weights[node] = self._clamp_weight(weight)

    def remove(self, node: str) -> None:
        self._weights.pop(node, None)

    # -- placement ---------------------------------------------------------
    def _score(self, node: str, weight: float, key: str) -> float:
        # _hash is uniform on [0, 2^64); shift to (0, 1) so ln() is finite
        h = (_hash(f"{node}|{key}") + 1) / float((1 << 64) + 1)
        return -weight / math.log(h)

    def owners(self, key: str, n: int | None = None) -> list[str]:
        if not self._weights:
            return []
        want = self.replicas if n is None else max(1, int(n))
        ranked = sorted(self._weights,
                        key=lambda u: (-self._score(u, self._weights[u], key),
                                       u))
        return ranked[:want]


def make_placement(kind: str, nodes: Iterable = (),
                   vnodes: int = DEFAULT_VNODES,
                   replicas: int = DEFAULT_REPLICAS) -> Placement:
    """Placement factory keyed by the ``placement`` field every node (and
    the ring-aware client) reads off the ``/v1/cluster`` view — the whole
    fleet must run one strategy or two nodes would disagree on owners."""
    kind = (kind or "ring").strip().lower()
    if kind == "rendezvous":
        return RendezvousHash(nodes, vnodes=vnodes, replicas=replicas)
    if kind in ("", "ring"):
        return HashRing(nodes, vnodes=vnodes, replicas=replicas)
    raise ValueError(f"unknown placement {kind!r} (expected one of "
                     f"{', '.join(PLACEMENTS)})")


class _Node:
    """One known fleet member, as seen from this node."""

    __slots__ = ("url", "up", "last_seen", "failures", "weight", "load")

    def __init__(self, url: str):
        self.url = url
        self.up = False
        self.last_seen: float | None = None  # monotonic; None = never
        self.failures = 0                    # consecutive failed probes
        self.weight = 1.0                    # learned from the node's view
        self.load: dict = {}                 # last advertised load snapshot


class ClusterMembership:
    """This node's view of the fleet + the loops that keep it honest.

    ``start()`` launches two daemon threads: the heartbeat loop (probe every
    known node via ``GET /v1/cluster``, merge the URLs each answer reveals,
    mark nodes up/down) and the anti-entropy loop (manifest exchange +
    owned-key repair against live peers).  Both are also callable directly
    (``heartbeat_now`` / ``sync_now``) so tests and operators can force a
    round without waiting out an interval."""

    def __init__(self, self_url: str, seeds: Iterable[str] = (),
                 vnodes: int = DEFAULT_VNODES,
                 replicas: int = DEFAULT_REPLICAS,
                 heartbeat_interval: float = 1.0,
                 down_after: float | None = None,
                 forget_after: float | None = None,
                 sync_interval: float = 5.0,
                 probe_timeout: float = 2.0,
                 store=None,
                 placement: str = "ring",
                 weight: float = 1.0,
                 gossip_fanout: int = 0,
                 load_provider: Callable[[], dict] | None = None,
                 on_load: Callable[[str, dict], Any] | None = None):
        self.self_url = self_url.rstrip("/")
        self.vnodes = max(1, int(vnodes))
        self.replicas = max(1, int(replicas))
        self.placement = (placement or "ring").strip().lower()
        make_placement(self.placement)  # fail fast on an unknown strategy
        self.weight = Placement._clamp_weight(weight)
        # 0 = auto: ceil(log2(N)) + 2, recomputed per round as N changes;
        # <0 = uncapped (probe everyone, the pre-PR-9 behavior)
        self.gossip_fanout = int(gossip_fanout)
        #: this node's own advertised load (piggybacked on every view);
        #: the HTTP frontends point this at their router's queue snapshot
        self.load_provider = load_provider
        #: callback fed every (url, load) advertisement a probe brings back
        #: — the router's selector learns peer queue depths through it
        self.on_load = on_load
        self.heartbeat_interval = heartbeat_interval
        self.down_after = (3.0 * heartbeat_interval if down_after is None
                           else down_after)
        # a down node is kept (for rejoin tracking) this long past its last
        # successful probe, then forgotten entirely — without this, every
        # decommissioned URL would be probed every round forever
        self.forget_after = (max(30.0, 10.0 * self.down_after)
                             if forget_after is None else forget_after)
        self.sync_interval = sync_interval
        self.probe_timeout = probe_timeout
        self.store = store  # TieredStore (anti-entropy repairs through it)
        self._nodes: dict[str, _Node] = {}
        self._aliases: set[str] = set()  # URLs discovered to be *us*
        self._mu = threading.Lock()
        self._ring = make_placement(
            self.placement, [(self.self_url, self.weight)],
            vnodes=self.vnodes, replicas=self.replicas)
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []
        self._probe_cycle: list[str] = []  # pending probe order (capped mode)
        self._cycle_epoch = 0
        # counters
        self.heartbeats = 0
        self.probes_last_round = 0
        self.probe_failures = 0
        self.transitions = 0          # up<->down flips observed
        self.manifest_exchanges = 0
        self.repairs = 0              # records pulled by anti-entropy
        self.repair_errors = 0
        self.rebalanced = 0           # non-owned copies dropped post-churn
        self.forgotten = 0            # dead nodes pruned from the view
        self._seeds: set[str] = set()
        for seed in seeds:
            seed = (seed or "").strip().rstrip("/")
            if seed and seed != self.self_url:
                self._seeds.add(seed)  # seeds are never forgotten: a fleet
                self._nodes[seed] = _Node(seed)  # must form even if the
                # seed boots after its joiners

    # -- ring views --------------------------------------------------------
    def _rebuild_ring(self) -> None:
        """Callers hold ``_mu``."""
        live = [(self.self_url, self.weight)] + [
            (n.url, n.weight) for n in self._nodes.values() if n.up]
        self._ring = make_placement(self.placement, live, vnodes=self.vnodes,
                                    replicas=self.replicas)

    @property
    def ring(self) -> Placement:
        with self._mu:
            return self._ring

    def owners(self, key: str) -> list[str]:
        return self.ring.owners(key)

    def owns(self, key: str) -> bool:
        return self.self_url in self.ring.owners(key)

    def replica_peers(self, key: str) -> list[str]:
        """The owner URLs a :class:`~repro.core.store.PeerStore` should
        route ``key`` to — the K replicas, this node excluded.  This is the
        router that turns PR 4's broadcast replication into sharding."""
        return [u for u in self.ring.owners(key) if u != self.self_url]

    def live_peers(self) -> list[str]:
        with self._mu:
            return sorted(n.url for n in self._nodes.values() if n.up)

    # -- view exchange (the /v1/cluster payload) ---------------------------
    def _self_load(self) -> dict:
        provider = self.load_provider
        if provider is None:
            return {}
        try:
            load = provider()
        except Exception:  # noqa: BLE001 — advertising must never 500 a view
            return {}
        return load if isinstance(load, dict) else {}

    def view(self) -> dict[str, Any]:
        now = time.monotonic()
        self_entry = {"url": self.self_url, "status": "up", "self": True,
                      "weight": self.weight, "load": self._self_load()}
        with self._mu:
            nodes = [self_entry]
            for n in sorted(self._nodes.values(), key=lambda n: n.url):
                nodes.append({
                    "url": n.url,
                    "status": "up" if n.up else "down",
                    "age_seconds": (None if n.last_seen is None
                                    else now - n.last_seen),
                    "weight": n.weight,
                    "load": dict(n.load),
                })
        return {"self": self.self_url, "replicas": self.replicas,
                "vnodes": self.vnodes, "placement": self.placement,
                "nodes": nodes}

    def node_loads(self) -> dict[str, dict]:
        """Last advertised load per live peer (the heartbeat piggyback the
        router's selector consumes), self included."""
        with self._mu:
            loads = {n.url: dict(n.load)
                     for n in self._nodes.values() if n.up}
        loads[self.self_url] = self._self_load()
        return loads

    def stats(self) -> dict[str, Any]:
        with self._mu:
            up = sum(1 for n in self._nodes.values() if n.up) + 1
            known = len(self._nodes) + 1
        return {"self": self.self_url, "nodes_up": up, "nodes_known": known,
                "replicas": self.replicas, "vnodes": self.vnodes,
                "placement": self.placement, "weight": self.weight,
                "gossip_fanout": self.effective_fanout(known),
                "probes_last_round": self.probes_last_round,
                "heartbeats": self.heartbeats,
                "probe_failures": self.probe_failures,
                "transitions": self.transitions,
                "manifest_exchanges": self.manifest_exchanges,
                "repairs": self.repairs, "repair_errors": self.repair_errors,
                "rebalanced": self.rebalanced, "forgotten": self.forgotten}

    # -- membership loop ---------------------------------------------------
    def _get_json(self, url: str, path: str):
        with urllib.request.urlopen(  # noqa: S310 — operator-set URLs
                f"{url}{path}", timeout=self.probe_timeout) as resp:
            return json.loads(resp.read())

    def observe(self, url: str) -> None:
        """Fold in a node that just contacted *us* (the ``?from=`` announce
        on a heartbeat probe) as a *candidate*.  This is what makes
        discovery symmetric: a seed learns its joiners the moment they
        first probe it — heartbeats alone only discover in the
        seed->joiner direction.  The candidate joins the ring only once
        our own next heartbeat probes it successfully: an unauthenticated
        announce must never place an unverified URL into routing."""
        url = (url or "").strip().rstrip("/")
        if not url or url == self.self_url:
            return
        with self._mu:
            if url not in self._nodes and url not in self._aliases:
                self._nodes[url] = _Node(url)

    def _probe(self, url: str) -> list[str]:
        """Probe one node; returns the URLs its view revealed (empty on
        failure).  Only URLs the peer itself reports *up* are merged —
        gossiping dead nodes around would keep them probed fleet-wide
        forever.  Up/down transitions and ring rebuilds happen here."""
        try:
            view = self._get_json(
                url, f"/v1/cluster?from={quote(self.self_url, safe='')}")
            if str(view.get("self", "")).rstrip("/") == self.self_url:
                # the "peer" answered as *us*: ``url`` is an alias of this
                # node (e.g. the documented self-seed bootstrap spelled
                # localhost against a 127.0.0.1 bind).  Joining the ring
                # under two names would silently collapse the replication
                # factor — both "replicas" of a key could be one machine.
                with self._mu:
                    self._aliases.add(url)
                    node = self._nodes.pop(url, None)
                    if node is not None and node.up:
                        self.transitions += 1
                        self._rebuild_ring()
                return []
            revealed = [str(n.get("url", "")) for n in view.get("nodes", [])
                        if isinstance(n, dict) and n.get("status") == "up"]
            # the peer's own entry carries its weight + live load snapshot
            weight, load = 1.0, {}
            for entry in view.get("nodes", []):
                if isinstance(entry, dict) and entry.get("self"):
                    weight = Placement._clamp_weight(entry.get("weight", 1.0))
                    load = entry.get("load") or {}
                    break
            ok = True
        except (urllib.error.URLError, ConnectionError, TimeoutError,
                OSError, ValueError):
            revealed, ok = [], False
        now = time.monotonic()
        with self._mu:
            node = self._nodes.get(url)
            if node is None:  # removed concurrently — nothing to record
                return revealed if ok else []
            if ok:
                node.last_seen = now
                node.failures = 0
                node.load = load if isinstance(load, dict) else {}
                reweighted = node.weight != weight
                node.weight = weight
                if not node.up:  # fresh join or rejoin
                    node.up = True
                    self.transitions += 1
                    self._rebuild_ring()
                elif reweighted:  # operator restarted it with a new budget
                    self._rebuild_ring()
            else:
                self.probe_failures += 1
                node.failures += 1
                # a never-seen node is down immediately; a known-good one
                # gets down_after of grace before it leaves the ring
                if node.up and (node.last_seen is None
                                or now - node.last_seen > self.down_after):
                    node.up = False
                    self.transitions += 1
                    self._rebuild_ring()
        if ok and self.on_load is not None:
            try:  # hand the piggybacked load to the router's selector
                self.on_load(url, node.load)
            except Exception:  # noqa: BLE001 — routing hints must not break
                pass           # membership
        return revealed if ok else []

    def _forget_dead(self) -> None:
        """Prune down nodes past ``forget_after`` (and never-seen
        candidates after a few failed probes) so decommissioned URLs stop
        costing a probe per round.  Seeds are exempt: the fleet must still
        form when the seed boots after its joiners.  A pruned node that
        comes back re-announces itself on its own next probe."""
        now = time.monotonic()
        with self._mu:
            for url in list(self._nodes):
                node = self._nodes[url]
                if node.up or url in self._seeds:
                    continue
                dead = (node.failures >= 3 if node.last_seen is None
                        else now - node.last_seen > self.forget_after)
                if dead:
                    del self._nodes[url]
                    self.forgotten += 1

    def effective_fanout(self, n_known: int) -> int:
        """Probes allowed per round: the configured cap, or the O(log N)
        auto cap (``ceil(log2 N) + 2``) when ``gossip_fanout == 0``.  A
        negative setting disables the cap (probe everyone, the pre-adaptive
        behavior).  With the cap, fleet-wide membership traffic is
        O(N log N) per interval instead of O(N²), and a dead node is still
        noticed within ``down_after`` plus one cycle (≤ ``ceil(N/fanout)``
        rounds), because the shuffled cycle visits every node."""
        if self.gossip_fanout > 0:
            return self.gossip_fanout
        if self.gossip_fanout < 0:
            return max(1, n_known)
        return math.ceil(math.log2(max(2, n_known))) + 2

    def _next_probe_targets(self) -> list[str]:
        """The deterministic-random subset this round probes.  A shuffled
        cycle (reshuffled each time it drains, seeded from the node URL and
        a cycle counter) guarantees every known node is visited at least
        once per ``ceil(N/fanout)`` rounds — a plain random sample would
        leave unlucky nodes unprobed for unboundedly long."""
        with self._mu:
            known = set(self._nodes)
        fanout = self.effective_fanout(len(known))
        if fanout >= len(known):
            return sorted(known)
        targets: list[str] = []
        self._probe_cycle = [u for u in self._probe_cycle if u in known]
        for _ in range(2 * len(known)):
            if len(targets) >= fanout:
                break
            if not self._probe_cycle:
                cycle = sorted(known)
                random.Random(_hash(
                    f"{self.self_url}#cycle#{self._cycle_epoch}"
                )).shuffle(cycle)
                self._probe_cycle = cycle
                self._cycle_epoch += 1
            url = self._probe_cycle.pop(0)
            if url not in targets:
                targets.append(url)
        return targets

    def heartbeat_now(self) -> None:
        """One membership round: probe the capped deterministic-random
        subset of known nodes, folding in any URL a view reveals.  Nodes
        never probed before (fresh announces, seed-bootstrap reveals) are
        probed in the same round regardless of the cap, so a single
        heartbeat after a seed bootstrap still reaches the whole fleet —
        the cap only paces the steady-state re-probing that was O(N²)."""
        self.heartbeats += 1
        probed: set[str] = set()

        def probe_one(url: str) -> None:
            probed.add(url)
            for revealed in self._probe(url):
                revealed = revealed.rstrip("/")
                if not revealed or revealed == self.self_url:
                    continue
                with self._mu:
                    if revealed not in self._nodes \
                            and revealed not in self._aliases:
                        self._nodes[revealed] = _Node(revealed)

        for url in self._next_probe_targets():
            if url not in probed:
                probe_one(url)
        while True:  # newcomers revealed mid-round join immediately
            with self._mu:
                fresh = [u for u, n in self._nodes.items()
                         if u not in probed and n.last_seen is None
                         and n.failures == 0]
            if not fresh:
                break
            for url in fresh:
                probe_one(url)
        self.probes_last_round = len(probed)
        self._forget_dead()

    # -- anti-entropy repair -----------------------------------------------
    def sync_now(self) -> int:
        """One repair round: exchange key manifests with every live peer,
        pull each record this node owns but lacks, then drop local copies
        of records this node does *not* own once every owner verifiably
        holds them (self-healing back to exactly K copies after churn —
        e.g. the extra replica a node keeps after a dead owner rejoins).
        Returns records repaired."""
        store = self.store
        if store is None:
            return 0
        repaired = 0
        manifests: dict[str, set] = {}  # peer -> keys it advertises
        for peer in self.live_peers():
            try:
                manifest = self._get_json(peer, "/v1/replicate/manifest")
                keys = manifest.get("keys", [])
            except (urllib.error.URLError, ConnectionError, TimeoutError,
                    OSError, ValueError):
                self.repair_errors += 1
                continue
            self.manifest_exchanges += 1
            manifests[peer] = {k for k in keys if valid_key(k)}
            ring = self.ring
            for key in manifests[peer]:
                if self.self_url not in ring.owners(key):
                    continue
                if key in store:  # already resident locally
                    continue
                try:
                    rec = self._get_json(peer, f"/v1/replicate/{key}")
                except (urllib.error.URLError, ConnectionError, TimeoutError,
                        OSError, ValueError):
                    self.repair_errors += 1
                    continue
                if not verify_envelope(key, rec):
                    self.repair_errors += 1
                    continue
                # store_local, not store: a repair pull must never echo a
                # push back out (the surviving replica already holds it)
                store.store_local(key, rec)
                self.repairs += 1
                repaired += 1
        self._rebalance(manifests)
        return repaired

    def _rebalance(self, manifests: dict[str, set]) -> None:
        """Drop local records this node does not own, but only when every
        ring owner's manifest (fetched this round) lists the record *and*
        the primary owner still serves it right now — the manifests may
        have gone stale during the repair pulls (an owner's TTL/size
        eviction could have run meanwhile), and a handoff must never
        destroy what might be the last copy.  Better to keep a stray
        replica than to re-pay an LLM inference."""
        store = self.store
        if store is None or not manifests:
            return
        ring = self.ring
        for key in store.keys():
            owners = ring.owners(key)
            if not owners or self.self_url in owners:
                continue
            if not all(o in manifests and key in manifests[o]
                       for o in owners):
                continue
            try:  # freshness re-check, immediately before the delete
                rec = self._get_json(owners[0], f"/v1/replicate/{key}")
            except (urllib.error.URLError, ConnectionError, TimeoutError,
                    OSError, ValueError):
                continue
            if verify_envelope(key, rec) and store.delete(key):
                self.rebalanced += 1

    # -- lifecycle ---------------------------------------------------------
    def _loop(self, interval: float, tick: Callable[[], Any],
              name: str) -> None:
        thread = threading.Thread(
            name=name, daemon=True,
            target=lambda: self._run_loop(interval, tick))
        self._threads.append(thread)
        thread.start()

    def _run_loop(self, interval: float, tick: Callable[[], Any]) -> None:
        while not self._stop.wait(interval):
            try:
                tick()
            except Exception:  # noqa: BLE001 — loops must survive anything
                pass

    def start(self) -> "ClusterMembership":
        """Bootstrap (one immediate heartbeat so the seed's view lands
        before the first request) and launch the periodic loops."""
        self.heartbeat_now()
        self.sync_now()
        self._loop(self.heartbeat_interval, self.heartbeat_now,
                   "cluster-heartbeat")
        self._loop(self.sync_interval, self.sync_now, "cluster-antientropy")
        return self

    def close(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=2.0)
        self._threads.clear()
