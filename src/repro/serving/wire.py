"""Binary wire protocol for the evaluation hot path — stdlib + numpy only.

The paper's deployed-kernel economics die on a JSON wire: a warm mapped
launch costs ~19µs behind the compile cache, but ``tolist()``-ing a
10⁵–10⁶-point coordinate block and re-parsing it client-side costs
milliseconds and tens of MB of text.  This module frames numpy arrays as
raw little-endian bytes with a small JSON metadata header, so the server
serializes with ``ndarray.tobytes()`` and the client rehydrates with
``np.frombuffer`` — zero text, zero per-element work, exact dtypes.

Frame layout (one response, or one streamed sweep cell)::

    offset 0   MAGIC            4 bytes  b"RPWF"
    offset 4   version          u32 LE   (currently 1)
    offset 8   header length    u32 LE
    offset 12  header           JSON, utf-8
    then, per segment:
               payload length   u32 LE
               payload          raw little-endian array bytes

The header is ``{"payload": <JSON structure>, "segments": [{"dtype":
"int32", "shape": [8, 4096]}, ...]}`` where every array in the original
payload is replaced by ``{"__nd__": i}`` — an index into ``segments``.
Decoding walks the structure back, attaching ``np.frombuffer`` views onto
the frame buffer.  Anything JSON-serializable passes through unchanged, so
the same codec frames a single result, a ``{"results": [...]}`` batch, and
each cell of a sweep stream.

Streams are length-prefixed: each cell is ``u32 LE frame length`` + frame,
and the stream end is connection close (the same close-delimited framing
the NDJSON sweeps use, so pull-driven backpressure carries over).

Negotiation: a client asks for binary with ``Accept:
application/x-repro-binary`` (or ``?format=binary``); servers that predate
this module ignore both and answer JSON, which clients detect from the
response Content-Type — fallback needs no version handshake.

Malformed frames (bad magic, truncated header or segment, unknown
version) raise :class:`WireFormatError`, a ``ValueError`` subclass so the
frontends' shared ``map_error`` turns it into a structured 400 — never a
500, never a hung keep-alive connection.
"""
from __future__ import annotations

import json
import struct
import threading
from collections import OrderedDict
from typing import Callable

import numpy as np

MAGIC = b"RPWF"
VERSION = 1

#: one binary frame (single result or {"results": [...]} batch)
CONTENT_TYPE = "application/x-repro-binary"
#: length-prefixed frame stream (the sweep surface); close-delimited
STREAM_CONTENT_TYPE = "application/x-repro-binary-stream"

_U32 = struct.Struct("<I")
_MAX_HEADER_BYTES = 1 << 20      # a metadata header past 1 MiB is corrupt
_MAX_SEGMENT_BYTES = 1 << 31     # and so is a >2 GiB single segment


class WireFormatError(ValueError):
    """A frame that cannot be decoded: wrong magic, unknown version,
    truncated header/segment, or a header that is not valid metadata.
    Subclasses ``ValueError`` so ``serving.http.map_error`` answers a
    structured 400 for wire-supplied garbage instead of a 500."""


# -- encode ------------------------------------------------------------------

def _strip_arrays(obj, segments: list[np.ndarray]):
    """Replace every ndarray in a JSON-ish structure with an ``{"__nd__":
    i}`` placeholder, collecting the arrays in order."""
    if isinstance(obj, np.ndarray):
        segments.append(obj)
        return {"__nd__": len(segments) - 1}
    if isinstance(obj, dict):
        return {k: _strip_arrays(v, segments) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_strip_arrays(v, segments) for v in obj]
    if isinstance(obj, np.generic):  # numpy scalar leaked into metadata
        return obj.item()
    return obj


def _le(arr: np.ndarray) -> np.ndarray:
    """The array in little-endian memory order (no-op on LE hosts)."""
    if arr.dtype.byteorder == ">":
        return arr.astype(arr.dtype.newbyteorder("<"))
    return arr


def encode_frame(payload) -> bytes:
    """One binary frame for a JSON-ish payload whose arrays are numpy.

    Arrays serialize as raw little-endian bytes (C order); everything else
    rides in the JSON metadata header.  ``decode_frame`` is the exact
    inverse, dtype and shape included."""
    segments: list[np.ndarray] = []
    stripped = _strip_arrays(payload, segments)
    header = {
        "payload": stripped,
        "segments": [{"dtype": _le(a).dtype.name, "shape": list(a.shape)}
                     for a in segments],
    }
    head = json.dumps(header, default=str).encode()
    parts = [MAGIC, _U32.pack(VERSION), _U32.pack(len(head)), head]
    for arr in segments:
        raw = np.ascontiguousarray(_le(arr)).tobytes()
        parts.append(_U32.pack(len(raw)))
        parts.append(raw)
    return b"".join(parts)


# -- decode ------------------------------------------------------------------

def _restore_arrays(obj, arrays: list[np.ndarray], used: list[bool]):
    if isinstance(obj, dict):
        if set(obj) == {"__nd__"}:
            idx = obj["__nd__"]
            if not isinstance(idx, int) or not 0 <= idx < len(arrays):
                raise WireFormatError(
                    f"frame header references segment {idx!r} of "
                    f"{len(arrays)}")
            used[idx] = True
            return arrays[idx]
        return {k: _restore_arrays(v, arrays, used) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_restore_arrays(v, arrays, used) for v in obj]
    return obj


def decode_frame(buf: bytes | bytearray | memoryview):
    """Decode one frame back to its payload.  Array segments come back as
    ``np.frombuffer`` views over ``buf`` (zero-copy) with the dtype and
    shape the header declares.  Raises :class:`WireFormatError` on any
    malformed, truncated, or version-unknown frame."""
    view = memoryview(buf)
    if len(view) < 12:
        raise WireFormatError(
            f"binary frame truncated: {len(view)} bytes < 12-byte preamble")
    if bytes(view[:4]) != MAGIC:
        raise WireFormatError(
            f"bad frame magic {bytes(view[:4])!r} (expected {MAGIC!r}) — "
            "not a repro binary frame")
    version = _U32.unpack_from(view, 4)[0]
    if version != VERSION:
        raise WireFormatError(
            f"unknown wire version {version} (this build speaks "
            f"{VERSION})")
    head_len = _U32.unpack_from(view, 8)[0]
    if head_len > _MAX_HEADER_BYTES:
        raise WireFormatError(f"frame header length {head_len} exceeds "
                              f"{_MAX_HEADER_BYTES} bytes")
    if 12 + head_len > len(view):
        raise WireFormatError(
            f"frame truncated inside header: need {12 + head_len} bytes, "
            f"have {len(view)}")
    try:
        header = json.loads(bytes(view[12:12 + head_len]))
    except ValueError as e:
        raise WireFormatError(f"frame header is not valid JSON: {e}") from e
    if not isinstance(header, dict) or "payload" not in header \
            or not isinstance(header.get("segments"), list):
        raise WireFormatError(
            "frame header must be an object with 'payload' and 'segments'")
    offset = 12 + head_len
    arrays: list[np.ndarray] = []
    for i, seg in enumerate(header["segments"]):
        if not isinstance(seg, dict):
            raise WireFormatError(f"segment {i} metadata is not an object")
        try:
            dtype = np.dtype(seg["dtype"])
            shape = tuple(int(s) for s in seg["shape"])
        except (KeyError, TypeError, ValueError) as e:
            raise WireFormatError(
                f"segment {i} carries bad dtype/shape metadata: {e}") from e
        if offset + 4 > len(view):
            raise WireFormatError(
                f"frame truncated before segment {i} length prefix")
        nbytes = _U32.unpack_from(view, offset)[0]
        offset += 4
        if nbytes > _MAX_SEGMENT_BYTES or offset + nbytes > len(view):
            raise WireFormatError(
                f"frame truncated inside segment {i}: declared {nbytes} "
                f"bytes, {len(view) - offset} remain")
        expect = dtype.itemsize * int(np.prod(shape, dtype=np.int64)) \
            if shape else dtype.itemsize
        if nbytes != expect:
            raise WireFormatError(
                f"segment {i} is {nbytes} bytes but dtype={dtype.name} "
                f"shape={list(shape)} needs {expect}")
        arr = np.frombuffer(view, dtype=dtype.newbyteorder("<"),
                            count=expect // dtype.itemsize,
                            offset=offset).reshape(shape)
        if arr.dtype.byteorder == ">":  # pragma: no cover — BE hosts only
            arr = arr.astype(dtype)
        arrays.append(arr)
        offset += nbytes
    if offset != len(view):
        raise WireFormatError(
            f"{len(view) - offset} trailing bytes after the last segment")
    used = [False] * len(arrays)
    payload = _restore_arrays(header["payload"], arrays, used)
    if not all(used):
        raise WireFormatError(
            "frame carries segments its payload never references")
    return payload


def decode_request(raw: bytes) -> dict:
    """A binary-framed *request* body: the decoded payload must be a JSON
    object (the same contract the JSON request path enforces)."""
    body = decode_frame(raw)
    if not isinstance(body, dict):
        raise WireFormatError("binary request body must frame a JSON object")
    return body


# -- streaming ---------------------------------------------------------------

def stream_chunk(frame: bytes) -> bytes:
    """One cell of a binary sweep stream: u32 LE length prefix + frame."""
    return _U32.pack(len(frame)) + frame


def read_exact(read: Callable[[int], bytes], n: int) -> bytes:
    """Drain exactly ``n`` bytes from a sized-read callable (``http.client``
    responses may return short reads); b"" on clean EOF at a boundary,
    :class:`WireFormatError` on EOF mid-chunk."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        piece = read(n - got)
        if not piece:
            if not chunks:
                return b""
            raise WireFormatError(
                f"binary stream truncated: expected {n} bytes, got {got}")
        chunks.append(piece)
        got += len(piece)
    return b"".join(chunks)


def iter_stream(read: Callable[[int], bytes]):
    """Decode a length-prefixed frame stream until clean EOF, yielding one
    payload per frame.  A truncated prefix or frame raises
    :class:`WireFormatError` — close-delimited streams end exactly on a
    frame boundary or they are broken."""
    while True:
        prefix = read_exact(read, 4)
        if prefix == b"":
            return
        (length,) = _U32.unpack(prefix)
        frame = read_exact(read, length)
        if frame == b"" and length:
            raise WireFormatError(
                "binary stream truncated: frame body missing after prefix")
        yield decode_frame(frame)


# -- negotiation -------------------------------------------------------------

def wants_binary(accept: str | None, path: str = "",
                 content_type: str | None = None) -> bool:
    """Did the request ask for a binary response?  Any of: an ``Accept``
    header naming the binary media type, ``?format=binary`` in the URL, or
    a binary-framed request body (a client speaking binary understands
    binary).  Absent all three the answer stays JSON — old clients never
    see a byte they can't parse."""
    if accept and CONTENT_TYPE in accept:
        return True
    if content_type and content_type.startswith(CONTENT_TYPE):
        return True
    if "?" in path:
        from urllib.parse import parse_qs, urlsplit

        if parse_qs(urlsplit(path).query).get("format", [""])[0] == "binary":
            return True
    return False


def is_binary(content_type: str | None) -> bool:
    """Is a *response* Content-Type one of the binary framings?  The
    client's fallback test: an old server ignores the Accept header and
    answers JSON, which this returns False for."""
    return bool(content_type) and content_type.startswith(CONTENT_TYPE)


# -- response-bytes LRU ------------------------------------------------------

class WireCache:
    """LRU of encoded evaluate responses, keyed by the batch's resolved
    executable identity (per member: fingerprint × tier × λ-range/extent ×
    block × interpret) plus the wire format — the evaluate-plane mirror of
    the async frontend's derive blob cache.

    Entries are generation-stamped with the compile cache's eviction
    counter: once the compile cache rotates, cached blobs whose provenance
    says ``executable: hit`` may be stale, so they stop serving.  Entries
    also remember which artifact content addresses they depend on, so a
    ``DELETE /v1/artifact/<key>`` drops exactly the blobs that embedded
    that artifact's coordinates.  Thread-safe: the threaded frontend hits
    it from many handler threads, the async one from loop + workers."""

    def __init__(self, entries: int = 256):
        self.entries = entries
        self.hits = 0
        self.misses = 0
        self._mu = threading.Lock()
        # cell -> (generation, artifact_keys, blob)
        self._cache: "OrderedDict[tuple, tuple[int, tuple, bytes]]" = \
            OrderedDict()

    def get(self, cell: tuple, generation: int = 0) -> bytes | None:
        with self._mu:
            hit = self._cache.get(cell)
            if hit is None or hit[0] != generation:
                if hit is not None:  # stale generation: drop eagerly
                    self._cache.pop(cell, None)
                self.misses += 1
                return None
            self._cache.move_to_end(cell)
            self.hits += 1
            return hit[2]

    def put(self, cell: tuple, blob: bytes, generation: int = 0,
            artifact_keys: tuple = ()) -> None:
        with self._mu:
            self._cache[cell] = (generation, artifact_keys, blob)
            self._cache.move_to_end(cell)
            while len(self._cache) > self.entries:
                self._cache.popitem(last=False)

    def invalidate_artifact(self, key: str) -> None:
        with self._mu:
            stale = [cell for cell, (_, keys, _) in self._cache.items()
                     if key in keys]
            for cell in stale:
                self._cache.pop(cell, None)

    def clear(self) -> None:
        with self._mu:
            self._cache.clear()

    def stats_dict(self) -> dict:
        with self._mu:
            return {"entries": len(self._cache), "capacity": self.entries,
                    "hits": self.hits, "misses": self.misses}
