"""Minimal batched serving engine: prefill once, decode greedily/sampled.

Static-shape batching (the dry-run serving shapes): a batch of requests is
padded to a common prompt length, prefilled in one pass, then decoded
step-by-step with jitted `decode_step`.  Continuous batching at production
scale would slot new requests into freed cache rows; the cache layout here
(batch-major, fixed max_seq) is chosen so that extension is a row update.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import transformer as T


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray          # (B, prompt + generated)
    steps: int


def greedy(logits, key=None, temperature: float = 0.0):
    if temperature and key is not None:
        return jax.random.categorical(key, logits / temperature, axis=-1)
    return jnp.argmax(logits, axis=-1)


def generate(params, cfg, prompts: jnp.ndarray, max_new_tokens: int,
             extra=None, temperature: float = 0.0, seed: int = 0,
             eos_id: int | None = None) -> GenerationResult:
    """prompts: (B, S) int32, already padded. Greedy when temperature=0."""
    b, s = prompts.shape
    max_seq = cfg.max_seq
    assert s + max_new_tokens <= max_seq, "cache too small"

    prefill = jax.jit(lambda p, t, e: T.prefill(p, cfg, t, e))
    step = jax.jit(lambda p, t, c, e: T.decode_step(p, cfg, t, c, e))

    logits, cache = prefill(params, prompts, extra)
    key = jax.random.PRNGKey(seed)
    out = [prompts]
    tok = greedy(logits[:, -1:, : cfg.vocab_size], key, temperature)
    tok = tok.astype(jnp.int32)
    done = jnp.zeros((b, 1), bool)
    n = 0
    for i in range(max_new_tokens):
        out.append(tok)
        n += 1
        if eos_id is not None:
            done = done | (tok == eos_id)
            if bool(done.all()):
                break
        key, sub = jax.random.split(key)
        logits, cache = step(params, tok, cache, extra)
        tok = greedy(logits[:, :, : cfg.vocab_size], sub, temperature)
        tok = tok.astype(jnp.int32)
    return GenerationResult(tokens=jnp.concatenate(out, axis=1), steps=n)
