"""Serving layer: the LM prefill/decode engine (``engine``), the
concurrency-safe mapping-artifact service (``map_service``), and its
networked form — HTTP frontend (``http``), remote client (``client``), and
per-model request batching/admission (``batching``)."""
from repro.serving.batching import (  # noqa: F401
    AdmissionError, BatchingBackend, BatchStats, batching_factory,
)
from repro.serving.client import (  # noqa: F401
    ClientStats, RemoteMappingService, RemoteServiceError,
)
from repro.serving.http import MappingHTTPServer  # noqa: F401
from repro.serving.map_service import MappingService, ServiceStats  # noqa: F401
