"""Serving layer: the LM prefill/decode engine (``engine``), the
concurrency-safe mapping-artifact service (``map_service``), and its
networked form — threaded HTTP frontend (``http``), asyncio event-loop
frontend (``aio``: inline hot path, backpressure-aware streaming),
keep-alive remote client (``client``), per-model request
batching/admission (``batching``: gather-then-drain) and continuous
batching (``async_engine``: step-interleaved cohort scheduler for the
engine backend), the consistent-hash sharded fleet layer (``cluster``:
ring placement, membership heartbeats, anti-entropy repair), and the
batched map *evaluation* hot path (``evaluate``: compiled-executable
groups behind ``POST /v1/evaluate``), and the load-aware request router
(``router``: bounded FIFO + retry lane, EWMA-latency/queue-depth replica
selection with epsilon-greedy exploration), and the binary evaluation
wire codec (``wire``: zero-copy array framing negotiated via ``Accept:
application/x-repro-binary``, plus the encoded-response LRU both
frontends serve warm evaluates from).  Both frontends carry the
observability plane (``repro.obs``): per-request traces
(``X-Repro-Trace-Id`` -> ``GET /v1/trace/<id>``) and a metrics registry
served as JSON and Prometheus text (``GET /metrics?format=prometheus``).

``EvaluationService`` is imported lazily (it pulls in jax + the kernels) —
``from repro.serving.evaluate import EvaluationService``."""
from repro.serving.aio import AsyncMappingHTTPServer  # noqa: F401
from repro.serving.async_engine import (  # noqa: F401
    AsyncEngineBackend, ContinuousBatcher, ContinuousBatchingBackend,
    ContinuousStats, EngineStepper, continuous_factory,
)
from repro.serving.batching import (  # noqa: F401
    AdmissionError, BatchingBackend, BatchStats, batching_factory,
)
from repro.serving.cluster import (  # noqa: F401
    ClusterMembership, HashRing, Placement, RendezvousHash, make_placement,
)
from repro.serving.client import (  # noqa: F401
    ClientStats, RemoteBusyError, RemoteMappingService, RemoteServiceError,
    RemoteTimeoutError,
)
from repro.serving.http import MappingHTTPServer  # noqa: F401
from repro.serving.map_service import MappingService, ServiceStats  # noqa: F401
from repro.serving.router import (  # noqa: F401
    ReplicaSelector, RequestQueue, RequestRouter, RouterStats,
)
from repro.serving.wire import (  # noqa: F401
    WireCache, WireFormatError, decode_frame, encode_frame,
)
