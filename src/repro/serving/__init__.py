"""Serving layer: prefill + batched decode with per-family caches."""
