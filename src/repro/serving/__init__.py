"""Serving layer: the LM prefill/decode engine (``engine``), the
concurrency-safe mapping-artifact service (``map_service``), and its
networked form — HTTP frontend (``http``), keep-alive remote client
(``client``), per-model request batching/admission (``batching``), the
consistent-hash sharded fleet layer (``cluster``: ring placement,
membership heartbeats, anti-entropy repair), and the batched map
*evaluation* hot path (``evaluate``: compiled-executable groups behind
``POST /v1/evaluate``).

``EvaluationService`` is imported lazily (it pulls in jax + the kernels) —
``from repro.serving.evaluate import EvaluationService``."""
from repro.serving.batching import (  # noqa: F401
    AdmissionError, BatchingBackend, BatchStats, batching_factory,
)
from repro.serving.cluster import (  # noqa: F401
    ClusterMembership, HashRing,
)
from repro.serving.client import (  # noqa: F401
    ClientStats, RemoteMappingService, RemoteServiceError,
)
from repro.serving.http import MappingHTTPServer  # noqa: F401
from repro.serving.map_service import MappingService, ServiceStats  # noqa: F401
