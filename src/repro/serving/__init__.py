"""Serving layer: the LM prefill/decode engine (``engine``) and the
concurrency-safe mapping-artifact service (``map_service``)."""
from repro.serving.map_service import MappingService, ServiceStats  # noqa: F401
