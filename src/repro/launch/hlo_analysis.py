"""Post-SPMD HLO text analysis with while-loop trip-count multiplication.

XLA's cost_analysis visits each instruction ONCE — a scan-over-layers body is
counted a single time, under-reporting FLOPs/collectives by ~n_layers.  This
parser rebuilds per-computation costs from the compiled (per-device!) HLO
text and multiplies every while body by its trip count (recovered from the
loop condition's comparison constant).

Extracted per cell:
  * flops          — dot/convolution FLOPs (2*M*N*K), trip-count scaled
  * hbm_bytes      — post-fusion buffer traffic: sum over non-trivial
                     instructions of (operand + result bytes); fusions count
                     only their boundary buffers (inner ops live in registers/
                     VMEM), which is exactly the fusion model of HBM traffic
  * collectives    — bytes/count per collective type, trip-count scaled
                     (an FSDP all-gather inside the layer scan costs L times)
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_TOKEN = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|f8e4m3|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) \(.*\) -> .+ \{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT )?%?([\w\.\-]+) = (.*?)$")
# first lowercase word directly followed by '(' = the op name (the result
# type precedes it and may be a tuple with /*index=N*/ comments)
_OPNAME = re.compile(r"(?:^|\s)([a-z][\w\-]*)\(")
_CALLED = re.compile(r"(?:to_apply|condition|body|branch_computations|called_computations|calls)=\{?%?([\w\.\-]+(?:, ?%?[\w\.\-]+)*)\}?")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")


def _split_top_level(s: str) -> list[str]:
    """Split on commas not nested in []/{}/() — newer HLO text prints operand
    shapes inline (``dot(f32[32,128]{1,0} %Arg_0.1, ...)``)."""
    parts: list[str] = []
    depth = 0
    cur: list[str] = []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


def _operand_parts(rest: str) -> list[str]:
    m = _OPERANDS.search(rest)
    if not m:
        return []
    return [p.strip() for p in _split_top_level(m.group(1)) if p.strip()]


def _operand_name(part: str) -> str:
    return part.split(" ")[-1].lstrip("%")


def _shape_elems_bytes(text: str) -> tuple[int, int]:
    """(elements, bytes) summed over all typed shape tokens in `text`."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_TOKEN.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_text: str        # result shape part
    rest: str               # everything after the op name


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    shapes: dict            # instr name -> result shape text


_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "copy-start", "copy-done", "partition-id",
}


def parse_computations(hlo: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name: str | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_HEADER.match(line)
        if m and line.endswith("{"):
            cur = Computation(m.group(1), [], {})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry_name = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        mo = _OPNAME.search(rhs)
        if not mo:
            continue
        result_text, op = rhs[: mo.start()], mo.group(1)
        cur.shapes[name] = result_text
        cur.instrs.append(Instr(name, op, result_text, rhs[mo.start():]))
    return comps, entry_name


def _trip_count(cond: Computation) -> int:
    """Trip count from the loop condition.

    Post-optimization the `compare(iter, constant(N))` is often wrapped in a
    fusion; loop conditions are tiny, so the max positive integer constant in
    the condition computation is the bound.
    """
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, shapes: dict) -> float:
    """2 * prod(result) * prod(contracting dims of lhs)."""
    res_elems, _ = _shape_elems_bytes(ins.result_text)
    parts = _operand_parts(ins.rest)
    # lhs shape: inline in the operand text (newer HLO) or via name lookup
    lhs_shape_text = ""
    if parts:
        lhs_shape_text = parts[0]
        if not _SHAPE_TOKEN.search(lhs_shape_text):
            lhs_shape_text = shapes.get(_operand_name(parts[0]), "")
    k = 1
    mc = _CONTRACT.search(ins.rest)
    if mc and lhs_shape_text:
        dims_txt = _SHAPE_TOKEN.search(lhs_shape_text)
        if dims_txt:
            lhs_dims = [int(d) for d in dims_txt.group(2).split(",") if d]
            for ci in mc.group(1).split(","):
                if ci:
                    idx = int(ci)
                    if idx < len(lhs_dims):
                        k *= lhs_dims[idx]
    return 2.0 * res_elems * k


def _operand_bytes(ins: Instr, shapes: dict) -> int:
    total = 0
    for part in _operand_parts(ins.rest):
        text = shapes.get(_operand_name(part), "")
        if not text and _SHAPE_TOKEN.search(part):
            text = part  # shape printed inline with the operand
        _, b = _shape_elems_bytes(text)
        total += b
    return total


def analyze(hlo: str, entry: str | None = None) -> dict:
    comps, entry_name = parse_computations(hlo)
    if entry is None:
        entry = entry_name
    if entry is None:
        # fallback: a computation never referenced as a callee
        called = set()
        for c in comps.values():
            for ins in c.instrs:
                for m in re.finditer(
                        r"(?:to_apply|condition|body|branch_computations|"
                        r"called_computations|calls)=\{?%?([\w\.\-]+"
                        r"(?:, ?%?[\w\.\-]+)*)\}?", ins.rest):
                    for nm in re.split(r",\s*", m.group(1)):
                        called.add(nm.lstrip("%"))
        candidates = [n for n in comps if n not in called]
        entry = candidates[0] if candidates else next(iter(comps))

    flops = 0.0
    hbm_bytes = 0.0
    coll = {op: {"count": 0.0, "bytes": 0.0} for op in COLLECTIVE_OPS}
    per_op_flops: dict[str, float] = defaultdict(float)

    seen: set[tuple[str, float]] = set()

    def visit(comp_name: str, mult: float):
        nonlocal flops, hbm_bytes
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            base_op = ins.op.replace("-start", "")
            if base_op in ("dot", "convolution"):
                f = _dot_flops(ins, comp.shapes) * mult
                flops += f
                per_op_flops[base_op] += f
            if base_op in COLLECTIVE_OPS:
                _, rb = _shape_elems_bytes(ins.result_text)
                coll[base_op]["count"] += mult
                coll[base_op]["bytes"] += rb * mult
            if ins.op in ("while", "conditional"):
                pass  # loop/branch I/O aliases carries; bodies count below
            elif ins.op == "dynamic-slice":
                # reads only the sliced window, not the full source buffer
                _, rb = _shape_elems_bytes(ins.result_text)
                hbm_bytes += rb * mult
            elif ins.op == "dynamic-update-slice":
                # in-place: reads + writes only the update window (operand 1)
                parts = _operand_parts(ins.rest)
                ub = 0
                if len(parts) > 1:
                    text = comp.shapes.get(_operand_name(parts[1]), "")
                    if not text and _SHAPE_TOKEN.search(parts[1]):
                        text = parts[1]
                    _, ub = _shape_elems_bytes(text)
                hbm_bytes += 2 * ub * mult
            elif ins.op not in _SKIP_OPS:
                _, rb = _shape_elems_bytes(ins.result_text)
                hbm_bytes += (rb + _operand_bytes(ins, comp.shapes)) * mult
            if ins.op == "while":
                m = _CALLED.search(ins.rest)
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                if mb and mc:
                    body, cond = mb.group(1), mc.group(1)
                    tc = _trip_count(comps[cond]) if cond in comps else 1
                    visit(body, mult * max(tc, 1))
                    visit(cond, mult * max(tc, 1))
            elif ins.op in ("fusion", "call", "custom-call", "map", "reduce",
                            "reduce-window", "scatter", "sort", "conditional",
                            "select-and-scatter", "all-reduce", "reduce-scatter"):
                # fused/called computations: FLOPs of inner dots still count
                # (e.g. a dot fused with bias); buffer traffic does not.
                m = _CALLED.search(ins.rest)
                if m:
                    for nm in re.split(r",\s*", m.group(1)):
                        nm = nm.lstrip("%")
                        visit_flops_only(nm, mult)

    def visit_flops_only(comp_name: str, mult: float):
        nonlocal flops
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                f = _dot_flops(ins, comp.shapes) * mult
                flops += f
                per_op_flops[ins.op] += f
            m = _CALLED.search(ins.rest)
            if m and ins.op in ("fusion", "call", "while", "conditional", "map"):
                tc = 1
                if ins.op == "while":
                    mc = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                    if mc and mc.group(1) in comps:
                        tc = _trip_count(comps[mc.group(1)])
                for nm in re.split(r",\s*", m.group(1)):
                    visit_flops_only(nm.lstrip("%"), mult * max(tc, 1))

    visit(entry, 1.0)
    coll_total_bytes = sum(v["bytes"] for v in coll.values())
    coll_total_count = sum(v["count"] for v in coll.values())
    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "per_op_flops": dict(per_op_flops),
        "collectives": {
            **{k: v for k, v in coll.items()},
            "total_bytes": coll_total_bytes,
            "total_count": coll_total_count,
        },
        "entry": entry,
        "n_computations": len(comps),
    }
