"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) single-pod cell:
    compute term    = HLO_FLOPs_per_device / peak_FLOPs          [s]
    memory term     = HLO_bytes_per_device / HBM_bw              [s]  (pessimistic)
    memory term*    = analytic_bytes / (chips x HBM_bw)          [s]  (idealized)
    collective term = collective_bytes_per_device / link_bw      [s]

HLO numbers are per-device (post-SPMD partition) with while-loop trip counts
applied (launch/hlo_analysis.py).  MODEL_FLOPS = 6·N_active·D (train) or
2·N_active·D (inference); useful-compute ratio = MODEL_FLOPS / global HLO
FLOPs.  The roofline fraction reported in §Perf is
MODEL_FLOPS / (chips · peak · max(term)).

    PYTHONPATH=src python -m repro.launch.roofline --dir results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12     # bf16 / chip (v5e-class, assignment constant)
HBM_BW = 819e9          # B/s / chip
LINK_BW = 50e9          # B/s / link
CHIPS = 256             # single-pod roofline


def bottleneck_hint(row: dict) -> str:
    dom = row["dominant"]
    arch, shape = row["arch"], row["shape"]
    if dom == "collective":
        if "moe" in row.get("family", "") or row.get("all_to_all", 0) > 0:
            return ("shrink EP all-to-all payloads (bf16 dispatch, "
                    "capacity-factor cut) or overlap with expert compute")
        return ("FSDP all-gathers dominate — raise per-step arithmetic "
                "intensity (bigger microbatch) or switch embed to 1D TP")
    if dom == "memory":
        if row["kind"] == "decode":
            return ("decode is cache-bandwidth-bound by nature — fuse cache "
                    "read+attend (flash-decode kernel), quantize KV to int8")
        return ("materialized attention logits dominate HBM traffic — the "
                "mapped-grid Pallas kernel keeps them in VMEM")
    if row["kind"] == "train":
        return ("compute-bound — recover the causal-waste half with the "
                "mapped triangular grid and cut remat recompute")
    return "compute-bound — batch decode further or widen TP"


def load_rows(dry_dir: str, multi_pod: bool = False,
              profile: str = "") -> list[dict]:
    rows = []
    suffix = ("mp" if multi_pod else "sp") + (f"__{profile}" if profile else "")
    for path in sorted(glob.glob(os.path.join(dry_dir, f"*__{suffix}.json"))):
        if not profile and "__optimized" in path:
            continue
        r = json.load(open(path))
        if r.get("status") != "ok":
            if r.get("status") == "skipped":
                rows.append({"arch": r["arch"], "shape": r["shape"],
                             "status": "skipped", "reason": r["reason"]})
            continue
        h = r.get("hlo", {})
        a = r.get("analytic", {})
        coll = h.get("collectives", {})
        flops_dev = h.get("flops_per_device", 0.0)
        bytes_dev = h.get("hbm_bytes_per_device", 0.0)
        coll_dev = coll.get("total_bytes", 0.0)
        t_c = flops_dev / PEAK_FLOPS
        t_m = bytes_dev / HBM_BW
        t_m_ideal = a.get("analytic_bytes", 0.0) / (CHIPS * HBM_BW)
        t_n = coll_dev / LINK_BW
        terms = {"compute": t_c, "memory": t_m, "collective": t_n}
        dominant = max(terms, key=terms.get)
        model_flops = a.get("model_flops", 0.0) + a.get("attn_flops_mapped", 0.0)
        hlo_global = flops_dev * CHIPS
        useful = model_flops / hlo_global if hlo_global else 0.0
        step_time = max(terms.values())
        frac = model_flops / (CHIPS * PEAK_FLOPS * step_time) if step_time else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "kind": r["kind"],
            "status": "ok",
            "t_compute": t_c, "t_memory": t_m, "t_memory_ideal": t_m_ideal,
            "t_collective": t_n, "dominant": dominant,
            "model_flops": model_flops, "hlo_flops_global": hlo_global,
            "useful_ratio": useful, "roofline_fraction": frac,
            "all_to_all": coll.get("all-to-all", {}).get("bytes", 0.0),
            "mem_gb": (r.get("memory_analysis", {})
                       .get("temp_size_in_bytes", 0)) / 1e9,
        })
    return rows


def fmt(v: float) -> str:
    if v == 0:
        return "0"
    if v < 1e-3:
        return f"{v * 1e6:.0f}µs"
    if v < 1:
        return f"{v * 1e3:.1f}ms"
    return f"{v:.2f}s"


def render_markdown(rows: list[dict]) -> str:
    lines = [
        "| arch | shape | compute | memory (hlo) | memory (ideal) | "
        "collective | dominant | MODEL_FLOPS | useful | roofline frac | "
        "temp GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | *skipped* "
                f"| — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['t_compute'])} | "
            f"{fmt(r['t_memory'])} | {fmt(r['t_memory_ideal'])} | "
            f"{fmt(r['t_collective'])} | **{r['dominant']}** | "
            f"{r['model_flops']:.2e} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['mem_gb']:.1f} |")
    return "\n".join(lines)


def render_hints(rows: list[dict]) -> str:
    out = []
    for r in rows:
        if r["status"] != "ok":
            continue
        out.append(f"- **{r['arch']} × {r['shape']}** ({r['dominant']}-bound):"
                   f" {bottleneck_hint(r)}")
    return "\n".join(out)


def render_comparison(base: list[dict], opt: list[dict]) -> str:
    """Baseline vs optimized per cell (paper-faithful vs beyond-paper)."""
    by_key = {(r["arch"], r["shape"]): r for r in opt if r["status"] == "ok"}
    lines = [
        "| arch | shape | max-term base→opt | compute | memory | collective "
        "| roofline frac base→opt |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in base:
        if r["status"] != "ok":
            continue
        o = by_key.get((r["arch"], r["shape"]))
        if o is None:
            continue
        def ratio(a, b):
            return f"{a / b:.1f}×" if b > 0 else "—"
        mt_b = max(r["t_compute"], r["t_memory"], r["t_collective"])
        mt_o = max(o["t_compute"], o["t_memory"], o["t_collective"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt(mt_b)}→{fmt(mt_o)} "
            f"({ratio(mt_b, mt_o)}) | {ratio(r['t_compute'], o['t_compute'])} "
            f"| {ratio(r['t_memory'], o['t_memory'])} "
            f"| {ratio(r['t_collective'], o['t_collective'])} "
            f"| {r['roofline_fraction']:.3f}→{o['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="results/dryrun")
    p.add_argument("--out", default="results/roofline.md")
    args = p.parse_args()
    rows = load_rows(args.dir)
    md = render_markdown(rows)
    hints = render_hints(rows)
    opt_rows = load_rows(args.dir, profile="optimized")
    cmp_md = render_comparison(rows, opt_rows) if opt_rows else ""
    with open(args.out, "w") as f:
        f.write("# Roofline (single-pod 16x16, per-device terms)\n\n")
        f.write("## Baseline (paper-faithful deployment)\n\n")
        f.write(md + "\n\n## What would move the dominant term\n\n"
                + hints + "\n")
        if cmp_md:
            f.write("\n## Baseline vs optimized profile (beyond-paper)\n\n"
                    + cmp_md + "\n")
    print(md)
    if cmp_md:
        print("\n" + cmp_md)
    print(f"\nwritten to {args.out}")


if __name__ == "__main__":
    main()
