import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices back the production meshes
(16x16 single-pod, 2x16x16 multi-pod); every cell must lower AND compile,
and the compiled artifact yields the memory/cost/collective numbers the
roofline analysis (launch/roofline.py) consumes.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod both] --out results/dryrun
"""
import argparse  # noqa: E402
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.distribution import sharding as shd
from repro.launch import analytic, hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import act_rules_for, input_specs


def apply_profile(cfg, shape, profile: str, overrides: dict | None = None):
    """Named optimization profiles (§Perf iterations).

    baseline  — the paper-faithful naive deployment (BB-masked XLA attention,
                global MoE dispatch, no MLA absorption, no microbatching).
    optimized — the beyond-paper configuration: mapped triangular attention
                scan, grouped MoE dispatch, MLA weight absorption (decode),
                8-way microbatch accumulation, sequence-parallel attention
                fallback for head counts that don't divide the tensor axis.
    """
    from repro.train.train_step import TrainConfig

    tcfg = None
    rules = act_rules_for(shape)
    if profile == "optimized":
        over = {"mla_absorb": "auto"}
        # the XLA-mapped grid pays when heads can't shard the tensor axis
        # (λ-axis SP recovers the 16x) or when attention is a small slice of
        # a MoE layer; heads-divisible dense archs keep the head-sharded
        # chunked path (measured: mapped+gather duplication regresses them —
        # on real TPU the Pallas mapped kernel provides the 2x instead).
        odd_heads = cfg.n_heads and cfg.n_heads % 16 != 0
        if shape.kind in ("train", "prefill") and (
                odd_heads or cfg.family == "moe"):
            over["attn_impl"] = "xla_mapped"
        if cfg.family == "moe":
            over["moe_impl"] = "a2a"   # grouped dispatch: moe_groups=16
        cfg = cfg.replace(**over)
        if shape.kind == "train":
            # microbatch only when saved layer-boundary activations would
            # overflow HBM (batch/16 per device, bf16, ~2 passes):
            eff_layers = (cfg.decoder_layers if cfg.family == "audio"
                          else cfg.n_layers)  # encoder seq is fixed/short
            act_gb = (eff_layers * (shape.global_batch / 16)
                      * shape.seq_len * cfg.d_model * 2 * 2) / 1e9
            if act_gb > 8.0:
                tcfg = TrainConfig(microbatches=8)
        if odd_heads:
            rules = {**rules, "attn_seq": "model"}
    else:
        cfg = cfg.replace(mla_absorb="never")
    for k, v in (overrides or {}).items():
        if k.startswith("rule:"):
            rules = {**rules, k[5:]: v}
        elif k == "microbatches":
            from repro.train.train_step import TrainConfig as TC

            tcfg = TC(microbatches=v)
        else:
            cfg = cfg.replace(**{k: v})
    return cfg, tcfg, rules


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, profile: str = "baseline",
             overrides: dict | None = None) -> dict:
    """Lower + compile one cell; returns the roofline-input record."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch).replace(max_seq=shape.seq_len, attn_impl="xla")
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}
    cfg, tcfg, rules = apply_profile(cfg, shape, profile, overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with shd.use_sharding(mesh, act_rules=rules):
        fn, args, donate = input_specs(cfg, shape, mesh, tcfg=tcfg,
                                       rules=rules)
        jitted = jax.jit(fn, donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    record = {
        "arch": arch, "shape": shape_name,
        "multi_pod": multi_pod, "mesh": dict(mesh.shape),
        "status": "ok", "kind": shape.kind, "profile": profile,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        record["xla_cost_flops_raw"] = float(ca.get("flops", 0.0))
        record["xla_bytes_raw"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        record["cost_analysis_error"] = repr(e)
    try:
        ma = compiled.memory_analysis()
        record["memory_analysis"] = {
            attr: int(getattr(ma, attr))
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "alias_size_in_bytes")
            if hasattr(ma, attr)
        }
    except Exception as e:  # pragma: no cover
        record["memory_analysis_error"] = repr(e)
    try:
        # trip-count-aware per-device numbers from the post-SPMD HLO
        h = hlo_analysis.analyze(compiled.as_text())
        record["hlo"] = {
            "flops_per_device": h["flops"],
            "hbm_bytes_per_device": h["hbm_bytes"],
            "per_op_flops": h["per_op_flops"],
            "collectives": {
                k: v for k, v in h["collectives"].items()},
        }
    except Exception as e:  # pragma: no cover
        record["hlo_error"] = repr(e)
    try:
        record["analytic"] = analytic.cell_analytics(cfg, shape)
    except Exception as e:  # pragma: no cover
        record["analytic_error"] = repr(e)
    if verbose:
        mp = "2x16x16" if multi_pod else "16x16"
        hf = record.get("hlo", {}).get("flops_per_device", 0)
        cb = record.get("hlo", {}).get("collectives", {}).get("total_bytes", 0)
        print(f"[dryrun] {arch} x {shape_name} x {mp}: OK "
              f"(lower {record['lower_s']}s, compile {record['compile_s']}s, "
              f"hlo_flops/dev {hf:.3e}, coll/dev {cb:.3e} B)",
              flush=True)
    return record


def run_domain_map_cell(artifact, n_points: int = 65_536,
                        block_n: int = 1024, interpret: bool = True,
                        verbose: bool = True) -> dict:
    """Deploy a validated ``MappingArtifact`` through the mapped-grid Pallas
    kernel and verify the compiled coordinates against the artifact's own
    validated scalar map — the Phase-4 integration proof for one artifact."""
    import numpy as np

    from repro.kernels.domain_map.ops import block_counts, map_coordinates

    t0 = time.time()
    coords = map_coordinates(artifact, n_points, block_n=block_n,
                             interpret=interpret)
    t_run = time.time() - t0
    sample = np.linspace(0, n_points - 1, 256, dtype=np.int64)
    scalar = artifact.scalar_fn()
    ok = all(tuple(coords[i]) == tuple(scalar(int(i))) for i in sample)
    record = {
        "kind": "domain_map", "status": "ok" if ok else "mismatch",
        "domain": artifact.domain, "model": artifact.model,
        "stage": artifact.stage, "logic": artifact.logic,
        "report_digest": artifact.report_digest,
        "n_points": n_points, "block_n": block_n,
        "kernel_s": round(t_run, 3),
        "blocks": block_counts(artifact, n_points, block_n),
        "analytic": analytic.artifact_deployment_analytics(artifact),
    }
    if verbose:
        a = record["analytic"]
        print(f"[dryrun] domain-map {artifact.domain} x {artifact.model} "
              f"s{artifact.stage}: {record['status']} "
              f"(kernel {t_run:.2f}s, projected speedup {a['speedup']:.0f}x, "
              f"energy {a['energy_reduction']:.0f}x)", flush=True)
    return record


def _run_domain_map(domain_name: str, model: str, out_dir: str) -> None:
    from repro.core.backends import MockLLMBackend
    from repro.core.domains import get_domain
    from repro.core.pipeline import derive_mapping

    res = derive_mapping(get_domain(domain_name), MockLLMBackend(model),
                         stage=100, n_validate=50_000, sample_every=10)
    art = res.artifact
    if art is None or not art.deployable:
        raise SystemExit(
            f"derivation not deployable: {domain_name} x {model} "
            f"(ordered {res.report.ordered_pct:.2f}%, error={res.error!r})")
    rec = run_domain_map_cell(art)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"domain_map__{domain_name}__{model}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] != "ok":
        raise SystemExit(f"domain-map dry-run MISMATCH: {path}")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS)
    p.add_argument("--shape", choices=tuple(SHAPES))
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", choices=("off", "on", "both"), default="both")
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--profile", choices=("baseline", "optimized"),
                   default="baseline")
    p.add_argument("--domain-map", metavar="DOMAIN",
                   help="derive + deploy one domain's MappingArtifact "
                        "through the Pallas mapped kernel instead of an "
                        "(arch x shape) cell")
    p.add_argument("--map-model", default="OSS:120b",
                   help="backend model for --domain-map")
    args = p.parse_args()

    if args.domain_map:
        _run_domain_map(args.domain_map, args.map_model, args.out)
        return

    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    pods = {"off": (False,), "on": (True,), "both": (False, True)}[args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mp in pods:
            tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
            if args.profile != "baseline":
                tag += f"__{args.profile}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] {tag}: cached, skipping")
                continue
            try:
                rec = run_cell(arch, shape, mp, profile=args.profile)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                       "status": "failed", "error": repr(e)}
                failures.append(tag)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    if failures:
        raise SystemExit(f"dry-run FAILURES: {failures}")
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
